"""jax version-compat shims.

The codebase targets the public jax >= 0.6 surface (`jax.shard_map`,
`jax.make_mesh(..., axis_types=...)`); this container ships jax 0.4.x where
`shard_map` still lives in `jax.experimental.shard_map` (with the replication
check spelled `check_rep` instead of `check_vma`) and `make_mesh` does not
take `axis_types`.  Everything in-repo imports through here so both surfaces
work unchanged.
"""
from __future__ import annotations

import inspect

import jax

try:                                   # jax >= 0.6: public API
    from jax import shard_map as _shard_map
except ImportError:                    # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_PARAMS = set(inspect.signature(_shard_map).parameters)


def shard_map(f, *args, **kwargs):
    """`jax.shard_map` with `check_vma`/`check_rep` translated as needed."""
    if "check_vma" in kwargs and "check_vma" not in _SHARD_MAP_PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    elif "check_rep" in kwargs and "check_rep" not in _SHARD_MAP_PARAMS:
        kwargs["check_vma"] = kwargs.pop("check_rep")
    return _shard_map(f, *args, **kwargs)


_MAKE_MESH_PARAMS = set(inspect.signature(jax.make_mesh).parameters)


def make_mesh(axis_shapes, axis_names, **kwargs):
    """`jax.make_mesh`, dropping `axis_types` on jax versions without it
    (pre-AxisType meshes behave as fully-auto, which is what we pass)."""
    if "axis_types" in kwargs and "axis_types" not in _MAKE_MESH_PARAMS:
        kwargs.pop("axis_types")
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


def auto_axis_types(n: int):
    """(AxisType.Auto,) * n where AxisType exists, else None (old jax)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return None
    return (axis_type.Auto,) * n

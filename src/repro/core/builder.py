"""Index construction (paper: ALGORITHM FOR INDEX CREATION).

Builds, from a tokenized corpus + morphological analyzer:

  * the three-stream basic index (all non-stop basic forms),
  * the expanded (w, v) index for frequently-used words,
  * the stop-phrase index for MinLength..MaxLength stop-word phrases,
  * an "ordinary" single inverted index (the Sphinx-style baseline the paper
    compares against — every basic form, stop words included).

Everything is vectorized numpy (index construction is offline, exactly as in
the paper); a paper-literal Queue/`Process` implementation is kept as the
reference oracle for the stop-phrase enumeration and cross-checked in tests.
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.core.analyzer import Analyzer
from repro.core.basic_index import BasicIndex
from repro.core.corpus import Corpus
from repro.core.expanded_index import ExpandedIndex
from repro.core.lexicon import Lexicon
from repro.core.postings import (
    CSR,
    DenseCSR,
    MAX_STOP_PHRASE_LEN,
    pack_near_stop_slot,
    pack_stop_phrase_key,
)
from repro.core.stop_phrase_index import StopPhraseIndex


@dataclasses.dataclass(frozen=True)
class IndexParams:
    min_len: int = 2           # MinLength (stop-phrase index)
    max_len: int = 5           # MaxLength (paper uses 5)
    max_distance: int = 5      # MaxDistance for stream 3 (paper: 5-7)
    near_slots: int = 20       # fixed-width stream-3 slots per occurrence;
                               # 4*max_distance (2 forms x 2D positions) is
                               # lossless -- smaller trades recall for size
    chunk: int = 1 << 20       # build-time chunking to bound peak memory

    def __post_init__(self):
        assert 2 <= self.min_len <= self.max_len <= MAX_STOP_PHRASE_LEN
        if self.near_slots < 4 * self.max_distance:
            import warnings
            warnings.warn("near_slots < 4*max_distance: stream-3 verification "
                          "may drop stop words in dense stop runs (lossy)")


@dataclasses.dataclass
class TokenForms:
    """Per-token expansion of the analyzer output, split by tier.

    s1/s2: up to two *stop* basic forms per token (as stop-local ids; -1 pad).
    n1/n2: up to two *non-stop* basic forms per token (as base ids; -1 pad).
    """

    doc_of: np.ndarray
    pos_of: np.ndarray
    s1_local: np.ndarray
    s2_local: np.ndarray
    n1: np.ndarray
    n2: np.ndarray

    @property
    def stop_mask(self) -> np.ndarray:
        return self.s1_local >= 0


def expand_token_forms(corpus: Corpus, lexicon: Lexicon, analyzer: Analyzer) -> TokenForms:
    prim = analyzer.primary[corpus.tokens]
    sec = analyzer.secondary[corpus.tokens]
    prim_stop = lexicon.is_stop(prim)
    sec_exists = sec >= 0
    sec_stop = sec_exists & lexicon.is_stop(np.maximum(sec, 0))

    s1 = np.where(prim_stop, prim, np.where(sec_stop, sec, -1))
    s2 = np.where(prim_stop & sec_stop, sec, -1)
    to_local = lambda b: np.where(b >= 0, lexicon.stop_local[np.maximum(b, 0)], -1).astype(np.int32)

    prim_ns = ~prim_stop
    sec_ns = sec_exists & ~sec_stop
    n1 = np.where(prim_ns, prim, np.where(sec_ns, sec, -1)).astype(np.int32)
    n2 = np.where(prim_ns & sec_ns, sec, -1).astype(np.int32)

    return TokenForms(
        doc_of=corpus.doc_ids_per_token(),
        pos_of=corpus.positions_per_token(),
        s1_local=to_local(s1),
        s2_local=to_local(s2),
        n1=n1,
        n2=n2,
    )


# ---------------------------------------------------------------------------
# basic index (3 streams)
# ---------------------------------------------------------------------------

def build_basic_index(tf: TokenForms, lexicon: Lexicon, params: IndexParams) -> BasicIndex:
    T = len(tf.doc_of)
    g_idx = np.arange(T, dtype=np.int64)
    m1, m2 = tf.n1 >= 0, tf.n2 >= 0
    bases = np.concatenate([tf.n1[m1], tf.n2[m2]])
    g = np.concatenate([g_idx[m1], g_idx[m2]])

    order = np.lexsort((tf.pos_of[g], tf.doc_of[g], bases))
    bases, g = bases[order], g[order]
    doc, pos = tf.doc_of[g], tf.pos_of[g]

    occurrences = DenseCSR.from_ids(
        bases, lexicon.config.n_base, {"doc": doc, "pos": pos}, presorted=True
    )

    # stream 1: first occurrence per (base, doc) + count
    boundary = np.ones(len(bases), dtype=bool)
    boundary[1:] = (bases[1:] != bases[:-1]) | (doc[1:] != doc[:-1])
    starts = np.nonzero(boundary)[0]
    run_len = np.diff(np.append(starts, len(bases))).astype(np.int32)
    first_occ = DenseCSR.from_ids(
        bases[starts], lexicon.config.n_base,
        {"doc": doc[starts], "pos": pos[starts], "count": run_len},
        presorted=True,
    )

    # stream 3: near-stop slots per occurrence, nearest-first, K slots
    D, K = params.max_distance, params.near_slots
    deltas = np.array([s * d for d in range(1, D + 1) for s in (-1, 1)], dtype=np.int64)
    near = np.full((len(g), K), -1, dtype=np.int16)
    col_rank = np.abs(deltas)  # nearest-first priority (already interleaved)
    for lo in range(0, len(g), params.chunk):
        gs = g[lo : lo + params.chunk]
        part = gs[:, None] + deltas[None, :]
        inb = (part >= 0) & (part < T)
        pc = np.clip(part, 0, T - 1)
        same = inb & (tf.doc_of[pc] == tf.doc_of[gs][:, None])
        cands, ranks = [], []
        for s_local in (tf.s1_local, tf.s2_local):
            sl = s_local[pc]
            ok = same & (sl >= 0)
            cands.append(np.where(ok, pack_near_stop_slot(
                np.broadcast_to(deltas[None, :], sl.shape), sl, D),
                np.int16(-1)).astype(np.int16))
            ranks.append(np.where(ok, col_rank[None, :], 1 << 20))
        cand = np.concatenate(cands, axis=1)
        rank = np.concatenate(ranks, axis=1)
        take = np.argsort(rank, axis=1, kind="stable")[:, :K]
        near[lo : lo + params.chunk] = np.take_along_axis(cand, take, axis=1)

    return BasicIndex(occurrences=occurrences, first_occ=first_occ,
                      near_stop=near, max_distance=D)


# ---------------------------------------------------------------------------
# expanded (w, v) index
# ---------------------------------------------------------------------------

def build_expanded_index(tf: TokenForms, lexicon: Lexicon, params: IndexParams) -> ExpandedIndex:
    T = len(tf.doc_of)
    n_base = lexicon.config.n_base
    g_idx = np.arange(T, dtype=np.int64)

    # occurrences of frequently-used basic forms (w side)
    m1 = (tf.n1 >= 0) & lexicon.is_frequent(np.maximum(tf.n1, 0))
    m2 = (tf.n2 >= 0) & lexicon.is_frequent(np.maximum(tf.n2, 0))
    w_base = np.concatenate([tf.n1[m1], tf.n2[m2]]).astype(np.int64)
    w_g = np.concatenate([g_idx[m1], g_idx[m2]])
    w_pd = lexicon.processing_distance(w_base)

    keys_parts, doc_parts, pos_parts, dist_parts = [], [], [], []
    max_pd = int(w_pd.max(initial=0))
    for d in range(1, max_pd + 1):
        for sd in (d, -d):
            part = w_g + sd
            inb = (part >= 0) & (part < T)
            pc = np.clip(part, 0, T - 1)
            ok_base = inb & (tf.doc_of[pc] == tf.doc_of[w_g]) & (d <= w_pd)
            for col in (tf.n1, tf.n2):
                v = col[pc].astype(np.int64)
                ok = ok_base & (v >= 0)
                if not ok.any():
                    continue
                w_ok, v_ok = w_base[ok], v[ok]
                # canonical orientation: when both frequent and v < w the pair
                # is stored under (v, w) (emitted from v's side); w == v keeps
                # only the positive direction.
                both_freq = lexicon.is_frequent(v_ok)
                keep = ~(both_freq & (v_ok < w_ok)) & ~((v_ok == w_ok) & (sd < 0))
                if not keep.any():
                    continue
                w_k, v_k, g_k = w_ok[keep], v_ok[keep], w_g[ok][keep]
                keys_parts.append(w_k * n_base + v_k)
                doc_parts.append(tf.doc_of[g_k])
                pos_parts.append(tf.pos_of[g_k])
                dist_parts.append(np.full(len(g_k), sd, dtype=np.int8))

    if keys_parts:
        keys = np.concatenate(keys_parts)
        doc = np.concatenate(doc_parts)
        pos = np.concatenate(pos_parts)
        dist = np.concatenate(dist_parts)
        order = np.lexsort((pos, doc, keys))
        pairs = CSR.from_unsorted(
            keys[order],
            {"doc": doc[order], "pos": pos[order], "dist": dist[order]},
            presorted=True,
        )
    else:
        pairs = CSR.from_unsorted(np.empty(0, np.int64),
                                  {"doc": np.empty(0, np.int32),
                                   "pos": np.empty(0, np.int32),
                                   "dist": np.empty(0, np.int8)})
    return ExpandedIndex(pairs=pairs, n_base=n_base)


# ---------------------------------------------------------------------------
# stop-phrase index
# ---------------------------------------------------------------------------

def _multi_form_window_keys(tf: TokenForms, start: int, L: int):
    """All form-choice combinations for one window (paper's Process cycle)."""
    choices = []
    for t in range(start, start + L):
        c = [tf.s1_local[t]]
        if tf.s2_local[t] >= 0:
            c.append(tf.s2_local[t])
        choices.append(c)
    keys = []
    for combo in itertools.product(*choices):
        keys.append(int(pack_stop_phrase_key(np.sort(np.array(combo, np.int64))[None, :])[0]))
    return keys


def build_stop_phrase_index(tf: TokenForms, params: IndexParams) -> StopPhraseIndex:
    T = len(tf.doc_of)
    stop = tf.stop_mask
    multi = tf.s2_local >= 0

    all_keys, all_doc, all_pos = [], [], []
    for L in range(params.min_len, params.max_len + 1):
        if T < L:
            continue
        win_stop = np.lib.stride_tricks.sliding_window_view(stop, L)
        valid = win_stop.all(axis=1) & (tf.doc_of[: T - L + 1] == tf.doc_of[L - 1 :])
        starts = np.nonzero(valid)[0]
        if len(starts) == 0:
            continue
        win_multi = np.lib.stride_tricks.sliding_window_view(multi, L)[starts].any(axis=1)

        single = starts[~win_multi]
        if len(single):
            ids = np.lib.stride_tricks.sliding_window_view(tf.s1_local, L)[single]
            keys = pack_stop_phrase_key(np.sort(ids.astype(np.int64), axis=1))
            all_keys.append(keys)
            all_doc.append(tf.doc_of[single])
            all_pos.append(tf.pos_of[single])

        for st in starts[win_multi]:
            ks = _multi_form_window_keys(tf, int(st), L)
            all_keys.append(np.array(ks, dtype=np.int64))
            all_doc.append(np.full(len(ks), tf.doc_of[st], dtype=np.int32))
            all_pos.append(np.full(len(ks), tf.pos_of[st], dtype=np.int32))

    if all_keys:
        keys = np.concatenate(all_keys)
        doc = np.concatenate(all_doc).astype(np.int32)
        pos = np.concatenate(all_pos).astype(np.int32)
        order = np.lexsort((pos, doc, keys))
        phrases = CSR.from_unsorted(keys[order], {"doc": doc[order], "pos": pos[order]},
                                    presorted=True)
    else:
        phrases = CSR.from_unsorted(np.empty(0, np.int64),
                                    {"doc": np.empty(0, np.int32),
                                     "pos": np.empty(0, np.int32)})
    return StopPhraseIndex(phrases=phrases, min_len=params.min_len, max_len=params.max_len)


# ---------------------------------------------------------------------------
# paper-literal reference (Queue / Process) — oracle for tests
# ---------------------------------------------------------------------------

def reference_stop_phrase_postings(tf: TokenForms, params: IndexParams):
    """The ALGORITHM FOR INDEX CREATION section, implemented literally.

    A queue of the last <= MaxLength stop tokens is maintained; whenever the
    head is about to leave (overflow or drain on a non-stop token / document
    boundary), every prefix phrase starting at the head is emitted, cycling
    through each item's form list (`Process`'s Index recursion).  This emits
    each (start, L) window exactly once — matching the paper's "nine phrases
    with 2 words, eight with 3" count for a run of ten stop words.

    Returns a list of (key, doc, pos) tuples (unsorted).
    """
    out = []

    def emit_head(queue):
        head_doc, head_pos = queue[0][0], queue[0][1]
        for L in range(params.min_len, min(len(queue), params.max_len) + 1):
            for combo in itertools.product(*[item[2] for item in queue[:L]]):
                key = int(pack_stop_phrase_key(np.sort(np.array(combo, np.int64))[None, :])[0])
                out.append((key, head_doc, head_pos))

    queue: list[tuple[int, int, list[int]]] = []
    prev_doc = -1
    T = len(tf.doc_of)
    for t in range(T):
        doc = int(tf.doc_of[t])
        if doc != prev_doc:
            while queue:
                emit_head(queue)
                queue.pop(0)
            prev_doc = doc
        forms = []
        if tf.s1_local[t] >= 0:
            forms.append(int(tf.s1_local[t]))
        if tf.s2_local[t] >= 0:
            forms.append(int(tf.s2_local[t]))
        if forms:
            queue.append((doc, int(tf.pos_of[t]), forms))
            if len(queue) > params.max_len:
                emit_head(queue)
                queue.pop(0)
        else:
            while queue:
                emit_head(queue)
                queue.pop(0)
    while queue:
        emit_head(queue)
        queue.pop(0)
    return out


# ---------------------------------------------------------------------------
# ordinary single inverted index (Sphinx-style baseline)
# ---------------------------------------------------------------------------

def build_ordinary_index(tf: TokenForms, lexicon: Lexicon) -> DenseCSR:
    """Every basic form (stop words included) -> (doc, pos). The paper's
    comparison baseline: phrase queries must read full posting lists."""
    T = len(tf.doc_of)
    g_idx = np.arange(T, dtype=np.int64)
    n_stop = lexicon.config.n_stop

    bases_parts, g_parts = [], []
    # non-stop forms
    for col in (tf.n1, tf.n2):
        m = col >= 0
        bases_parts.append(col[m].astype(np.int64))
        g_parts.append(g_idx[m])
    # stop forms (local id -> base id is the identity on [0, n_stop))
    for col in (tf.s1_local, tf.s2_local):
        m = col >= 0
        bases_parts.append(col[m].astype(np.int64))
        g_parts.append(g_idx[m])
    bases = np.concatenate(bases_parts)
    g = np.concatenate(g_parts)
    order = np.lexsort((tf.pos_of[g], tf.doc_of[g], bases))
    bases, g = bases[order], g[order]
    return DenseCSR.from_ids(bases, lexicon.config.n_base,
                             {"doc": tf.doc_of[g], "pos": tf.pos_of[g]}, presorted=True)


# ---------------------------------------------------------------------------
# top-level build
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class IndexSet:
    lexicon: Lexicon
    analyzer: Analyzer
    params: IndexParams
    basic: BasicIndex
    expanded: ExpandedIndex
    stop_phrase: StopPhraseIndex
    ordinary: DenseCSR
    n_docs: int

    def base_occ_counts(self) -> np.ndarray:
        """Total occurrences per basic form (ordinary-index view, incl. stop)."""
        return self.ordinary.counts()

    def size_report(self) -> dict[str, int]:
        return {
            "stop_phrase_index_bytes": self.stop_phrase.nbytes(),
            "expanded_index_bytes": self.expanded.nbytes(),
            "basic_index_bytes": self.basic.nbytes(),
            "ordinary_index_bytes": self.ordinary.nbytes(),
            "stop_phrase_postings": self.stop_phrase.phrases.n_postings,
            "expanded_postings": self.expanded.pairs.n_postings,
            "basic_postings": self.basic.occurrences.n_postings,
            "ordinary_postings": self.ordinary.n_postings,
        }


def build_all(corpus: Corpus, lexicon: Lexicon, analyzer: Analyzer,
              params: IndexParams = IndexParams()) -> IndexSet:
    tf = expand_token_forms(corpus, lexicon, analyzer)
    return IndexSet(
        lexicon=lexicon,
        analyzer=analyzer,
        params=params,
        basic=build_basic_index(tf, lexicon, params),
        expanded=build_expanded_index(tf, lexicon, params),
        stop_phrase=build_stop_phrase_index(tf, params),
        ordinary=build_ordinary_index(tf, lexicon),
        n_docs=corpus.n_docs,
    )

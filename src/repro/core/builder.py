"""Index construction (paper: ALGORITHM FOR INDEX CREATION).

Builds, from a tokenized corpus + morphological analyzer:

  * the three-stream basic index (all non-stop basic forms),
  * the expanded (w, v) index for frequently-used words,
  * the stop-phrase index for MinLength..MaxLength stop-word phrases,
  * the multi-component key index — (s, v) pairs and (s1, s2, v) triples
    around stop forms (arXiv:1812.07640 / arXiv:2006.07954) that give
    near-mode queries containing stop words true windowed semantics,
  * an "ordinary" single inverted index (the Sphinx-style baseline the paper
    compares against — every basic form, stop words included).

Everything is vectorized numpy (index construction is offline, exactly as in
the paper); a paper-literal Queue/`Process` implementation is kept as the
reference oracle for the stop-phrase enumeration and cross-checked in tests.
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.core.analyzer import Analyzer
from repro.core.basic_index import BasicIndex
from repro.core.corpus import Corpus
from repro.core.expanded_index import ExpandedIndex
from repro.core.lexicon import Lexicon
from repro.core.multi_key_index import MultiKeyIndex
from repro.core.postings import (
    CSR,
    DenseCSR,
    MAX_STOP_PHRASE_LEN,
    PackedPostings,
    pack_dist_pair,
    pack_multi_pair_key,
    pack_multi_triple_key,
    pack_near_stop_slot,
    pack_stop_phrase_key,
)
from repro.core.stop_phrase_index import StopPhraseIndex


@dataclasses.dataclass(frozen=True)
class IndexParams:
    min_len: int = 2           # MinLength (stop-phrase index)
    max_len: int = 5           # MaxLength (paper uses 5)
    max_distance: int = 5      # MaxDistance for stream 3 (paper: 5-7)
    near_window: int = 8       # default NEAR-mode window = NeighborDistance
                               # of the multi-key index and the minimum reach
                               # of expanded pairs.  8 = 2*(5-1): the paper's
                               # 2.2 every-other-word queries (<= 5 words)
                               # are within-window of ANY pivot by
                               # construction, which is what makes
                               # near_stop_confined_misses == 0 structural
                               # rather than empirical (the follow-up papers
                               # run MaxDistance up to 9 for the same
                               # reason).  <= 15 so triple distance pairs
                               # pack into the int8 dpair payload.
    near_slots: int = 20       # fixed-width stream-3 slots per occurrence;
                               # 4*max_distance (2 forms x 2D positions) is
                               # lossless -- smaller trades recall for size
    chunk: int = 1 << 20       # build-time chunking to bound peak memory
    triple_pair_min_count: int = 0
                               # multi-key size dial (ROADMAP): keep
                               # (s1, s2, v) triples only for (s1, s2) stop
                               # pairs with at least this many triple
                               # postings — the planner answers gated pairs
                               # with two two-component lookups instead
                               # (identical semantics, more postings read).
                               # 0 = keep every triple (no gating).
    neighbor_distance: int = 0
                               # multi-key size dial, decoupled from
                               # near_window: NeighborDistance of the (s, v)
                               # pair / (s1, s2, v) triple index.  0 (the
                               # default) follows near_window, preserving
                               # the structural recall guarantee; a smaller
                               # value shrinks the multi-key index roughly
                               # linearly, and near windows wider than it
                               # fall back to banded full ordinary-index
                               # reads (the planner's existing
                               # window > NeighborDistance guard) — correct
                               # at any window, at full-list cost.

    @property
    def multi_key_neighbor_distance(self) -> int:
        return self.neighbor_distance or self.near_window

    def __post_init__(self):
        assert 2 <= self.min_len <= self.max_len <= MAX_STOP_PHRASE_LEN
        assert 1 <= self.near_window <= 15
        assert 0 <= self.neighbor_distance <= 15   # dpair nibble payloads
        if self.near_slots < 4 * self.max_distance:
            import warnings
            warnings.warn("near_slots < 4*max_distance: stream-3 verification "
                          "may drop stop words in dense stop runs (lossy)")


@dataclasses.dataclass
class TokenForms:
    """Per-token expansion of the analyzer output, split by tier.

    s1/s2: up to two *stop* basic forms per token (as stop-local ids; -1 pad).
    n1/n2: up to two *non-stop* basic forms per token (as base ids; -1 pad).
    """

    doc_of: np.ndarray
    pos_of: np.ndarray
    s1_local: np.ndarray
    s2_local: np.ndarray
    n1: np.ndarray
    n2: np.ndarray

    @property
    def stop_mask(self) -> np.ndarray:
        return self.s1_local >= 0


def expand_token_forms(corpus: Corpus, lexicon: Lexicon, analyzer: Analyzer) -> TokenForms:
    prim = analyzer.primary[corpus.tokens]
    sec = analyzer.secondary[corpus.tokens]
    prim_stop = lexicon.is_stop(prim)
    sec_exists = sec >= 0
    sec_stop = sec_exists & lexicon.is_stop(np.maximum(sec, 0))

    s1 = np.where(prim_stop, prim, np.where(sec_stop, sec, -1))
    s2 = np.where(prim_stop & sec_stop, sec, -1)
    to_local = lambda b: np.where(b >= 0, lexicon.stop_local[np.maximum(b, 0)], -1).astype(np.int32)

    prim_ns = ~prim_stop
    sec_ns = sec_exists & ~sec_stop
    n1 = np.where(prim_ns, prim, np.where(sec_ns, sec, -1)).astype(np.int32)
    n2 = np.where(prim_ns & sec_ns, sec, -1).astype(np.int32)

    return TokenForms(
        doc_of=corpus.doc_ids_per_token(),
        pos_of=corpus.positions_per_token(),
        s1_local=to_local(s1),
        s2_local=to_local(s2),
        n1=n1,
        n2=n2,
    )


# ---------------------------------------------------------------------------
# basic index (3 streams)
# ---------------------------------------------------------------------------

def build_basic_index(tf: TokenForms, lexicon: Lexicon, params: IndexParams) -> BasicIndex:
    T = len(tf.doc_of)
    g_idx = np.arange(T, dtype=np.int64)
    m1, m2 = tf.n1 >= 0, tf.n2 >= 0
    bases = np.concatenate([tf.n1[m1], tf.n2[m2]])
    g = np.concatenate([g_idx[m1], g_idx[m2]])

    order = np.lexsort((tf.pos_of[g], tf.doc_of[g], bases))
    bases, g = bases[order], g[order]
    doc, pos = tf.doc_of[g], tf.pos_of[g]

    occurrences = DenseCSR.from_ids(
        bases, lexicon.config.n_base, {"doc": doc, "pos": pos}, presorted=True
    )

    # stream 1: first occurrence per (base, doc) + count
    boundary = np.ones(len(bases), dtype=bool)
    boundary[1:] = (bases[1:] != bases[:-1]) | (doc[1:] != doc[:-1])
    starts = np.nonzero(boundary)[0]
    run_len = np.diff(np.append(starts, len(bases))).astype(np.int32)
    first_occ = DenseCSR.from_ids(
        bases[starts], lexicon.config.n_base,
        {"doc": doc[starts], "pos": pos[starts], "count": run_len},
        presorted=True,
    )

    # stream 3: near-stop slots per occurrence, nearest-first, K slots
    D, K = params.max_distance, params.near_slots
    deltas = np.array([s * d for d in range(1, D + 1) for s in (-1, 1)], dtype=np.int64)
    near = np.full((len(g), K), -1, dtype=np.int16)
    col_rank = np.abs(deltas)  # nearest-first priority (already interleaved)
    for lo in range(0, len(g), params.chunk):
        gs = g[lo : lo + params.chunk]
        part = gs[:, None] + deltas[None, :]
        inb = (part >= 0) & (part < T)
        pc = np.clip(part, 0, T - 1)
        same = inb & (tf.doc_of[pc] == tf.doc_of[gs][:, None])
        cands, ranks = [], []
        for s_local in (tf.s1_local, tf.s2_local):
            sl = s_local[pc]
            ok = same & (sl >= 0)
            cands.append(np.where(ok, pack_near_stop_slot(
                np.broadcast_to(deltas[None, :], sl.shape), sl, D),
                np.int16(-1)).astype(np.int16))
            ranks.append(np.where(ok, col_rank[None, :], 1 << 20))
        cand = np.concatenate(cands, axis=1)
        rank = np.concatenate(ranks, axis=1)
        take = np.argsort(rank, axis=1, kind="stable")[:, :K]
        near[lo : lo + params.chunk] = np.take_along_axis(cand, take, axis=1)

    return BasicIndex(occurrences=occurrences, first_occ=first_occ,
                      near_stop=near, max_distance=D,
                      packed_occ=_pack_stream(occurrences),
                      packed_first=_pack_stream(first_occ))


# ---------------------------------------------------------------------------
# expanded (w, v) index
# ---------------------------------------------------------------------------

def build_expanded_index(tf: TokenForms, lexicon: Lexicon, params: IndexParams) -> ExpandedIndex:
    T = len(tf.doc_of)
    n_base = lexicon.config.n_base
    g_idx = np.arange(T, dtype=np.int64)

    # occurrences of frequently-used basic forms (w side)
    m1 = (tf.n1 >= 0) & lexicon.is_frequent(np.maximum(tf.n1, 0))
    m2 = (tf.n2 >= 0) & lexicon.is_frequent(np.maximum(tf.n2, 0))
    w_base = np.concatenate([tf.n1[m1], tf.n2[m2]]).astype(np.int64)
    w_g = np.concatenate([g_idx[m1], g_idx[m2]])
    # pair reach: ProcessingDistance, floored at the near window so the
    # expanded fast path covers default near-mode queries end to end (the
    # planner's _expanded_group guards any window beyond this reach)
    w_pd = np.maximum(lexicon.processing_distance(w_base), params.near_window)

    keys_parts, doc_parts, pos_parts, dist_parts = [], [], [], []
    max_pd = int(w_pd.max(initial=0))
    for d in range(1, max_pd + 1):
        for sd in (d, -d):
            part = w_g + sd
            inb = (part >= 0) & (part < T)
            pc = np.clip(part, 0, T - 1)
            ok_base = inb & (tf.doc_of[pc] == tf.doc_of[w_g]) & (d <= w_pd)
            for col in (tf.n1, tf.n2):
                v = col[pc].astype(np.int64)
                ok = ok_base & (v >= 0)
                if not ok.any():
                    continue
                w_ok, v_ok = w_base[ok], v[ok]
                # canonical orientation: when both frequent and v < w the pair
                # is stored under (v, w) (emitted from v's side); w == v keeps
                # only the positive direction.
                both_freq = lexicon.is_frequent(v_ok)
                keep = ~(both_freq & (v_ok < w_ok)) & ~((v_ok == w_ok) & (sd < 0))
                if not keep.any():
                    continue
                w_k, v_k, g_k = w_ok[keep], v_ok[keep], w_g[ok][keep]
                keys_parts.append(w_k * n_base + v_k)
                doc_parts.append(tf.doc_of[g_k])
                pos_parts.append(tf.pos_of[g_k])
                dist_parts.append(np.full(len(g_k), sd, dtype=np.int8))

    # same-token pairs (dist 0): a token whose two basic forms straddle the
    # frequent tier is its own (w, v) co-occurrence.  Near-mode windows
    # include the pivot position itself, so without these the expanded path
    # would miss matches the basic-fetch path (and the oracle) finds.
    both = (tf.n1 >= 0) & (tf.n2 >= 0)
    f1 = lexicon.is_frequent(np.maximum(tf.n1, 0)) & both
    f2 = lexicon.is_frequent(np.maximum(tf.n2, 0)) & both
    m0 = f1 | f2
    if m0.any():
        a, b = tf.n1[m0].astype(np.int64), tf.n2[m0].astype(np.int64)
        bf = f1[m0] & f2[m0]
        w0 = np.where(bf, np.minimum(a, b), np.where(f1[m0], a, b))
        v0 = np.where(bf, np.maximum(a, b), np.where(f1[m0], b, a))
        g0 = g_idx[m0]
        keys_parts.append(w0 * n_base + v0)
        doc_parts.append(tf.doc_of[g0])
        pos_parts.append(tf.pos_of[g0])
        dist_parts.append(np.zeros(len(g0), dtype=np.int8))

    pairs = _csr_from_parts(keys_parts, {"doc": doc_parts, "pos": pos_parts,
                                         "dist": dist_parts})
    return ExpandedIndex(pairs=pairs, n_base=n_base,
                         packed=_pack_stream(pairs))


# ---------------------------------------------------------------------------
# multi-component key index (pairs + triples around stop forms)
# ---------------------------------------------------------------------------

def build_multi_key_index(tf: TokenForms, lexicon: Lexicon,
                          params: IndexParams) -> MultiKeyIndex:
    """Multi-component keys around stop forms (see multi_key_index.py).

    Pairs are emitted from the stop side (one pass per signed delta,
    vectorized over every stop occurrence); triples use the arXiv:2006.07954
    two-phase construction: (1) per non-stop occurrence, the NEAREST
    distance to each distinct stop form within NeighborDistance; (2) all
    s1 < s2 combinations per occurrence, enumerated as offset-pairs over
    the (occurrence, stop form)-sorted record list.  Delta 0 (one token
    carrying both a stop and a non-stop form) is included — near-mode
    windows contain the pivot position itself.  NeighborDistance =
    `params.multi_key_neighbor_distance` (= near_window unless the
    `neighbor_distance` size dial shrinks it).
    """
    T = len(tf.doc_of)
    n_base = lexicon.config.n_base
    n_stop = lexicon.config.n_stop
    D = params.multi_key_neighbor_distance
    g_idx = np.arange(T, dtype=np.int64)

    # -- pairs: (s, v), emitted from each stop occurrence -------------------
    s_base = np.concatenate([c[c >= 0].astype(np.int64)
                             for c in (tf.s1_local, tf.s2_local)])
    s_g = np.concatenate([g_idx[c >= 0] for c in (tf.s1_local, tf.s2_local)])
    keys_p, doc_p, pos_p, dist_p = [], [], [], []
    for sd in range(-D, D + 1):
        part = s_g + sd
        inb = (part >= 0) & (part < T)
        pc = np.clip(part, 0, T - 1)
        ok_base = inb & (tf.doc_of[pc] == tf.doc_of[s_g])
        for col in (tf.n1, tf.n2):
            v = col[pc].astype(np.int64)
            ok = ok_base & (v >= 0)
            if not ok.any():
                continue
            keys_p.append(pack_multi_pair_key(s_base[ok], v[ok], n_base))
            doc_p.append(tf.doc_of[s_g[ok]])
            pos_p.append(tf.pos_of[s_g[ok]])
            dist_p.append(np.full(int(ok.sum()), sd, dtype=np.int8))
    pairs = _csr_from_parts(keys_p, {"doc": doc_p, "pos": pos_p,
                                     "dist": dist_p})

    # -- triples: (s1, s2, v), one posting per v occurrence -----------------
    v_base = np.concatenate([c[c >= 0].astype(np.int64)
                             for c in (tf.n1, tf.n2)])
    v_g = np.concatenate([g_idx[c >= 0] for c in (tf.n1, tf.n2)])
    keys_t, doc_t, pos_t, dist_t, dpair_t = [], [], [], [], []
    for lo in range(0, len(v_base), params.chunk):
        vb, vg = v_base[lo:lo + params.chunk], v_g[lo:lo + params.chunk]
        r_idx = np.arange(len(vb), dtype=np.int64)
        rec_r, rec_s, rec_d = [], [], []
        for sd in range(-D, D + 1):
            part = vg + sd
            inb = (part >= 0) & (part < T)
            pc = np.clip(part, 0, T - 1)
            ok_base = inb & (tf.doc_of[pc] == tf.doc_of[vg])
            for col in (tf.s1_local, tf.s2_local):
                s = col[pc].astype(np.int64)
                ok = ok_base & (s >= 0)
                if not ok.any():
                    continue
                rec_r.append(r_idx[ok])
                rec_s.append(s[ok])
                rec_d.append(np.full(int(ok.sum()), abs(sd), dtype=np.int64))
        if not rec_r:
            continue
        r = np.concatenate(rec_r)
        s = np.concatenate(rec_s)
        d = np.concatenate(rec_d)
        # phase 1: nearest distance per (occurrence, stop form)
        rs = r * n_stop + s
        order = np.lexsort((d, rs))
        rs, r, s, d = rs[order], r[order], s[order], d[order]
        keep = np.ones(len(rs), dtype=bool)
        keep[1:] = rs[1:] != rs[:-1]
        r, s, d = r[keep], s[keep], d[keep]
        # phase 2: all s1 < s2 pairs per occurrence (s ascends within each
        # r segment, so offset-pairs enumerate each combination once)
        off = 1
        while off < len(r):
            same = r[:-off] == r[off:]
            if not same.any():
                break
            i = np.nonzero(same)[0]
            s1, d1 = s[i], d[i]
            s2, d2 = s[i + off], d[i + off]
            ri = r[i]
            keys_t.append(pack_multi_triple_key(s1, s2, vb[ri], n_stop))
            doc_t.append(tf.doc_of[vg[ri]])
            pos_t.append(tf.pos_of[vg[ri]])
            dist_t.append(np.maximum(d1, d2).astype(np.int8))
            dpair_t.append(pack_dist_pair(d1, d2))
            off += 1
    triples = _csr_from_parts(keys_t, {"doc": doc_t, "pos": pos_t,
                                       "dist": dist_t, "dpair": dpair_t})
    triples, admitted = _gate_triples(triples, n_stop,
                                      params.triple_pair_min_count)
    return MultiKeyIndex(pairs=pairs, triples=triples, n_base=n_base,
                         n_stop=n_stop, neighbor_distance=D,
                         triple_stop_pairs=admitted,
                         packed_pairs=_pack_stream(pairs),
                         packed_triples=_pack_stream(triples))


def _gate_triples(triples: CSR, n_stop: int, min_count: int):
    """Size dial: drop triples of uncommon (s1, s2) stop pairs (fewer than
    `min_count` postings across all pivots).  Returns (filtered CSR, sorted
    admitted pair codes) — or (triples, None) when gating is off."""
    if min_count <= 0:
        return triples, None
    key_pair = triples.keys % (n_stop * n_stop)       # s2 * n_stop + s1
    s1 = key_pair % n_stop
    s2 = key_pair // n_stop
    pair_code = s1 * n_stop + s2
    counts = np.diff(triples.offsets)
    pair_total = np.zeros(n_stop * n_stop, np.int64)
    np.add.at(pair_total, pair_code, counts)
    admitted = np.nonzero(pair_total >= min_count)[0].astype(np.int64)
    keep_key = pair_total[pair_code] >= min_count
    if keep_key.all():
        return triples, admitted
    keep_post = np.repeat(keep_key, counts)
    flat_keys = np.repeat(triples.keys, counts)[keep_post]
    cols = {k: v[keep_post] for k, v in triples.columns.items()}
    return CSR.from_unsorted(flat_keys, cols, presorted=True), admitted


def _pack_stream(store) -> PackedPostings:
    """Bit-packed device twin of a posting store's (doc, pos, dist) columns.

    Every device stream packs the SAME field triple (zeros standing in for
    absent dist — a constant block is width class 0, i.e. free), so the
    executors' unified arena is one `concat_packed` away.  The triples'
    `dpair` payload stays host-side only (introspection / construction
    tests) and is never shipped."""
    cols = store.columns
    n = len(cols["doc"])
    dist = cols.get("dist")
    return PackedPostings.from_columns(
        {"doc": cols["doc"], "pos": cols["pos"],
         "dist": dist if dist is not None else np.zeros(n, np.int8)},
        fields=("doc", "pos", "dist"))


def _csr_from_parts(key_parts: list, col_parts: dict[str, list]) -> CSR:
    """Concatenate emitted parts into a (key, doc, pos)-lexsorted CSR."""
    if not key_parts:
        empty_cols = {"doc": np.empty(0, np.int32), "pos": np.empty(0, np.int32),
                      "dist": np.empty(0, np.int8), "dpair": np.empty(0, np.int8)}
        return CSR.from_unsorted(np.empty(0, np.int64),
                                 {k: empty_cols[k] for k in col_parts})
    keys = np.concatenate(key_parts)
    cols = {k: np.concatenate(v) for k, v in col_parts.items()}
    order = np.lexsort((cols["pos"], cols["doc"], keys))
    return CSR.from_unsorted(keys[order],
                             {k: v[order] for k, v in cols.items()},
                             presorted=True)


def reference_multi_key_postings(tf: TokenForms, lexicon: Lexicon,
                                 params: IndexParams):
    """Literal nested-loop reference for the multi-key construction — the
    oracle the vectorized builder is cross-checked against in tests.

    Returns (pairs, triples): pairs = list of (key, doc, pos, dist) tuples;
    triples = list of (key, doc, pos, max_dist, (d1, d2)) tuples.
    """
    T = len(tf.doc_of)
    D = params.multi_key_neighbor_distance
    n_base, n_stop = lexicon.config.n_base, lexicon.config.n_stop
    pairs, triples = [], []
    for g in range(T):
        stop_forms = [int(c[g]) for c in (tf.s1_local, tf.s2_local) if c[g] >= 0]
        ns_forms = [int(c[g]) for c in (tf.n1, tf.n2) if c[g] >= 0]
        # pairs from the stop side
        for s in stop_forms:
            for sd in range(-D, D + 1):
                u = g + sd
                if not (0 <= u < T) or tf.doc_of[u] != tf.doc_of[g]:
                    continue
                for v in (int(c[u]) for c in (tf.n1, tf.n2) if c[u] >= 0):
                    pairs.append((int(pack_multi_pair_key(s, v, n_base)),
                                  int(tf.doc_of[g]), int(tf.pos_of[g]), sd))
        # triples from the non-stop side: nearest distance per stop form
        for v in ns_forms:
            nearest: dict[int, int] = {}
            for sd in range(-D, D + 1):
                u = g + sd
                if not (0 <= u < T) or tf.doc_of[u] != tf.doc_of[g]:
                    continue
                for s in (int(c[u]) for c in (tf.s1_local, tf.s2_local)
                          if c[u] >= 0):
                    nearest[s] = min(nearest.get(s, D + 1), abs(sd))
            forms = sorted(nearest)
            for i, s1 in enumerate(forms):
                for s2 in forms[i + 1:]:
                    d1, d2 = nearest[s1], nearest[s2]
                    triples.append((
                        int(pack_multi_triple_key(s1, s2, v, n_stop)),
                        int(tf.doc_of[g]), int(tf.pos_of[g]),
                        max(d1, d2), (d1, d2)))
    return pairs, triples


# ---------------------------------------------------------------------------
# stop-phrase index
# ---------------------------------------------------------------------------

def _multi_form_window_keys(tf: TokenForms, start: int, L: int):
    """All form-choice combinations for one window (paper's Process cycle)."""
    choices = []
    for t in range(start, start + L):
        c = [tf.s1_local[t]]
        if tf.s2_local[t] >= 0:
            c.append(tf.s2_local[t])
        choices.append(c)
    keys = []
    for combo in itertools.product(*choices):
        keys.append(int(pack_stop_phrase_key(np.sort(np.array(combo, np.int64))[None, :])[0]))
    return keys


def build_stop_phrase_index(tf: TokenForms, params: IndexParams) -> StopPhraseIndex:
    T = len(tf.doc_of)
    stop = tf.stop_mask
    multi = tf.s2_local >= 0

    all_keys, all_doc, all_pos = [], [], []
    for L in range(params.min_len, params.max_len + 1):
        if T < L:
            continue
        win_stop = np.lib.stride_tricks.sliding_window_view(stop, L)
        valid = win_stop.all(axis=1) & (tf.doc_of[: T - L + 1] == tf.doc_of[L - 1 :])
        starts = np.nonzero(valid)[0]
        if len(starts) == 0:
            continue
        win_multi = np.lib.stride_tricks.sliding_window_view(multi, L)[starts].any(axis=1)

        single = starts[~win_multi]
        if len(single):
            ids = np.lib.stride_tricks.sliding_window_view(tf.s1_local, L)[single]
            keys = pack_stop_phrase_key(np.sort(ids.astype(np.int64), axis=1))
            all_keys.append(keys)
            all_doc.append(tf.doc_of[single])
            all_pos.append(tf.pos_of[single])

        for st in starts[win_multi]:
            ks = _multi_form_window_keys(tf, int(st), L)
            all_keys.append(np.array(ks, dtype=np.int64))
            all_doc.append(np.full(len(ks), tf.doc_of[st], dtype=np.int32))
            all_pos.append(np.full(len(ks), tf.pos_of[st], dtype=np.int32))

    if all_keys:
        keys = np.concatenate(all_keys)
        doc = np.concatenate(all_doc).astype(np.int32)
        pos = np.concatenate(all_pos).astype(np.int32)
        order = np.lexsort((pos, doc, keys))
        phrases = CSR.from_unsorted(keys[order], {"doc": doc[order], "pos": pos[order]},
                                    presorted=True)
    else:
        phrases = CSR.from_unsorted(np.empty(0, np.int64),
                                    {"doc": np.empty(0, np.int32),
                                     "pos": np.empty(0, np.int32)})
    return StopPhraseIndex(phrases=phrases, min_len=params.min_len,
                           max_len=params.max_len,
                           packed=_pack_stream(phrases))


# ---------------------------------------------------------------------------
# paper-literal reference (Queue / Process) — oracle for tests
# ---------------------------------------------------------------------------

def reference_stop_phrase_postings(tf: TokenForms, params: IndexParams):
    """The ALGORITHM FOR INDEX CREATION section, implemented literally.

    A queue of the last <= MaxLength stop tokens is maintained; whenever the
    head is about to leave (overflow or drain on a non-stop token / document
    boundary), every prefix phrase starting at the head is emitted, cycling
    through each item's form list (`Process`'s Index recursion).  This emits
    each (start, L) window exactly once — matching the paper's "nine phrases
    with 2 words, eight with 3" count for a run of ten stop words.

    Returns a list of (key, doc, pos) tuples (unsorted).
    """
    out = []

    def emit_head(queue):
        head_doc, head_pos = queue[0][0], queue[0][1]
        for L in range(params.min_len, min(len(queue), params.max_len) + 1):
            for combo in itertools.product(*[item[2] for item in queue[:L]]):
                key = int(pack_stop_phrase_key(np.sort(np.array(combo, np.int64))[None, :])[0])
                out.append((key, head_doc, head_pos))

    queue: list[tuple[int, int, list[int]]] = []
    prev_doc = -1
    T = len(tf.doc_of)
    for t in range(T):
        doc = int(tf.doc_of[t])
        if doc != prev_doc:
            while queue:
                emit_head(queue)
                queue.pop(0)
            prev_doc = doc
        forms = []
        if tf.s1_local[t] >= 0:
            forms.append(int(tf.s1_local[t]))
        if tf.s2_local[t] >= 0:
            forms.append(int(tf.s2_local[t]))
        if forms:
            queue.append((doc, int(tf.pos_of[t]), forms))
            if len(queue) > params.max_len:
                emit_head(queue)
                queue.pop(0)
        else:
            while queue:
                emit_head(queue)
                queue.pop(0)
    while queue:
        emit_head(queue)
        queue.pop(0)
    return out


# ---------------------------------------------------------------------------
# ordinary single inverted index (Sphinx-style baseline)
# ---------------------------------------------------------------------------

def build_ordinary_index(tf: TokenForms, lexicon: Lexicon) -> DenseCSR:
    """Every basic form (stop words included) -> (doc, pos). The paper's
    comparison baseline: phrase queries must read full posting lists."""
    T = len(tf.doc_of)
    g_idx = np.arange(T, dtype=np.int64)
    n_stop = lexicon.config.n_stop

    bases_parts, g_parts = [], []
    # non-stop forms
    for col in (tf.n1, tf.n2):
        m = col >= 0
        bases_parts.append(col[m].astype(np.int64))
        g_parts.append(g_idx[m])
    # stop forms (local id -> base id is the identity on [0, n_stop))
    for col in (tf.s1_local, tf.s2_local):
        m = col >= 0
        bases_parts.append(col[m].astype(np.int64))
        g_parts.append(g_idx[m])
    bases = np.concatenate(bases_parts)
    g = np.concatenate(g_parts)
    order = np.lexsort((tf.pos_of[g], tf.doc_of[g], bases))
    bases, g = bases[order], g[order]
    return DenseCSR.from_ids(bases, lexicon.config.n_base,
                             {"doc": tf.doc_of[g], "pos": tf.pos_of[g]}, presorted=True)


# ---------------------------------------------------------------------------
# top-level build
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class IndexSet:
    lexicon: Lexicon
    analyzer: Analyzer
    params: IndexParams
    basic: BasicIndex
    expanded: ExpandedIndex
    stop_phrase: StopPhraseIndex
    multi_key: MultiKeyIndex
    ordinary: DenseCSR
    n_docs: int
    # device representation of the ordinary stream (the other streams carry
    # their packed twin on their own container)
    ordinary_packed: PackedPostings | None = None

    def base_occ_counts(self) -> np.ndarray:
        """Total occurrences per basic form (ordinary-index view, incl. stop)."""
        return self.ordinary.counts()

    def max_posting_run(self) -> int:
        """Longest single posting list across every stream — the stat the
        doc-shard auto-pick keys off (the longest list bounds the per-row
        sort slab of the segmented gather)."""
        stores = (self.basic.occurrences, self.basic.first_occ,
                  self.expanded.pairs, self.stop_phrase.phrases,
                  self.multi_key.pairs, self.multi_key.triples, self.ordinary)
        return max((int(np.diff(s.offsets).max(initial=0)) for s in stores),
                   default=0)

    def size_report(self) -> dict[str, int]:
        rep = {
            "stop_phrase_index_bytes": self.stop_phrase.nbytes(),
            "expanded_index_bytes": self.expanded.nbytes(),
            "multi_key_index_bytes": self.multi_key.nbytes(),
            "basic_index_bytes": self.basic.nbytes(),
            "ordinary_index_bytes": self.ordinary.nbytes(),
            "stop_phrase_postings": self.stop_phrase.phrases.n_postings,
            "expanded_postings": self.expanded.pairs.n_postings,
            "multi_key_pair_postings": self.multi_key.n_pair_postings,
            "multi_key_triple_postings": self.multi_key.n_triple_postings,
            "basic_postings": self.basic.occurrences.n_postings,
            "ordinary_postings": self.ordinary.n_postings,
        }
        rep.update(self.packed_size_report())
        return rep

    def packed_size_report(self) -> dict[str, int]:
        """Device bytes of each bit-packed stream (vs the raw int32/int8
        columns the pre-packed arena shipped, `*_col_bytes`)."""
        mk = self.multi_key

        def cols(store):
            return sum(c.nbytes for n, c in store.columns.items()
                       if n in ("doc", "pos", "dist"))

        rep = {
            "basic_packed_bytes": self.basic.packed_nbytes(),
            "stop_phrase_packed_bytes": self.stop_phrase.packed_nbytes(),
            "expanded_packed_bytes": self.expanded.packed_nbytes(),
            "multi_key_pair_packed_bytes":
                mk.packed_pairs.nbytes() if mk.packed_pairs else 0,
            "multi_key_triple_packed_bytes":
                mk.packed_triples.nbytes() if mk.packed_triples else 0,
            "multi_key_packed_bytes": mk.packed_nbytes(),
            "ordinary_packed_bytes":
                self.ordinary_packed.nbytes() if self.ordinary_packed else 0,
            "basic_col_bytes": (cols(self.basic.occurrences)
                                + cols(self.basic.first_occ)),
            "stop_phrase_col_bytes": cols(self.stop_phrase.phrases),
            "expanded_col_bytes": cols(self.expanded.pairs),
            "multi_key_pair_col_bytes": cols(mk.pairs),
            "multi_key_triple_col_bytes": cols(mk.triples),
            "ordinary_col_bytes": cols(self.ordinary),
        }
        return rep


def auto_docs_per_shard(n_docs: int, max_list_len: int,
                        seg_target: int = 4096) -> int:
    """Doc-shard granularity for the segmented gather, from posting-list
    stats (ROADMAP "easy future win"): enough shards that the longest
    posting list splits into ~seg_target-posting segments, rounded up to a
    power of two and clamped to the packed-key shard cap.  At the canonical
    bench scale (1200 docs, longest list ~9e4) this picks 64 docs/shard
    (19 shards) — ~1.4x faster than 1 shard on the pre-windowed workload
    and parity on the current one (QTYPE_MULTI plans carry many short
    multi-key fetches, so over-sharding multiplies rows: 75 shards cost
    ~1.3-2x; see BENCH_search.json shard_scaling) — while bounding the largest
    per-row sort slab, which is what matters as corpora grow."""
    from repro.core.fetch_tables import DOCS_PER_SHARD
    if n_docs <= 0 or max_list_len <= 0:
        return DOCS_PER_SHARD
    n_shards = max(1, -(-max_list_len // seg_target))
    dps = max(1, -(-n_docs // n_shards))
    p = 1
    while p < dps:
        p <<= 1
    return min(p, DOCS_PER_SHARD)


def build_all(corpus: Corpus, lexicon: Lexicon, analyzer: Analyzer,
              params: IndexParams = IndexParams()) -> IndexSet:
    tf = expand_token_forms(corpus, lexicon, analyzer)
    ordinary = build_ordinary_index(tf, lexicon)
    return IndexSet(
        lexicon=lexicon,
        analyzer=analyzer,
        params=params,
        basic=build_basic_index(tf, lexicon, params),
        expanded=build_expanded_index(tf, lexicon, params),
        stop_phrase=build_stop_phrase_index(tf, params),
        multi_key=build_multi_key_index(tf, lexicon, params),
        ordinary=ordinary,
        n_docs=corpus.n_docs,
        ordinary_packed=_pack_stream(ordinary),
    )

"""Query planning (paper: ANSWERING QUERIES / PROCESSING QUERIES).

The planner runs host-side.  It

  1. expands each query word into its basic forms (morphological analyzer),
  2. *splits* the query whenever one word's forms span different frequency
     tiers (the paper's PROCESSING QUERIES rule) -- one subquery per tier
     combination, results to be unioned,
  3. classifies every subquery into the paper's Type 1-4 — plus Type 5
     (QTYPE_MULTI), this repo's multi-component-key plan: a NEAR-mode
     subquery containing stop forms is no longer confined to sequential
     matching (the paper's Type-4 rule); it splits around its stop words
     into multi-key lookups ((s, pivot) pairs / (s1, s2, pivot) triples,
     arXiv:1812.07640 / 2006.07954) plus the residual ordinary/expanded
     fetches, all keyed at the pivot position — true windowed semantics,
  4. resolves every posting fetch down to explicit (start, length) slices in
     the index arrays, so the device executor is pure array math,
  5. accounts the paper's primary metric -- the number of postings read.

Plan vocabulary
---------------
A *FetchGroup* is the union of posting lists standing in for one query slot
(one group per slot; several fetches per group when a slot has several basic
forms or a stop-phrase part has several form combinations).  The executor
turns each group into a sorted array of anchor keys and intersects the groups
(band-width 0 = precise phrase; W > 0 = word-set-with-distance).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

import numpy as np

from repro.core.builder import IndexSet
from repro.core.kword import MODE_KWORD, pick_kword_anchor
from repro.core.lexicon import TIER_FREQUENT, TIER_ORDINARY, TIER_STOP
from repro.core.postings import MAX_STOP_PHRASE_LEN

MODE_PHRASE = "phrase"   # precise: order + adjacency
MODE_NEAR = "near"       # word set: all words within a window of the pivot

QTYPE_MULTI = 5          # windowed near+stop via multi-component keys
QTYPE_KWORD = 6          # K-word span proximity via multi-key cover


@dataclasses.dataclass(frozen=True)
class ResolvedFetch:
    stream: str                    # 'basic' | 'first' | 'expanded' | 'stop'
    start: int
    length: int
    offset: int                    # phrase offset of the *stored/anchor* word
    required_dist: Optional[int] = None   # expanded, phrase mode: exact dist
    max_abs_dist: Optional[int] = None    # expanded, near mode: |dist| <= W
    pivot_from_dist: bool = False  # expanded, near mode: pivot pos = pos + dist
    stop_checks: tuple = ()        # ((delta, stop_local), ...) via stream 3
    read_near_stop: bool = False   # stream 3 is read alongside (counts twice)
    # ranking metadata (arXiv:2108.00410): this fetch's postings are keyed at
    # the anchor and their |dist| payload IS the slot word's distance from it
    # (near-mode expanded / multi-key fetches) — the score contribution reads
    # w(|dist|).  False => the slot's distance is the banded key distance
    # (full-list fetches) or 0 (precise-phrase keys).
    score_delta_from_dist: bool = False

    @property
    def postings_read(self) -> int:
        return self.length * (2 if self.read_near_stop else 1)


@dataclasses.dataclass
class FetchGroup:
    slot: int
    fetches: list[ResolvedFetch]
    band: int = 0                  # intersection band width vs. the anchor
    score_slot: Optional[int] = None   # the query slot this group scores
                                       # (None: covers several slots / a part)

    @property
    def postings_read(self) -> int:
        return sum(f.postings_read for f in self.fetches)


@dataclasses.dataclass
class SubPlan:
    qtype: int                     # 1..4 (paper's query types)
    mode: str
    groups: list[FetchGroup]
    fallback_groups: list[FetchGroup] = dataclasses.field(default_factory=list)
    supported: bool = True
    note: str = ""
    n_slots: int = 0               # query slots of this tier combination —
                                   # the ranked executors' per-anchor score is
                                   # biased by (n_slots - len(groups)) so
                                   # every slot contributes exactly once even
                                   # when groups merge or imply slots
    kw_window: Optional[int] = None  # QTYPE_KWORD only: the span width W —
                                     # every constraint group is banded at W
                                     # and the executors run the K-way
                                     # windowed join instead of pairwise
                                     # membership (core/kword.py)

    @property
    def postings_read(self) -> int:
        return sum(g.postings_read for g in self.groups)


@dataclasses.dataclass
class QueryPlan:
    subplans: list[SubPlan]        # results are unioned (query splitting)

    @property
    def postings_read(self) -> int:
        return sum(p.postings_read for p in self.subplans)


# ---------------------------------------------------------------------------


def pick_pivot(tiered, occ_counts) -> int:
    """Paper's 'basic word': the rarest non-stop slot (ordinary preferred)."""
    ordinary = [i for i, (t, _) in enumerate(tiered) if t == TIER_ORDINARY]
    eligible = ordinary or [i for i, (t, _) in enumerate(tiered) if t != TIER_STOP]
    return min(eligible, key=lambda i: sum(int(occ_counts[f]) for f in tiered[i][1]))


def split_query_parts(n: int, min_len: int, max_len: int) -> list[tuple[int, int]]:
    """Split an n-word stop phrase into (start, length) parts with
    min_len <= length <= max_len, covering every word; the final part may
    overlap its predecessor when the tail would otherwise be too short."""
    parts = []
    i = 0
    while i < n:
        L = min(max_len, n - i)
        if L < min_len:                       # short tail: overlap backwards
            parts.append((n - min_len, min_len))
            break
        rem = n - i - L
        if 0 < rem < min_len:                 # shrink so the tail is viable
            L = max(L - (min_len - rem), min_len)
        parts.append((i, L))
        i += L
    return parts


class Planner:
    def __init__(self, index: IndexSet, windowed_near_stop: bool = True,
                 occ_counts=None):
        self.index = index
        self.lex = index.lexicon
        # `occ_counts` overrides the pivot/seed statistics with CLUSTER-WIDE
        # counts: a doc-sharded deployment (serve.front) plans every shard
        # with the global numbers so pick_pivot lands on the same slot
        # everywhere — the precondition for bit-identical shard merges.
        self.refresh_occ_counts(occ_counts)
        # expanded-pair reach per basic form: max(ProcessingDistance,
        # near_window) — precomputed once; planning is on the per-query
        # latency path
        self._pair_reach = np.maximum(
            index.lexicon.processing_distance(
                np.arange(index.lexicon.config.n_base)),
            index.params.near_window)
        # True (default): near-mode subqueries containing stop forms get the
        # multi-component-key windowed plan (QTYPE_MULTI).  False restores
        # the paper's Type-4 sequential confinement (kept for the benchmark's
        # before/after comparison).
        self.windowed_near_stop = windowed_near_stop

    # -- public API ---------------------------------------------------------

    def refresh_occ_counts(self, occ_counts=None):
        """Re-snapshot the pivot/seed occurrence statistics.

        The counts are deliberately a snapshot (planning must not race a
        mutating index), but a mutable corpus — segments landing via
        `core.segments.SegmentManager` — must re-snapshot on every
        generation bump or pivot choice drifts from the true statistics.
        `occ_counts=None` re-reads the planner's own index; pass the
        cluster-global sum for doc-sharded / segmented deployments."""
        self._occ_counts = (self.index.base_occ_counts() if occ_counts is None
                            else np.asarray(occ_counts))

    def plan(self, surface_ids: list[int], mode: str = MODE_PHRASE,
             window: Optional[int] = None, ranked: bool = False) -> QueryPlan:
        """`ranked=True` plans for per-slot proximity scoring: multi-key stop
        slots stay one pair group per slot (no triple merging, no identical-
        form-set dedup) so every slot carries its own distance payload —
        match semantics are identical, only the group decomposition differs.
        """
        if window is None:
            if mode == MODE_KWORD:
                # kword windows are semantic (the span width) — no implicit
                # default; SearchRequest.__post_init__ enforces the same
                raise ValueError("kword mode requires an explicit window")
            # near-mode default: the near window (2*(MaxLength-1)) — every
            # slot of the paper's 2.2 every-other-word procedure is within
            # reach of any pivot, making source recall structural
            window = self.index.params.near_window
        form_lists = [self.index.analyzer.forms_of(s) for s in surface_ids]
        subplans = []
        for tiered in self._split_by_tier(form_lists):
            sp = self._plan_subquery(tiered, mode, window, ranked)
            sp.n_slots = len(tiered)
            subplans.append(sp)
        return QueryPlan(subplans=subplans)

    # -- query splitting (paper: PROCESSING QUERIES) -------------------------

    def _split_by_tier(self, form_lists):
        """Yield per-slot (tier, [forms]) lists, one per tier combination."""
        per_slot_choices = []
        for forms in form_lists:
            tiers = {}
            for f in forms:
                tiers.setdefault(int(self.lex.base_tier[f]), []).append(f)
            per_slot_choices.append(sorted(tiers.items()))
        for combo in itertools.product(*per_slot_choices):
            yield list(combo)   # [(tier, [forms]), ...] per slot

    # -- classification + dispatch ------------------------------------------

    def _plan_subquery(self, tiered, mode, window, ranked=False) -> SubPlan:
        tiers = [t for t, _ in tiered]
        if mode == MODE_KWORD:
            return self._plan_kword(tiered, window, ranked)
        if all(t == TIER_STOP for t in tiers):
            return self._plan_type1(tiered)
        if any(t == TIER_STOP for t in tiers):
            if mode == MODE_NEAR and self.windowed_near_stop:
                return self._plan_type5(tiered, window, ranked)
            return self._plan_type4(tiered, mode, window)
        if all(t == TIER_FREQUENT for t in tiers):
            return self._plan_type2(tiered, mode, window)
        return self._plan_type3(tiered, mode, window)

    # -- helpers --------------------------------------------------------------

    def _slot_count(self, forms) -> int:
        return int(sum(self._occ_counts[f] for f in forms))

    def _pick_pivot(self, tiered) -> int:
        return pick_pivot(tiered, self._occ_counts)

    def _basic_group(self, slot, forms, band=0, first_only=False) -> FetchGroup:
        idx = self.index.basic.first_occ if first_only else self.index.basic.occurrences
        stream = "first" if first_only else "basic"
        fetches = []
        for f in forms:
            s, e = idx.find(f)
            if e > s:
                fetches.append(ResolvedFetch(stream=stream, start=s, length=e - s,
                                             offset=slot))
        return FetchGroup(slot=slot, fetches=fetches, band=band,
                          score_slot=slot)

    def _pivot_group(self, slot, forms, stop_checks) -> FetchGroup:
        """Pivot occurrences verified against near-stop stream 3 (Type 4)."""
        fetches = []
        for f in forms:
            s, e = self.index.basic.occurrences.find(f)
            if e > s:
                fetches.append(ResolvedFetch(
                    stream="basic", start=s, length=e - s, offset=slot,
                    stop_checks=tuple(stop_checks), read_near_stop=bool(stop_checks)))
        return FetchGroup(slot=slot, fetches=fetches, band=0)

    def _expanded_group(self, slot, forms, pivot_slot, pivot_forms, mode, window) -> Optional[FetchGroup]:
        """Union of expanded (w, v=pivot) fetches over form combinations.

        Returns None when the expanded index CANNOT cover the slot — the
        required distance / window exceeds the pair reach
        (max(ProcessingDistance, near_window)) for some orientation, so a
        lookup would silently under-cover.  The caller must then fall back
        to a basic fetch for the slot (paper Type 3: "In the case of words
        for which no expanded index exists, we use an ordinary index").

        Returns a fetchless group when every combination was looked up
        within reach and no pair exists — then no within-reach match exists
        anywhere and the group correctly kills the subplan."""
        exp = self.index.expanded
        fetches = []
        for w, v in itertools.product(forms, pivot_forms):
            for stored_w, stored_v, mirrored in ((w, v, False), (v, w, True)):
                reach = int(self._pair_reach[stored_w])
                rd = (slot - pivot_slot) if mirrored else (pivot_slot - slot)
                if (abs(rd) if mode == MODE_PHRASE else window) > reach:
                    return None      # under-coverage: slot needs basic fetches
                s, e = exp.pairs.find(stored_w * exp.n_base + stored_v)
                if e == s:
                    continue
                # stored postings: (doc, pos of stored_w, dist to stored_v)
                anchor_offset = pivot_slot if mirrored else slot
                if mode == MODE_PHRASE:
                    fetches.append(ResolvedFetch(
                        stream="expanded", start=s, length=e - s,
                        offset=anchor_offset, required_dist=rd))
                else:
                    fetches.append(ResolvedFetch(
                        stream="expanded", start=s, length=e - s,
                        offset=anchor_offset, max_abs_dist=window,
                        pivot_from_dist=not mirrored,
                        score_delta_from_dist=True))
                break   # canonical orientation found
        return FetchGroup(slot=slot, fetches=fetches, band=0, score_slot=slot)

    def _fallback_groups(self, tiered) -> list[FetchGroup]:
        """Distance-disregarding doc search: stream 1 only (paper step 3)."""
        groups = []
        for i, (t, forms) in enumerate(tiered):
            if t == TIER_STOP:
                continue    # stop words carry no meaning doc-level
            groups.append(self._basic_group(i, forms, first_only=True))
        return groups

    # -- Type 1: all stop words ----------------------------------------------

    def _plan_type1(self, tiered) -> SubPlan:
        n = len(tiered)
        p = self.index.params
        if n < p.min_len:
            return SubPlan(qtype=1, mode=MODE_PHRASE, groups=[], supported=False,
                           note="single stop word: not indexed (paper: stop words "
                                "are never searched alone)")
        # split into parts of <= MaxLength (paper: EXPERIMENTS "the phrase may
        # be divided into parts ... results are combined")
        parts = split_query_parts(n, p.min_len, p.max_len)
        groups = []
        for part_start, L in parts:
            fetches = []
            slot_forms = [tiered[part_start + j][1] for j in range(L)]
            for combo in itertools.product(*slot_forms):
                locals_ = [int(self.lex.stop_local[f]) for f in combo]
                s, e = self.index.stop_phrase.find(locals_)
                if e > s:
                    fetches.append(ResolvedFetch(stream="stop", start=s,
                                                 length=e - s, offset=part_start))
            groups.append(FetchGroup(slot=part_start, fetches=fetches, band=0))
        return SubPlan(qtype=1, mode=MODE_PHRASE, groups=groups)

    # -- Type 2: all frequently used ------------------------------------------

    def _plan_type2(self, tiered, mode, window) -> SubPlan:
        n = len(tiered)
        pivot = self._pick_pivot(tiered)
        groups = []
        fell_back = False
        if n == 1:
            groups.append(self._basic_group(0, tiered[0][1]))
        else:
            for i, (t, forms) in enumerate(tiered):
                if i == pivot:
                    continue
                g = self._expanded_group(i, forms, pivot, tiered[pivot][1], mode, window)
                if g is None:   # beyond pair reach: exact basic fetches instead
                    g = self._basic_group(i, forms,
                                          band=window if mode == MODE_NEAR else 0)
                    fell_back = True
                groups.append(g)
            if fell_back:
                # basic fallbacks don't imply the pivot's own presence the
                # way expanded (w, pivot) pairs do — and near mode needs a
                # band-0 seed — so the pivot's occurrences join the plan
                groups.insert(0, self._basic_group(pivot, tiered[pivot][1]))
        return SubPlan(qtype=2, mode=mode, groups=groups,
                       fallback_groups=self._fallback_groups(tiered))

    # -- Type 3: no stop, at least one ordinary --------------------------------

    def _plan_type3(self, tiered, mode, window) -> SubPlan:
        pivot = self._pick_pivot(tiered)
        groups = []
        n_expanded = 0
        for i, (t, forms) in enumerate(tiered):
            if i == pivot:
                continue
            g = None
            if t == TIER_FREQUENT:
                g = self._expanded_group(i, forms, pivot, tiered[pivot][1], mode, window)
                if g is not None and g.fetches:
                    n_expanded += 1
            if g is None:
                band = window if mode == MODE_NEAR else 0
                g = self._basic_group(i, forms, band=band)
            groups.append(g)
        # the pivot's own occurrences are needed when no expanded group pins
        # its positions (all-ordinary query) or in near mode (band anchors)
        if n_expanded == 0 or mode == MODE_NEAR:
            groups.insert(0, self._basic_group(pivot, tiered[pivot][1]))
        return SubPlan(qtype=3, mode=mode, groups=groups,
                       fallback_groups=self._fallback_groups(tiered))

    # -- Type 4: stop words mixed with others ----------------------------------

    def _plan_type4(self, tiered, mode, window) -> SubPlan:
        # paper (STRUCTURE OF SEARCH EXPERIMENTS): "If one of the query words
        # has a stop basic form, the search is confined to sequential words."
        mode = MODE_PHRASE
        pivot = self._pick_pivot(tiered)
        p = self.index.params
        stop_checks, unsupported = [], []
        for i, (t, forms) in enumerate(tiered):
            if t != TIER_STOP:
                continue
            delta = i - pivot
            if abs(delta) > p.max_distance:
                unsupported.append(i)
                continue
            # any of the slot's stop forms at the required delta satisfies it
            stop_checks.append((delta, tuple(int(self.lex.stop_local[f]) for f in forms)))
        groups = [self._pivot_group(pivot, tiered[pivot][1], stop_checks)]
        for i, (t, forms) in enumerate(tiered):
            if i == pivot or t == TIER_STOP:
                continue
            g = None
            if t == TIER_FREQUENT:
                g = self._expanded_group(i, forms, pivot, tiered[pivot][1], mode, window)
            if g is None:
                band = window if mode == MODE_NEAR else 0
                g = self._basic_group(i, forms, band=band)
            groups.append(g)
        note = ""
        if unsupported:
            note = f"stop slots {unsupported} beyond MaxDistance of pivot; phrase split required"
        return SubPlan(qtype=4, mode=mode, groups=groups,
                       fallback_groups=self._fallback_groups(tiered), note=note)

    # -- Type 5: windowed near + stop via multi-component keys -----------------

    def _pair_group(self, slot, stop_forms, pivot_forms, window) -> FetchGroup:
        """(s, pivot) two-component lookups: postings are occurrences of s
        with the pivot form within NeighborDistance, keyed at the pivot
        position (pos + dist) and masked to |dist| <= window — band-0
        against the seed, exactly like an expanded near fetch."""
        mk = self.index.multi_key
        fetches = []
        for s, v in itertools.product(stop_forms, pivot_forms):
            st, e = mk.find_pair(int(s), int(v))
            if e > st:
                fetches.append(ResolvedFetch(
                    stream="multi", start=st, length=e - st, offset=slot,
                    max_abs_dist=window, pivot_from_dist=True,
                    score_delta_from_dist=True))
        return FetchGroup(slot=slot, fetches=fetches, band=0, score_slot=slot)

    def _triple_group(self, slot, s1, s2, pivot_forms, window) -> Optional[FetchGroup]:
        """(s1, s2, pivot) three-component lookup covering TWO stop slots in
        one group: postings are pivot occurrences with both stops within
        NeighborDistance, anchored at the pivot position with dist =
        max(nearest |d1|, nearest |d2|) — so |dist| <= window answers "both
        stops inside the window".  None when no pivot form has the key (no
        windowed match can exist: the caller plants an empty group)."""
        mk = self.index.multi_key
        fetches = []
        for v in pivot_forms:
            st, e = mk.find_triple(int(s1), int(s2), int(v))
            if e > st:
                fetches.append(ResolvedFetch(
                    stream="multi", start=st, length=e - st, offset=slot,
                    max_abs_dist=window, pivot_from_dist=False,
                    score_delta_from_dist=True))
        if not fetches:
            return None
        return FetchGroup(slot=slot, fetches=fetches, band=0)

    def _ordinary_band_group(self, slot, forms, window) -> FetchGroup:
        """Escape for window > NeighborDistance: the stop form's full
        ordinary-index posting list, banded against the pivot — correct at
        any window, at the full posting-list cost the multi-key index
        exists to avoid."""
        fetches = []
        for f in forms:
            s, e = self.index.ordinary.find(f)
            if e > s:
                fetches.append(ResolvedFetch(stream="ordinary", start=s,
                                             length=e - s, offset=slot))
        return FetchGroup(slot=slot, fetches=fetches, band=window,
                          score_slot=slot)

    def _multi_key_groups(self, stop_slots, pivot_forms, window,
                          ranked=False) -> list[FetchGroup]:
        """One constraint group per distinct stop-slot form set: identical
        form sets impose identical window constraints (one occurrence may
        satisfy several slots), single-form slots with distinct forms pair
        into three-component lookups, the rest use two-component lookups.

        `ranked` keeps one PAIR group per stop slot (no triple merging, no
        dedup): each slot then carries its own |dist| payload, which is what
        the per-slot proximity score reads.  Triples gated off at build time
        (IndexParams.triple_pair_min_count — uncommon (s1, s2) pairs) fall
        back to the same two pair lookups; semantics are identical either
        way, only postings_read differs."""
        mk = self.index.multi_key
        if window > mk.neighbor_distance:
            return [self._ordinary_band_group(i, forms, window)
                    for i, forms in stop_slots]
        if ranked:
            return [self._pair_group(i, forms, pivot_forms, window)
                    for i, forms in stop_slots]
        uniq, seen = [], set()
        for i, forms in stop_slots:
            key = tuple(sorted(forms))
            if key in seen:
                continue
            seen.add(key)
            uniq.append((i, forms))
        groups = []
        singles = [(i, forms[0]) for i, forms in uniq if len(forms) == 1]
        pair_back = []        # gated (uncommon) triples -> two pair lookups
        for k in range(0, len(singles) - 1, 2):
            (i1, s1), (i2, s2) = singles[k], singles[k + 1]
            if not mk.has_triple_pair(int(s1), int(s2)):
                pair_back.extend([(i1, s1), (i2, s2)])
                continue
            g = self._triple_group(i1, s1, s2, pivot_forms, window)
            if g is None:
                # the stops never co-occur near any pivot form, so the
                # windowed intersection is empty: a fetchless group kills
                # the subplan (the doc-only fallback still runs)
                g = FetchGroup(slot=i1, fetches=[], band=0)
            groups.append(g)
        if len(singles) % 2:
            pair_back.append(singles[-1])
        for i, s in pair_back:
            groups.append(self._pair_group(i, (s,), pivot_forms, window))
        for i, forms in uniq:
            if len(forms) > 1:
                groups.append(self._pair_group(i, forms, pivot_forms, window))
        return groups

    def _plan_type5(self, tiered, window, ranked=False) -> SubPlan:
        """Windowed near-mode subquery containing stop forms: split around
        the stop words (arXiv:1812.07640) — the pivot's own occurrences
        seed, non-stop slots constrain as in Type 3 near, and every stop
        slot becomes a multi-component key lookup keyed at the pivot
        position.  No Type-4 sequential confinement."""
        pivot = self._pick_pivot(tiered)
        pivot_forms = tiered[pivot][1]
        groups = [self._basic_group(pivot, pivot_forms)]
        for i, (t, forms) in enumerate(tiered):
            if i == pivot or t == TIER_STOP:
                continue
            g = None
            if t == TIER_FREQUENT:
                g = self._expanded_group(i, forms, pivot, pivot_forms,
                                         MODE_NEAR, window)
            if g is None:
                g = self._basic_group(i, forms, band=window)
            groups.append(g)
        stop_slots = [(i, forms) for i, (t, forms) in enumerate(tiered)
                      if t == TIER_STOP]
        groups.extend(self._multi_key_groups(stop_slots, pivot_forms, window,
                                             ranked=ranked))
        return SubPlan(qtype=QTYPE_MULTI, mode=MODE_NEAR, groups=groups,
                       fallback_groups=self._fallback_groups(tiered))

    # -- QTYPE_KWORD: K-word span proximity via multi-key cover ----------------

    def _kword_pair_group(self, slot, stop_forms, anchor_forms, window) -> FetchGroup:
        """(s, anchor) two-component lookups for one kword stop slot, keyed
        at the STOP word's own position (pos, not pos + dist): the K-way
        join needs each slot's candidate positions, not pivot echoes.  The
        |dist| <= window mask prunes to postings whose anchor co-occurrence
        is inside the span — every in-band stop occurrence of any matching
        anchor survives it (its own (s, anchor) co-occurrence is within
        W <= NeighborDistance), so the banded in-band set per anchor is
        exactly the full occurrence set's."""
        mk = self.index.multi_key
        fetches = []
        for s, v in itertools.product(stop_forms, anchor_forms):
            st, e = mk.find_pair(int(s), int(v))
            if e > st:
                fetches.append(ResolvedFetch(
                    stream="multi", start=st, length=e - st, offset=slot,
                    max_abs_dist=window, pivot_from_dist=False))
        return FetchGroup(slot=slot, fetches=fetches, band=window,
                          score_slot=slot)

    def _kword_stop_group(self, slot, forms, anchor_forms, window) -> FetchGroup:
        """Cover choice for a kword stop slot: the multi-key pair lookup
        (W <= NeighborDistance only) vs the ordinary full posting list,
        by postings-read cost.  An EMPTY pair group is exact and wins: the
        stop never co-occurs within NeighborDistance >= W of any anchor
        form, so no span match exists and the group kills the subplan
        (the doc-only fallback still runs)."""
        mk = self.index.multi_key
        ordn = self._ordinary_band_group(slot, forms, window)
        if window > mk.neighbor_distance:
            return ordn
        pair = self._kword_pair_group(slot, forms, anchor_forms, window)
        return pair if pair.postings_read <= ordn.postings_read else ordn

    def _kword_expanded_group(self, slot, forms, anchor_forms, window) -> Optional[FetchGroup]:
        """Expanded (w, v) cover for a kword frequent slot, keyed at the
        SLOT word's position: pos itself when the slot word is the stored
        anchor (direct), pos + dist when the query anchor is (mirrored) —
        the inverse of the near-mode pivot keying.  None when the window
        exceeds the pair reach for some orientation (under-coverage: the
        caller falls back to basic fetches); an empty group is exact (no
        within-reach co-occurrence anywhere) and kills the subplan."""
        exp = self.index.expanded
        fetches = []
        for w, v in itertools.product(forms, anchor_forms):
            for stored_w, stored_v, mirrored in ((w, v, False), (v, w, True)):
                reach = int(self._pair_reach[stored_w])
                if window > reach:
                    return None
                s, e = exp.pairs.find(stored_w * exp.n_base + stored_v)
                if e == s:
                    continue
                # stored postings: (doc, pos of stored_w, dist to stored_v)
                fetches.append(ResolvedFetch(
                    stream="expanded", start=s, length=e - s, offset=slot,
                    max_abs_dist=window, pivot_from_dist=mirrored))
                break   # canonical orientation found
        return FetchGroup(slot=slot, fetches=fetches, band=window,
                          score_slot=slot)

    def _kword_triple_seed(self, anchor_slot, s1, s2, anchor_forms,
                           window) -> Optional[FetchGroup]:
        """(s1, s2, anchor) three-component seed filter: anchor occurrences
        with BOTH stops within NeighborDistance, masked to |dist| =
        max(nearest |d1|, nearest |d2|) <= window — a necessary condition
        for any span match (both in-span stops sit within W of the anchor),
        and usually far shorter than the anchor's basic posting list: the
        'triples first' cost win of the K-word cover.  None when no anchor
        form has the key."""
        mk = self.index.multi_key
        fetches = []
        for v in anchor_forms:
            st, e = mk.find_triple(int(s1), int(s2), int(v))
            if e > st:
                fetches.append(ResolvedFetch(
                    stream="multi", start=st, length=e - st,
                    offset=anchor_slot, max_abs_dist=window,
                    pivot_from_dist=False))
        if not fetches:
            return None
        return FetchGroup(slot=anchor_slot, fetches=fetches, band=0,
                          score_slot=anchor_slot)

    def _plan_kword(self, tiered, window, ranked=False) -> SubPlan:
        """K-word span proximity (arXiv:2009.02684): anchor on the rarest
        non-stop slot; one band-W constraint group per remaining slot —
        each covered by the cheapest admissible index (multi-key pairs /
        expanded pairs / ordinary / basic, by occ-count cost) and keyed at
        its OWN word's positions; the seed is the anchor's basic list or,
        when cheaper, a (s1, s2, anchor) triple filter.  The executors
        evaluate the K-way windowed join over these groups (core/kword.py).
        """
        anchor = pick_kword_anchor(tiered, self._occ_counts)
        if anchor < 0:
            return SubPlan(qtype=QTYPE_KWORD, mode=MODE_KWORD, groups=[],
                           supported=False, kw_window=window,
                           note="all-stop kword tier combination: no "
                                "non-stop slot to anchor the span join on")
        anchor_forms = tiered[anchor][1]
        mk = self.index.multi_key
        constraints = []
        stop_singles = []
        stop_seen = set()
        for i, (t, forms) in enumerate(tiered):
            if i == anchor:
                continue
            if t == TIER_STOP:
                if len(forms) == 1:
                    stop_singles.append((i, int(forms[0])))
                if not ranked:
                    # identical form sets impose identical span constraints
                    # (one occurrence may satisfy several slots); ranked
                    # keeps per-slot groups for per-slot score payloads
                    key = tuple(sorted(forms))
                    if key in stop_seen:
                        continue
                    stop_seen.add(key)
                constraints.append(
                    self._kword_stop_group(i, forms, anchor_forms, window))
            else:
                g = None
                if t == TIER_FREQUENT:
                    g = self._kword_expanded_group(i, forms, anchor_forms,
                                                   window)
                basic = self._basic_group(i, forms, band=window)
                if g is None or g.postings_read > basic.postings_read:
                    g = basic
                constraints.append(g)
        # seed: the anchor's own occurrences, or a triple filter when one is
        # admissible and cheaper (triples first, pairs for the remainder)
        seed = self._basic_group(anchor, anchor_forms)
        if window <= mk.neighbor_distance:
            best_triple = None
            for (i1, s1), (i2, s2) in itertools.combinations(stop_singles, 2):
                if s1 == s2 or not mk.has_triple_pair(s1, s2):
                    continue
                trip = self._kword_triple_seed(anchor, s1, s2, anchor_forms,
                                               window)
                if trip is None:
                    # admitted (s1, s2) key with no postings for any anchor
                    # form: the stops never co-occur near an anchor, so the
                    # span join is empty — a fetchless seed kills the
                    # subplan (the doc-only fallback still runs)
                    seed = FetchGroup(slot=anchor, fetches=[], band=0)
                    best_triple = None
                    break
                if (best_triple is None
                        or trip.postings_read < best_triple.postings_read):
                    best_triple = trip
            if (best_triple is not None
                    and best_triple.postings_read < seed.postings_read):
                seed = best_triple
        return SubPlan(qtype=QTYPE_KWORD, mode=MODE_KWORD,
                       groups=[seed] + constraints,
                       fallback_groups=self._fallback_groups(tiered),
                       kw_window=window)

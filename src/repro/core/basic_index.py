"""Three-stream basic index (paper: EXPANSION OF INFORMATION STORAGE...).

Stream 1: per (word, doc) -- doc id, first occurrence, occurrence count.
          Distance-insensitive search reads ONLY this stream (an order of
          magnitude fewer postings).
Stream 2: all occurrences (doc, pos).  (Storage-wise we keep streams 1+2 as a
          single occurrence CSR; the *metric* distinction -- how many postings
          a query reads -- is preserved because stream 1 is its own CSR.)
Stream 3: near-stop info, one fixed-width slot row per occurrence, read only
          when the query actually contains stop words (Type 4).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.postings import DenseCSR, PackedPostings, unpack_near_stop_slot


@dataclasses.dataclass
class BasicIndex:
    occurrences: DenseCSR      # key = basic-form id; columns: doc, pos
    first_occ: DenseCSR        # key = basic-form id; columns: doc, pos, count
    near_stop: np.ndarray      # [n_postings, K] int32 slots, -1 = empty (stream 3)
    max_distance: int
    # device representation: bit-packed (doc, pos) block stores
    packed_occ: PackedPostings | None = None
    packed_first: PackedPostings | None = None

    def nbytes(self) -> int:
        return self.occurrences.nbytes() + self.first_occ.nbytes() + self.near_stop.nbytes

    def packed_nbytes(self) -> int:
        if self.packed_occ is None:
            return 0
        return self.packed_occ.nbytes() + self.packed_first.nbytes()

    def occ_count(self, base_id: int) -> int:
        return self.occurrences.count(base_id)

    def doc_count(self, base_id: int) -> int:
        return self.first_occ.count(base_id)

    def near_stop_of(self, base_id: int):
        """Stream-3 rows aligned with `occurrences.slice(base_id)`."""
        s, e = self.occurrences.find(base_id)
        return self.near_stop[s:e]

    def decode_near_stop(self, slots: np.ndarray):
        """[N, K] slots -> (delta [N,K], stop_local [N,K], valid [N,K])."""
        valid = slots >= 0
        delta, stop_local = unpack_near_stop_slot(np.maximum(slots, 0), self.max_distance)
        return delta, stop_local, valid

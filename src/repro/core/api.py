"""Typed request/response API — the ONE public search surface.

Every public entry point (`AdditionalIndexEngine`, `OrdinaryEngine`,
`SearchServe`, the launchers, benchmarks, and examples) consumes a
`SearchRequest` and returns a `SearchResponse`.  The old positional
signatures (`search(surface_ids, mode=..., window=...)`) survive only as
thin shims that emit `DeprecationWarning` (CI runs the suite with
``-W error::DeprecationWarning`` to prove no in-repo caller uses them).

Proximity relevance (arXiv:2108.00410)
--------------------------------------
`SearchRequest.rank=True` turns on on-device proximity scoring, computed
from the SAME (doc, pos, dist) postings the match already fetches — zero
extra postings read.  The model follows Veretennikov's relevance-ranking
follow-up on these exact indexes: the score of a match *anchor* (a pivot /
phrase-start occurrence at position ``p``) is a sum of per-query-slot
contributions that decay with the slot word's distance from the anchor,

    score(anchor) = sum_i  w(d_i),      w(d) = 1 / (1 + d)

where ``d_i`` is the distance from the anchor to the nearest matching
occurrence of slot *i* (0 for the pivot itself and for every slot of a
precise-phrase match; the ``dist`` payload of expanded / multi-component-key
postings; the banded key distance for full posting-list slots).  A
document's relevance is the sum over its anchors (duplicated anchors across
tier-split subqueries dedupe by max), so a phrase occurring twice outranks
one occurrence, and tighter word sets outrank looser ones.  Doc-only
fallback hits (the paper's distance-disregarding step 3) carry
`RankingParams.doc_only_score`.

The executors compute contributions in float32 in one canonical order
(per-task bias, then the seed group, then each constraint group), which is
what makes ranked output bit-identical between `engine.search_batch`, the
flexible per-query executor, and the shard_map'd `SearchServe` tier.
"""
from __future__ import annotations

import dataclasses
import warnings

import numpy as np

MODE_PHRASE = "phrase"
MODE_NEAR = "near"
MODE_KWORD = "kword"

# kword window bounds (== core.kword.KW_FLEX_MAX_WINDOW; literal here so the
# API layer stays import-free of the planner stack): the flexible executor's
# int64 delta masks reach W = 31; the device executors handle W <= 15 and
# route wider windows to flex automatically.
_KWORD_MAX_WINDOW = 31

# -- serving statuses (serve.front) -----------------------------------------
# Every response handed out by the serving front door carries exactly one of
# these.  Engine / serve-tier responses are exact by construction, so the
# dataclass default is STATUS_SERVED_EXACT and only the front door ever
# downgrades it.
STATUS_SERVED_EXACT = "SERVED_EXACT"        # all shards answered, on time
STATUS_SERVED_DEGRADED = "SERVED_DEGRADED"  # partial shards and/or past the
                                            # deadline: results are a correct
                                            # merge of the contributing shards
STATUS_SHED = "SHED"                        # admission control refused the
                                            # request: no search executed

_LEGACY_MSG = ("positional search signatures are deprecated: pass a "
               "SearchRequest (repro.core.api) — e.g. "
               "engine.search(SearchRequest(ids, mode=MODE_NEAR)) — and "
               "consume the returned SearchResponse")


def warn_legacy(what: str):
    warnings.warn(f"{what}: {_LEGACY_MSG}", DeprecationWarning, stacklevel=3)


@dataclasses.dataclass(frozen=True)
class RankingParams:
    """Knobs of the proximity relevance model (see module docstring).

    `proximity_scale` multiplies every positional score host-side (both
    executors apply it after the device pass, so it never forces a jit
    recompile); `doc_only_score` is the flat relevance assigned to
    distance-disregarding fallback hits, which therefore rank below any
    positional hit at the default 0.0.
    """
    proximity_scale: float = 1.0
    doc_only_score: float = 0.0


@dataclasses.dataclass(frozen=True)
class SearchRequest:
    """One query: surface ids + match semantics + ranking controls.

    mode      : MODE_PHRASE (order + adjacency), MODE_NEAR (word set within
                `window` of the pivot), or MODE_KWORD (K-word proximity,
                arXiv:2009.02684: every query word inside ONE
                (window + 1)-wide position span, any order — anchors are
                occurrences of the rarest non-stop word; the planner covers
                stop slots with multi-component-key lookups, see
                core/kword.py).  kword requires K >= 2 words and an explicit
                window in [1, 31]; windows <= 15 run on the device
                executors, wider ones ride the flexible escape path.
    window    : near-mode window; None = IndexParams.near_window.
                kword mode: the span width (required, 1..31).
    top_k     : ranked => keep the top_k highest-scoring documents;
                unranked => truncate the flat anchor arrays (the legacy
                `max_results` semantics).  None = unlimited.
    rank      : compute proximity relevance and order hits by it.
    ranking   : scoring weights (ignored unless rank=True).
    deadline_ms : latency budget for the serving front door (relative; the
                front converts it to an absolute deadline at admission and
                sheds the request if it cannot be met).  None = the front's
                default.  Engines ignore it — a direct engine call always
                runs to completion.
    """
    surface_ids: tuple
    mode: str = MODE_PHRASE
    window: int | None = None
    top_k: int | None = None
    rank: bool = False
    ranking: RankingParams = RankingParams()
    deadline_ms: float | None = None

    def __post_init__(self):
        object.__setattr__(self, "surface_ids",
                           tuple(int(s) for s in self.surface_ids))
        if self.mode not in (MODE_PHRASE, MODE_NEAR, MODE_KWORD):
            raise ValueError(f"unknown mode {self.mode!r}")
        if self.mode == MODE_KWORD:
            if len(self.surface_ids) < 2:
                raise ValueError("kword mode needs at least 2 query words")
            if self.window is None or not 1 <= int(self.window) <= _KWORD_MAX_WINDOW:
                raise ValueError(
                    f"kword mode needs an explicit window in "
                    f"[1, {_KWORD_MAX_WINDOW}], got {self.window!r}")
        if self.top_k is not None and self.top_k < 0:
            raise ValueError("top_k must be >= 0")
        if self.deadline_ms is not None and self.deadline_ms <= 0:
            raise ValueError("deadline_ms must be > 0")

    def plan_signature(self) -> tuple:
        """Hashable identity of the *plan* this request compiles to — every
        field that changes the result, and nothing that doesn't.  Two
        requests with equal signatures get bit-identical responses, which is
        what makes it the front door's cache key.  `deadline_ms` is
        deliberately excluded: it shapes scheduling, not results."""
        return (self.surface_ids, self.mode, self.window, self.top_k,
                self.rank, self.ranking.proximity_scale,
                self.ranking.doc_only_score)


@dataclasses.dataclass(frozen=True)
class DocHit:
    """One ranked document: relevance score, its match anchors, and the
    subplan (tier-split subquery) indices that produced them."""
    doc: int
    score: float
    positions: np.ndarray          # anchor positions, ascending (empty when
                                   # the hit came from the doc-only fallback)
    subplans: tuple = ()           # indices into SearchResponse.subplan_types

    def __repr__(self):
        return (f"DocHit(doc={self.doc}, score={self.score:.4f}, "
                f"n_pos={len(self.positions)}, subplans={self.subplans})")


@dataclasses.dataclass
class SearchResponse:
    """Search outcome.  Flat per-anchor arrays (`doc`, `pos`, ascending by
    (doc, pos) — or per-doc when `doc_only`) keep the unranked path as cheap
    as the pre-API result object; ranked fields and the `hits` view are
    filled / built only when the request asked for ranking.
    """
    doc: np.ndarray                # per-anchor doc ids (per-doc if doc_only)
    pos: np.ndarray                # anchor positions (-1 when doc_only)
    postings_read: int
    used_fallback: bool
    doc_only: bool
    subplan_types: tuple = ()
    # -- ranked fields (None unless request.rank) ---------------------------
    ranked: bool = False
    anchor_scores: np.ndarray | None = None   # float32, aligned with doc/pos
    anchor_subplans: np.ndarray | None = None  # uint64 bitmask per anchor
                                               # (exact for subplans 0..63,
                                               # omitted beyond)
    doc_ids: np.ndarray | None = None         # ranked docs (top_k applied)
    doc_scores: np.ndarray | None = None      # float32, aligned with doc_ids
    request: SearchRequest | None = None
    # -- execution provenance -----------------------------------------------
    # positional-key count per supported subplan: how many anchor keys each
    # tier-split subquery matched BEFORE the union/dedup merge.  This is what
    # lets a doc-sharded front door reconstruct the global fallback decision
    # (a subplan falls back iff it has fallback groups and zero positional
    # keys across ALL shards) without re-executing anything.
    subplan_pos_hits: tuple = ()
    # -- serving transport metadata (set by serve.front only) ---------------
    status: str = STATUS_SERVED_EXACT
    shards: tuple = ()             # doc-shard indices that contributed
    cached: bool = False           # served from the hot-query result cache
    shed_reason: str = ""          # SHED / DEGRADED: why ("" otherwise)
    latency_ms: float | None = None
    _hits: list | None = dataclasses.field(default=None, repr=False)

    def __len__(self):
        return len(self.doc_ids) if self.ranked else len(self.doc)

    @property
    def hits(self) -> list[DocHit]:
        """Ranked DocHit view (score desc, doc asc).  Unranked responses
        yield doc-ascending hits with score 0.0 and no provenance."""
        if self._hits is None:
            self._hits = self._build_hits()
        return self._hits

    def _build_hits(self) -> list[DocHit]:
        if not self.ranked:
            docs = np.unique(self.doc)
            if self.doc_only:
                return [DocHit(int(d), 0.0, np.empty(0, np.int32))
                        for d in docs]
            return [DocHit(int(d), 0.0,
                           np.sort(self.pos[self.doc == d]).astype(np.int32))
                    for d in docs]
        out = []
        for d, s in zip(self.doc_ids.tolist(), self.doc_scores.tolist()):
            if self.doc_only:
                out.append(DocHit(int(d), float(s), np.empty(0, np.int32),
                                  self._doc_subplans(d)))
                continue
            sel = self.doc == d
            out.append(DocHit(int(d), float(s),
                              np.sort(self.pos[sel]).astype(np.int32),
                              self._doc_subplans(d)))
        return out

    def _doc_subplans(self, d) -> tuple:
        if self.anchor_subplans is None:
            return ()
        mask = int(np.bitwise_or.reduce(
            self.anchor_subplans[self.doc == d], initial=np.uint64(0)))
        return tuple(i for i in range(min(len(self.subplan_types), 64))
                     if mask >> i & 1)


# legacy alias: PR 1-3 code (and any external user) imported SearchResult;
# the response type is a strict superset of the old dataclass fields
SearchResult = SearchResponse


def as_request(q, mode=MODE_PHRASE, window=None, max_results=None,
               what: str = "search") -> SearchRequest:
    """Adapt a legacy positional call to a SearchRequest, warning once per
    call site (the shim every deprecated signature routes through)."""
    warn_legacy(what)
    return SearchRequest(tuple(int(s) for s in q), mode=mode, window=window,
                         top_k=max_results)

"""Expanded index (w, v): pre-joined co-occurrences of a frequently-used word
w with any non-stop word v within ProcessingDistance(w) (paper: OPTIMIZATION
OF SEARCH-QUERY PROCESSING USING EXPANDED INDEXES).

Postings store the position of w and the *signed* distance to v, so when both
(w, v) and (v, w) would exist (w, v both frequently used) only the canonical
pair min(w,v) < max(w,v) is stored -- the paper's size optimization.  A lookup
of the mirrored pair recovers v's positions as pos + dist.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.postings import CSR, PackedPostings


def pair_key(w: int, v: int, n_base: int) -> int:
    return int(w) * n_base + int(v)


@dataclasses.dataclass
class ExpandedIndex:
    pairs: CSR            # key = w * n_base + v; columns: doc, pos (of w), dist (int8)
    n_base: int
    # device representation: bit-packed (doc, pos, dist) block store
    packed: PackedPostings | None = None

    def nbytes(self) -> int:
        return self.pairs.nbytes()

    def packed_nbytes(self) -> int:
        return self.packed.nbytes() if self.packed is not None else 0

    def has_pair(self, w: int, v: int) -> bool:
        s, e = self.pairs.find(pair_key(w, v, self.n_base))
        return e > s

    def find(self, w: int, v: int, mirrored: bool) -> tuple[int, int]:
        """Slice of the stored (w, v) postings.

        mirrored=True means the caller asked for (v, w) but both words are
        frequent and only the canonical orientation is stored; positions of
        the *second* word are then pos + dist.
        """
        if mirrored:
            w, v = v, w
        return self.pairs.find(pair_key(w, v, self.n_base))

    def lookup(self, w: int, v: int):
        """Occurrences of w with v within ProcessingDistance.

        Returns dict(doc, pos, dist) with pos = positions of w; resolves the
        canonical-orientation mirror transparently.
        """
        s, e = self.pairs.find(pair_key(w, v, self.n_base))
        if e > s:
            return {k: c[s:e] for k, c in self.pairs.columns.items()}
        # mirrored orientation: stored under (v, w); w's positions = pos + dist
        s, e = self.pairs.find(pair_key(v, w, self.n_base))
        if e == s:
            return None
        cols = {k: c[s:e] for k, c in self.pairs.columns.items()}
        return {"doc": cols["doc"],
                "pos": (cols["pos"] + cols["dist"]).astype(np.int32),
                "dist": (-cols["dist"]).astype(np.int8)}

"""Multi-component key index: additional indexes built around stop forms
(arXiv:1812.07640 multi-component keys; arXiv:2006.07954 three-component
construction).

The paper's Type-4 rule confines near-mode queries that contain stop forms
to sequential matching, because the basic index holds no stop-word posting
lists to window against.  Veretennikov's follow-up closes that gap with
additional indexes whose keys have several word components:

* **pairs** — two-component keys ``(s, v)``: every co-occurrence of a stop
  basic form *s* with a non-stop basic form *v* within NeighborDistance
  (= ``IndexParams.near_window``, the default near-mode window), including
  distance 0 (a single token
  carrying both forms).  Postings store ``(doc, pos of s, dist = pos_v -
  pos_s)``, exactly the expanded-index layout, so a near-mode lookup keyed
  at the *pivot* position is ``pos + dist`` — the same ``pivot_from_dist``
  math the executor already jits for expanded fetches.

* **triples** — three-component keys ``(s1, s2, v)`` with ``s1 < s2`` two
  distinct stop forms near a non-stop *v*.  One posting per *v* occurrence
  that has both stops within NeighborDistance, anchored at ``pos of v``
  with ``dist = max(nearest |d1|, nearest |d2|)`` — so the executor's
  ``|dist| <= window`` mask answers "both stops within the window of this
  pivot occurrence" in one fetch instead of two.  The individual nearest
  distances ride along as a packed position-pair payload (``dpair``,
  4 bits each — see postings.pack_dist_pair) for introspection and the
  construction property tests.

Both CSRs are (doc, pos)-sorted per key, so the batch executor's
shard-segmented gather splits multi-key fetches at doc-shard boundaries
with the same single ``searchsorted`` it uses for every other stream; the
two tables are exposed as ONE concatenated arena stream ("multi").  Device-
side the stream ships as bit-packed blocks (``packed_pairs`` /
``packed_triples``, postings.PackedPostings): the pair segment is padded to
a BLOCK multiple so the triple segment starts block-aligned, and
``find_triple`` offsets its slices by that padded base.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.postings import (BLOCK, CSR, PackedPostings,
                                 pack_multi_pair_key, pack_multi_triple_key,
                                 pad_block_multiple)


@dataclasses.dataclass
class MultiKeyIndex:
    pairs: CSR      # key = s * n_base + v; columns: doc, pos (of s), dist
    triples: CSR    # key = (v * n_stop + s2) * n_stop + s1;
                    # columns: doc, pos (of v), dist (= max nearest), dpair
    n_base: int
    n_stop: int
    neighbor_distance: int   # = IndexParams.near_window at build time
    # size dial (IndexParams.triple_pair_min_count): when triples are gated
    # to the common (s1, s2) stop pairs, this holds the ADMITTED pairs as a
    # sorted array of packed s1 * n_stop + s2 codes (s1 < s2).  None = every
    # pair admitted (no gating).  The planner falls back to two two-component
    # lookups for non-admitted pairs — semantics identical, postings differ.
    triple_stop_pairs: np.ndarray | None = None
    # device representation: bit-packed block stores of the (doc, pos, dist)
    # columns (postings.PackedPostings), built once at index-build time
    packed_pairs: PackedPostings | None = None
    packed_triples: PackedPostings | None = None

    @property
    def n_pair_postings(self) -> int:
        return self.pairs.n_postings

    @property
    def n_triple_postings(self) -> int:
        return self.triples.n_postings

    @property
    def n_postings(self) -> int:
        return self.n_pair_postings + self.n_triple_postings

    def nbytes(self) -> int:
        return self.pairs.nbytes() + self.triples.nbytes()

    def packed_nbytes(self) -> int:
        """Device bytes of the packed pair + triple stream."""
        if self.packed_pairs is None:
            return 0
        return self.packed_pairs.nbytes() + self.packed_triples.nbytes()

    @property
    def pair_pad(self) -> int:
        """BLOCK-aligned length of the pair segment in the "multi" stream
        (triples start here, in both the raw and the packed arena)."""
        return -(-max(self.pairs.n_postings, 1) // BLOCK) * BLOCK

    def arena_columns(self) -> dict[str, np.ndarray]:
        """doc/pos/dist concatenated pairs-then-triples — the single "multi"
        stream of the executor arenas, with the pair segment edge-padded to
        `pair_pad` so the raw columns line up ordinal-for-ordinal with the
        packed block store.  find_pair/find_triple return slices into this
        concatenation (pads are never inside a slice)."""
        out = {}
        for name in ("doc", "pos", "dist"):
            out[name] = np.concatenate(
                [pad_block_multiple(self.pairs.columns[name], self.pair_pad),
                 self.triples.columns[name]])
        return out

    def find_pair(self, stop_id: int, v: int) -> tuple[int, int]:
        """(start, end) slice of the (s, v) postings in the multi stream."""
        return self.pairs.find(int(pack_multi_pair_key(stop_id, v, self.n_base)))

    def has_triple_pair(self, s1: int, s2: int) -> bool:
        """True when (s1, s2) triples were admitted at build time (always
        true without gating) — the planner's triple-vs-two-pairs dispatch."""
        if self.triple_stop_pairs is None:
            return True
        a, b = (s1, s2) if s1 < s2 else (s2, s1)
        code = a * self.n_stop + b
        i = int(np.searchsorted(self.triple_stop_pairs, code))
        return i < len(self.triple_stop_pairs) and \
            int(self.triple_stop_pairs[i]) == code

    def find_triple(self, s1: int, s2: int, v: int) -> tuple[int, int]:
        """(start, end) slice of the (s1, s2, v) postings in the multi
        stream (canonicalizes the stop-component order)."""
        a, b = (s1, s2) if s1 < s2 else (s2, s1)
        s, e = self.triples.find(int(pack_multi_triple_key(a, b, v, self.n_stop)))
        off = self.pair_pad
        return s + off, e + off

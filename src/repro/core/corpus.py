"""Synthetic text corpus with Zipfian token statistics.

The paper's experiments index 45 GB of fiction/magazine text (~130k documents).
We synthesize a corpus with the same *statistical* drivers: Zipf token
frequencies (so the top-700 basic forms carry a large share of token mass),
log-normal document lengths, and mild topical burstiness (a document re-uses
the ordinary words it has already used, which makes first-occurrence
compression in stream 1 meaningful, exactly as in real text).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.lexicon import LexiconConfig


@dataclasses.dataclass(frozen=True)
class CorpusConfig:
    n_docs: int = 2_000
    mean_doc_len: float = 900.0
    sigma_doc_len: float = 0.6
    burstiness: float = 0.25   # prob. of re-sampling a recent token in-doc
    seed: int = 0
    stop_mass: float | None = None
                               # target share of tokens carrying a stop basic
                               # form.  The raw Zipf draw over this synthetic
                               # lexicon lands at ~64% stop tokens — far
                               # above real running text (~40% in English
                               # fiction; the paper's 700-lemma Russian list
                               # is comparable) — which inflates every
                               # additional-index-over-corpus ratio.  When
                               # set (and `generate_corpus` is given the
                               # stop-surface mask), stop-surface
                               # probabilities are rescaled so the expected
                               # stop share hits this target; None keeps the
                               # raw Zipf draw.


@dataclasses.dataclass
class Corpus:
    """doc_offsets: [n_docs+1] int64 into tokens; tokens: [T] int32 surface ids."""

    doc_offsets: np.ndarray
    tokens: np.ndarray

    @property
    def n_docs(self) -> int:
        return len(self.doc_offsets) - 1

    @property
    def n_tokens(self) -> int:
        return int(self.doc_offsets[-1])

    def doc(self, i: int) -> np.ndarray:
        return self.tokens[self.doc_offsets[i] : self.doc_offsets[i + 1]]

    def doc_ids_per_token(self) -> np.ndarray:
        """[T] int32 document id of every token."""
        out = np.zeros(self.n_tokens, dtype=np.int32)
        out[self.doc_offsets[1:-1]] = 1
        return np.cumsum(out, dtype=np.int32)

    def positions_per_token(self) -> np.ndarray:
        """[T] int32 in-document ordinal of every token (paper's P)."""
        t = np.arange(self.n_tokens, dtype=np.int64)
        starts = np.repeat(self.doc_offsets[:-1], np.diff(self.doc_offsets))
        return (t - starts).astype(np.int32)


def zipf_probs(n: int, s: float) -> np.ndarray:
    ranks = np.arange(1, n + 1, dtype=np.float64)
    p = ranks ** (-s)
    return p / p.sum()


def generate_corpus(lex_cfg: LexiconConfig, cfg: CorpusConfig,
                    stop_mask: np.ndarray | None = None) -> Corpus:
    """`stop_mask` ([n_surface] bool: surface has a stop basic form) enables
    the `cfg.stop_mass` re-weighting — scale stop-surface probabilities by
    the unique factor that moves the expected stop-token share from the raw
    Zipf mass q to the target t (α = t(1-q) / (q(1-t))), then renormalize.
    Rank order within each class is untouched, so the corpus stays Zipfian.
    """
    rng = np.random.default_rng(cfg.seed + 0xC0)
    probs = zipf_probs(lex_cfg.n_surface, lex_cfg.zipf_s)
    if cfg.stop_mass is not None:
        if stop_mask is None:
            raise ValueError(
                "CorpusConfig.stop_mass is set but generate_corpus got no "
                "stop_mask — the re-weighting would silently no-op (pass "
                "the [n_surface] stop-surface mask; see benchmarks/common)")
        t = float(cfg.stop_mass)
        q = float(probs[stop_mask].sum())
        if not (0.0 < t < 1.0 and 0.0 < q < 1.0):
            raise ValueError(f"degenerate stop_mass target {t} / raw mass {q}")
        alpha = t * (1.0 - q) / (q * (1.0 - t))
        probs = np.where(stop_mask, probs * alpha, probs)
        probs = probs / probs.sum()

    lengths = rng.lognormal(np.log(cfg.mean_doc_len), cfg.sigma_doc_len, cfg.n_docs)
    lengths = np.maximum(lengths.astype(np.int64), 8)
    doc_offsets = np.zeros(cfg.n_docs + 1, dtype=np.int64)
    np.cumsum(lengths, out=doc_offsets[1:])
    total = int(doc_offsets[-1])

    # Base Zipf draw for every token (inverse-CDF; fast for multi-million T).
    cdf = np.cumsum(probs)
    tokens = np.searchsorted(cdf, rng.random(total)).astype(np.int32)

    # Burstiness: with prob `burstiness`, replace a token with one drawn from a
    # short window earlier in the same document (vectorized approximation of
    # per-doc topical re-use).
    if cfg.burstiness > 0:
        lag = rng.integers(1, 64, size=total)
        src = np.maximum(np.arange(total) - lag, 0)
        doc_of = np.repeat(np.arange(cfg.n_docs), lengths)
        same_doc = doc_of[src] == doc_of
        take = (rng.random(total) < cfg.burstiness) & same_doc
        tokens[take] = tokens[src[take]]

    return Corpus(doc_offsets=doc_offsets, tokens=tokens)

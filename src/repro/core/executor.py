"""Batched JAX execution of query plans.

The planner resolves every fetch to (start, length) slices; the executor is
pure array math on device: slice -> packed-block unpack (the bit-packed
posting store of core/postings.py, via ops.unpack_postings) -> key
construction -> (banded) k-way intersection -> anchor unpacking.  Intersections run through jit'd,
shape-bucketed primitives (padded to powers of two) so the compile cache
stays small while latencies remain measurable.  This per-query walker is
the correctness oracle and escape hatch for the batched executor
(core/batch_executor.py), whose tables both the engine's `search_batch`
and the distributed serve tier (serve/search_serve.py) execute; the Pallas
`banded_intersect` kernel implements the same membership test for TPU.

Ranked requests (api.py) run `_run_groups_ranked`: the same banded
intersection, plus a per-group minimum of (key distance + stored |dist|
delta) probed against composite-sorted keys — accumulated into per-anchor
float32 proximity scores in the SAME canonical order as the batched bucket
step, so flex-routed plans rank bit-identically.  `merge_subplan_results`
is the one shared merge tail: anchor dedup (max score), per-doc segment
sums, (score desc, doc asc) ordering with jax top_k selection.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import (RankingParams, SearchRequest, SearchResponse,
                            SearchResult)
from repro.core.builder import IndexSet
from repro.core.fetch_tables import SCORE_DELTA_BITS
from repro.core.kword import kword_span_ok
from repro.core.planner import (FetchGroup, MODE_NEAR, MODE_PHRASE, QueryPlan,
                                ResolvedFetch, SubPlan)
from repro.core.postings import NS_SHIFT, PHRASE_BIAS, POS_BITS

SENTINEL = np.int64(2**62)      # pads; sorts after every real key
_DELTA_MASK = (1 << SCORE_DELTA_BITS) - 1


def _next_pow2(n: int, floor: int = 256) -> int:
    p = floor
    while p < n:
        p <<= 1
    return p


def order_groups_seed_first(groups, ranked=False):
    """Seed-first execution order shared by the batched tensorizer and the
    flexible ranked path (identical order => identical float32 score
    accumulation => bit-identical ranked output).  None when no valid seed
    exists (no band-0 group and no near-stop-checked pivot).

    Unranked seeds pick the smallest band-0 group by *resolved* posting
    count — a pure speed heuristic (the surviving key set is seed-invariant).
    Ranked seeds instead take the FIRST band-0 group in plan order: plan
    order is lexicon/params-driven, so a doc-sharded deployment (serve.front)
    where every shard resolves different posting lengths still accumulates
    float32 scores in one global order — shard merges stay bit-identical to
    the unsharded engine.  (Plan construction puts the pivot's own group
    first whenever it exists, so the ranked seed is the natural anchor.)
    """
    ns = [g for g in groups if any(f.stop_checks for f in g.fetches)]
    if ns:
        seed = ns[0]
    else:
        band0 = [g for g in groups if g.band == 0]
        if not band0:
            return None
        if ranked:
            seed = band0[0]
        else:
            seed = min(band0, key=lambda g: sum(f.length for f in g.fetches))
    return [seed] + [g for g in groups if g is not seed]


def proximity_w(delta):
    """w(d) = 1 / (1 + d), float32 — the proximity decay of the relevance
    model (api.py; arXiv:2108.00410's decreasing distance weight)."""
    return 1.0 / (1.0 + delta.astype(jnp.float32))


def scored_probe(comp_sorted, probe, band):
    """Banded min-delta membership against a composite-sorted key list.

    comp_sorted : [..., Pb] int64 ascending (key << SCORE_DELTA_BITS | delta,
                  pads = any value above every real composite); probe:
                  [..., Pa] int64 (key << SCORE_DELTA_BITS, invalid entries
                  padded like comp — the caller masks them out).  Returns
                  int32 delta_total [..., Pa]: min over b with |key(b) -
                  key(a)| <= band of (key distance + b's stored delta), or
                  I32_SENTINEL when no such b — `< I32_SENTINEL` IS the
                  banded-membership bit.  Two probes suffice: within an
                  equal-key run the first entry carries the minimal stored
                  delta (composite order), and stored deltas are zero in
                  every band > 0 group by plan construction (dist-carrying
                  fetches are always band-0)."""
    from repro.kernels.ops import I32_SENTINEL
    Pb = comp_sorted.shape[-1]
    comp2 = comp_sorted.reshape(-1, Pb)
    probe2 = probe.reshape(comp2.shape[0], -1)
    if comp2.shape[0] == 1:
        idx = jnp.searchsorted(comp2[0], probe2[0], side="left")[None]
    else:
        idx = jax.vmap(
            lambda c, p: jnp.searchsorted(c, p, side="left"))(comp2, probe2)
    hi = jnp.clip(idx, 0, Pb - 1)
    lo = jnp.clip(idx - 1, 0, Pb - 1)
    e_hi = jnp.take_along_axis(comp2, hi, axis=-1).reshape(probe.shape)
    e_lo = jnp.take_along_axis(comp2, lo, axis=-1).reshape(probe.shape)
    idx = idx.reshape(probe.shape)
    a_key = probe >> SCORE_DELTA_BITS
    kd_hi = (e_hi >> SCORE_DELTA_BITS) - a_key
    kd_lo = a_key - (e_lo >> SCORE_DELTA_BITS)
    ok_hi = (idx < Pb) & (kd_hi <= band)
    ok_lo = (idx > 0) & (kd_lo <= band)
    big = jnp.int32(I32_SENTINEL)
    d_hi = (e_hi & _DELTA_MASK).astype(jnp.int32)
    d_lo = (e_lo & _DELTA_MASK).astype(jnp.int32)
    cand_hi = jnp.where(ok_hi, kd_hi.astype(jnp.int32) + d_hi, big)
    cand_lo = jnp.where(ok_lo, kd_lo.astype(jnp.int32) + d_lo, big)
    return jnp.minimum(cand_hi, cand_lo)


@partial(jax.jit, static_argnums=(3,))
def _band_member(a, a_valid, b_sorted, band):
    """a_valid & (exists b in [a - band, a + band])."""
    lo = jnp.searchsorted(b_sorted, a - band, side="left")
    hi = jnp.searchsorted(b_sorted, a + band, side="right")
    return a_valid & (hi > lo)


@jax.jit
def _sort_keys(keys):
    return jnp.sort(keys)


@jax.jit
def _ranked_seed_init(a, d_self, bias):
    """Fused seed-side init of the ranked accumulation (one dispatch):
    validity mask, bias + w(self-delta) score, composite probe keys."""
    a_valid = a < SENTINEL
    score = bias + proximity_w(d_self)
    probe = jnp.where(a_valid, a << SCORE_DELTA_BITS, SENTINEL)
    return a_valid, score, probe


@jax.jit
def _ranked_group_step(comp_sorted, probe, a_valid, score):
    """One constraint group of the ranked flex path, fused into a single
    dispatch: banded min-delta membership + masked score accumulation.
    All operands are pow2-padded (pads probe at SENTINEL => no hit; pads in
    comp_sorted sort last and never fall inside a band), so the compile
    cache stays bounded like the batched executor's shape buckets.  The
    band rides in `comp_sorted`'s companion scalar (traced — no recompile
    per window width)."""
    from repro.kernels.ops import I32_SENTINEL
    comp, band = comp_sorted
    delta_g = scored_probe(comp[None], probe[None], band)[0]
    hit = delta_g < I32_SENTINEL
    return a_valid & hit, score + jnp.where(hit, proximity_w(delta_g), 0.0)


@jax.jit
def _near_stop_ok(slots, packed_targets, target_valid):
    """slots [N, K]; packed_targets [C, M]: per check C, any of M ids at the
    required delta must appear among the K slots; all checks must pass."""
    eq = slots[:, :, None, None] == packed_targets[None, None, :, :]
    eq = eq & target_valid[None, None, :, :]
    per_check = eq.any(axis=(1, 3))             # [N, C]
    return per_check.all(axis=1)


def _rank_docs(doc_ids: np.ndarray, doc_scores: np.ndarray,
               top_k: int | None):
    """Order docs by (score desc, doc asc); top_k selection runs through
    `jax.lax.top_k` (ties break toward the lower index = lower doc, exactly
    the lexsort rule, so truncated and full orderings agree)."""
    if top_k is not None and top_k < len(doc_ids):
        _, idx = jax.lax.top_k(jnp.asarray(doc_scores), top_k)
        idx = np.asarray(idx)
    else:
        idx = np.lexsort((doc_ids, -doc_scores.astype(np.float64)))
    return doc_ids[idx], doc_scores[idx]


def merge_subplan_results(all_keys: list, doc_only_keys: list, postings: int,
                          used_fallback: bool, types: tuple,
                          request: SearchRequest | None,
                          all_scores: list | None = None) -> SearchResponse:
    """Union per-subplan key sets into a SearchResponse.

    Shared by the flexible and batched executors — their result parity
    depends on this tail being literally the same code.  Positional keys win
    over doc-only fallback keys; keys are unpacked doc/pos via the global
    63-bit codec.

    Ranked (`request.rank` with `all_scores` aligned to `all_keys`):
    duplicate anchors across subplans dedupe by MAX score, per-anchor
    subplan provenance ORs over duplicates, document relevance is the
    float32 sum of its anchors' scores, and documents order by (score desc,
    doc asc) with `top_k` selection via jax top_k.  Every step is a
    vectorized pass over key-sorted arrays, so identical inputs (which the
    executors guarantee) give bit-identical ranked output."""
    ranked = request is not None and request.rank
    top_k = request.top_k if request is not None else None
    rank_p = request.ranking if request is not None else RankingParams()
    resp = SearchResponse(
        doc=np.empty(0, np.int32), pos=np.empty(0, np.int32),
        postings_read=postings, used_fallback=used_fallback, doc_only=False,
        subplan_types=tuple(types), ranked=ranked, request=request,
        subplan_pos_hits=tuple(len(k) for k in all_keys))
    have_pos = any(len(k) for k in all_keys)
    if have_pos and not ranked:
        keys = np.unique(np.concatenate(all_keys))
        resp.doc = (keys >> POS_BITS).astype(np.int32)
        resp.pos = ((keys & ((1 << POS_BITS) - 1)) - PHRASE_BIAS).astype(np.int32)
        if top_k is not None:           # legacy max_results truncation
            resp.doc, resp.pos = resp.doc[:top_k], resp.pos[:top_k]
        return resp
    if have_pos:
        scale = np.float32(rank_p.proximity_scale)
        keys = np.concatenate(all_keys)
        scores = np.concatenate(
            [np.asarray(s, np.float32) for s in all_scores]) * scale
        # provenance bitmask: exact for the first 64 subplans, omitted (not
        # misattributed) beyond — tier splits are a per-slot product, so >64
        # needs 7+ words with multi-tier forms; scores are unaffected
        masks = np.concatenate(
            [np.full(len(k), np.uint64(1) << i if i < 64 else np.uint64(0),
                     np.uint64)
             for i, k in enumerate(all_keys)])
        order = np.lexsort((-scores.astype(np.float64), keys))
        k_s, s_s, m_s = keys[order], scores[order], masks[order]
        first = np.ones(len(k_s), bool)
        first[1:] = k_s[1:] != k_s[:-1]
        starts = np.nonzero(first)[0]
        uniq_keys = k_s[starts]
        uniq_scores = s_s[starts]                   # max score per anchor
        uniq_masks = np.bitwise_or.reduceat(m_s, starts)
        resp.doc = (uniq_keys >> POS_BITS).astype(np.int32)
        resp.pos = ((uniq_keys & ((1 << POS_BITS) - 1))
                    - PHRASE_BIAS).astype(np.int32)
        resp.anchor_scores = uniq_scores
        resp.anchor_subplans = uniq_masks
        dfirst = np.ones(len(resp.doc), bool)
        dfirst[1:] = resp.doc[1:] != resp.doc[:-1]
        dstarts = np.nonzero(dfirst)[0]
        doc_ids = resp.doc[dstarts].copy()
        doc_scores = np.add.reduceat(uniq_scores, dstarts).astype(np.float32)
        resp.doc_ids, resp.doc_scores = _rank_docs(doc_ids, doc_scores, top_k)
        return resp
    if doc_only_keys:
        docs = np.unique(np.concatenate(doc_only_keys))
        resp.doc = docs.astype(np.int32)
        resp.pos = np.full(len(resp.doc), -1, dtype=np.int32)
        resp.doc_only = True
        if ranked:
            resp.anchor_scores = np.full(len(resp.doc),
                                         rank_p.doc_only_score, np.float32)
            resp.doc_ids = resp.doc.copy()
            resp.doc_scores = resp.anchor_scores.copy()
            if top_k is not None:
                resp.doc_ids = resp.doc_ids[:top_k]
                resp.doc_scores = resp.doc_scores[:top_k]
        elif top_k is not None:
            resp.doc, resp.pos = resp.doc[:top_k], resp.pos[:top_k]
        return resp
    if ranked:
        resp.anchor_scores = np.empty(0, np.float32)
        resp.doc_ids = np.empty(0, np.int32)
        resp.doc_scores = np.empty(0, np.float32)
    return resp


@partial(jax.jit, static_argnums=(2,))
def _unpack_slice(arena, start, L: int):
    """Decode L consecutive posting ordinals from `start` — ONE jit dispatch
    per fetch on the flexible path (eager per-op unpack math costs ~10x in
    dispatch overhead; L is pow2-bucketed so the compile cache stays small).
    Ordinals past the arena tail read clamped garbage the caller slices off.
    """
    from repro.kernels.ops import unpack_postings
    idx = start + jnp.arange(L, dtype=jnp.int32)
    return unpack_postings(arena, idx)


class DeviceIndex:
    """Per-stream packed postings as device (jnp) arrays.

    Since the packed-store refactor the flexible executor holds the SAME
    bit-packed block representation as the batched arena (one packed store
    per stream instead of one concatenation) and unpacks fetch slices on
    device via ops.unpack_postings — no raw int32 posting columns ever ship.
    """

    STREAMS = ("basic", "first", "expanded", "stop", "ordinary", "multi")

    def __init__(self, index: IndexSet):
        from repro.core.batch_executor import ensure_packed_streams
        packed = ensure_packed_streams(index)
        self._arenas = {}
        for name in self.STREAMS:
            p = packed[name]
            self._arenas[name] = {
                "lanes": jnp.asarray(p.lanes),
                "blk_meta": jnp.asarray(p.meta_matrix()),
            }
        self.near_stop = jnp.asarray(index.basic.near_stop)
        self.max_distance = index.basic.max_distance
        self._unpack_memo = {}

    def unpack(self, stream: str, s: int, e: int):
        """(doc, pos, dist) int32 device arrays for postings [s, e).

        Recent decodes are memoized (small FIFO): the ranked path asks for
        each scored fetch's slice twice — _fetch_keys for the whole group,
        then _fetch_delta per fetch — and the arrays are immutable."""
        key = (stream, s, e)
        hit = self._unpack_memo.get(key)
        if hit is not None:
            return hit
        n = e - s
        doc, pos, dist = _unpack_slice(self._arenas[stream], s,
                                       _next_pow2(max(n, 1), floor=128))
        out = (doc[:n], pos[:n], dist[:n])
        if len(self._unpack_memo) >= 16:       # bounds device-array liveness
            self._unpack_memo.pop(next(iter(self._unpack_memo)))
        self._unpack_memo[key] = out
        return out


class Executor:
    def __init__(self, index: IndexSet, device_index: DeviceIndex | None = None):
        self.index = index
        self.dev = device_index or DeviceIndex(index)

    # -- key construction -----------------------------------------------------

    def _phrase_keys(self, doc, pos, offset):
        shifted = pos.astype(jnp.int64) - offset + PHRASE_BIAS
        return (doc.astype(jnp.int64) << POS_BITS) | shifted

    def _plain_keys(self, doc, pos):
        return (doc.astype(jnp.int64) << POS_BITS) | (pos.astype(jnp.int64) + PHRASE_BIAS)

    def _fetch_keys(self, f: ResolvedFetch, mode: str):
        d = self.dev
        s, e = f.start, f.start + f.length
        doc, pos, dist = d.unpack(f.stream, s, e)
        if f.stream == "stop":
            return self._phrase_keys(doc, pos, f.offset)
        if f.stream == "first":
            return doc.astype(jnp.int64)
        if f.stream in ("expanded", "multi"):
            # dist-carrying streams share one keying rule (the math the
            # batched gather mirrors in bucket_step_math).  Phrase mode
            # (expanded only): anchor keys + exact-distance mask.  Near
            # mode: keys at the pivot position — pos + dist when
            # pivot_from_dist (expanded fetches, (s, v) pairs), pos itself
            # otherwise ((s1, s2, v) triples, whose dist is the max of the
            # two nearest stop distances); |dist| <= window masks the band.
            if f.stream == "expanded" and mode == MODE_PHRASE:
                keys = self._phrase_keys(doc, pos, f.offset)
                mask = dist == f.required_dist
            else:
                pivot_pos = pos + jnp.where(f.pivot_from_dist, dist, 0).astype(pos.dtype)
                keys = self._plain_keys(doc, pivot_pos)
                mask = jnp.abs(dist) <= f.max_abs_dist
            return jnp.where(mask, keys, SENTINEL)
        if f.stream == "ordinary":
            if mode == MODE_PHRASE:
                return self._phrase_keys(doc, pos, f.offset)
            return self._plain_keys(doc, pos)
        # basic occurrences (possibly with near-stop verification)
        if mode == MODE_PHRASE:
            keys = self._phrase_keys(doc, pos, f.offset)
        else:
            keys = self._plain_keys(doc, pos)
        if f.stop_checks:
            slots = d.near_stop[s:e]
            D = d.max_distance
            C = len(f.stop_checks)
            M = max(len(ids) for _, ids in f.stop_checks)
            packed = np.full((C, M), -2, dtype=np.int16)
            valid = np.zeros((C, M), dtype=bool)
            for ci, (delta, ids) in enumerate(f.stop_checks):
                for mi, sid in enumerate(ids):
                    packed[ci, mi] = ((delta + D) << NS_SHIFT) | sid
                    valid[ci, mi] = True
            ok = _near_stop_ok(slots, jnp.asarray(packed), jnp.asarray(valid))
            keys = jnp.where(ok, keys, SENTINEL)
        return keys

    def _fetch_delta(self, f: ResolvedFetch):
        """Per-posting slot delta for ranked scoring: the |dist| payload when
        the planner marked the fetch `score_delta_from_dist` (near-mode
        expanded / multi-key lookups, keyed at the anchor), else 0 (precise
        keys — the key distance carries any remaining spread)."""
        if not f.score_delta_from_dist:
            return jnp.zeros((f.length,), jnp.int32)
        _, _, dist = self.dev.unpack(f.stream, f.start, f.start + f.length)
        return jnp.abs(dist)

    def _group_keys(self, g: FetchGroup, mode: str, scored: bool = False):
        """Sorted, sentinel-padded key array for one fetch group.  `scored`
        returns (composite-sorted keys<<SCORE_DELTA_BITS|delta, raw keys,
        raw deltas) for the ranked path instead."""
        parts = [self._fetch_keys(f, mode) for f in g.fetches]
        if scored:
            deltas = [self._fetch_delta(f) for f in g.fetches]
            keys = jnp.concatenate([p.astype(jnp.int64) for p in parts]) \
                if parts else jnp.empty((0,), jnp.int64)
            delta = jnp.concatenate(deltas) if deltas \
                else jnp.empty((0,), jnp.int32)
            comp = jnp.where(keys < SENTINEL,
                             (keys << SCORE_DELTA_BITS) | delta.astype(jnp.int64),
                             SENTINEL)
            return _sort_keys(comp), keys, delta
        total = sum(int(p.shape[0]) for p in parts)
        width = _next_pow2(max(total, 1))
        buf = jnp.full((width,), SENTINEL, dtype=jnp.int64)
        off = 0
        for p in parts:
            buf = jax.lax.dynamic_update_slice(buf, p.astype(jnp.int64), (off,))
            off += int(p.shape[0])
        return _sort_keys(buf)

    # -- plan execution ---------------------------------------------------------

    def _run_groups(self, groups: list[FetchGroup], mode: str):
        """Banded k-way intersection; returns surviving anchor keys (np)."""
        if not groups:
            return np.empty(0, dtype=np.int64)
        if any(not g.fetches for g in groups):
            return np.empty(0, dtype=np.int64)   # a slot with no postings
        keyed = [(g, self._group_keys(g, mode)) for g in groups]
        # seed must be a band-0 group; prefer the smallest for speed
        band0 = [kg for kg in keyed if kg[0].band == 0]
        seed = min(band0, key=lambda kg: int(kg[1].shape[0]))
        a = seed[1]
        a_valid = a < SENTINEL
        for g, b in keyed:
            if g is seed[0]:
                continue
            a_valid = _band_member(a, a_valid, b, int(g.band))
        res = np.asarray(a)[np.asarray(a_valid)]
        return res[res < SENTINEL]

    def _kword_span_mask(self, sp: SubPlan, a: np.ndarray) -> np.ndarray:
        """K-way windowed join over the subplan's constraint groups for the
        anchor keys `a` (core/kword.py; host int64 masks, so windows up to
        KW_FLEX_MAX_WINDOW — this is the wide-window / cap-overflow escape
        the batched executors route to)."""
        ordered = order_groups_seed_first(sp.groups, ranked=True)
        bs = [np.asarray(self._group_keys(g, sp.mode)) for g in ordered[1:]]
        return kword_span_ok(a, bs, int(sp.kw_window))

    def _run_groups_kword(self, sp: SubPlan):
        """Unranked kword: seed anchors filtered by the K-way span join
        (every slot inside one (W + 1)-wide window containing the anchor)
        instead of pairwise banded membership."""
        groups = sp.groups
        if not groups or any(not g.fetches for g in groups):
            return np.empty(0, dtype=np.int64)
        ordered = order_groups_seed_first(groups, ranked=True)
        if ordered is None:
            return np.empty(0, dtype=np.int64)
        a = np.asarray(self._group_keys(ordered[0], sp.mode))
        sel = (a < SENTINEL) & self._kword_span_mask(sp, a)
        return a[sel]

    # toggled off only by the benchmark's A/B pass (ranked_qps_flex_eager)
    ranked_jit = True

    def _run_groups_ranked(self, sp: SubPlan):
        """Ranked twin of _run_groups: surviving anchors AND their proximity
        scores, accumulated in the SAME canonical float32 order as the
        batched bucket step (bias, seed self-delta, then each constraint
        group seed-first) — identical group sets give bit-identical scores.

        The per-query ranked path is the flex escape a deadline-bounded
        front door falls back to, so it runs pow2-padded through two fused
        jit kernels (seed init + one dispatch per constraint group) instead
        of the old eager op chain; `ranked_jit=False` keeps the eager chain
        alive for the benchmark's A/B comparison.
        """
        from repro.kernels.ops import I32_SENTINEL
        groups = sp.groups
        empty = (np.empty(0, np.int64), np.empty(0, np.float32))
        if not groups or any(not g.fetches for g in groups):
            return empty
        ordered = order_groups_seed_first(groups, ranked=True)
        if ordered is None:
            return empty
        seed = ordered[0]
        a_parts = [self._fetch_keys(f, sp.mode) for f in seed.fetches]
        d_parts = [self._fetch_delta(f) for f in seed.fetches]
        bias = jnp.float32(sp.n_slots - len(groups))
        n = sum(int(p.shape[0]) for p in a_parts)
        if not self.ranked_jit:
            a = jnp.concatenate([p.astype(jnp.int64) for p in a_parts])
            d_self = jnp.concatenate(d_parts)
            a_valid = a < SENTINEL
            score = bias + proximity_w(d_self)
            probe = jnp.where(a_valid, a << SCORE_DELTA_BITS, SENTINEL)
            for g in ordered[1:]:
                comp, _, _ = self._group_keys(g, sp.mode, scored=True)
                delta_g = scored_probe(comp[None], probe[None], int(g.band))[0]
                hit = delta_g < I32_SENTINEL
                a_valid &= hit
                score = score + jnp.where(hit, proximity_w(delta_g), 0.0)
            sel = np.asarray(a_valid)
            if sp.kw_window is not None:
                # kword: found is the span join, not pairwise membership —
                # a span match implies an in-band hit for every group, so
                # the score accumulation above is exact for every survivor
                sel = sel & self._kword_span_mask(sp, np.asarray(a))
            return np.asarray(a)[sel], np.asarray(score, np.float32)[sel]
        # pow2-pad the seed side once (pads = SENTINEL keys, delta 0): every
        # downstream dispatch then hits a bounded set of compiled shapes
        A = _next_pow2(max(n, 1), floor=128)
        a = jnp.full((A,), SENTINEL, dtype=jnp.int64)
        d_self = jnp.zeros((A,), dtype=jnp.int32)
        off = 0
        for p, dp in zip(a_parts, d_parts):
            a = jax.lax.dynamic_update_slice(a, p.astype(jnp.int64), (off,))
            d_self = jax.lax.dynamic_update_slice(d_self, dp, (off,))
            off += int(p.shape[0])
        a_valid, score, probe = _ranked_seed_init(a, d_self, bias)
        for g in ordered[1:]:
            comp, _, _ = self._group_keys(g, sp.mode, scored=True)
            Pb = _next_pow2(max(int(comp.shape[0]), 1), floor=128)
            if int(comp.shape[0]) < Pb:
                comp = jnp.concatenate(
                    [comp, jnp.full((Pb - int(comp.shape[0]),), SENTINEL,
                                    dtype=jnp.int64)])
            a_valid, score = _ranked_group_step(
                (comp, jnp.int32(g.band)), probe, a_valid, score)
        sel = np.asarray(a_valid)[:n]
        if sp.kw_window is not None:
            # kword found bit = span join (see the eager branch above)
            sel = sel & self._kword_span_mask(sp, np.asarray(a)[:n])
        return (np.asarray(a)[:n][sel],
                np.asarray(score, np.float32)[:n][sel])

    def execute(self, plan: QueryPlan, max_results: int | None = None,
                request: SearchRequest | None = None) -> SearchResponse:
        if request is None:
            request = SearchRequest((), top_k=max_results)
        ranked = request.rank
        all_keys, all_scores = [], []
        doc_only_keys = []
        postings = 0
        used_fallback = False
        types = []
        for sp in plan.subplans:
            if not sp.supported:
                continue
            types.append(sp.qtype)
            postings += sp.postings_read
            if ranked:
                keys, scores = self._run_groups_ranked(sp)
            elif sp.kw_window is not None:
                keys = self._run_groups_kword(sp)
                scores = None
            else:
                keys = self._run_groups(sp.groups, sp.mode)
                scores = None
            if len(keys) == 0 and sp.fallback_groups:
                # paper: "if no result is obtained, we disregard the distance"
                used_fallback = True
                postings += sum(g.postings_read for g in sp.fallback_groups)
                dkeys = self._run_groups(sp.fallback_groups, MODE_PHRASE)
                doc_only_keys.append(dkeys)
                keys = keys[:0]
            all_keys.append(keys)
            all_scores.append(scores if scores is not None
                              else np.empty(0, np.float32))
        return merge_subplan_results(all_keys, doc_only_keys, postings,
                                     used_fallback, tuple(types), request,
                                     all_scores=all_scores)

"""Batched JAX execution of query plans.

The planner resolves every fetch to (start, length) slices; the executor is
pure array math on device: slice -> key construction -> (banded) k-way
intersection -> anchor unpacking.  Intersections run through jit'd,
shape-bucketed primitives (padded to powers of two) so the compile cache
stays small while latencies remain measurable.  This per-query walker is
the correctness oracle and escape hatch for the batched executor
(core/batch_executor.py), whose tables both the engine's `search_batch`
and the distributed serve tier (serve/search_serve.py) execute; the Pallas
`banded_intersect` kernel implements the same membership test for TPU.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.builder import IndexSet
from repro.core.planner import (FetchGroup, MODE_NEAR, MODE_PHRASE, QueryPlan,
                                ResolvedFetch, SubPlan)
from repro.core.postings import NS_SHIFT, PHRASE_BIAS, POS_BITS

SENTINEL = np.int64(2**62)      # pads; sorts after every real key


def _next_pow2(n: int, floor: int = 256) -> int:
    p = floor
    while p < n:
        p <<= 1
    return p


@partial(jax.jit, static_argnums=(3,))
def _band_member(a, a_valid, b_sorted, band):
    """a_valid & (exists b in [a - band, a + band])."""
    lo = jnp.searchsorted(b_sorted, a - band, side="left")
    hi = jnp.searchsorted(b_sorted, a + band, side="right")
    return a_valid & (hi > lo)


@jax.jit
def _sort_keys(keys):
    return jnp.sort(keys)


@jax.jit
def _near_stop_ok(slots, packed_targets, target_valid):
    """slots [N, K]; packed_targets [C, M]: per check C, any of M ids at the
    required delta must appear among the K slots; all checks must pass."""
    eq = slots[:, :, None, None] == packed_targets[None, None, :, :]
    eq = eq & target_valid[None, None, :, :]
    per_check = eq.any(axis=(1, 3))             # [N, C]
    return per_check.all(axis=1)


@dataclasses.dataclass
class SearchResult:
    doc: np.ndarray                 # matched documents
    pos: np.ndarray                 # anchor positions (phrase start / pivot)
    postings_read: int
    used_fallback: bool
    doc_only: bool                  # True when results came from stream-1 fallback
    subplan_types: tuple = ()


def merge_subplan_keys(all_keys: list, doc_only_keys: list, postings: int,
                       used_fallback: bool, types: tuple,
                       max_results: int | None) -> SearchResult:
    """Union per-subplan key sets into a SearchResult.

    Shared by the flexible and batched executors — their result parity
    depends on this tail being literally the same code.  Positional keys win
    over doc-only fallback keys; keys are unpacked doc/pos via the global
    63-bit codec."""
    keys = (np.unique(np.concatenate(all_keys)) if all_keys
            else np.empty(0, np.int64))
    if len(keys):
        doc = (keys >> POS_BITS).astype(np.int32)
        pos = ((keys & ((1 << POS_BITS) - 1)) - PHRASE_BIAS).astype(np.int32)
        doc_only = False
    elif doc_only_keys:
        docs = np.unique(np.concatenate(doc_only_keys))
        doc = docs.astype(np.int32)
        pos = np.full(len(doc), -1, dtype=np.int32)
        doc_only = True
    else:
        doc = np.empty(0, np.int32)
        pos = np.empty(0, np.int32)
        doc_only = False
    if max_results is not None:
        doc, pos = doc[:max_results], pos[:max_results]
    return SearchResult(doc=doc, pos=pos, postings_read=postings,
                        used_fallback=used_fallback, doc_only=doc_only,
                        subplan_types=tuple(types))


class DeviceIndex:
    """Index columns as device (jnp) arrays."""

    def __init__(self, index: IndexSet):
        b = index.basic
        self.basic_doc = jnp.asarray(b.occurrences.columns["doc"])
        self.basic_pos = jnp.asarray(b.occurrences.columns["pos"])
        self.near_stop = jnp.asarray(b.near_stop)
        self.first_doc = jnp.asarray(b.first_occ.columns["doc"])
        self.first_pos = jnp.asarray(b.first_occ.columns["pos"])
        e = index.expanded.pairs
        self.exp_doc = jnp.asarray(e.columns["doc"])
        self.exp_pos = jnp.asarray(e.columns["pos"])
        self.exp_dist = jnp.asarray(e.columns["dist"])
        s = index.stop_phrase.phrases
        self.stop_doc = jnp.asarray(s.columns["doc"])
        self.stop_pos = jnp.asarray(s.columns["pos"])
        m = index.multi_key.arena_columns()
        self.multi_doc = jnp.asarray(m["doc"])
        self.multi_pos = jnp.asarray(m["pos"])
        self.multi_dist = jnp.asarray(m["dist"])
        o = index.ordinary
        self.ord_doc = jnp.asarray(o.columns["doc"])
        self.ord_pos = jnp.asarray(o.columns["pos"])
        self.max_distance = b.max_distance


class Executor:
    def __init__(self, index: IndexSet, device_index: DeviceIndex | None = None):
        self.index = index
        self.dev = device_index or DeviceIndex(index)

    # -- key construction -----------------------------------------------------

    def _phrase_keys(self, doc, pos, offset):
        shifted = pos.astype(jnp.int64) - offset + PHRASE_BIAS
        return (doc.astype(jnp.int64) << POS_BITS) | shifted

    def _plain_keys(self, doc, pos):
        return (doc.astype(jnp.int64) << POS_BITS) | (pos.astype(jnp.int64) + PHRASE_BIAS)

    def _fetch_keys(self, f: ResolvedFetch, mode: str):
        d = self.dev
        s, e = f.start, f.start + f.length
        if f.stream == "stop":
            return self._phrase_keys(d.stop_doc[s:e], d.stop_pos[s:e], f.offset)
        if f.stream == "first":
            return d.first_doc[s:e].astype(jnp.int64)
        if f.stream in ("expanded", "multi"):
            # dist-carrying streams share one keying rule (the math the
            # batched gather mirrors in bucket_step_math).  Phrase mode
            # (expanded only): anchor keys + exact-distance mask.  Near
            # mode: keys at the pivot position — pos + dist when
            # pivot_from_dist (expanded fetches, (s, v) pairs), pos itself
            # otherwise ((s1, s2, v) triples, whose dist is the max of the
            # two nearest stop distances); |dist| <= window masks the band.
            if f.stream == "expanded":
                doc, pos, dist = d.exp_doc[s:e], d.exp_pos[s:e], d.exp_dist[s:e]
            else:
                doc, pos, dist = (d.multi_doc[s:e], d.multi_pos[s:e],
                                  d.multi_dist[s:e])
            if f.stream == "expanded" and mode == MODE_PHRASE:
                keys = self._phrase_keys(doc, pos, f.offset)
                mask = dist == f.required_dist
            else:
                pivot_pos = pos + jnp.where(f.pivot_from_dist, dist, 0).astype(pos.dtype)
                keys = self._plain_keys(doc, pivot_pos)
                mask = jnp.abs(dist) <= f.max_abs_dist
            return jnp.where(mask, keys, SENTINEL)
        if f.stream == "ordinary":
            doc, pos = d.ord_doc[s:e], d.ord_pos[s:e]
            if mode == MODE_PHRASE:
                return self._phrase_keys(doc, pos, f.offset)
            return self._plain_keys(doc, pos)
        # basic occurrences (possibly with near-stop verification)
        doc, pos = d.basic_doc[s:e], d.basic_pos[s:e]
        if mode == MODE_PHRASE:
            keys = self._phrase_keys(doc, pos, f.offset)
        else:
            keys = self._plain_keys(doc, pos)
        if f.stop_checks:
            slots = d.near_stop[s:e]
            D = d.max_distance
            C = len(f.stop_checks)
            M = max(len(ids) for _, ids in f.stop_checks)
            packed = np.full((C, M), -2, dtype=np.int16)
            valid = np.zeros((C, M), dtype=bool)
            for ci, (delta, ids) in enumerate(f.stop_checks):
                for mi, sid in enumerate(ids):
                    packed[ci, mi] = ((delta + D) << NS_SHIFT) | sid
                    valid[ci, mi] = True
            ok = _near_stop_ok(slots, jnp.asarray(packed), jnp.asarray(valid))
            keys = jnp.where(ok, keys, SENTINEL)
        return keys

    def _group_keys(self, g: FetchGroup, mode: str):
        """Sorted, sentinel-padded key array for one fetch group."""
        parts = [self._fetch_keys(f, mode) for f in g.fetches]
        total = sum(int(p.shape[0]) for p in parts)
        width = _next_pow2(max(total, 1))
        buf = jnp.full((width,), SENTINEL, dtype=jnp.int64)
        off = 0
        for p in parts:
            buf = jax.lax.dynamic_update_slice(buf, p.astype(jnp.int64), (off,))
            off += int(p.shape[0])
        return _sort_keys(buf)

    # -- plan execution ---------------------------------------------------------

    def _run_groups(self, groups: list[FetchGroup], mode: str):
        """Banded k-way intersection; returns surviving anchor keys (np)."""
        if not groups:
            return np.empty(0, dtype=np.int64)
        if any(not g.fetches for g in groups):
            return np.empty(0, dtype=np.int64)   # a slot with no postings
        keyed = [(g, self._group_keys(g, mode)) for g in groups]
        # seed must be a band-0 group; prefer the smallest for speed
        band0 = [kg for kg in keyed if kg[0].band == 0]
        seed = min(band0, key=lambda kg: int(kg[1].shape[0]))
        a = seed[1]
        a_valid = a < SENTINEL
        for g, b in keyed:
            if g is seed[0]:
                continue
            a_valid = _band_member(a, a_valid, b, int(g.band))
        res = np.asarray(a)[np.asarray(a_valid)]
        return res[res < SENTINEL]

    def execute(self, plan: QueryPlan, max_results: int | None = None) -> SearchResult:
        all_keys = []
        doc_only_keys = []
        postings = 0
        used_fallback = False
        types = []
        for sp in plan.subplans:
            if not sp.supported:
                continue
            types.append(sp.qtype)
            postings += sp.postings_read
            keys = self._run_groups(sp.groups, sp.mode)
            if len(keys) == 0 and sp.fallback_groups:
                # paper: "if no result is obtained, we disregard the distance"
                used_fallback = True
                postings += sum(g.postings_read for g in sp.fallback_groups)
                dkeys = self._run_groups(sp.fallback_groups, MODE_PHRASE)
                doc_only_keys.append(dkeys)
            else:
                all_keys.append(keys)
        return merge_subplan_keys(all_keys, doc_only_keys, postings,
                                  used_fallback, tuple(types), max_results)

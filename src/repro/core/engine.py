"""Engine facades behind the typed request/response API (core/api.py).

`AdditionalIndexEngine` — the paper's system: planner (Type 1-4 dispatch over
the stop-phrase / expanded / 3-stream basic indexes) + JAX executor.

`OrdinaryEngine` — the comparison baseline (the paper benchmarks Sphinx
2.0.6): a single inverted index over every basic form, stop words included;
every query reads the *full* posting list of every query word.

Both consume `SearchRequest`s (`search` / `search_batch`) and return
`SearchResponse`s — proximity-ranked DocHits when `rank=True`; the old
positional signatures are DeprecationWarning shims.

`brute_force_search` — O(corpus) oracle used by tests and the experiment
harness to verify that indexed phrases are found exactly (paper: "Since
phrases are selected from an already-indexed document, they should be
precisely found"); `brute_force_ranked` — its scoring twin (literal
nested-loop proximity relevance per arXiv:2108.00410).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.analyzer import Analyzer
from repro.core.api import SearchRequest, SearchResponse, as_request
from repro.core.batch_executor import BatchExecutor
from repro.core.builder import IndexSet, expand_token_forms
from repro.core.corpus import Corpus
from repro.core.executor import DeviceIndex, Executor, SearchResult
from repro.core.kword import MODE_KWORD, pick_kword_anchor
from repro.core.lexicon import Lexicon
from repro.core.planner import (FetchGroup, MODE_NEAR, MODE_PHRASE,
                                QTYPE_KWORD, Planner, QueryPlan,
                                ResolvedFetch, SubPlan)


def _coerce_requests(queries, modes, window, max_results, what) -> list[SearchRequest]:
    """Legacy (queries, modes=...) batch signature -> SearchRequests."""
    if isinstance(modes, str):
        modes = [modes] * len(queries)
    if len(modes) != len(queries):
        raise ValueError("modes must be a str or match len(queries)")
    from repro.core.api import warn_legacy
    warn_legacy(what)
    return [SearchRequest(tuple(int(s) for s in q), mode=m, window=window,
                          top_k=max_results) for q, m in zip(queries, modes)]


class _BatchSearchMixin:
    """Shared lazy batch-executor plumbing: the batched arena duplicates the
    posting streams on device, so per-query-only users never pay for it."""

    def _init_batch(self, batch_impl: str, interpret: bool,
                    docs_per_shard: int | None = None, doc_base: int = 0):
        self._batch_impl = batch_impl
        self._interpret = interpret
        self._docs_per_shard = docs_per_shard
        self._doc_base = doc_base
        self._batch_executor = None

    @property
    def batch_executor(self) -> BatchExecutor:
        if self._batch_executor is None:
            self._batch_executor = BatchExecutor(
                self.index, flex=self.executor, impl=self._batch_impl,
                interpret=self._interpret,
                docs_per_shard=self._docs_per_shard,
                doc_base=self._doc_base)
        return self._batch_executor

    def search(self, request, mode: str = MODE_PHRASE,
               window: int | None = None,
               max_results: int | None = None) -> SearchResponse:
        """One query through the flexible per-query executor.  The only
        supported argument is a SearchRequest; the positional form
        (surface_ids, mode=..., window=..., max_results=...) is a deprecated
        shim."""
        if not isinstance(request, SearchRequest):
            request = as_request(request, mode, window, max_results,
                                 what=f"{type(self).__name__}.search")
        plan = self.plan_request(request)
        return self.executor.execute(plan, request=request)

    def search_batch(self, requests, modes: str | list = MODE_PHRASE,
                     window: int | None = None,
                     max_results: int | None = None) -> list[SearchResponse]:
        """Batched search: a sequence of SearchRequests through the
        plan-compiled batched executor — same results as per-query `search`,
        one jit'd call per shape bucket, ranked and unranked requests mixing
        freely.  The positional (queries, modes=...) form is a deprecated
        shim."""
        requests = list(requests)
        if not all(isinstance(r, SearchRequest) for r in requests):
            requests = _coerce_requests(
                requests, modes, window, max_results,
                what=f"{type(self).__name__}.search_batch")
        plans = [self.plan_request(r) for r in requests]
        return self.batch_executor.execute_batch(plans, requests=requests)


class AdditionalIndexEngine(_BatchSearchMixin):
    """The paper's engine: additional indexes + Type 1-4 query processing.

    `search(SearchRequest)` runs one query through the flexible executor;
    `search_batch([SearchRequest, ...])` runs a whole batch through the
    plan-compiled batched executor (one jit'd call per shape bucket;
    identical results — see batch_executor.py).  Both return
    `SearchResponse`s; `rank=True` requests carry proximity-ranked DocHits.
    """

    def __init__(self, index: IndexSet, batch_impl: str = "ref",
                 interpret: bool = True, docs_per_shard: int | None = None,
                 windowed_near_stop: bool = True, occ_counts=None,
                 doc_base: int = 0):
        self.index = index
        # occ_counts: cluster-global occurrence stats for doc-sharded
        # deployments (serve.front) — see Planner.__init__.  doc_base: this
        # engine's first GLOBAL doc id (segments / doc shards); the batched
        # executor lays its rows on the global shard grid so every segment
        # buckets identically.
        self.planner = Planner(index, windowed_near_stop=windowed_near_stop,
                               occ_counts=occ_counts)
        self.executor = Executor(index)
        self._init_batch(batch_impl, interpret, docs_per_shard, doc_base)

    def refresh_occ_counts(self, occ_counts=None):
        """Re-snapshot planner pivot statistics (see Planner.refresh_occ_counts)."""
        self.planner.refresh_occ_counts(occ_counts)

    def plan_request(self, request: SearchRequest) -> QueryPlan:
        return self.planner.plan(list(request.surface_ids),
                                 mode=request.mode, window=request.window,
                                 ranked=request.rank)

    def plan(self, surface_ids, mode: str = MODE_PHRASE,
             window: int | None = None, ranked: bool = False):
        """Host-side plan introspection (not a search entry point)."""
        return self.planner.plan(list(surface_ids), mode=mode, window=window,
                                 ranked=ranked)


class OrdinaryEngine(_BatchSearchMixin):
    """Sphinx-style baseline: one inverted index, full posting-list reads."""

    def __init__(self, index: IndexSet, batch_impl: str = "ref",
                 interpret: bool = True, docs_per_shard: int | None = None):
        self.index = index
        self.executor = Executor(index)
        self._init_batch(batch_impl, interpret, docs_per_shard)
        self._counts = index.ordinary.counts()

    def _slot_group(self, slot, forms, band) -> FetchGroup:
        fetches = []
        for f in forms:
            s, e = self.index.ordinary.find(f)
            if e > s:
                fetches.append(ResolvedFetch(stream="ordinary", start=s,
                                             length=e - s, offset=slot))
        return FetchGroup(slot=slot, fetches=fetches, band=band,
                          score_slot=slot)

    def plan_request(self, request: SearchRequest) -> QueryPlan:
        return self.plan(list(request.surface_ids), mode=request.mode,
                         window=request.window)

    def plan(self, surface_ids, mode: str = MODE_PHRASE,
             window: int | None = None, ranked: bool = False) -> QueryPlan:
        if window is None:
            window = self.index.params.near_window
        ana = self.index.analyzer
        form_lists = [ana.forms_of(s) for s in surface_ids]
        # near mode is windowed for every query, stop forms included — the
        # baseline's single index holds stop posting lists, so it pays the
        # full-list reads the multi-key index exists to avoid
        groups = []
        if mode == MODE_PHRASE:
            for i, forms in enumerate(form_lists):
                groups.append(self._slot_group(i, forms, band=0))
        elif mode == MODE_KWORD:
            # the baseline pays full posting-list reads for every slot, stop
            # words included; the anchor is the rarest slot that has a
            # non-stop form (the span join needs an anchorable slot), and
            # the K-way windowed join runs over the full lists — the cost
            # comparison the multi-key cover is benchmarked against
            if window is None:
                raise ValueError("kword mode requires an explicit window")
            lex = self.index.lexicon
            counts = [sum(int(self._counts[f]) for f in forms)
                      for forms in form_lists]
            nonstop = [i for i, forms in enumerate(form_lists)
                       if not bool(lex.is_stop(np.asarray(forms)).all())]
            eligible = nonstop or list(range(len(form_lists)))
            anchor = min(eligible, key=lambda i: counts[i])
            for i, forms in enumerate(form_lists):
                groups.append(self._slot_group(i, forms,
                                               band=0 if i == anchor else window))
            return QueryPlan(subplans=[SubPlan(
                qtype=QTYPE_KWORD, mode=MODE_KWORD, groups=groups,
                n_slots=len(form_lists), kw_window=window)])
        else:
            counts = [sum(int(self._counts[f]) for f in forms) for forms in form_lists]
            pivot = int(np.argmin(counts))
            for i, forms in enumerate(form_lists):
                groups.append(self._slot_group(i, forms,
                                               band=0 if i == pivot else window))
        return QueryPlan(subplans=[SubPlan(qtype=0, mode=mode, groups=groups,
                                           n_slots=len(form_lists))])


def near_query_contains_stop(lexicon, analyzer, surface_ids,
                             mode: str = MODE_NEAR) -> bool:
    """True when a near-mode query has at least one stop basic form among
    its words' forms — the population the paper's Type-4 rule used to
    confine to sequential matching, and which the multi-component key index
    (QTYPE_MULTI plans) now serves with true windowed semantics."""
    if mode != MODE_NEAR:
        return False
    return any(bool(lexicon.is_stop(np.asarray(analyzer.forms_of(s))).any())
               for s in surface_ids)


def near_query_stop_confined(lexicon, analyzer, surface_ids,
                             mode: str = MODE_NEAR) -> bool:
    """True when EVERY basic form of EVERY query word is a stop form.

    Such a near query has only all-stop tier combinations, so every subquery
    is Type 1 — contiguous stop-phrase matching, word order disregarded —
    and it has no doc-level fallback either (stop words carry no meaning
    doc-level).  An every-other-word query sampled from an indexed document
    legitimately may not find its source; these are the ONLY near queries
    recall is not promised for since the multi-component key index
    (QTYPE_MULTI) gave every mixed stop-containing near query windowed
    semantics.  The benchmark's `missed_source_docs` and the serve parity
    tests share this single predicate."""
    if mode != MODE_NEAR:
        return False
    return all(bool(lexicon.is_stop(np.asarray(analyzer.forms_of(s))).all())
               for s in surface_ids)


# ---------------------------------------------------------------------------
# brute-force oracle
# ---------------------------------------------------------------------------

def _tier_splits(form_lists, lexicon):
    """Mirror Planner._split_by_tier (the paper's query-splitting rule)."""
    import itertools
    per_slot = []
    for forms in form_lists:
        tiers = {}
        for f in forms:
            tiers.setdefault(int(lexicon.base_tier[f]), []).append(f)
        per_slot.append(sorted(tiers.items()))
    return list(itertools.product(*per_slot))


def _stop_multiset_anchor_set(tiered, tf_prim, tf_sec, doc_of, pos_of,
                              lexicon, params):
    """Any-order contiguous matches of an all-stop subquery (Type 1) — the
    anchor set shared by the plain and the ranked oracle."""
    import itertools
    from repro.core.lexicon import TIER_STOP
    from repro.core.planner import split_query_parts
    T = len(tf_prim)
    n = len(tiered)
    parts = split_query_parts(n, params.min_len, params.max_len)
    part_hits = []
    for (pstart, L) in parts:
        slot_forms = [tiered[pstart + j][1] for j in range(L)]
        qsets = {tuple(sorted(c)) for c in itertools.product(*slot_forms)}
        hits = set()
        for t in range(T - L + 1):
            if doc_of[t] != doc_of[t + L - 1]:
                continue
            cands = []
            okwin = True
            for u in range(t, t + L):
                forms = [f for f in (tf_prim[u], tf_sec[u])
                         if f >= 0 and lexicon.base_tier[f] == TIER_STOP]
                if not forms:
                    okwin = False
                    break
                cands.append(forms)
            if not okwin:
                continue
            wsets = {tuple(sorted(c)) for c in itertools.product(*cands)}
            if wsets & qsets:
                hits.add((int(doc_of[t]), int(pos_of[t]) - pstart))
        part_hits.append(hits)
    out = part_hits[0]
    for h in part_hits[1:]:
        out &= h
    return out


def brute_force_search(corpus: Corpus, index: IndexSet, surface_ids,
                       mode: str = MODE_PHRASE, window: int | None = None):
    """O(corpus) oracle with the *paper's* match semantics.

    Mirrors the engine exactly: the query is tier-split; each subquery is
    matched per its type:

      * all-stop subqueries: contiguous window, word order DISREGARDED
        (the stop-phrase index keys are sorted multisets), with the planner's
        part-splitting for phrases longer than MaxLength;
      * stop-containing subqueries, phrase mode: precise positional match
        (Type 4);
      * otherwise, phrase mode = precise positional; near mode = every word
        within `window` of the pivot (the planner's pivot rule) — INCLUDING
        stop slots: since the multi-component key index, near-mode
        subqueries containing stop forms get TRUE windowed answers
        (QTYPE_MULTI), no Type-4 sequential confinement.

    Returns (positional_matches, doc_matches): positional = set[(doc, anchor)]
    where anchor is the phrase start (phrase/stop) or the pivot position
    (near); doc_matches = distance-disregarding doc-level intersection of the
    non-stop words (the stream-1 fallback's ground truth).
    """
    import itertools

    lexicon, analyzer, params = index.lexicon, index.analyzer, index.params
    if window is None:
        window = params.near_window
    occ_counts = index.base_occ_counts()

    tf_prim = analyzer.primary[corpus.tokens]
    tf_sec = analyzer.secondary[corpus.tokens]
    doc_of = corpus.doc_ids_per_token()
    pos_of = corpus.positions_per_token()
    T = corpus.n_tokens
    from repro.core.lexicon import TIER_STOP
    from repro.core.planner import pick_pivot, split_query_parts

    def token_matches(slot_forms):
        m = np.isin(tf_prim, list(slot_forms))
        m |= np.isin(tf_sec, list(slot_forms)) & (tf_sec >= 0)
        return m

    def stop_multiset_anchors(tiered):
        """Any-order contiguous matches of an all-stop subquery."""
        return _stop_multiset_anchor_set(tiered, tf_prim, tf_sec, doc_of,
                                         pos_of, lexicon, params)

    positional = set()
    doc_level_all = set()
    for tiered in _tier_splits([analyzer.forms_of(s) for s in surface_ids], lexicon):
        tiers = [t for t, _ in tiered]
        n = len(tiered)
        sub_mode = mode   # near stays windowed even with stop slots (QTYPE_MULTI)
        if all(t == TIER_STOP for t in tiers):
            if n >= params.min_len:
                positional |= stop_multiset_anchors(tiered)
            docs = None   # stop-only: no doc-level fallback
        else:
            matches = [token_matches(forms) for _, forms in tiered]
            if sub_mode == MODE_PHRASE:
                ok = matches[0][: T - n + 1].copy()
                for i in range(1, n):
                    ok &= matches[i][i : T - n + 1 + i]
                if n > 1:
                    ok &= doc_of[: T - n + 1] == doc_of[n - 1 :]
                for t in np.nonzero(ok)[0]:
                    positional.add((int(doc_of[t]), int(pos_of[t])))
            else:
                pivot = pick_pivot(tiered, occ_counts)
                for t in np.nonzero(matches[pivot])[0]:
                    good = True
                    for i, m in enumerate(matches):
                        if i == pivot:
                            continue
                        lo, hi = max(0, t - window), min(T, t + window + 1)
                        if not (m[lo:hi] & (doc_of[lo:hi] == doc_of[t])).any():
                            good = False
                            break
                    if good:
                        positional.add((int(doc_of[t]), int(pos_of[t])))
            # doc-level (stream-1 fallback) truth: non-stop words only
            docs = None
            for (t, forms), m in zip(tiered, matches):
                if t == TIER_STOP:
                    continue
                d = set(np.unique(doc_of[m]).tolist())
                docs = d if docs is None else (docs & d)
        if docs:
            doc_level_all |= docs
    return positional, doc_level_all


def brute_force_ranked(corpus: Corpus, index: IndexSet, surface_ids,
                       mode: str = MODE_PHRASE, window: int | None = None,
                       ranking=None):
    """Ranked twin of `brute_force_search`: the proximity relevance model of
    api.py computed by literal nested loops over the corpus — the reference
    the engines' device scoring pass is checked against end to end.

    Per tier-split subquery, every match anchor scores

        sum over query slots i of w(d_i),     w(d) = 1 / (1 + d)

    with d_i = 0 for the pivot and for every slot of a precise-phrase /
    all-stop match (exact offsets), else the distance from the anchor to the
    nearest same-document token matching slot i within the window.  Anchors
    duplicated across subqueries keep their MAX score; a document's
    relevance is the sum over its anchors times `ranking.proximity_scale`.

    Returns (anchor_scores, doc_scores, doc_level): dicts keyed (doc, pos)
    and doc (float64 — the engines accumulate float32, so compare with
    tolerance), plus the doc-only fallback truth set (relevance
    `ranking.doc_only_score`, only reachable when no subquery has a
    positional match).
    """
    from repro.core.api import RankingParams
    from repro.core.lexicon import TIER_STOP
    from repro.core.planner import pick_pivot

    ranking = ranking or RankingParams()
    lexicon, analyzer, params = index.lexicon, index.analyzer, index.params
    if window is None:
        window = params.near_window
    occ_counts = index.base_occ_counts()
    tf_prim = analyzer.primary[corpus.tokens]
    tf_sec = analyzer.secondary[corpus.tokens]
    doc_of = corpus.doc_ids_per_token()
    pos_of = corpus.positions_per_token()
    T = corpus.n_tokens

    def token_matches(slot_forms):
        m = np.isin(tf_prim, list(slot_forms))
        m |= np.isin(tf_sec, list(slot_forms)) & (tf_sec >= 0)
        return m

    anchor_scores: dict = {}
    doc_level_all: set = set()

    def put(anchor, score):
        prev = anchor_scores.get(anchor)
        if prev is None or score > prev:
            anchor_scores[anchor] = score

    for tiered in _tier_splits([analyzer.forms_of(s) for s in surface_ids],
                               lexicon):
        tiers = [t for t, _ in tiered]
        n = len(tiered)
        if all(t == TIER_STOP for t in tiers):
            if n >= params.min_len:
                for anchor in _stop_multiset_anchor_set(
                        tiered, tf_prim, tf_sec, doc_of, pos_of, lexicon,
                        params):
                    put(anchor, float(n))       # exact offsets: n * w(0)
            continue                            # stop-only: no doc fallback
        matches = [token_matches(forms) for _, forms in tiered]
        if mode == MODE_PHRASE:
            ok = matches[0][: T - n + 1].copy()
            for i in range(1, n):
                ok &= matches[i][i: T - n + 1 + i]
            if n > 1:
                ok &= doc_of[: T - n + 1] == doc_of[n - 1:]
            for t in np.nonzero(ok)[0]:
                put((int(doc_of[t]), int(pos_of[t])), float(n))
        else:
            pivot = pick_pivot(tiered, occ_counts)
            for t in np.nonzero(matches[pivot])[0]:
                score = 1.0                     # the pivot slot: w(0)
                good = True
                for i, m in enumerate(matches):
                    if i == pivot:
                        continue
                    lo, hi = max(0, t - window), min(T, t + window + 1)
                    near = np.nonzero(m[lo:hi]
                                      & (doc_of[lo:hi] == doc_of[t]))[0]
                    if len(near) == 0:
                        good = False
                        break
                    delta = int(np.abs(near + lo - t).min())
                    score += 1.0 / (1.0 + delta)
                if good:
                    put((int(doc_of[t]), int(pos_of[t])), score)
        # doc-level (stream-1 fallback) truth: non-stop words only
        docs = None
        for (tr, forms), m in zip(tiered, matches):
            if tr == TIER_STOP:
                continue
            d = set(np.unique(doc_of[m]).tolist())
            docs = d if docs is None else (docs & d)
        if docs:
            doc_level_all |= docs

    scale = float(ranking.proximity_scale)
    anchor_scores = {k: v * scale for k, v in anchor_scores.items()}
    doc_scores: dict = {}
    for (d, _p), s in anchor_scores.items():
        doc_scores[d] = doc_scores.get(d, 0.0) + s
    return anchor_scores, doc_scores, doc_level_all


# ---------------------------------------------------------------------------
# K-word proximity oracle (arXiv:2009.02684; planner QTYPE_KWORD)
# ---------------------------------------------------------------------------

def _kword_tier_hits(tiered, matches, anchor, window, doc_of, pos_of, T):
    """Literal nested-loop span matching for one tier-split subquery: yields
    (doc, pos, score) for every anchor occurrence where some assignment of
    one occurrence per remaining slot fits inside a (window + 1)-wide span
    containing the anchor — the window-start scan is spelled out as loops,
    nothing shared with the executors' mask math.  `score` is the ranked
    model's anchor score: w(0) for the anchor plus, per remaining slot, w of
    the nearest in-window occurrence (the banded min the executors read)."""
    for t in np.nonzero(matches[anchor])[0]:
        d = doc_of[t]
        cands = []
        good = True
        for i, m in enumerate(matches):
            if i == anchor:
                continue
            lo, hi = max(0, t - window), min(T, t + window + 1)
            idx = np.nonzero(m[lo:hi] & (doc_of[lo:hi] == d))[0]
            if len(idx) == 0:
                good = False
                break
            cands.append((idx + lo - t).astype(int))
        if not good:
            continue
        ok = False
        for w0 in range(-window, 1):          # window starts containing t
            if all(any(w0 <= dd <= w0 + window for dd in c) for c in cands):
                ok = True
                break
        if not ok:
            continue
        score = 1.0 + sum(1.0 / (1.0 + int(np.abs(c).min())) for c in cands)
        yield int(d), int(pos_of[t]), score


def brute_force_kword(corpus: Corpus, index: IndexSet, surface_ids,
                      window: int):
    """O(corpus) K-word span oracle: anchors are occurrences of the rarest
    non-stop slot (pick_kword_anchor — the planner's anchor rule); an anchor
    matches iff every other query word has an occurrence such that ALL K
    words fall inside one (window + 1)-wide position span.  Tier-split like
    the engine; all-stop tier combinations are unsupported (no anchor) and
    contribute nothing, mirroring the planner.

    Returns (positional, doc_matches): positional = set[(doc, anchor_pos)];
    doc_matches = distance-disregarding doc-level intersection of the
    non-stop words (the stream-1 fallback's ground truth)."""
    lexicon, analyzer = index.lexicon, index.analyzer
    occ_counts = index.base_occ_counts()
    tf_prim = analyzer.primary[corpus.tokens]
    tf_sec = analyzer.secondary[corpus.tokens]
    doc_of = corpus.doc_ids_per_token()
    pos_of = corpus.positions_per_token()
    T = corpus.n_tokens
    from repro.core.lexicon import TIER_STOP

    def token_matches(slot_forms):
        m = np.isin(tf_prim, list(slot_forms))
        m |= np.isin(tf_sec, list(slot_forms)) & (tf_sec >= 0)
        return m

    positional = set()
    doc_level_all = set()
    for tiered in _tier_splits([analyzer.forms_of(s) for s in surface_ids],
                               lexicon):
        anchor = pick_kword_anchor(tiered, occ_counts)
        if anchor < 0:
            continue                         # all-stop: unsupported subplan
        matches = [token_matches(forms) for _, forms in tiered]
        for d, p, _s in _kword_tier_hits(tiered, matches, anchor, window,
                                         doc_of, pos_of, T):
            positional.add((d, p))
        docs = None
        for (t, _forms), m in zip(tiered, matches):
            if t == TIER_STOP:
                continue
            dset = set(np.unique(doc_of[m]).tolist())
            docs = dset if docs is None else (docs & dset)
        if docs:
            doc_level_all |= docs
    return positional, doc_level_all


def brute_force_kword_ranked(corpus: Corpus, index: IndexSet, surface_ids,
                             window: int, ranking=None):
    """Ranked twin of `brute_force_kword` (same shapes as
    `brute_force_ranked`): every span-matching anchor scores w(0) for the
    anchor slot plus w(nearest in-window distance) per remaining slot —
    exactly the banded min-delta accumulation the executors run, with
    found overridden by the span join.  Duplicate anchors across tier-split
    subqueries keep their MAX score; doc relevance sums a doc's anchors."""
    from repro.core.api import RankingParams
    from repro.core.lexicon import TIER_STOP

    ranking = ranking or RankingParams()
    lexicon, analyzer = index.lexicon, index.analyzer
    occ_counts = index.base_occ_counts()
    tf_prim = analyzer.primary[corpus.tokens]
    tf_sec = analyzer.secondary[corpus.tokens]
    doc_of = corpus.doc_ids_per_token()
    pos_of = corpus.positions_per_token()
    T = corpus.n_tokens

    def token_matches(slot_forms):
        m = np.isin(tf_prim, list(slot_forms))
        m |= np.isin(tf_sec, list(slot_forms)) & (tf_sec >= 0)
        return m

    anchor_scores: dict = {}
    doc_level_all: set = set()
    for tiered in _tier_splits([analyzer.forms_of(s) for s in surface_ids],
                               lexicon):
        anchor = pick_kword_anchor(tiered, occ_counts)
        if anchor < 0:
            continue
        matches = [token_matches(forms) for _, forms in tiered]
        for d, p, s in _kword_tier_hits(tiered, matches, anchor, window,
                                        doc_of, pos_of, T):
            prev = anchor_scores.get((d, p))
            if prev is None or s > prev:
                anchor_scores[(d, p)] = s
        docs = None
        for (t, _forms), m in zip(tiered, matches):
            if t == TIER_STOP:
                continue
            dset = set(np.unique(doc_of[m]).tolist())
            docs = dset if docs is None else (docs & dset)
        if docs:
            doc_level_all |= docs
    scale = float(ranking.proximity_scale)
    anchor_scores = {k: v * scale for k, v in anchor_scores.items()}
    doc_scores: dict = {}
    for (d, _p), s in anchor_scores.items():
        doc_scores[d] = doc_scores.get(d, 0.0) + s
    return anchor_scores, doc_scores, doc_level_all

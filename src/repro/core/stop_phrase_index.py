"""Stop-phrase index: all phrases of MinLength..MaxLength consecutive stop
words, keyed by the *sorted* list of stop basic-form ids (paper: SEARCH
INDEXES FOR PHRASES CONSISTING OF STOP WORDS).

The paper keys a B-tree with a Huffman-coded sorted id list; our TPU-native
adaptation packs the sorted list into a fixed-width int64 (10 bits per stop
id, 3-bit length tag) and binary-searches a sorted key array — branch-free
and batchable (DESIGN.md §2).  One logical index per length L is stored; all
lengths share one CSR since the length tag is part of the key.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.postings import CSR, PackedPostings, pack_stop_phrase_key


@dataclasses.dataclass
class StopPhraseIndex:
    phrases: CSR          # key = packed sorted stop ids; columns: doc, pos (phrase start)
    min_len: int
    max_len: int
    # device representation: bit-packed (doc, pos) block store
    packed: PackedPostings | None = None

    def nbytes(self) -> int:
        return self.phrases.nbytes()

    def packed_nbytes(self) -> int:
        return self.packed.nbytes() if self.packed is not None else 0

    def find(self, stop_local_ids) -> tuple[int, int]:
        """Slice for a phrase given its stop *local* ids (any order)."""
        ids = np.sort(np.asarray(stop_local_ids, dtype=np.int64))
        if not (self.min_len <= len(ids) <= self.max_len):
            return (0, 0)
        key = int(pack_stop_phrase_key(ids[None, :])[0])
        return self.phrases.find(key)

    def lookup(self, stop_local_ids):
        s, e = self.find(stop_local_ids)
        return {k: c[s:e] for k, c in self.phrases.columns.items()}

"""Core: the paper's additional-index phrase-search system.

Public search surface: build a `SearchRequest`, hand it to an engine's
`search` / `search_batch` (or the serve tier), read the `SearchResponse`
(ranked `DocHit`s when `rank=True`) — see core/api.py.
"""
from repro.core.analyzer import Analyzer, make_lexicon_and_analyzer
from repro.core.api import (DocHit, RankingParams, SearchRequest,
                            SearchResponse)
from repro.core.batch_executor import BatchDeviceIndex, BatchExecutor
from repro.core.builder import (IndexParams, IndexSet, auto_docs_per_shard,
                                build_all, build_multi_key_index)
from repro.core.corpus import Corpus, CorpusConfig, generate_corpus
from repro.core.engine import (AdditionalIndexEngine, OrdinaryEngine,
                               brute_force_kword, brute_force_kword_ranked,
                               brute_force_ranked, brute_force_search,
                               near_query_contains_stop,
                               near_query_stop_confined)
from repro.core.kword import MODE_KWORD
from repro.core.executor import DeviceIndex, Executor, SearchResult
from repro.core.lexicon import (Lexicon, LexiconConfig, TIER_FREQUENT,
                                TIER_ORDINARY, TIER_STOP)
from repro.core.multi_key_index import MultiKeyIndex
from repro.core.planner import (MODE_NEAR, MODE_PHRASE, Planner, QTYPE_MULTI,
                                QueryPlan)
# segments last: it builds on builder/corpus/planner above (its serve-side
# imports are lazy, inside methods — no core -> serve import cycle)
from repro.core.segments import (IndexSegment, SegmentManager, concat_corpora,
                                 corpus_batches)

__all__ = [
    "Analyzer", "make_lexicon_and_analyzer",
    "DocHit", "RankingParams", "SearchRequest", "SearchResponse",
    "BatchDeviceIndex", "BatchExecutor",
    "IndexParams", "IndexSet", "auto_docs_per_shard", "build_all",
    "build_multi_key_index", "MultiKeyIndex",
    "Corpus", "CorpusConfig", "generate_corpus",
    "AdditionalIndexEngine", "OrdinaryEngine", "brute_force_kword",
    "brute_force_kword_ranked", "brute_force_ranked",
    "brute_force_search", "near_query_contains_stop",
    "near_query_stop_confined",
    "DeviceIndex", "Executor", "SearchResult",
    "Lexicon", "LexiconConfig", "TIER_FREQUENT", "TIER_ORDINARY", "TIER_STOP",
    "MODE_KWORD", "MODE_NEAR", "MODE_PHRASE", "Planner", "QTYPE_MULTI",
    "QueryPlan",
    "IndexSegment", "SegmentManager", "concat_corpora", "corpus_batches",
]

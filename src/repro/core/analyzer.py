"""Simulated morphological analyzer: surface word -> list of basic forms.

The paper's analyzer (Russian dictionary, ~200k basic forms) maps each surface
word form to one or more basic-form numbers; e.g. <rose> -> {rise, rose}.  The
dictionary is unavailable, so we synthesize a deterministic analyzer with the
properties the algorithms actually depend on:

  * every surface form has >= 1 basic form;
  * a configurable fraction has a second basic form;
  * second forms may land in a *different* frequency tier, exercising the
    paper's query-splitting rule (PROCESSING QUERIES section);
  * basic-form frequency ranks follow the surface Zipf ranks, so tier
    membership (stop / frequent / ordinary) is realistic.

If a word is absent from the dictionary the paper treats the word itself as
its basic form — here every surface id maps onto the basic-form range, so the
fallback is implicit.

Layout is CSR so that both host (numpy) and device (jnp) sides can consume it.
"""
from __future__ import annotations

import numpy as np

from repro.core.lexicon import Lexicon, LexiconConfig


class Analyzer:
    """CSR map surface-id -> basic-form ids.

    Attributes
    ----------
    form_offsets : [n_surface + 1] int64
    form_ids     : [total_forms] int32  (basic-form ids)
    """

    def __init__(self, config: LexiconConfig):
        self.config = config
        rng = np.random.default_rng(config.seed + 0xA11A)
        n_s, n_b = config.n_surface, config.n_base

        # Primary basic form: monotone surjection surface-rank -> base-rank,
        # preserving Zipf ordering (surface 0 = most frequent maps to base 0).
        primary = (np.arange(n_s, dtype=np.int64) * n_b // n_s).astype(np.int32)

        # Secondary basic form for a random subset ("rose" -> {rise, rose}).
        has_second = rng.random(n_s) < config.multi_form_frac
        # Log-uniform rank so second forms span all tiers (incl. stop forms --
        # needed to exercise query splitting).
        log_rank = rng.uniform(0.0, np.log(n_b), size=n_s)
        secondary = np.exp(log_rank).astype(np.int32) % n_b
        has_second &= secondary != primary

        counts = 1 + has_second.astype(np.int64)
        self.form_offsets = np.zeros(n_s + 1, dtype=np.int64)
        np.cumsum(counts, out=self.form_offsets[1:])
        self.form_ids = np.empty(self.form_offsets[-1], dtype=np.int32)
        self.form_ids[self.form_offsets[:-1]] = primary
        self.form_ids[self.form_offsets[1:][has_second] - 1] = secondary[has_second]

        self._primary = primary
        self._secondary = np.where(has_second, secondary, -1).astype(np.int32)

    # -- vectorized accessors -------------------------------------------------
    @property
    def primary(self) -> np.ndarray:
        """[n_surface] int32 primary basic form."""
        return self._primary

    @property
    def secondary(self) -> np.ndarray:
        """[n_surface] int32 second basic form or -1."""
        return self._secondary

    def forms_of(self, surface_id: int) -> list[int]:
        lo, hi = self.form_offsets[surface_id], self.form_offsets[surface_id + 1]
        return self.form_ids[lo:hi].tolist()

    def forms_batch(self, surface_ids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Dense [N, 2] int32 forms (-1 pad) + [N] counts, vectorized."""
        prim = self._primary[surface_ids]
        sec = self._secondary[surface_ids]
        out = np.stack([prim, sec], axis=-1).astype(np.int32)
        return out, 1 + (sec >= 0).astype(np.int32)


def make_lexicon_and_analyzer(config: LexiconConfig) -> tuple[Lexicon, Analyzer]:
    return Lexicon(config), Analyzer(config)

"""K-word proximity search over multi-component keys (arXiv:2009.02684).

The improved K-word proximity algorithm asks: find documents (and anchor
occurrences) where ALL K query words fall inside one (window + 1)-wide
position span — any order, any mix of stop / frequent / ordinary forms.
The additional indexes of arXiv:1801.09079 / 1812.07640 make the stop-heavy
case cheap: the planner (`Planner._plan_kword`, QTYPE_KWORD) decomposes the
query into a minimal multi-component-key *cover* — a (s1, s2, anchor)
triple as the anchor seed filter when one is admissible, (s, anchor) pairs
for the remaining stop slots, expanded pairs for frequent slots, ordinary /
basic postings as the last resort — every choice by occ-count cost, so the
plan reads measurably fewer postings than a Sphinx-style full-list plan.

Join semantics
--------------
An anchor occurrence p matches iff there is one occurrence per remaining
slot, in p's document, such that max(positions incl. p) - min <= window.
Equivalently: some window start t in [-W, 0] (relative to p) has every
slot's candidate set intersect [p + t, p + t + W].  Both executors decide
that with per-slot *delta masks* — bit (d + W) set iff the slot has a
candidate at signed offset d from p — then AND the per-slot window scans
(`t_bits`) over all slots:

  * device: `ops.banded_delta_mask_rows` + `ops.delta_mask_t_bits`
    (int32 lanes => W <= KW_DEVICE_MAX_WINDOW; wider windows ride the flex
    escape exactly like cap-overflowing plans);
  * flex (this module): the same math in host numpy int64
    (W <= KW_FLEX_MAX_WINDOW).

The ranked path reuses the banded min-delta score accumulation
(arXiv:2108.00410): every constraint group's score contribution is the
in-band minimum key distance, accumulated in the canonical float32 order;
only the *found* bit is overridden by the span join — a span match implies
an in-band hit for every group, so scores of surviving anchors are
bit-identical to the near-mode accumulation the executors already share.
"""
from __future__ import annotations

import numpy as np

from repro.core.lexicon import TIER_ORDINARY, TIER_STOP

MODE_KWORD = "kword"

# Device (batched / serve) kword window cap: the delta mask keeps bit
# (d + W) <= 30 inside an int32 lane (kernels/ops._KW_MAX_BAND).  Wider
# windows are valid requests and route to the flexible executor, whose
# int64 host masks reach KW_FLEX_MAX_WINDOW.
KW_DEVICE_MAX_WINDOW = 15
KW_FLEX_MAX_WINDOW = 31


def pick_kword_anchor(tiered, occ_counts) -> int:
    """The rarest non-stop slot (ordinary preferred) — same statistic as the
    near-mode pivot rule, on the same CLUSTER-GLOBAL counts, so doc-sharded
    deployments anchor every shard identically (the bit-identity
    precondition).  tiered: [(tier, [forms]), ...] per slot."""
    ordinary = [i for i, (t, _) in enumerate(tiered) if t == TIER_ORDINARY]
    eligible = ordinary or [i for i, (t, _) in enumerate(tiered)
                            if t != TIER_STOP]
    if not eligible:
        return -1                    # all-stop tier combination: no anchor
    return min(eligible,
               key=lambda i: sum(int(occ_counts[f]) for f in tiered[i][1]))


# ---------------------------------------------------------------------------
# flex-path span join (host numpy, int64 masks)
# ---------------------------------------------------------------------------

def kword_delta_mask(a: np.ndarray, b_sorted: np.ndarray,
                     window: int) -> np.ndarray:
    """int64 delta mask per anchor key: bit (d + window) set iff `b_sorted`
    holds a + d, for each signed d in [-window, window].  Anchor and
    candidate keys share the global (doc << POS_BITS | pos) codec, so key
    arithmetic IS position arithmetic inside one document (the PHRASE_BIAS
    headroom guarantees d never borrows across the doc boundary)."""
    a = np.asarray(a, np.int64)
    b = np.asarray(b_sorted, np.int64)
    mask = np.zeros(a.shape, np.int64)
    for d in range(-window, window + 1):
        lo = np.searchsorted(b, a + d, side="left")
        hi = np.searchsorted(b, a + d, side="right")
        mask |= np.where(hi > lo, np.int64(1) << (d + window), np.int64(0))
    return mask


def kword_t_bits(mask: np.ndarray, window: int) -> np.ndarray:
    """Window scan of one slot's delta mask: bit t set iff the slot has a
    candidate inside the window starting at offset t - window from the
    anchor (t in [0, window]).  The K-way combine is a plain AND."""
    low = (np.int64(1) << (window + 1)) - 1
    bits = np.zeros_like(mask)
    for t in range(window + 1):
        bits |= np.where((mask >> t) & low != 0,
                         np.int64(1) << t, np.int64(0))
    return bits


def kword_span_ok(a: np.ndarray, group_keys: list, window: int) -> np.ndarray:
    """bool per anchor key: every group in `group_keys` (sorted int64 key
    arrays, sentinel-padded) has a candidate inside one shared
    (window + 1)-wide span containing the anchor — the flexible executor's
    K-way windowed join (device twin: ops.kword_window_hits)."""
    t_and = np.full(np.asarray(a).shape, -1, np.int64)
    for b in group_keys:
        t_and &= kword_t_bits(kword_delta_mask(a, b, window), window)
    return t_and != 0

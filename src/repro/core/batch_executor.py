"""Batched plan-compiled execution: a whole query batch in one jit'd call.

The flexible `Executor` (executor.py) walks plans in Python — one device
dispatch per fetch group, one host↔device round-trip per query.  That is
correct but leaves the paper's order-of-magnitude win on the table at serving
time.  This module makes batched search the first-class engine path:

1. **Tensorize** — every supported subplan of every query in the batch
   becomes one *task* row of fixed-shape fetch tables (schema in
   core/fetch_tables.py): `start/length/offset/req_dist/max_abs :
   [T, G, F]`, `band/active : [T, G]`, near-stop checks `[T, C, M]`.
   Group 0 is the seed (the near-stop-checked pivot when present, else the
   smallest band-0 group — the same seed rule as the flexible executor);
   groups 1..G-1 constrain it.  F fetch slots per group carry unions over
   morphological forms / expanded orientations / stop-phrase parts.

2. **Execute** — one jit'd call per shape bucket: gather from a unified
   posting arena (basic | expanded | stop | first | ordinary concatenated,
   so a fetch is a single dynamic-slice) → global 63-bit key construction →
   per-doc-shard **int32 re-basing** (`(doc - shard_base) << 17 | pos'`, the
   re-basing intersect.py's docstring promises: TPU vector units have no
   int64 lanes) → k-way banded intersection via `ops.banded_intersect_rows`
   (Pallas kernel with per-row dynamic bands, or the `searchsorted` ref path)
   → OR of per-shard hits.  Near-stop (type 4) checks mask the seed's keys
   in the same call.

3. **Merge** — host-side, mirroring `Executor.execute` exactly: subplan
   results are unioned per query; a subplan with no positional hits falls
   back to its distance-disregarding doc-only task (paper step 3), with
   fallback postings counted only when triggered.

Shape discipline: tasks are bucketed by (G, F, P, C, M) with `_next_pow2`
padding on every axis and chunked to a gather budget, so the jit compile
cache stays small while padding waste stays bounded.  Queries that exceed
the table caps (very long unions, > G_CAP groups, giant posting lists) or an
index whose positions overflow the 17-bit packed domain fall back to the
flexible executor per plan — identical results, just not batched.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.builder import IndexSet
from repro.core.executor import (SENTINEL, Executor, SearchResult,
                                 _next_pow2, merge_subplan_keys)
from repro.core.fetch_tables import (DOCS_PER_SHARD, NO_DIST, TABLE_POS_BITS,
                                     alloc_batch_tables, pack_ns_checks)
from repro.core.planner import MODE_PHRASE, QueryPlan
from repro.core.postings import PHRASE_BIAS, POS_BITS
from repro.kernels.ops import I32_SENTINEL, banded_intersect_rows

# table caps: a task exceeding these routes its whole plan to the flexible
# executor (rare: >8 AND-groups or >8 unioned fetches per slot)
G_CAP = 8
F_CAP = 8
P_CAP = 1 << 15
P_FLOOR = 256
GATHER_BUDGET = 1 << 23        # max T*G*F*P elements per jit'd gather


class BatchDeviceIndex:
    """All five posting streams concatenated into one device arena."""

    def __init__(self, index: IndexSet):
        b = index.basic.occurrences
        e = index.expanded.pairs
        s = index.stop_phrase.phrases
        f = index.basic.first_occ
        o = index.ordinary

        docs, poss, dists = [], [], []
        self.bases = {}
        off = 0
        for name, doc, pos, dist in (
                ("basic", b.columns["doc"], b.columns["pos"], None),
                ("expanded", e.columns["doc"], e.columns["pos"], e.columns["dist"]),
                ("stop", s.columns["doc"], s.columns["pos"], None),
                ("first", f.columns["doc"], f.columns["pos"], None),
                ("ordinary", o.columns["doc"], o.columns["pos"], None)):
            self.bases[name] = off
            off += len(doc)
            docs.append(np.asarray(doc, np.int32))
            poss.append(np.asarray(pos, np.int32))
            dists.append(np.asarray(dist, np.int8) if dist is not None
                         else np.zeros(len(doc), np.int8))
        self.arena_doc = jnp.asarray(np.concatenate(docs))
        self.arena_pos = jnp.asarray(np.concatenate(poss))
        self.arena_dist = jnp.asarray(np.concatenate(dists))
        self.near_stop = jnp.asarray(np.asarray(index.basic.near_stop, np.int16))
        self.max_distance = int(index.basic.max_distance)
        self.n_docs = int(max((int(d.max()) + 1 for d in docs if len(d)),
                              default=0))
        self.max_pos = int(max((int(p.max()) for p in poss if len(p)),
                               default=0))
        self.n_shards = max(1, -(-self.n_docs // DOCS_PER_SHARD))


@dataclasses.dataclass
class _Task:
    plan_i: int            # which plan in the batch
    subplan_i: int
    fallback: bool         # doc-only fallback task (stream-1)
    groups: list           # seed-first ordered FetchGroups
    stop_checks: tuple     # seed group's near-stop checks
    mode: str = MODE_PHRASE
    sortfree: bool = False  # constraint keys already ascending (see below)
    # filled after execution:
    keys: np.ndarray | None = None


@dataclasses.dataclass
class _Bucket:
    G: int
    F: int
    P0: int                # seed pad (rarest list)
    P: int                 # constraint-group pad
    C: int
    M: int
    sortfree: bool
    tasks: list = dataclasses.field(default_factory=list)


@partial(jax.jit, static_argnames=("P0", "P", "n_shards", "impl", "interpret",
                                   "presorted"))
def _batch_step(arena_doc, arena_pos, arena_dist, near_stop, t, *,
                P0: int, P: int, n_shards: int, impl: str, interpret: bool,
                presorted: bool = False):
    """One shape bucket, one call: gather → keys → per-shard int32 rebase →
    banded rows intersection.  The seed (group 0) gets its own pad P0 —
    the planner seeds with the RAREST list, so the membership probe side
    stays narrow while constraint groups pad to P.  Returns (seed global
    keys [T, F*P0] int64, found [T, F*P0] bool)."""
    T, G, F = t["start"].shape
    A = arena_doc.shape[0]
    dt1 = t["doc_task"]

    def gather(sl, Pw):
        """Keys for group slice `sl` padded to Pw: [T, g, F, Pw]."""
        start, length = t["start"][:, sl], t["length"][:, sl]
        offset, req = t["offset"][:, sl], t["req_dist"][:, sl]
        maxab, pfd = t["max_abs"][:, sl], t["pivot_from_dist"][:, sl]
        iota = jnp.arange(Pw, dtype=jnp.int32)
        idx = jnp.clip(start[..., None] + iota, 0, A - 1)
        valid = iota < length[..., None]
        doc = arena_doc[idx]
        pos = arena_pos[idx]
        dist = arena_dist[idx].astype(jnp.int32)
        valid &= (req[..., None] == NO_DIST) | (dist == req[..., None])
        valid &= jnp.abs(dist) <= maxab[..., None]
        valid &= t["active"][:, sl, None, None]
        # global 63-bit keys (identical to the flexible executor's packing)
        pos_eff = pos + jnp.where(pfd[..., None], dist, 0)
        low = pos_eff.astype(jnp.int64) - offset[..., None] + PHRASE_BIAS
        doc64 = doc.astype(jnp.int64)
        gk = jnp.where(dt1[:, None, None, None], doc64,
                       (doc64 << POS_BITS) | low)
        return idx, jnp.where(valid, gk, SENTINEL)

    idx0, gk0 = gather(slice(0, 1), P0)
    gk0 = gk0[:, 0]                                            # [T, F, P0]
    _, gkc = gather(slice(1, None), P)                         # [T, G-1, F, P]

    # near-stop verification on the seed group (type-4 pivot checks)
    C = t["ns_packed"].shape[1]
    if C > 0:
        nb = near_stop.shape[0]
        ns = near_stop[jnp.clip(idx0[:, 0], 0, nb - 1)]        # [T, F, P0, K]
        ok = jnp.ones((T, F, P0), bool)
        Mns = t["ns_packed"].shape[2]
        for c in range(C):
            hit_c = jnp.zeros((T, F, P0), bool)
            for m in range(Mns):
                tgt = t["ns_packed"][:, c, m][:, None, None, None]
                val = t["ns_valid"][:, c, m][:, None, None]
                hit_c |= (ns == tgt).any(axis=-1) & val
            has_check = t["ns_valid"][:, c].any(axis=-1)[:, None, None]
            ok &= hit_c | ~has_check
        gk0 = jnp.where(ok, gk0, SENTINEL)

    m26 = (1 << POS_BITS) - 1

    def rebase(gk, dt_b, s):
        """Per-doc-shard int32 re-basing (doc-only keys ARE doc ids and are
        resolved on shard 0 only)."""
        base = s * DOCS_PER_SHARD
        dglob = jnp.where(dt_b, gk, gk >> POS_BITS)
        in_shard = (dglob >= base) & (dglob < base + DOCS_PER_SHARD) \
            & (gk < SENTINEL)
        if s > 0:
            in_shard &= ~dt_b
        else:
            in_shard = jnp.where(dt_b, gk < SENTINEL, in_shard)
        k32 = jnp.where(dt_b, gk, ((dglob - base) << TABLE_POS_BITS) | (gk & m26))
        return jnp.where(in_shard, k32, I32_SENTINEL).astype(jnp.int32)

    a64 = gk0.reshape(T, F * P0)
    found = jnp.zeros((T, F * P0), bool)
    for s in range(n_shards):
        a32 = rebase(gk0, dt1[:, None, None], s).reshape(T, F * P0)
        if G > 1:
            b32 = rebase(gkc, dt1[:, None, None, None], s).reshape(T, G - 1, F * P)
            if not presorted:
                b32 = jnp.sort(b32, axis=-1)
            a_rows = jnp.broadcast_to(a32[:, None], (T, G - 1, F * P0))
            hit = banded_intersect_rows(
                a_rows.reshape(T * (G - 1), F * P0),
                b32.reshape(T * (G - 1), F * P),
                jnp.broadcast_to(t["band"][:, 1:], (T, G - 1)).reshape(-1),
                implementation=impl, interpret=interpret)
            hit = hit.reshape(T, G - 1, F * P0) | ~t["active"][:, 1:, None]
            shard_found = hit.all(axis=1)
        else:
            shard_found = jnp.ones((T, F * P0), bool)
        found |= shard_found & (a32 != I32_SENTINEL)
    return a64, found


class BatchExecutor:
    """Executes a batch of QueryPlans with result parity vs. the flexible
    `Executor` (same doc/pos sets, same postings accounting, same fallback
    semantics), but in O(#shape-buckets) jit dispatches instead of
    O(#queries * #groups)."""

    def __init__(self, index: IndexSet, flex: Executor | None = None,
                 impl: str = "ref", interpret: bool = True):
        self.index = index
        self.dev = BatchDeviceIndex(index)
        self.flex = flex or Executor(index)
        self.impl = impl
        self.interpret = interpret
        # packed-key safety: positions (plus bias and the widest band) must
        # fit the 17-bit in-doc field or cross-doc false positives appear
        self._pos_budget = (1 << TABLE_POS_BITS) - PHRASE_BIAS \
            - self.dev.max_pos - self.dev.max_distance

    # -- tensorization ------------------------------------------------------

    def _task_sortfree(self, ordered) -> bool:
        """True when every constraint group's key row comes out of the
        gather already ascending, so the device sort can be skipped: single
        fetch per non-seed group (multi-fetch unions interleave), no
        dist/pivot masks (holes in the middle break order — the arena is
        (doc, pos)-sorted per fetch slice and the key packings are monotone
        in (doc, pos); invalid-tail sentinels sort last), and a single doc
        shard (out-of-shard masking would also punch mid-row holes)."""
        if self.dev.n_shards != 1:
            return False
        for g in ordered[1:]:
            if len(g.fetches) > 1:
                return False
            for f in g.fetches:
                if (f.required_dist is not None or f.max_abs_dist is not None
                        or f.pivot_from_dist):
                    return False
        return True

    def _order_groups(self, groups):
        """Seed-first ordering; None when no valid seed exists."""
        ns = [g for g in groups
              if any(f.stop_checks for f in g.fetches)]
        if ns:
            seed = ns[0]
        else:
            band0 = [g for g in groups if g.band == 0]
            if not band0:
                return None
            seed = min(band0, key=lambda g: sum(f.length for f in g.fetches))
        return [seed] + [g for g in groups if g is not seed]

    def _task_fits(self, groups) -> bool:
        if len(groups) > G_CAP:
            return False
        for g in groups:
            if len(g.fetches) > F_CAP:
                return False
            if int(g.band) > self._pos_budget:
                return False
            for f in g.fetches:
                if f.length > P_CAP:
                    return False
                if f.stream == "first" and not _is_first_group(g):
                    return False
        return True

    def _build_tasks(self, plan_i: int, plan: QueryPlan, tasks: list) -> bool:
        """Append tasks for one plan; False => route plan to the flexible
        executor (table caps exceeded)."""
        if self._pos_budget <= 0:
            return False
        for sp_i, sp in enumerate(plan.subplans):
            if not sp.supported:
                continue
            main_dead = (not sp.groups) or any(not g.fetches for g in sp.groups)
            if not main_dead:
                ordered = self._order_groups(sp.groups)
                if ordered is None or not self._task_fits(ordered):
                    return False
                checks = ordered[0].fetches[0].stop_checks
                if any(f.stop_checks != checks for f in ordered[0].fetches) or \
                   any(f.stop_checks for g in ordered[1:] for f in g.fetches):
                    return False
                tasks.append(_Task(plan_i, sp_i, False, ordered, checks,
                                   mode=sp.mode,
                                   sortfree=self._task_sortfree(ordered)))
            if sp.fallback_groups:
                fb_dead = any(not g.fetches for g in sp.fallback_groups)
                if not fb_dead:
                    ordered = self._order_groups(sp.fallback_groups)
                    if ordered is None or not self._task_fits(ordered):
                        return False
                    # fallback tasks are validated eagerly (the flex-routing
                    # decision must not depend on results) but executed
                    # lazily: only when the main task comes back empty
                    tasks.append(_Task(plan_i, sp_i, True, ordered, (),
                                       mode=MODE_PHRASE,
                                       sortfree=self._task_sortfree(ordered)))
        return True

    def _bucket_key(self, task: _Task):
        G = max(2, _next_pow2(len(task.groups), floor=2))
        F = _next_pow2(max(len(g.fetches) for g in task.groups), floor=1)
        P0 = _next_pow2(max((f.length for f in task.groups[0].fetches),
                            default=1), floor=P_FLOOR)
        P = _next_pow2(max((f.length for g in task.groups[1:]
                            for f in g.fetches), default=1), floor=P_FLOOR)
        # near-stop slots are padded to coarse buckets (invalid slots are
        # inert) so check-count variation doesn't multiply compile shapes
        if task.stop_checks:
            C = _next_pow2(len(task.stop_checks), floor=4)
            M = _next_pow2(max(len(ids) for _, ids in task.stop_checks), floor=2)
        else:
            C = M = 0
        # only big slabs are worth a separate sort-free compile shape; for
        # small P the sort is cheap and splitting buckets costs more calls
        sortfree = task.sortfree and P >= 2048
        return (G, F, min(P0, P_CAP), min(P, P_CAP), C, M, sortfree)

    def _tensorize_bucket(self, bucket: _Bucket, T_pad: int) -> dict:
        t = alloc_batch_tables(T_pad, bucket.G, bucket.F, bucket.C, bucket.M)
        bases = self.dev.bases
        for ti, task in enumerate(bucket.tasks):
            t["doc_task"][ti] = task.fallback
            if task.stop_checks:
                pack_ns_checks(t, ti, task.stop_checks, self.dev.max_distance)
            for gi, g in enumerate(task.groups):
                t["band"][ti, gi] = g.band
                t["active"][ti, gi] = True
                for fi, f in enumerate(g.fetches):
                    t["start"][ti, gi, fi] = f.start + bases[f.stream]
                    t["length"][ti, gi, fi] = f.length
                    # mirror Executor._fetch_keys key selection
                    if f.stream == "first":
                        continue                        # doc key: no offset
                    phrase_keyed = (
                        f.stream == "stop"
                        or (f.stream == "expanded" and f.required_dist is not None)
                        or (f.stream in ("basic", "ordinary")
                            and task.mode == MODE_PHRASE))
                    if phrase_keyed:
                        t["offset"][ti, gi, fi] = f.offset
                    if f.required_dist is not None:
                        t["req_dist"][ti, gi, fi] = f.required_dist
                    if f.max_abs_dist is not None:
                        t["max_abs"][ti, gi, fi] = f.max_abs_dist
                    t["pivot_from_dist"][ti, gi, fi] = bool(f.pivot_from_dist)
        return t

    # -- execution ----------------------------------------------------------

    def _run_tasks(self, tasks: list):
        buckets: dict = {}
        for task in tasks:
            key = self._bucket_key(task)
            b = buckets.setdefault(key, _Bucket(G=key[0], F=key[1], P0=key[2],
                                                P=key[3], C=key[4], M=key[5],
                                                sortfree=key[6]))
            b.tasks.append(task)
        d = self.dev
        for (G, F, P0, P, C, M, sortfree), b in buckets.items():
            per_task = F * P0 + (G - 1) * F * P
            if C > 0:                  # near-stop gather adds an [F, P0, K] slab
                per_task += F * P0 * int(d.near_stop.shape[1])
            chunk = max(1, GATHER_BUDGET // per_task)
            for lo in range(0, len(b.tasks), chunk):
                part = b.tasks[lo:lo + chunk]
                # tight T padding: big-P buckets usually hold 1-4 tasks, and
                # padding them to a large T multiplies the gather/sort slab;
                # the extra pow2 compile variants are absorbed by warm-up
                T_pad = _next_pow2(len(part), floor=4)
                t = self._tensorize_bucket(
                    dataclasses.replace(b, tasks=part), T_pad)
                tj = {k: jnp.asarray(v) for k, v in t.items()}
                a64, found = _batch_step(
                    d.arena_doc, d.arena_pos, d.arena_dist, d.near_stop, tj,
                    P0=P0, P=P, n_shards=d.n_shards, impl=self.impl,
                    interpret=self.interpret, presorted=sortfree)
                a64 = np.asarray(a64)
                found = np.asarray(found)
                # one pass over the hit mask instead of T boolean-indexings
                rows, cols = np.nonzero(found)
                keys = a64[rows, cols]
                splits = np.searchsorted(rows, np.arange(1, len(part)))
                for ti, task_keys in enumerate(np.split(keys, splits)):
                    part[ti].keys = task_keys

    # -- merge (mirrors Executor.execute) -----------------------------------

    def _merge_plan(self, plan: QueryPlan, task_map: dict,
                    max_results: int | None) -> SearchResult:
        all_keys, doc_only_keys = [], []
        postings = 0
        used_fallback = False
        types = []
        for sp_i, sp in enumerate(plan.subplans):
            if not sp.supported:
                continue
            types.append(sp.qtype)
            postings += sp.postings_read
            main = task_map.get((sp_i, False))
            keys = main.keys if main is not None else np.empty(0, np.int64)
            if len(keys) == 0 and sp.fallback_groups:
                used_fallback = True
                postings += sum(g.postings_read for g in sp.fallback_groups)
                fb = task_map.get((sp_i, True))
                dkeys = fb.keys if fb is not None else np.empty(0, np.int64)
                doc_only_keys.append(dkeys)
            else:
                all_keys.append(keys)
        return merge_subplan_keys(all_keys, doc_only_keys, postings,
                                  used_fallback, tuple(types), max_results)

    # -- public API ---------------------------------------------------------

    def execute_batch(self, plans: list[QueryPlan],
                      max_results: int | None = None) -> list[SearchResult]:
        tasks: list[_Task] = []
        flex_plans: dict[int, QueryPlan] = {}
        plan_tasks: dict[int, list] = {}
        for i, plan in enumerate(plans):
            start = len(tasks)
            if self._build_tasks(i, plan, tasks):
                plan_tasks[i] = tasks[start:]
            else:
                del tasks[start:]
                flex_plans[i] = plan
        # round 1: main tasks; round 2: only the fallback tasks whose main
        # result came back empty (mirrors the flexible executor, which never
        # touches stream 1 when the positional search hits)
        self._run_tasks([t for t in tasks if not t.fallback])
        main_keys = {(t.plan_i, t.subplan_i): t.keys
                     for t in tasks if not t.fallback}
        needed = [t for t in tasks if t.fallback
                  and len(main_keys.get((t.plan_i, t.subplan_i),
                                        np.empty(0))) == 0]
        self._run_tasks(needed)
        out: list[SearchResult | None] = [None] * len(plans)
        for i, plan in enumerate(plans):
            if i in flex_plans:
                out[i] = self.flex.execute(plan, max_results=max_results)
            else:
                task_map = {(t.subplan_i, t.fallback): t for t in plan_tasks[i]}
                out[i] = self._merge_plan(plan, task_map, max_results)
        return out


def _is_first_group(g) -> bool:
    return all(f.stream == "first" for f in g.fetches)

"""Batched plan-compiled execution: a whole query batch in one jit'd call.

The flexible `Executor` (executor.py) walks plans in Python — one device
dispatch per fetch group, one host↔device round-trip per query.  That is
correct but leaves the paper's order-of-magnitude win on the table at serving
time.  This module makes batched search the first-class engine path — and it
is the SINGLE execution engine: the distributed serve tier
(serve/search_serve.py) consumes the same tables and the same bucket math
under shard_map.

1. **Tensorize + segment** — every supported subplan of every query becomes
   one or more *rows* of fixed-shape fetch tables (schema in
   core/fetch_tables.py): `start/length/offset/req_dist/max_abs : [T, G, F]`,
   `band/active : [T, G]`, `shard_base : [T]`, near-stop checks `[T, C, M]`.
   Group 0 is the seed (the near-stop-checked pivot when present, else the
   smallest band-0 group — the same seed rule as the flexible executor);
   groups 1..G-1 constrain it.  F fetch slots per group carry unions over
   morphological forms / expanded orientations / stop-phrase parts.

   *Shard-segmented gather*: posting slices are split host-side at doc-shard
   boundaries (the arena is (doc, pos)-sorted per fetch, so a shard's rows
   are one `searchsorted` away), one row per (task, doc shard) — so each row
   gathers and intersects only its own shard's postings and the whole batch
   does O(arena) work total, instead of re-basing and re-sorting the full
   slab once per shard.  Posting lists longer than P_CAP are split across
   additional F slots of the same group (a union — exactly the semantics F
   already has), which lifts the old 32k-postings-per-fetch cap.

2. **Execute** — one jit'd call per shape bucket: gather from a unified
   posting arena (basic | expanded | stop | first | ordinary | multi
   concatenated block-aligned, so a fetch is a single dynamic-slice of
   posting ordinals) → vectorized unpack of the bit-packed block store
   (core/postings.PackedPostings lanes + per-block anchor/width metadata,
   ops.unpack_postings — ref math or the Pallas unpack kernel) → global
   63-bit key construction → per-row int32 re-basing against the row's
   `shard_base` (`(doc - base) << 17 | pos'` — TPU vector units have no
   int64 lanes) → k-way banded intersection via `ops.banded_intersect_rows`
   (Pallas kernel with per-row dynamic bands, or the `searchsorted` ref
   path).  Near-stop (type 4) checks mask the seed's keys in the same call.

3. **Merge** — host-side, mirroring `Executor.execute` exactly: row keys are
   unioned per task, task results per query; a subplan with no positional
   hits falls back to its distance-disregarding doc-only task (paper step 3),
   with fallback postings counted only when triggered.

Shape discipline: rows are bucketed by (G, F, P, C, M) with `_next_pow2`
padding on every axis and chunked to a gather budget, so the jit compile
cache stays small while padding waste stays bounded.  Queries that exceed
the table caps (> G_CAP groups, > F_CAP unioned forms, splits overflowing
F_SPLIT_CAP slots) or an index whose positions overflow the 17-bit packed
domain fall back to the flexible executor per plan — identical results,
just not batched.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import SearchRequest
from repro.core.builder import IndexSet
from repro.core.executor import (SENTINEL, Executor, SearchResult,
                                 _next_pow2, merge_subplan_results,
                                 order_groups_seed_first, proximity_w,
                                 scored_probe)
from repro.core.fetch_tables import (DOCS_PER_SHARD, NO_DIST,
                                     SCORE_DELTA_BITS, TABLE_POS_BITS,
                                     alloc_batch_tables, pack_ns_checks)
from repro.core.kword import KW_DEVICE_MAX_WINDOW, MODE_KWORD
from repro.core.planner import MODE_PHRASE, QueryPlan
from repro.core.postings import (BLOCK, PHRASE_BIAS, POS_BITS, concat_packed,
                                 pad_block_multiple)
from repro.kernels.ops import (I32_SENTINEL, banded_delta_mask_rows,
                               banded_intersect_rows, banded_min_delta_rows,
                               kword_window_hits, unpack_postings)

# table caps: a task exceeding these routes its whole plan to the flexible
# executor (rare: >8 AND-groups or >8 unioned form fetches per slot).
# Fetches longer than P_CAP no longer escape: they are split across extra
# F slots (up to F_SPLIT_CAP per group) by the segmented-gather tensorizer.
G_CAP = 8
F_CAP = 8
F_SPLIT_CAP = 64
P_CAP = 1 << 15
P_FLOOR = 128
GATHER_BUDGET = 1 << 23        # max T*G*F*P elements per jit'd gather


def ensure_packed_streams(index: IndexSet) -> dict:
    """The six per-stream packed stores, packing any the builder didn't
    (hand-assembled IndexSets in tests).  "multi" is the pairs-then-triples
    concatenation, matching MultiKeyIndex.arena_columns ordinals."""
    from repro.core.builder import _pack_stream
    b, mk = index.basic, index.multi_key
    if index.ordinary_packed is None:
        index.ordinary_packed = _pack_stream(index.ordinary)
    if b.packed_occ is None:
        b.packed_occ = _pack_stream(b.occurrences)
        b.packed_first = _pack_stream(b.first_occ)
    if index.expanded.packed is None:
        index.expanded.packed = _pack_stream(index.expanded.pairs)
    if index.stop_phrase.packed is None:
        index.stop_phrase.packed = _pack_stream(index.stop_phrase.phrases)
    if mk.packed_pairs is None:
        mk.packed_pairs = _pack_stream(mk.pairs)
        mk.packed_triples = _pack_stream(mk.triples)
    return {
        "basic": b.packed_occ,
        "expanded": index.expanded.packed,
        "stop": index.stop_phrase.packed,
        "first": b.packed_first,
        "ordinary": index.ordinary_packed,
        "multi": concat_packed([mk.packed_pairs, mk.packed_triples]),
    }


class BatchDeviceIndex:
    """All six posting streams concatenated into one device arena — since
    the packed-store refactor, a bit-packed block arena: `lanes` (int32
    packed deltas) plus the `blk_meta` [NB, 5] per-block metadata matrix
    (base lane word, packed widths, per-field anchors), decoded on device
    by ops.unpack_postings.  Each
    stream is padded to a BLOCK multiple so stream bases stay block-aligned;
    the raw `arena_*_np` columns are kept host-side only (shard segmentation
    + serve bucketing + build stats) and never shipped.

    `docs_per_shard` sets the doc-shard granularity of the segmented gather
    (≤ fetch_tables.DOCS_PER_SHARD so packed int32 keys can't overflow);
    smaller shards only add rows, never change results.

    `doc_base` is the index's first GLOBAL doc id (0 for a standalone
    index).  A segment built from a corpus slice (core/segments.py) stores
    LOCAL doc ids in its arenas, but its execution rows are laid on the
    GLOBAL shard grid: row shard ids are global, and each row's
    `shard_base` is the local re-basing origin `shard*dps - doc_base` (may
    be negative), so the rebased int32 keys stay in [0, dps) exactly as for
    an unsegmented index.  Output keys are unaffected (still local doc
    ids); only the row cuts move — and smaller/shifted shards never change
    results.
    """

    def __init__(self, index: IndexSet, docs_per_shard: int | None = None,
                 doc_base: int = 0):
        packed = ensure_packed_streams(index)
        b = index.basic.occurrences
        e = index.expanded.pairs
        s = index.stop_phrase.phrases
        f = index.basic.first_occ
        m = index.multi_key.arena_columns()
        o = index.ordinary

        docs, poss, dists, reals = [], [], [], []
        self.bases = {}
        off = 0
        for name, doc, pos, dist in (
                ("basic", b.columns["doc"], b.columns["pos"], None),
                ("expanded", e.columns["doc"], e.columns["pos"], e.columns["dist"]),
                ("stop", s.columns["doc"], s.columns["pos"], None),
                ("first", f.columns["doc"], f.columns["pos"], None),
                ("ordinary", o.columns["doc"], o.columns["pos"], None),
                ("multi", m["doc"], m["pos"], m["dist"])):
            self.bases[name] = off
            n_pad = packed[name].n_padded
            assert n_pad >= len(doc)
            off += n_pad
            docs.append(pad_block_multiple(np.asarray(doc, np.int32), n_pad))
            poss.append(pad_block_multiple(np.asarray(pos, np.int32), n_pad))
            dists.append(pad_block_multiple(
                np.asarray(dist, np.int8) if dist is not None
                else np.zeros(len(doc), np.int8), n_pad))
            real = np.zeros(n_pad, bool)
            real[:len(doc)] = True
            reals.append(real)
        self.arena_doc_np = np.concatenate(docs)
        self.arena_pos_np = np.concatenate(poss)
        self.arena_dist_np = np.concatenate(dists)
        # pads (stream tails; incl. the multi stream's internal pair pad)
        # must never enter a serve dp-shard selection
        self.arena_real_np = np.concatenate(reals)
        self.arena_real_np[self.bases["multi"]:
                           self.bases["multi"]
                           + index.multi_key.pair_pad][
            index.multi_key.pairs.n_postings:] = False
        self.packed = concat_packed([packed[n] for n in self.bases])
        self.near_stop_np = np.asarray(index.basic.near_stop, np.int16)
        # device copies are lazy: the serve tier builds per-dp-shard arenas
        # from the numpy columns and must not also hold a full global copy
        # on device
        self._dev_arena = None
        self.max_distance = int(index.basic.max_distance)
        self.n_docs = int(max((int(d.max()) + 1 for d in docs if len(d)),
                              default=0))
        self.max_pos = int(max((int(p.max()) for p in poss if len(p)),
                               default=0))
        # widest |dist| any pivot_from_dist fetch can add to a position
        # (expanded reach / multi-key NeighborDistance) — part of the
        # 17-bit packed-key safety budget
        self.max_shift = int(np.abs(self.arena_dist_np).max(initial=0))
        if docs_per_shard is None:
            # auto-pick the segmentation grain from posting-list stats:
            # smaller per-row sort slabs beat one big slab (ROADMAP
            # shard_scaling) — results are identical at any grain
            from repro.core.builder import auto_docs_per_shard
            docs_per_shard = auto_docs_per_shard(self.n_docs,
                                                 index.max_posting_run())
        self.docs_per_shard = max(1, min(docs_per_shard, DOCS_PER_SHARD))
        # global shard grid: shard ids count from GLOBAL doc 0 so every
        # segment of a growing corpus buckets on the same boundaries
        self.doc_base = int(doc_base)
        self.n_shards = max(1, -(-(self.doc_base + self.n_docs)
                                 // self.docs_per_shard))

    @property
    def device_arena(self) -> dict:
        """The packed block arena + stream-3 slots as device arrays — the
        only index bytes the jit'd step ever touches."""
        if self._dev_arena is None:
            p = self.packed
            self._dev_arena = {
                "lanes": jnp.asarray(p.lanes),
                "blk_meta": jnp.asarray(p.meta_matrix()),
                "near_stop": jnp.asarray(self.near_stop_np),
            }
        return self._dev_arena

    def device_nbytes(self) -> int:
        """Bytes the device arena holds (packed lanes + block metadata +
        stream-3 slots)."""
        return self.packed.nbytes() + self.near_stop_np.nbytes


@dataclasses.dataclass
class _Task:
    """One subplan (or its doc-only fallback): the host-side merge unit."""
    plan_i: int            # which plan in the batch
    subplan_i: int
    fallback: bool         # doc-only fallback task (stream-1)
    stop_checks: tuple     # seed group's near-stop checks
    mode: str = MODE_PHRASE
    ranked: bool = False   # proximity scoring rides the bucket step
    score_bias: float = 0.0   # n_slots - n_groups (see SubPlan.n_slots)
    rows: list = dataclasses.field(default_factory=list)

    def collect_keys(self) -> np.ndarray:
        parts = [r.keys for r in self.rows if r.keys is not None and len(r.keys)]
        return np.concatenate(parts) if parts else np.empty(0, np.int64)

    def collect_scores(self) -> np.ndarray:
        parts = [r.scores for r in self.rows
                 if r.scores is not None and len(r.scores)]
        return np.concatenate(parts) if parts else np.empty(0, np.float32)


@dataclasses.dataclass
class _RowGroup:
    band: int
    slots: list            # [(ResolvedFetch, arena_start, length)] — absolute


@dataclasses.dataclass
class _Row:
    """One (task × doc shard) execution row of the fetch tables."""
    task: _Task
    shard: int             # doc-shard id (0 when unsharded / doc-only)
    shard_base: int        # first doc of the shard (re-basing origin)
    groups: list           # seed-first ordered _RowGroups, shard-clipped
    sortfree: bool = False  # constraint keys already ascending (see below)
    # filled after execution:
    keys: np.ndarray | None = None
    scores: np.ndarray | None = None   # ranked rows only, aligned with keys


def bucket_step_math(arena, t, *,
                     P0: int, P: int, impl: str, interpret: bool,
                     presorted: bool = False, ranked: bool = False,
                     kword: bool = False):
    """One shape bucket of segmented rows: gather packed lanes → vectorized
    unpack (ops.unpack_postings over the bit-packed block arena) → keys →
    per-row int32 rebase against `shard_base` → banded rows intersection.
    The seed (group 0) gets its own pad P0 — the planner seeds with the
    RAREST list, so the membership probe side stays narrow while constraint
    groups pad to P.  Rows are shard-clipped host-side, so there is no
    per-shard device loop and no in-shard masking.  `arena` is the packed
    device dict (BatchDeviceIndex.device_arena: lanes + per-block metadata +
    the raw stream-3 `near_stop` slots).  Returns (seed global keys
    [T, F*P0] int64, found [T, F*P0] bool) — plus proximity scores
    [T, F*P0] float32 when `ranked` (see api.py: bias + w(seed delta) + sum
    over constraint groups of w(banded min key-distance + stored |dist|
    delta), computed in this one fused pass from the postings already
    gathered).  Pure trace function — the engine jit-wraps it
    (`_batch_step`) and the serve tier calls it inside shard_map."""
    T, G, F = t["start"].shape
    near_stop = arena["near_stop"]
    A = arena["blk_meta"].shape[0] * BLOCK
    dt1 = t["doc_task"]
    base = t["shard_base"].astype(jnp.int64)

    def gather(sl, Pw):
        """Keys for group slice `sl` padded to Pw: [T, g, F, Pw] (+ the
        per-posting score delta when ranked)."""
        start, length = t["start"][:, sl], t["length"][:, sl]
        offset, req = t["offset"][:, sl], t["req_dist"][:, sl]
        maxab, pfd = t["max_abs"][:, sl], t["pivot_from_dist"][:, sl]
        iota = jnp.arange(Pw, dtype=jnp.int32)
        idx = jnp.clip(start[..., None] + iota, 0, A - 1)
        valid = iota < length[..., None]
        doc, pos, dist = unpack_postings(arena, idx, implementation=impl,
                                         interpret=interpret)
        valid &= (req[..., None] == NO_DIST) | (dist == req[..., None])
        valid &= jnp.abs(dist) <= maxab[..., None]
        valid &= t["active"][:, sl, None, None]
        # global 63-bit keys (identical to the flexible executor's packing)
        pos_eff = pos + jnp.where(pfd[..., None], dist, 0)
        low = pos_eff.astype(jnp.int64) - offset[..., None] + PHRASE_BIAS
        doc64 = doc.astype(jnp.int64)
        gk = jnp.where(dt1[:, None, None, None], doc64,
                       (doc64 << POS_BITS) | low)
        if not ranked:
            return idx, jnp.where(valid, gk, SENTINEL), None
        sfd = t["score_from_dist"][:, sl]
        delta = jnp.where(sfd[..., None], jnp.abs(dist), 0)
        return idx, jnp.where(valid, gk, SENTINEL), delta

    idx0, gk0, delta0 = gather(slice(0, 1), P0)
    gk0 = gk0[:, 0]                                            # [T, F, P0]

    # near-stop verification on the seed group (type-4 pivot checks)
    C = t["ns_packed"].shape[1]
    if C > 0:
        nb = near_stop.shape[0]
        ns = near_stop[jnp.clip(idx0[:, 0], 0, nb - 1)]        # [T, F, P0, K]
        ok = jnp.ones((T, F, P0), bool)
        Mns = t["ns_packed"].shape[2]
        for c in range(C):
            hit_c = jnp.zeros((T, F, P0), bool)
            for m in range(Mns):
                tgt = t["ns_packed"][:, c, m][:, None, None, None]
                val = t["ns_valid"][:, c, m][:, None, None]
                hit_c |= (ns == tgt).any(axis=-1) & val
            has_check = t["ns_valid"][:, c].any(axis=-1)[:, None, None]
            ok &= hit_c | ~has_check
        gk0 = jnp.where(ok, gk0, SENTINEL)

    m26 = (1 << POS_BITS) - 1

    def rebase(gk, dt_b, b):
        """Row-local int32 re-basing (doc-only keys ARE doc ids: globally
        comparable in int32, no re-basing needed)."""
        dglob = jnp.where(dt_b, gk, gk >> POS_BITS)
        k32 = jnp.where(dt_b, gk, ((dglob - b) << TABLE_POS_BITS) | (gk & m26))
        return jnp.where(gk < SENTINEL, k32, I32_SENTINEL).astype(jnp.int32)

    a64 = gk0.reshape(T, F * P0)
    a32 = rebase(gk0, dt1[:, None, None], base[:, None, None]).reshape(T, F * P0)

    def kword_found(b32_sorted):
        """K-way windowed span join (kword buckets): per-group signed delta
        masks, window-start scans ANDed across groups (core/kword.py;
        ops.banded_delta_mask_rows + kword_window_hits).  Every active
        constraint group of a kword task is banded at the task's window W
        (plan construction), so the per-row W is the max over group bands
        (inactive pads are band 0 and never constrain)."""
        a_rows = jnp.broadcast_to(a32[:, None], (T, G - 1, F * P0))
        masks = banded_delta_mask_rows(
            a_rows.reshape(T * (G - 1), F * P0),
            b32_sorted.reshape(T * (G - 1), F * P),
            jnp.broadcast_to(t["band"][:, 1:], (T, G - 1)).reshape(-1),
            implementation=impl, interpret=interpret)
        masks = masks.reshape(T, G - 1, F * P0).transpose(1, 0, 2)
        kw_bands = t["band"][:, 1:].max(axis=1)
        active = t["active"][:, 1:].transpose(1, 0)
        return kword_window_hits(masks, active, kw_bands)

    if ranked:
        # proximity scores, canonical accumulation order (mirrored exactly by
        # Executor._run_groups_ranked): per-task bias, the seed's own delta,
        # then each constraint group seed-first.  Constraint deltas come from
        # one banded min-(key distance + |dist|) pass per group — the scoring
        # twin of the boolean membership test, on the same gathered slab.
        score = t["score_bias"][:, None] + proximity_w(delta0[:, 0].reshape(T, F * P0))
        found = jnp.ones((T, F * P0), bool)
        if G > 1:
            _, gkc, deltac = gather(slice(1, None), P)         # [T, G-1, F, P]
            b32 = rebase(gkc, dt1[:, None, None, None],
                         base[:, None, None, None]).reshape(T, G - 1, F * P)
            dl = deltac.reshape(T, G - 1, F * P)
            bands = t["band"][:, 1:]                           # [T, G-1]
            if impl == "pallas":
                b_sorted = jnp.sort(
                    jnp.where(b32 == I32_SENTINEL, jnp.int64(1) << 40,
                              (b32.astype(jnp.int64) << SCORE_DELTA_BITS)
                              | dl.astype(jnp.int64)), axis=-1)
                bk = (b_sorted >> SCORE_DELTA_BITS).astype(jnp.int32)
                bk = jnp.where(b_sorted >= jnp.int64(1) << 40, I32_SENTINEL, bk)
                bd = (b_sorted & ((1 << SCORE_DELTA_BITS) - 1)).astype(jnp.int32)
                a_rows = jnp.broadcast_to(a32[:, None], (T, G - 1, F * P0))
                delta_g = banded_min_delta_rows(
                    a_rows.reshape(T * (G - 1), F * P0),
                    bk.reshape(T * (G - 1), F * P),
                    bd.reshape(T * (G - 1), F * P),
                    jnp.broadcast_to(bands, (T, G - 1)).reshape(-1),
                    implementation=impl, interpret=interpret)
                delta_g = delta_g.reshape(T, G - 1, F * P0)
            else:
                pad = jnp.int64(1) << 40
                comp = jnp.where(
                    b32 == I32_SENTINEL, pad,
                    (b32.astype(jnp.int64) << SCORE_DELTA_BITS)
                    | dl.astype(jnp.int64))
                comp = jnp.sort(comp, axis=-1)
                probe = jnp.where(a32 == I32_SENTINEL, pad,
                                  a32.astype(jnp.int64) << SCORE_DELTA_BITS)
                probe = jnp.broadcast_to(probe[:, None], (T, G - 1, F * P0))
                delta_g = scored_probe(
                    comp.reshape(T * (G - 1), F * P),
                    probe.reshape(T * (G - 1), F * P0),
                    jnp.broadcast_to(bands, (T, G - 1)).reshape(-1, 1))
                delta_g = delta_g.reshape(T, G - 1, F * P0)
            active_c = t["active"][:, 1:, None]
            for gi in range(G - 1):
                hit_g = delta_g[:, gi] < I32_SENTINEL
                live = hit_g & active_c[:, gi]
                score = score + jnp.where(live, proximity_w(delta_g[:, gi]), 0.0)
                found &= hit_g | ~active_c[:, gi]
            if kword:
                # kword found = the span join, not pairwise membership; a
                # span match implies an in-band hit for every group, so the
                # score accumulated above is exact for every survivor (and
                # zeroed below for the rest)
                found = kword_found(jnp.sort(b32, axis=-1))
        found &= a32 != I32_SENTINEL
        return a64, found, jnp.where(found, score, 0.0)
    if G > 1:
        _, gkc, _ = gather(slice(1, None), P)                  # [T, G-1, F, P]
        b32 = rebase(gkc, dt1[:, None, None, None],
                     base[:, None, None, None]).reshape(T, G - 1, F * P)
        if not presorted:
            b32 = jnp.sort(b32, axis=-1)
        if kword:
            found = kword_found(b32)
            return a64, found & (a32 != I32_SENTINEL)
        a_rows = jnp.broadcast_to(a32[:, None], (T, G - 1, F * P0))
        hit = banded_intersect_rows(
            a_rows.reshape(T * (G - 1), F * P0),
            b32.reshape(T * (G - 1), F * P),
            jnp.broadcast_to(t["band"][:, 1:], (T, G - 1)).reshape(-1),
            implementation=impl, interpret=interpret)
        hit = hit.reshape(T, G - 1, F * P0) | ~t["active"][:, 1:, None]
        found = hit.all(axis=1)
    else:
        found = jnp.ones((T, F * P0), bool)
    return a64, found & (a32 != I32_SENTINEL)


_batch_step = partial(jax.jit, static_argnames=(
    "P0", "P", "impl", "interpret", "presorted", "ranked",
    "kword"))(bucket_step_math)


class BatchExecutor:
    """Executes a batch of QueryPlans with result parity vs. the flexible
    `Executor` (same doc/pos sets, same postings accounting, same fallback
    semantics), but in O(#shape-buckets) jit dispatches instead of
    O(#queries * #groups) — and O(arena) gather/sort work total regardless
    of the doc-shard count (segmented rows)."""

    def __init__(self, index: IndexSet, flex: Executor | None = None,
                 impl: str = "ref", interpret: bool = True,
                 docs_per_shard: int | None = None, doc_base: int = 0):
        self.index = index
        self.dev = BatchDeviceIndex(index, docs_per_shard=docs_per_shard,
                                    doc_base=doc_base)
        self.flex = flex or Executor(index)
        self.impl = impl
        self.interpret = interpret
        # packed-key safety: positions (plus bias, the widest dist shift,
        # and the widest band) must fit the 17-bit in-doc field or
        # cross-doc false positives appear
        self._pos_budget = (1 << TABLE_POS_BITS) - PHRASE_BIAS \
            - self.dev.max_pos - max(self.dev.max_distance,
                                     self.dev.max_shift)

    # -- tensorization ------------------------------------------------------

    def _caps(self):
        """(g_cap, f_cap, split_cap, p0_cap, p_cap) — module globals by
        default so tests can shrink them; the serve executor overrides with
        its fixed-shape table limits (p0_cap = seed pad, p_cap = constraint
        pad)."""
        return G_CAP, F_CAP, F_SPLIT_CAP, P_CAP, P_CAP

    def _order_groups(self, groups, ranked=False):
        """Seed-first ordering; None when no valid seed exists.  Shared with
        the flexible ranked path (executor.order_groups_seed_first) so the
        two executors accumulate float32 scores in the same group order
        (ranked ordering is plan-order deterministic — see
        order_groups_seed_first)."""
        return order_groups_seed_first(groups, ranked=ranked)

    def _task_fits(self, groups, kword: bool = False) -> bool:
        g_cap, f_cap, _, _, _ = self._caps()
        if len(groups) > g_cap:
            return False
        for g in groups:
            if len(g.fetches) > f_cap:
                return False
            if int(g.band) > self._pos_budget:
                return False
            # kword delta masks are int32 bitfields over d in [-W, W]: wider
            # windows ride the flexible escape path (int64 masks, W <= 31)
            if kword and int(g.band) > KW_DEVICE_MAX_WINDOW:
                return False
            for f in g.fetches:
                if f.stream == "first" and not _is_first_group(g):
                    return False
        return True

    def _build_rows(self, task: _Task, ordered) -> list | None:
        """Segment a task at doc-shard boundaries: one row per shard the
        SEED group touches, every fetch clipped to the shard's sub-slice
        (the arena is doc-sorted per fetch, so a shard's rows are one
        `searchsorted` away).  Fetches longer than p_cap split across extra
        F slots of the same group (slot unions).  None => plan goes flex."""
        d = self.dev
        dps = d.docs_per_shard
        base = d.doc_base
        _, _, split_cap, p0_cap, p_cap = self._caps()
        p0_cap, p_cap = max(1, p0_cap), max(1, p_cap)
        # arena doc ids are LOCAL; shard ids live on the GLOBAL grid
        sh_lo = base // dps
        sh_hi = (base + max(d.n_docs - 1, 0)) // dps
        if sh_lo == sh_hi:
            per_group = [{sh_lo: [(f, d.bases[f.stream] + f.start, f.length)
                                  for f in g.fetches]} for g in ordered]
            seed_shards = [sh_lo]
        else:
            per_group = []
            for g in ordered:
                m: dict = {}
                for f in g.fetches:
                    s0 = d.bases[f.stream] + f.start
                    arr = d.arena_doc_np[s0:s0 + f.length]
                    lo = (int(arr[0]) + base) // dps
                    hi = (int(arr[-1]) + base) // dps
                    if lo == hi:
                        m.setdefault(lo, []).append((f, s0, f.length))
                        continue
                    cuts = np.searchsorted(
                        arr, np.arange(lo + 1, hi + 1) * dps - base)
                    edges = np.concatenate(([0], cuts, [f.length]))
                    for i in range(len(edges) - 1):
                        ln = int(edges[i + 1] - edges[i])
                        if ln:
                            m.setdefault(lo + i, []).append(
                                (f, s0 + int(edges[i]), ln))
                per_group.append(m)
            seed_shards = sorted(per_group[0])
        rows = []
        for sh in seed_shards:
            shard_base = sh * dps - base       # local re-basing origin
            groups, sortfree = [], True
            for gi in range(len(ordered)):
                cap = p0_cap if gi == 0 else p_cap
                slots = []
                for f, s, ln in per_group[gi].get(sh, ()):
                    while ln > cap:
                        slots.append((f, s, cap))
                        s += cap
                        ln -= cap
                    slots.append((f, s, ln))
                if len(slots) > split_cap:
                    return None
                if gi > 0:
                    # sort-free: a single unsplit slot gathers ascending keys
                    # (the arena is (doc, pos)-sorted per fetch and the key
                    # packings are monotone); dist/pivot masks punch holes
                    # mid-row and multi-slot unions interleave — both break
                    # order.  Trailing pads sort last, so they are harmless.
                    if len(slots) > 1:
                        sortfree = False
                    for f, _, _ in slots:
                        if (f.required_dist is not None
                                or f.max_abs_dist is not None
                                or f.pivot_from_dist):
                            sortfree = False
                groups.append(_RowGroup(band=int(ordered[gi].band), slots=slots))
            rows.append(_Row(task=task, shard=sh, shard_base=shard_base,
                             groups=groups, sortfree=sortfree))
        return rows

    def _build_tasks(self, plan_i: int, plan: QueryPlan, tasks: list,
                     ranked: bool = False) -> bool:
        """Append tasks (with segmented rows) for one plan; False => route
        plan to the flexible executor (table caps exceeded)."""
        if self._pos_budget <= 0:
            return False
        out = []
        for sp_i, sp in enumerate(plan.subplans):
            if not sp.supported:
                continue
            main_dead = (not sp.groups) or any(not g.fetches for g in sp.groups)
            if not main_dead:
                ordered = self._order_groups(sp.groups, ranked=ranked)
                if ordered is None or not self._task_fits(
                        ordered, kword=sp.mode == MODE_KWORD):
                    return False
                checks = ordered[0].fetches[0].stop_checks
                if any(f.stop_checks != checks for f in ordered[0].fetches) or \
                   any(f.stop_checks for g in ordered[1:] for f in g.fetches):
                    return False
                task = _Task(plan_i, sp_i, False, checks, mode=sp.mode,
                             ranked=ranked,
                             score_bias=float(sp.n_slots - len(sp.groups)))
                task.rows = self._build_rows(task, ordered)
                if task.rows is None:
                    return False
                out.append(task)
            if sp.fallback_groups:
                fb_dead = any(not g.fetches for g in sp.fallback_groups)
                if not fb_dead:
                    ordered = self._order_groups(sp.fallback_groups)
                    if ordered is None or not self._task_fits(ordered):
                        return False
                    # fallback tasks are validated eagerly (the flex-routing
                    # decision must not depend on results) but executed
                    # lazily: only when the main task comes back empty
                    task = _Task(plan_i, sp_i, True, (), mode=MODE_PHRASE)
                    task.rows = self._build_rows(task, ordered)
                    if task.rows is None:
                        return False
                    out.append(task)
        tasks.extend(out)
        return True

    def _bucket_key(self, row: _Row):
        G = max(2, _next_pow2(len(row.groups), floor=2))
        F = _next_pow2(max(len(g.slots) for g in row.groups), floor=1)
        P0 = _next_pow2(max((ln for _, _, ln in row.groups[0].slots),
                            default=1), floor=P_FLOOR)
        P = _next_pow2(max((ln for g in row.groups[1:] for _, _, ln in g.slots),
                           default=1), floor=P_FLOOR)
        # near-stop slots are padded to coarse buckets (invalid slots are
        # inert) so check-count variation doesn't multiply compile shapes
        checks = row.task.stop_checks
        if checks:
            C = _next_pow2(len(checks), floor=4)
            M = _next_pow2(max(len(ids) for _, ids in checks), floor=2)
        else:
            C = M = 0
        # only big slabs are worth a separate sort-free compile shape; for
        # small P the sort is cheap and splitting buckets costs more calls
        # (ranked rows always sort: scoring needs the composite order)
        sortfree = row.sortfree and P >= 2048 and not row.task.ranked
        return (G, F, P0, P, C, M, sortfree, row.task.ranked,
                row.task.mode == MODE_KWORD)

    def _tensorize_bucket(self, rows: list, G: int, F: int, C: int, M: int,
                          T_pad: int) -> dict:
        t = alloc_batch_tables(T_pad, G, F, C, M)
        for ti, row in enumerate(rows):
            task = row.task
            t["doc_task"][ti] = task.fallback
            t["shard_base"][ti] = row.shard_base
            t["score_bias"][ti] = task.score_bias
            if task.stop_checks:
                pack_ns_checks(t, ti, task.stop_checks, self.dev.max_distance)
            for gi, g in enumerate(row.groups):
                t["band"][ti, gi] = g.band
                t["active"][ti, gi] = True
                for fi, (f, s, ln) in enumerate(g.slots):
                    t["start"][ti, gi, fi] = s
                    t["length"][ti, gi, fi] = ln
                    # mirror Executor._fetch_keys key selection
                    if f.stream == "first":
                        continue                        # doc key: no offset
                    phrase_keyed = (
                        f.stream == "stop"
                        or (f.stream == "expanded" and f.required_dist is not None)
                        or (f.stream in ("basic", "ordinary")
                            and task.mode == MODE_PHRASE))
                    if phrase_keyed:
                        t["offset"][ti, gi, fi] = f.offset
                    if f.required_dist is not None:
                        t["req_dist"][ti, gi, fi] = f.required_dist
                    if f.max_abs_dist is not None:
                        t["max_abs"][ti, gi, fi] = f.max_abs_dist
                    t["pivot_from_dist"][ti, gi, fi] = bool(f.pivot_from_dist)
                    t["score_from_dist"][ti, gi, fi] = \
                        bool(f.score_delta_from_dist)
        return t

    # -- execution ----------------------------------------------------------

    @staticmethod
    def _scatter_row_keys(part: list, a64: np.ndarray, found: np.ndarray,
                          scores: np.ndarray | None = None):
        """Assign each row its found seed keys (and scores, when ranked) —
        one pass over the hit mask instead of T boolean-indexings.  Shared
        with the serve executor so the result-extraction semantics can never
        diverge."""
        hit_rows, cols = np.nonzero(found)
        keys = a64[hit_rows, cols]
        splits = np.searchsorted(hit_rows, np.arange(1, len(part)))
        for ti, row_keys in enumerate(np.split(keys, splits)):
            part[ti].keys = row_keys
        if scores is not None:
            svals = scores[hit_rows, cols].astype(np.float32)
            for ti, row_scores in enumerate(np.split(svals, splits)):
                part[ti].scores = row_scores

    def _run_rows(self, rows: list):
        buckets: dict = {}
        for row in rows:
            buckets.setdefault(self._bucket_key(row), []).append(row)
        d = self.dev
        for (G, F, P0, P, C, M, sortfree, ranked, kword), rs in buckets.items():
            per_task = F * P0 + (G - 1) * F * P
            if C > 0:                  # near-stop gather adds an [F, P0, K] slab
                per_task += F * P0 * int(d.near_stop_np.shape[1])
            chunk = max(1, GATHER_BUDGET // per_task)
            for lo in range(0, len(rs), chunk):
                part = rs[lo:lo + chunk]
                # tight T padding: big-P buckets usually hold 1-4 rows, and
                # padding them to a large T multiplies the gather/sort slab;
                # the extra pow2 compile variants are absorbed by warm-up
                T_pad = _next_pow2(len(part), floor=4)
                t = self._tensorize_bucket(part, G, F, C, M, T_pad)
                # the score columns are only read by the ranked program —
                # keep them off the per-call transfer path for unranked
                # buckets (device_put per table entry is the step's fixed
                # cost at smoke scale)
                tj = {k: jnp.asarray(v) for k, v in t.items()
                      if ranked or k not in ("score_bias", "score_from_dist")}
                out = _batch_step(
                    d.device_arena, tj,
                    P0=P0, P=P, impl=self.impl, interpret=self.interpret,
                    presorted=sortfree, ranked=ranked, kword=kword)
                if ranked:
                    a64, found, scores = out
                    self._scatter_row_keys(part, np.asarray(a64),
                                           np.asarray(found),
                                           np.asarray(scores))
                else:
                    a64, found = out
                    self._scatter_row_keys(part, np.asarray(a64),
                                           np.asarray(found))

    # -- merge (mirrors Executor.execute) -----------------------------------

    def _merge_plan(self, plan: QueryPlan, task_map: dict,
                    request: SearchRequest | None) -> SearchResult:
        ranked = request is not None and request.rank
        all_keys, all_scores, doc_only_keys = [], [], []
        postings = 0
        used_fallback = False
        types = []
        for sp_i, sp in enumerate(plan.subplans):
            if not sp.supported:
                continue
            types.append(sp.qtype)
            postings += sp.postings_read
            main = task_map.get((sp_i, False))
            keys = main.collect_keys() if main is not None else np.empty(0, np.int64)
            scores = (main.collect_scores() if ranked and main is not None
                      else np.empty(0, np.float32))
            if len(keys) == 0 and sp.fallback_groups:
                used_fallback = True
                postings += sum(g.postings_read for g in sp.fallback_groups)
                fb = task_map.get((sp_i, True))
                dkeys = fb.collect_keys() if fb is not None else np.empty(0, np.int64)
                doc_only_keys.append(dkeys)
                keys, scores = keys[:0], scores[:0]
            all_keys.append(keys)
            all_scores.append(scores)
        return merge_subplan_results(all_keys, doc_only_keys, postings,
                                     used_fallback, tuple(types), request,
                                     all_scores=all_scores)

    # -- public API ---------------------------------------------------------

    def execute_batch(self, plans: list[QueryPlan],
                      max_results: int | None = None,
                      requests: list[SearchRequest] | None = None
                      ) -> list[SearchResult]:
        """Requests (when given) align 1:1 with plans and carry ranking /
        top_k; plans stay the executor's input so escape routing and table
        building see resolved fetches only."""
        if requests is None:
            requests = [SearchRequest((), top_k=max_results)] * len(plans)
        tasks: list[_Task] = []
        flex_plans: dict[int, QueryPlan] = {}
        plan_tasks: dict[int, list] = {}
        for i, plan in enumerate(plans):
            start = len(tasks)
            if self._build_tasks(i, plan, tasks, ranked=requests[i].rank):
                plan_tasks[i] = tasks[start:]
            else:
                flex_plans[i] = plan
        # round 1: main rows; round 2: only the fallback rows whose main
        # result came back empty (mirrors the flexible executor, which never
        # touches stream 1 when the positional search hits)
        self._run_rows([r for t in tasks if not t.fallback for r in t.rows])
        main_keys = {(t.plan_i, t.subplan_i): t.collect_keys()
                     for t in tasks if not t.fallback}
        self._run_rows([r for t in tasks if t.fallback
                        and len(main_keys.get((t.plan_i, t.subplan_i),
                                              np.empty(0))) == 0
                        for r in t.rows])
        out: list[SearchResult | None] = [None] * len(plans)
        for i, plan in enumerate(plans):
            if i in flex_plans:
                out[i] = self.flex.execute(plan, request=requests[i])
            else:
                task_map = {(t.subplan_i, t.fallback): t for t in plan_tasks[i]}
                out[i] = self._merge_plan(plan, task_map, requests[i])
        return out


def _is_first_group(g) -> bool:
    return all(f.stream == "first" for f in g.fetches)

"""Posting-list containers, packed-key codecs, and the packed block store.

A *posting* is the paper's (ID, P) record: document id + in-document word
position.  Host-side, every index is a CSR structure-of-arrays:

    offsets : [K + 1] int64     -- slice bounds per key
    columns : dict[str, array]  -- parallel int columns (doc, pos, dist, ...)

The paper's on-disk indexes are compressed posting streams (VByte-style
codings; the follow-up arXiv:1812.07640 leans on compact encodings to make
multi-component keys affordable).  The device-resident twin of that economy
is `PackedPostings`: posting columns grouped into fixed-size blocks of
``BLOCK`` = 128, each block storing a per-field *anchor* (the block minimum)
plus bit-packed deltas in one of a small set of build-time *width classes*
(``PACK_WIDTHS`` = 0/1/2/4/8/16/32 bits — every class divides the 32-bit
lane, so a value never straddles lane words and decode is one gather + one
shift + one mask).  Random access is preserved: posting ordinal ``i`` lives
in block ``i >> 7`` at offset ``i & 127``, so executor fetch slices stay
plain ``(start, length)`` ranges and the un-pack runs vectorized on device
(kernels/ops.unpack_postings; Pallas kernel in kernels/unpack.py).  The CSR
``columns`` stay the host-side build product and oracle surface; only the
packed lanes ship to the device.

Key codecs
----------
* doc_pos_key:   doc << 32 | pos                      (total order on postings)
* shifted_key:   doc << 26 | (pos - offset + BIAS)    (phrase intersection)
* stop_phrase_key: L << 60 | sorted 10-bit stop ids   (B-tree key adaptation)
"""
from __future__ import annotations

import dataclasses

import numpy as np

PHRASE_BIAS = 64          # headroom so (pos - offset) never underflows
POS_BITS = 26             # in-doc positions < 2**26 - 2*BIAS
STOP_ID_BITS = 10         # stop vocabulary <= 1024
MAX_STOP_PHRASE_LEN = 5   # 5 * 10 bits + 3-bit length tag < 64 bits


# --------------------------------------------------------------------------
# key codecs (numpy; mirrored in jnp by the executor where needed)
# --------------------------------------------------------------------------

def doc_pos_key(doc: np.ndarray, pos: np.ndarray) -> np.ndarray:
    return (doc.astype(np.int64) << 32) | pos.astype(np.int64)


def shifted_key(doc: np.ndarray, pos: np.ndarray, offset) -> np.ndarray:
    """Key such that words at phrase offsets o_i over the same anchor collide.

    Word i of a phrase occurring at position p has anchor p - o_i; a precise
    phrase match is a k-way intersection of these keys (DESIGN.md §2).
    """
    shifted = pos.astype(np.int64) - np.asarray(offset, dtype=np.int64) + PHRASE_BIAS
    return (doc.astype(np.int64) << POS_BITS) | shifted


def unpack_shifted_key(key: np.ndarray, offset=0):
    doc = key >> POS_BITS
    pos = (key & ((1 << POS_BITS) - 1)) - PHRASE_BIAS + offset
    return doc.astype(np.int32), pos.astype(np.int32)


def pack_stop_phrase_key(sorted_local_ids: np.ndarray) -> np.ndarray:
    """[N, L] sorted stop local ids -> [N] int64 keys (duplicates preserved)."""
    ids = np.asarray(sorted_local_ids, dtype=np.int64)
    if ids.ndim == 1:
        ids = ids[None, :]
    n, L = ids.shape
    if L > MAX_STOP_PHRASE_LEN:
        raise ValueError(f"stop-phrase length {L} > {MAX_STOP_PHRASE_LEN}")
    key = np.full(n, np.int64(L) << 60, dtype=np.int64)
    for i in range(L):
        key |= ids[:, i] << (STOP_ID_BITS * i)
    return key


def pack_multi_pair_key(stop_id, v, n_base) -> np.ndarray:
    """Two-component multi-key: (s, v) with s a stop basic form and v any
    non-stop basic form.  s is always the first component (canonical
    stop-first orientation), so every stop-adjacent word pair in the corpus
    is reachable via exactly one key."""
    return np.asarray(stop_id, dtype=np.int64) * np.int64(n_base) \
        + np.asarray(v, dtype=np.int64)


def unpack_multi_pair_key(key, n_base):
    key = np.asarray(key, dtype=np.int64)
    return key // n_base, key % n_base


def pack_multi_triple_key(s1, s2, v, n_stop) -> np.ndarray:
    """Three-component multi-key (arXiv:2006.07954): two distinct stop basic
    forms s1 < s2 (canonical sorted order) around a non-stop form v.  Stop
    ids < 1024 and base ids < 2**40, so the key fits int64 with room."""
    s1 = np.asarray(s1, dtype=np.int64)
    s2 = np.asarray(s2, dtype=np.int64)
    v = np.asarray(v, dtype=np.int64)
    return (v * n_stop + s2) * n_stop + s1


def unpack_multi_triple_key(key, n_stop):
    key = np.asarray(key, dtype=np.int64)
    s1 = key % n_stop
    rest = key // n_stop
    return s1, rest % n_stop, rest // n_stop


MULTI_DIST_BITS = 4    # nearest-stop distances <= MaxDistance (7) fit 4 bits


def pack_dist_pair(d1, d2) -> np.ndarray:
    """Triple-posting payload: the pair of nearest |distances| (d1 of s1,
    d2 of s2) packed into one int8 — one nibble each (NeighborDistance
    <= 15), stored bit-exact in the int8 container (unpack masks the sign
    away) — compatible with the arena's int8 dist column and the 17-bit
    packed-key position layout (positions themselves stay in the pos
    column)."""
    return ((np.asarray(d1, np.int32) << MULTI_DIST_BITS)
            | np.asarray(d2, np.int32)).astype(np.int8)


def unpack_dist_pair(packed):
    p = np.asarray(packed).astype(np.int32) & 0xFF
    return p >> MULTI_DIST_BITS, p & ((1 << MULTI_DIST_BITS) - 1)


NS_SHIFT = 10     # stop local id < 1024 -> 10 bits; (delta+MaxD) <= 14 -> 4 bits


def pack_near_stop_slot(delta: np.ndarray, stop_local: np.ndarray, max_distance: int) -> np.ndarray:
    """Stream-3 slot: (delta + MaxDistance) << 10 | stop_local, in int16
    (14 bits used; empty = -1).  Half the stream-3 footprint of int32."""
    packed = ((delta.astype(np.int32) + max_distance) << NS_SHIFT) \
        | stop_local.astype(np.int32)
    return packed.astype(np.int16)


def unpack_near_stop_slot(slot: np.ndarray, max_distance: int):
    slot = np.asarray(slot).astype(np.int32)
    delta = (slot >> NS_SHIFT) - max_distance
    stop_local = slot & ((1 << NS_SHIFT) - 1)
    return delta, stop_local


# --------------------------------------------------------------------------
# CSR container
# --------------------------------------------------------------------------

@dataclasses.dataclass
class CSR:
    """Sorted-key CSR posting store.

    keys[k] owns columns[*][offsets[k]:offsets[k+1]].  `keys` is sorted so
    lookup is a binary search — the TPU-native replacement for the paper's
    B-tree (DESIGN.md §2).
    """

    keys: np.ndarray          # [K] int64, sorted ascending
    offsets: np.ndarray       # [K + 1] int64
    columns: dict[str, np.ndarray]

    def __post_init__(self):
        assert self.offsets.shape == (len(self.keys) + 1,)
        for c in self.columns.values():
            assert len(c) == self.offsets[-1], (len(c), self.offsets[-1])

    @property
    def n_keys(self) -> int:
        return len(self.keys)

    @property
    def n_postings(self) -> int:
        return int(self.offsets[-1])

    def nbytes(self) -> int:
        n = self.keys.nbytes + self.offsets.nbytes
        return n + sum(c.nbytes for c in self.columns.values())

    def find(self, key: int) -> tuple[int, int]:
        """(start, end) slice for `key`; (0, 0) when absent."""
        i = int(np.searchsorted(self.keys, key))
        if i == len(self.keys) or self.keys[i] != key:
            return (0, 0)
        return (int(self.offsets[i]), int(self.offsets[i + 1]))

    def count(self, key: int) -> int:
        s, e = self.find(key)
        return e - s

    def slice(self, key: int) -> dict[str, np.ndarray]:
        s, e = self.find(key)
        return {name: col[s:e] for name, col in self.columns.items()}

    @staticmethod
    def from_unsorted(keys: np.ndarray, columns: dict[str, np.ndarray],
                      presorted: bool = False) -> "CSR":
        """Group unsorted per-posting keys into a CSR (stable within key)."""
        if len(keys) == 0:
            return CSR(keys=np.empty(0, np.int64), offsets=np.zeros(1, np.int64),
                       columns={k: v[:0] for k, v in columns.items()})
        if not presorted:
            order = np.argsort(keys, kind="stable")
            keys = keys[order]
            columns = {k: v[order] for k, v in columns.items()}
        uniq, counts = np.unique(keys, return_counts=True)
        offsets = np.zeros(len(uniq) + 1, dtype=np.int64)
        np.cumsum(counts, out=offsets[1:])
        return CSR(keys=uniq.astype(np.int64), offsets=offsets, columns=columns)


@dataclasses.dataclass
class DenseCSR:
    """CSR over a dense id space [0, K): offsets only, no key search.

    Used for the basic index (key = basic-form id) where the id space is
    dense and small.
    """

    offsets: np.ndarray       # [K + 1] int64
    columns: dict[str, np.ndarray]

    @property
    def n_keys(self) -> int:
        return len(self.offsets) - 1

    @property
    def n_postings(self) -> int:
        return int(self.offsets[-1])

    def nbytes(self) -> int:
        return self.offsets.nbytes + sum(c.nbytes for c in self.columns.values())

    def find(self, key: int) -> tuple[int, int]:
        return (int(self.offsets[key]), int(self.offsets[key + 1]))

    def count(self, key: int) -> int:
        s, e = self.find(key)
        return e - s

    def counts(self) -> np.ndarray:
        return np.diff(self.offsets)

    def slice(self, key: int) -> dict[str, np.ndarray]:
        s, e = self.find(key)
        return {name: col[s:e] for name, col in self.columns.items()}

    @staticmethod
    def from_ids(ids: np.ndarray, n_keys: int, columns: dict[str, np.ndarray],
                 presorted: bool = False) -> "DenseCSR":
        if not presorted:
            order = np.argsort(ids, kind="stable")
            ids = ids[order]
            columns = {k: v[order] for k, v in columns.items()}
        counts = np.bincount(ids, minlength=n_keys).astype(np.int64)
        offsets = np.zeros(n_keys + 1, dtype=np.int64)
        np.cumsum(counts, out=offsets[1:])
        return DenseCSR(offsets=offsets, columns=columns)


# --------------------------------------------------------------------------
# packed block store (the device postings codec)
# --------------------------------------------------------------------------

BLOCK = 128                    # postings per packed block
BLOCK_LOG2 = 7
PACK_WIDTHS = (0, 1, 2, 4, 8, 16, 32)   # bits/value; all divide the 32b lane
PACK_WIDTH_BITS = 6            # field width slot in blk_widths (holds 0..32)

# mask per width, indexable by width value (0..32); int64 so numpy keeps the
# 32-bit all-ones mask positive host-side (device mirrors use int32 -1)
PACK_MASKS = np.zeros(33, np.int64)
for _w in PACK_WIDTHS:
    PACK_MASKS[_w] = (1 << _w) - 1


def _pack_width_classes(span: np.ndarray) -> np.ndarray:
    """Per-block value span (uint64) -> smallest admissible width class."""
    width = np.full(span.shape, 32, np.int32)
    for w in reversed(PACK_WIDTHS[:-1]):
        width[span <= np.uint64(PACK_MASKS[w])] = w
    return width


def pad_block_multiple(col: np.ndarray, n_padded: int) -> np.ndarray:
    """THE block-pad rule: edge-replicate `col` to `n_padded` entries.

    Shared by PackedPostings.from_columns, the executors' raw arena columns,
    and the multi stream's internal pair pad — raw and packed ordinals must
    line up one-for-one, so there is exactly one copy of this rule."""
    pad = n_padded - len(col)
    if pad <= 0:
        return col
    edge = col[-1:] if len(col) else np.zeros(1, col.dtype)
    return np.concatenate([col, np.repeat(edge, pad)])


@dataclasses.dataclass
class PackedPostings:
    """Bit-packed block store over parallel int columns.

    Postings are grouped into blocks of BLOCK = 128 (the tail block is
    padded by edge-replication, so pads never widen a class).  Per block and
    per field the store keeps the *anchor* (block minimum, int32) and a
    width class w ∈ PACK_WIDTHS; the 128 deltas ``value - anchor`` are
    bit-packed little-endian into ``128 * w / 32`` consecutive int32 lane
    words.  A block's fields are laid out back to back starting at
    ``blk_base[blk]``; widths ride ``blk_widths`` (PACK_WIDTH_BITS bits per
    field).  Values are recovered exactly modulo 2**32 — i.e. bit-exactly
    for every int32/int8 posting column — by

        word  = base_f + ((off * w) >> 5)        off = ordinal & 127
        shift = (off * w) & 31
        value = anchor + ((lanes[word] >> shift) & mask(w))

    which is one gather + shift + mask per field: random access, no block
    scan, no branch — the same math numpy-decoded here and jnp/Pallas-
    decoded on device (kernels/ops.unpack_postings).
    """

    n: int                        # real postings (pads excluded)
    fields: tuple                 # field order, e.g. ("doc", "pos", "dist")
    lanes: np.ndarray             # [W] int32 packed delta words
    blk_base: np.ndarray          # [NB] int32 first lane word of each block
    blk_widths: np.ndarray        # [NB] int32 packed per-field width classes
    anchors: dict                 # field -> [NB] int32 block minimum

    @property
    def n_blocks(self) -> int:
        return len(self.blk_base)

    @property
    def n_padded(self) -> int:
        """Postings including the tail pad — always a BLOCK multiple."""
        return self.n_blocks * BLOCK

    def nbytes(self) -> int:
        return (self.lanes.nbytes + self.blk_base.nbytes
                + self.blk_widths.nbytes
                + sum(a.nbytes for a in self.anchors.values()))

    def field_width(self, field: str) -> np.ndarray:
        i = self.fields.index(field)
        return (self.blk_widths >> (PACK_WIDTH_BITS * i)) \
            & ((1 << PACK_WIDTH_BITS) - 1)

    def meta_matrix(self) -> np.ndarray:
        """[NB, 2 + n_fields] int32 per-block metadata in the device layout
        ops.unpack_postings consumes — column 0 = blk_base, 1 = blk_widths,
        2.. = per-field anchors (field order) — so the jit'd step pays ONE
        metadata gather per posting instead of five."""
        return np.stack([self.blk_base, self.blk_widths]
                        + [self.anchors[f] for f in self.fields],
                        axis=1).astype(np.int32)

    def _field_base(self, field: str) -> np.ndarray:
        """Per-block first lane word of `field` (fields laid out in order;
        each occupies width * BLOCK / 32 = width << 2 words)."""
        base = self.blk_base.astype(np.int64).copy()
        for f in self.fields:
            if f == field:
                return base
            base += self.field_width(f).astype(np.int64) << 2
        raise KeyError(field)

    def decode(self, field: str, start: int = 0,
               end: int | None = None) -> np.ndarray:
        """Exact int32 values of `field` for posting ordinals [start, end)
        (pads beyond `n` decode to the edge-replicated tail value)."""
        if end is None:
            end = self.n
        idx = np.arange(start, end, dtype=np.int64)
        blk = idx >> BLOCK_LOG2
        off = idx & (BLOCK - 1)
        w = self.field_width(field)[blk].astype(np.int64)
        bit = off * w
        word = self._field_base(field)[blk] + (bit >> 5)
        word = np.minimum(word, len(self.lanes) - 1)   # w == 0 at the end
        sh = (bit & 31).astype(np.uint32)
        raw = self.lanes[word].astype(np.uint32)
        delta = (raw >> sh) & PACK_MASKS[w].astype(np.uint64).astype(np.uint32)
        return (self.anchors[field][blk].astype(np.uint32)
                + delta).astype(np.int32)

    def decode_all(self) -> dict:
        return {f: self.decode(f) for f in self.fields}

    @staticmethod
    def from_columns(columns: dict, fields: tuple | None = None
                     ) -> "PackedPostings":
        """Pack parallel posting columns (any int dtype ≤ 32 bits)."""
        fields = tuple(fields if fields is not None else columns.keys())
        n = len(columns[fields[0]]) if fields else 0
        nb = max(1, -(-n // BLOCK))
        views, widths, anchors = {}, {}, {}
        for f in fields:
            col = np.asarray(columns[f])
            assert len(col) == n, (f, len(col), n)
            col = pad_block_multiple(col, nb * BLOCK)
            v = col.astype(np.int64).reshape(nb, BLOCK)
            mn = v.min(axis=1)
            span = (v.max(axis=1) - mn).astype(np.uint64)
            views[f] = v
            widths[f] = _pack_width_classes(span)
            anchors[f] = mn.astype(np.int32)
        words_per_block = sum(widths[f].astype(np.int64) << 2 for f in fields) \
            if fields else np.zeros(nb, np.int64)
        blk_base = np.zeros(nb, np.int64)
        np.cumsum(words_per_block[:-1], out=blk_base[1:])
        total = int(blk_base[-1] + words_per_block[-1]) if nb else 0
        lanes = np.zeros(max(total, 1), np.uint32)
        field_base = blk_base.copy()
        for f in fields:
            w_f = widths[f]
            for w in PACK_WIDTHS[1:]:
                sel = np.nonzero(w_f == w)[0]
                if not len(sel):
                    continue
                delta = (views[f][sel]
                         - anchors[f][sel].astype(np.int64)[:, None])
                vpw = 32 // w
                d3 = delta.astype(np.uint64).astype(np.uint32) \
                    .reshape(len(sel), BLOCK // vpw, vpw)
                shifts = (np.arange(vpw, dtype=np.uint32) * np.uint32(w))
                packed = np.bitwise_or.reduce(d3 << shifts[None, None, :],
                                              axis=2)
                tgt = field_base[sel][:, None] \
                    + np.arange(BLOCK // vpw, dtype=np.int64)[None, :]
                lanes[tgt.ravel()] = packed.ravel()
            field_base += w_f.astype(np.int64) << 2
        blk_widths = np.zeros(nb, np.int32)
        for i, f in enumerate(fields):
            blk_widths |= widths[f] << (PACK_WIDTH_BITS * i)
        return PackedPostings(
            n=n, fields=fields, lanes=lanes.astype(np.int32),
            blk_base=blk_base.astype(np.int32), blk_widths=blk_widths,
            anchors=anchors)


def concat_packed(stores: list) -> "PackedPostings":
    """Concatenate packed stores into one (posting ordinals shift by each
    predecessor's *padded* count — callers must use BLOCK-aligned stream
    bases, which ``n_padded`` is by construction)."""
    assert stores
    fields = stores[0].fields
    assert all(s.fields == fields for s in stores)
    lane_off, base_parts = 0, []
    for s in stores:
        base_parts.append(s.blk_base.astype(np.int64) + lane_off)
        lane_off += len(s.lanes)
    return PackedPostings(
        n=sum(s.n_padded for s in stores),   # pads are addressable ordinals
        fields=fields,
        lanes=np.concatenate([s.lanes for s in stores]),
        blk_base=np.concatenate(base_parts).astype(np.int32),
        blk_widths=np.concatenate([s.blk_widths for s in stores]),
        anchors={f: np.concatenate([s.anchors[f] for s in stores])
                 for f in fields})

"""Posting-list containers and packed-key codecs.

A *posting* is the paper's (ID, P) record: document id + in-document word
position.  All indexes in this system are CSR structures-of-arrays:

    offsets : [K + 1] int64     -- slice bounds per key
    columns : dict[str, array]  -- parallel int columns (doc, pos, dist, ...)

which shard cleanly over the `data` mesh axis and scan at HBM bandwidth on the
TPU (see DESIGN.md §2 for why this replaces the paper's compressed streams).

Key codecs
----------
* doc_pos_key:   doc << 32 | pos                      (total order on postings)
* shifted_key:   doc << 26 | (pos - offset + BIAS)    (phrase intersection)
* stop_phrase_key: L << 60 | sorted 10-bit stop ids   (B-tree key adaptation)
"""
from __future__ import annotations

import dataclasses

import numpy as np

PHRASE_BIAS = 64          # headroom so (pos - offset) never underflows
POS_BITS = 26             # in-doc positions < 2**26 - 2*BIAS
STOP_ID_BITS = 10         # stop vocabulary <= 1024
MAX_STOP_PHRASE_LEN = 5   # 5 * 10 bits + 3-bit length tag < 64 bits


# --------------------------------------------------------------------------
# key codecs (numpy; mirrored in jnp by the executor where needed)
# --------------------------------------------------------------------------

def doc_pos_key(doc: np.ndarray, pos: np.ndarray) -> np.ndarray:
    return (doc.astype(np.int64) << 32) | pos.astype(np.int64)


def shifted_key(doc: np.ndarray, pos: np.ndarray, offset) -> np.ndarray:
    """Key such that words at phrase offsets o_i over the same anchor collide.

    Word i of a phrase occurring at position p has anchor p - o_i; a precise
    phrase match is a k-way intersection of these keys (DESIGN.md §2).
    """
    shifted = pos.astype(np.int64) - np.asarray(offset, dtype=np.int64) + PHRASE_BIAS
    return (doc.astype(np.int64) << POS_BITS) | shifted


def unpack_shifted_key(key: np.ndarray, offset=0):
    doc = key >> POS_BITS
    pos = (key & ((1 << POS_BITS) - 1)) - PHRASE_BIAS + offset
    return doc.astype(np.int32), pos.astype(np.int32)


def pack_stop_phrase_key(sorted_local_ids: np.ndarray) -> np.ndarray:
    """[N, L] sorted stop local ids -> [N] int64 keys (duplicates preserved)."""
    ids = np.asarray(sorted_local_ids, dtype=np.int64)
    if ids.ndim == 1:
        ids = ids[None, :]
    n, L = ids.shape
    if L > MAX_STOP_PHRASE_LEN:
        raise ValueError(f"stop-phrase length {L} > {MAX_STOP_PHRASE_LEN}")
    key = np.full(n, np.int64(L) << 60, dtype=np.int64)
    for i in range(L):
        key |= ids[:, i] << (STOP_ID_BITS * i)
    return key


def pack_multi_pair_key(stop_id, v, n_base) -> np.ndarray:
    """Two-component multi-key: (s, v) with s a stop basic form and v any
    non-stop basic form.  s is always the first component (canonical
    stop-first orientation), so every stop-adjacent word pair in the corpus
    is reachable via exactly one key."""
    return np.asarray(stop_id, dtype=np.int64) * np.int64(n_base) \
        + np.asarray(v, dtype=np.int64)


def unpack_multi_pair_key(key, n_base):
    key = np.asarray(key, dtype=np.int64)
    return key // n_base, key % n_base


def pack_multi_triple_key(s1, s2, v, n_stop) -> np.ndarray:
    """Three-component multi-key (arXiv:2006.07954): two distinct stop basic
    forms s1 < s2 (canonical sorted order) around a non-stop form v.  Stop
    ids < 1024 and base ids < 2**40, so the key fits int64 with room."""
    s1 = np.asarray(s1, dtype=np.int64)
    s2 = np.asarray(s2, dtype=np.int64)
    v = np.asarray(v, dtype=np.int64)
    return (v * n_stop + s2) * n_stop + s1


def unpack_multi_triple_key(key, n_stop):
    key = np.asarray(key, dtype=np.int64)
    s1 = key % n_stop
    rest = key // n_stop
    return s1, rest % n_stop, rest // n_stop


MULTI_DIST_BITS = 4    # nearest-stop distances <= MaxDistance (7) fit 4 bits


def pack_dist_pair(d1, d2) -> np.ndarray:
    """Triple-posting payload: the pair of nearest |distances| (d1 of s1,
    d2 of s2) packed into one int8 — one nibble each (NeighborDistance
    <= 15), stored bit-exact in the int8 container (unpack masks the sign
    away) — compatible with the arena's int8 dist column and the 17-bit
    packed-key position layout (positions themselves stay in the pos
    column)."""
    return ((np.asarray(d1, np.int32) << MULTI_DIST_BITS)
            | np.asarray(d2, np.int32)).astype(np.int8)


def unpack_dist_pair(packed):
    p = np.asarray(packed).astype(np.int32) & 0xFF
    return p >> MULTI_DIST_BITS, p & ((1 << MULTI_DIST_BITS) - 1)


NS_SHIFT = 10     # stop local id < 1024 -> 10 bits; (delta+MaxD) <= 14 -> 4 bits


def pack_near_stop_slot(delta: np.ndarray, stop_local: np.ndarray, max_distance: int) -> np.ndarray:
    """Stream-3 slot: (delta + MaxDistance) << 10 | stop_local, in int16
    (14 bits used; empty = -1).  Half the stream-3 footprint of int32."""
    packed = ((delta.astype(np.int32) + max_distance) << NS_SHIFT) \
        | stop_local.astype(np.int32)
    return packed.astype(np.int16)


def unpack_near_stop_slot(slot: np.ndarray, max_distance: int):
    slot = np.asarray(slot).astype(np.int32)
    delta = (slot >> NS_SHIFT) - max_distance
    stop_local = slot & ((1 << NS_SHIFT) - 1)
    return delta, stop_local


# --------------------------------------------------------------------------
# CSR container
# --------------------------------------------------------------------------

@dataclasses.dataclass
class CSR:
    """Sorted-key CSR posting store.

    keys[k] owns columns[*][offsets[k]:offsets[k+1]].  `keys` is sorted so
    lookup is a binary search — the TPU-native replacement for the paper's
    B-tree (DESIGN.md §2).
    """

    keys: np.ndarray          # [K] int64, sorted ascending
    offsets: np.ndarray       # [K + 1] int64
    columns: dict[str, np.ndarray]

    def __post_init__(self):
        assert self.offsets.shape == (len(self.keys) + 1,)
        for c in self.columns.values():
            assert len(c) == self.offsets[-1], (len(c), self.offsets[-1])

    @property
    def n_keys(self) -> int:
        return len(self.keys)

    @property
    def n_postings(self) -> int:
        return int(self.offsets[-1])

    def nbytes(self) -> int:
        n = self.keys.nbytes + self.offsets.nbytes
        return n + sum(c.nbytes for c in self.columns.values())

    def find(self, key: int) -> tuple[int, int]:
        """(start, end) slice for `key`; (0, 0) when absent."""
        i = int(np.searchsorted(self.keys, key))
        if i == len(self.keys) or self.keys[i] != key:
            return (0, 0)
        return (int(self.offsets[i]), int(self.offsets[i + 1]))

    def count(self, key: int) -> int:
        s, e = self.find(key)
        return e - s

    def slice(self, key: int) -> dict[str, np.ndarray]:
        s, e = self.find(key)
        return {name: col[s:e] for name, col in self.columns.items()}

    @staticmethod
    def from_unsorted(keys: np.ndarray, columns: dict[str, np.ndarray],
                      presorted: bool = False) -> "CSR":
        """Group unsorted per-posting keys into a CSR (stable within key)."""
        if len(keys) == 0:
            return CSR(keys=np.empty(0, np.int64), offsets=np.zeros(1, np.int64),
                       columns={k: v[:0] for k, v in columns.items()})
        if not presorted:
            order = np.argsort(keys, kind="stable")
            keys = keys[order]
            columns = {k: v[order] for k, v in columns.items()}
        uniq, counts = np.unique(keys, return_counts=True)
        offsets = np.zeros(len(uniq) + 1, dtype=np.int64)
        np.cumsum(counts, out=offsets[1:])
        return CSR(keys=uniq.astype(np.int64), offsets=offsets, columns=columns)


@dataclasses.dataclass
class DenseCSR:
    """CSR over a dense id space [0, K): offsets only, no key search.

    Used for the basic index (key = basic-form id) where the id space is
    dense and small.
    """

    offsets: np.ndarray       # [K + 1] int64
    columns: dict[str, np.ndarray]

    @property
    def n_keys(self) -> int:
        return len(self.offsets) - 1

    @property
    def n_postings(self) -> int:
        return int(self.offsets[-1])

    def nbytes(self) -> int:
        return self.offsets.nbytes + sum(c.nbytes for c in self.columns.values())

    def find(self, key: int) -> tuple[int, int]:
        return (int(self.offsets[key]), int(self.offsets[key + 1]))

    def count(self, key: int) -> int:
        s, e = self.find(key)
        return e - s

    def counts(self) -> np.ndarray:
        return np.diff(self.offsets)

    def slice(self, key: int) -> dict[str, np.ndarray]:
        s, e = self.find(key)
        return {name: col[s:e] for name, col in self.columns.items()}

    @staticmethod
    def from_ids(ids: np.ndarray, n_keys: int, columns: dict[str, np.ndarray],
                 presorted: bool = False) -> "DenseCSR":
        if not presorted:
            order = np.argsort(ids, kind="stable")
            ids = ids[order]
            columns = {k: v[order] for k, v in columns.items()}
        counts = np.bincount(ids, minlength=n_keys).astype(np.int64)
        offsets = np.zeros(n_keys + 1, dtype=np.int64)
        np.cumsum(counts, out=offsets[1:])
        return DenseCSR(offsets=offsets, columns=columns)

"""Incremental ingestion: immutable LSM-style index segments + background merge.

The builder (builder.py) is one-shot: adding a single document rebuilds every
stream (basic, expanded, stop-phrase, multi-key pairs/triples, packed twins).
This module makes the corpus GROWABLE while serving: documents arrive in
batches, each batch becomes a small immutable segment (its own `IndexSet` +
packed arenas over a contiguous doc-id range), and a background merger
re-packs accumulated small segments into one large segment.  Search unions
results across live segments through the exact machinery the doc-sharded
front door already uses (`serve.front.merge_shard_responses`) — segments ARE
doc shards from the executor's point of view: contiguous doc ranges whose
per-(task, shard) rows ride the global shard grid (`BatchDeviceIndex`'s
`doc_base`), so `bucket_step_math` is untouched.

Segment state machine
---------------------
::

    ingest(batch)                       merger picks sources
      │                                   │
      ▼                                   ▼
    FRESH ──────────────────────────► MERGING ──── build_all(concat) ok ──► RETIRED
      ▲                                   │                                (dropped from
      └────── merge failed (crash /      │                                 the live list;
              injected fault): revert ◄──┘                                 generation++)
              to FRESH, generation
              UNCHANGED, serving
              continues on the old
              segment set

    Every transition that changes the LIVE segment set bumps `generation`
    (monotonically increasing) and notifies subscribers — the front door's
    cache-invalidation + occ-refresh hook.  A failed merge changes nothing
    observable: the sources revert to FRESH, `merge_failures` increments,
    and queries keep unioning the old segments (chaos-tested).

Determinism
-----------
A merge rebuilds the merged segment with `builder.build_all` over the
concatenation of the source corpora — the same pure-numpy stream
construction, chunk by chunk, the one-shot build runs — so a fully merged
manager holds an index BIT-IDENTICAL to the one-shot build of the same
corpus: same stream contents, same packed blocks, same postings accounting.
Before full merge, multi-segment unions return identical doc/pos/score
results (doc ranges partition the corpus; shard-ascending concatenation is
the proven front-door merge), while `postings_read` accounting follows the
plan the union was EXECUTED with — pass `plan_index=` (e.g. the one-shot
index) to `search_batch` to replay accounting against a reference plan, the
same mechanism `serve.front` uses for its global plan.

Pivot invariance: every segment engine plans with CLUSTER-GLOBAL occurrence
counts (additive across segments: `occ_counts()` sums
`index.base_occ_counts()` over live segments), refreshed on every generation
bump — the `Planner.refresh_occ_counts` bugfix this module forced.
"""
from __future__ import annotations

import dataclasses
import threading

import numpy as np

from repro.core.builder import IndexParams, IndexSet, build_all
from repro.core.corpus import Corpus
from repro.core.planner import Planner

SEG_FRESH = "fresh"
SEG_MERGING = "merging"
SEG_RETIRED = "retired"


# ---------------------------------------------------------------------------
# corpus slicing helpers
# ---------------------------------------------------------------------------


def concat_corpora(parts: list[Corpus]) -> Corpus:
    """Concatenate doc-range corpora (doc ids renumber contiguously)."""
    parts = [p for p in parts if p.n_docs]
    if not parts:
        return Corpus(doc_offsets=np.zeros(1, np.int64),
                      tokens=np.empty(0, np.int32))
    offs = [np.asarray(parts[0].doc_offsets, np.int64)]
    base = int(parts[0].doc_offsets[-1])
    for p in parts[1:]:
        offs.append(np.asarray(p.doc_offsets[1:], np.int64) + base)
        base += int(p.doc_offsets[-1])
    return Corpus(doc_offsets=np.concatenate(offs),
                  tokens=np.concatenate([p.tokens for p in parts]))


def corpus_batches(corpus: Corpus, k: int) -> list[Corpus]:
    """Split a corpus into k contiguous doc-range batches (ingest feed;
    `concat_corpora(corpus_batches(c, k))` round-trips bit-exactly)."""
    k = max(1, min(int(k), corpus.n_docs)) if corpus.n_docs else 1
    offs = corpus.doc_offsets
    edges = [round(i * corpus.n_docs / k) for i in range(k + 1)]
    return [Corpus(doc_offsets=(offs[lo:hi + 1] - offs[lo]).copy(),
                   tokens=corpus.tokens[offs[lo]:offs[hi]].copy())
            for lo, hi in zip(edges[:-1], edges[1:])]


# ---------------------------------------------------------------------------
# segments
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class IndexSegment:
    """One immutable index over docs [doc_base, doc_base + n_docs).

    The corpus slice is retained: it is the merge input (merges REBUILD from
    text for bit-identity with the one-shot build, see module docstring) —
    the in-memory analogue of the stored fields every real engine keeps."""
    seg_id: int
    doc_base: int
    corpus: Corpus
    index: IndexSet
    state: str = SEG_FRESH

    @property
    def n_docs(self) -> int:
        return self.corpus.n_docs


class SegmentManager:
    """Mutable-corpus facade over immutable segments: `ingest()` appends doc
    batches as fresh segments, a background merger compacts them, and
    `search_batch()` serves the union — identical doc/pos/score results to
    the one-shot build at every generation (see module docstring).

    Thread safety: the segment list only ever changes under `_lock` and
    readers take an O(1) snapshot; segments themselves are immutable, so an
    in-flight search over a pre-merge snapshot stays valid after the swap
    (retired segments are dropped from the live list, not mutated)."""

    def __init__(self, lexicon, analyzer, params: IndexParams | None = None,
                 *, merge_threshold: int = 4, auto_merge: bool = True,
                 batch_impl: str = "ref", interpret: bool = True):
        self.lexicon = lexicon
        self.analyzer = analyzer
        self.params = params if params is not None else IndexParams()
        self.merge_threshold = max(2, int(merge_threshold))
        self.batch_impl = batch_impl
        self.interpret = interpret
        self._lock = threading.RLock()
        self._segments: list[IndexSegment] = []
        self._retired: list[IndexSegment] = []
        self._generation = 0
        self._next_seg_id = 0
        self._listeners: list = []
        self._backends: dict = {}        # seg_id -> serve.front.ShardBackend
        self._backends_gen = -1
        self._occ = None                 # cached global occ (per generation)
        self._planner = None             # cached union planner (per generation)
        self._planner_gen = -1
        self.merge_failures = 0
        self.merges_completed = 0
        # test hook: callable invoked at the top of every merge attempt —
        # raise to simulate a merger crash, sleep to widen the merge window
        self.merge_fault = None
        self._wake = threading.Event()
        self._closed = False
        self._merger = None
        if auto_merge:
            self._merger = threading.Thread(target=self._merge_loop,
                                            daemon=True,
                                            name="segment-merger")
            self._merger.start()

    # -- introspection -------------------------------------------------------

    @property
    def generation(self) -> int:
        with self._lock:
            return self._generation

    @property
    def segments(self) -> list[IndexSegment]:
        """Snapshot of the live segment list (doc_base ascending)."""
        with self._lock:
            return list(self._segments)

    @property
    def retired_segments(self) -> list[IndexSegment]:
        with self._lock:
            return list(self._retired)

    @property
    def n_docs(self) -> int:
        with self._lock:
            return sum(s.n_docs for s in self._segments)

    def occ_counts(self) -> np.ndarray:
        """Cluster-global occurrence counts: the elementwise sum of every
        live segment's `base_occ_counts()` (occurrences are additive over a
        doc partition) — what every segment planner pivots on."""
        with self._lock:
            return self._occ_locked().copy()

    def subscribe(self, fn) -> None:
        """`fn(generation)` is called after every generation bump (ingest or
        completed merge), outside the manager lock."""
        with self._lock:
            self._listeners.append(fn)

    # -- ingest --------------------------------------------------------------

    def ingest(self, batch: Corpus) -> int:
        """Index one document batch as a fresh segment; returns the new
        generation.  Doc ids continue from the current corpus end."""
        if batch.n_docs == 0:
            return self.generation
        index = build_all(batch, self.lexicon, self.analyzer, self.params)
        with self._lock:
            seg = IndexSegment(seg_id=self._next_seg_id,
                               doc_base=sum(s.n_docs for s in self._segments),
                               corpus=batch, index=index)
            self._next_seg_id += 1
            self._segments.append(seg)
            gen = self._bump_locked()
        self._notify(gen)
        self._wake.set()
        return gen

    # -- merge ---------------------------------------------------------------

    def merge_now(self) -> bool:
        """Synchronously merge ALL fresh segments into one (True when a merge
        ran and succeeded; False when <2 fresh segments, a merge is already
        in flight, or the merge failed — `merge_failures` tells which)."""
        return self._merge_once(min_sources=2)

    def _merge_once(self, min_sources: int) -> bool:
        with self._lock:
            if any(s.state == SEG_MERGING for s in self._segments):
                return False                  # one merge at a time
            srcs = [s for s in self._segments if s.state == SEG_FRESH]
            if len(srcs) < min_sources:
                return False
            for s in srcs:
                s.state = SEG_MERGING
        try:
            if self.merge_fault is not None:
                self.merge_fault()
            corpus = concat_corpora([s.corpus for s in srcs])
            index = build_all(corpus, self.lexicon, self.analyzer, self.params)
        except Exception:
            # crash containment: revert the sources, keep serving the old
            # generation — nothing observable changed, no results dropped
            with self._lock:
                for s in srcs:
                    s.state = SEG_FRESH
                self.merge_failures += 1
            return False
        with self._lock:
            merged = IndexSegment(seg_id=self._next_seg_id,
                                  doc_base=srcs[0].doc_base,
                                  corpus=corpus, index=index)
            self._next_seg_id += 1
            for s in srcs:
                s.state = SEG_RETIRED
            self._retired.extend(srcs)
            # segments ingested DURING the merge sit after the sources with
            # already-consistent doc bases: splice [merged] + tail
            self._segments = [merged] + [s for s in self._segments
                                         if s not in srcs]
            self.merges_completed += 1
            gen = self._bump_locked()
        self._notify(gen)
        return True

    def _merge_loop(self):
        while not self._closed:
            self._wake.wait(timeout=0.05)
            self._wake.clear()
            if self._closed:
                return
            try:
                while not self._closed \
                        and self._merge_once(min_sources=self.merge_threshold):
                    pass
            except Exception:                  # pragma: no cover
                pass                           # a merger bug must not die spinning

    def close(self):
        self._closed = True
        self._wake.set()
        if self._merger is not None:
            self._merger.join(timeout=30.0)

    # -- generation plumbing -------------------------------------------------

    def _bump_locked(self) -> int:
        self._generation += 1
        self._occ = None                       # occ is additive: re-sum lazily
        return self._generation

    def _notify(self, gen: int):
        for fn in list(self._listeners):
            try:
                fn(gen)
            except Exception:                  # pragma: no cover
                pass                           # listeners must not break ingest

    def _occ_locked(self) -> np.ndarray:
        if not self._segments:
            raise RuntimeError("SegmentManager has no segments — ingest first")
        if self._occ is None:
            occ = self._segments[0].index.base_occ_counts().astype(np.int64)
            for s in self._segments[1:]:
                occ = occ + s.index.base_occ_counts()
            self._occ = occ
        return self._occ

    # -- search --------------------------------------------------------------

    def current_planner(self) -> Planner:
        """A planner for the CURRENT generation: plans against the largest
        live segment's streams with cluster-global occ counts.  Plan
        STRUCTURE (tier splits, subplan count, pivot slots) is
        segment-invariant under the global-occ contract; resolved fetch
        lengths are that segment's — pass the result to
        `merge_shard_responses` as the union's accounting plan."""
        with self._lock:
            if self._planner_gen != self._generation:
                seg = max(self._segments, key=lambda s: s.n_docs)
                self._planner = Planner(seg.index,
                                        occ_counts=self._occ_locked())
                self._planner_gen = self._generation
            return self._planner

    def engine_backends(self) -> list:
        """One `serve.front.ShardBackend` per live segment (doc_base
        ascending), planning with cluster-global occ counts — directly
        pluggable into `FrontDoor(backends=...)` / `ShardDispatcher`.
        Backends are cached per segment and their occ snapshots refreshed on
        every generation bump; retired segments' backends are dropped."""
        from repro.serve.front import ShardBackend
        with self._lock:
            segs = list(self._segments)
            occ = self._occ_locked()
            live = {s.seg_id for s in segs}
            for sid in [sid for sid in self._backends if sid not in live]:
                del self._backends[sid]
            out = []
            for s in segs:
                b = self._backends.get(s.seg_id)
                if b is None:
                    b = ShardBackend(s.index, doc_base=s.doc_base,
                                     occ_counts=occ,
                                     batch_impl=self.batch_impl,
                                     interpret=self.interpret)
                    self._backends[s.seg_id] = b
                out.append(b)
            if self._backends_gen != self._generation:
                for b in self._backends.values():
                    b.engine.refresh_occ_counts(occ)
                self._backends_gen = self._generation
            return out

    def serve_backends(self, cfg, mesh) -> list:
        """One `SearchServe`-backed segment backend per live segment — the
        distributed serve tier unioned across segments exactly like the
        engine path (built fresh per call; serve tables are heavyweight)."""
        from repro.serve.search_serve import SearchServe
        with self._lock:
            segs = list(self._segments)
            occ = self._occ_locked()
        return [SegmentServeBackend(
            SearchServe(s.index, cfg, mesh, occ_counts=occ), s.doc_base)
            for s in segs]

    def search_batch(self, requests, backends=None, plan_index=None) -> list:
        """Union search across live segments: every segment answers every
        request (global-occ planning), responses merge shard-style.

        `plan_index` picks the index the ACCOUNTING plan is computed
        against (default: the largest live segment via `current_planner`) —
        pass the one-shot index to replay `postings_read` against it, the
        front-door mechanism for exact accounting parity.  `backends`
        overrides the engine backends (e.g. `serve_backends(...)`)."""
        from repro.serve.front import merge_shard_responses
        requests = list(requests)
        if backends is None:
            backends = self.engine_backends()
        if plan_index is None:
            planner = self.current_planner()
        else:
            planner = Planner(plan_index, occ_counts=self.occ_counts())
        plans = [planner.plan(list(r.surface_ids), mode=r.mode,
                              window=r.window, ranked=r.rank)
                 for r in requests]
        per_backend = [b(requests) for b in backends]
        out = []
        for qi, (r, plan) in enumerate(zip(requests, plans)):
            per_shard = [(si, per_backend[si][qi])
                         for si in range(len(backends))]
            out.append(merge_shard_responses(r, plan, per_shard))
        return out


class SegmentServeBackend:
    """Callable shard-backend adapter over one segment's `SearchServe`:
    answers for docs [doc_base, doc_base + n_docs), re-based globally."""

    def __init__(self, serve, doc_base: int):
        self.serve = serve
        self.doc_base = int(doc_base)

    def __call__(self, requests) -> list:
        resps = self.serve.search_batch(list(requests))
        if self.doc_base:
            base = np.int32(self.doc_base)
            for r in resps:
                r.doc = r.doc + base
                if r.doc_ids is not None:
                    r.doc_ids = r.doc_ids + base
        return resps

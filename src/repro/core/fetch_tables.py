"""Tensorized fetch tables — the shared plan→device schema.

The planner resolves every posting fetch to an explicit (start, length) slice
(planner.py); the batched executor (core/batch_executor.py) consumes those
plans as fixed-shape integer tables instead of Python loops, and the
distributed serve tier (serve/search_serve.py) runs the SAME tables — plus a
per-row `owner` column — inside shard_map over document shards.  There is
one schema, one tensorizer, one bucket step.

Every subplan of every query becomes one or more *rows* (one per doc shard
the seed list touches — the shard-segmented gather), with F fetch slots per
group carrying unions of morphological forms / expanded orientations /
stop-phrase parts / multi-component key lookups (QTYPE_MULTI windowed
near+stop plans: (s, v) pairs ride `pivot_from_dist` + `max_abs`, (s1, s2,
v) triples anchor at the pivot with `max_abs` alone — no schema additions)
/ long-list splits:

    start/length/offset/req_dist/max_abs : int32 [T, G, F]
    pivot_from_dist                      : bool  [T, G, F]
    score_from_dist                      : bool  [T, G, F] (ranked: slot delta
                                                            = |dist| payload)
    band                                 : int32 [T, G]
    active                               : bool  [T, G]
    doc_task                             : bool  [T]       (doc-level fallback)
    shard_base                           : int32 [T]       (row's first doc)
    score_bias                           : f32   [T]       (ranked: per-task
                                                            n_slots - n_groups)
    ns_packed                            : int16 [T, C, M]
    ns_valid                             : bool  [T, C, M]
    owner                                : int32 [T]       (serve only: dp shard)

Fetch `start`/`length` are POSTING ORDINALS into the unified device arena —
which, since the packed-store refactor, is a bit-packed block store
(core/postings.PackedPostings), not raw int32 columns.  Postings are grouped
into blocks of 128; ordinal `i` lives in block `i >> 7` at offset `i & 127`.
Per block and per field (doc, pos, dist) the arena holds an int32 *anchor*
(the block minimum) and a *width class* w ∈ {0, 1, 2, 4, 8, 16, 32} bits
(build-time, per block; every class divides the 32-bit lane so no value
straddles lane words), with the 128 deltas bit-packed into `lanes`.  Streams
are padded to block multiples so stream bases stay block-aligned.  The jit'd
step's gather therefore needs no new table columns and no new jit variants:
rows keep plain slices, and the per-block anchor/width metadata rides the
arena (one `blk_meta` [NB, 5] row + one lane word per field gathered per
posting by kernels/ops.unpack_postings — ref math or the Pallas unpack
kernel).

The intersect key domain is compact per-shard int32

    key = (doc - shard_base) << TABLE_POS_BITS | (pos - offset + TABLE_BIAS)

which is what the Pallas `banded_intersect` kernel operates on (TPU vector
units have no native int64 lane type).  DOCS_PER_SHARD bounds the shard size
so packed keys stay below 2**30 and `key ± band` can never wrap int32 (the
kernel's dense compare adds the band).

Group 0 is always the seed (the pivot / rarest band-0 list, or the
near-stop-checked pivot); groups 1..G-1 constrain it via banded-key
membership (band 0 = precise phrase, band W = word-set window).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.postings import NS_SHIFT

TABLE_POS_BITS = 17            # in-doc position < 131072
TABLE_BIAS = 64                # headroom so (pos - offset) never underflows
NO_DIST = np.int32(-128)       # req_dist wildcard (int8 dist can't reach it)
NO_MAX_ABS = np.int32(2**20)   # |dist| cap wildcard (always satisfied)

# doc_local must fit (30 - TABLE_POS_BITS) bits so packed keys stay < 2**30
DOCS_PER_SHARD = 1 << (30 - TABLE_POS_BITS)

# ranked scoring: constraint keys sort as (key << SCORE_DELTA_BITS | delta)
# int64 composites, so the FIRST entry of an equal-key run carries the run's
# minimum slot delta (|dist| <= near_window <= 15 fits 4 bits); one
# searchsorted then answers both "member within band?" and "at what delta?"
SCORE_DELTA_BITS = 4


def batch_table_specs(T: int, G: int, F: int, C: int, M: int,
                      owner: bool = False) -> dict:
    """ShapeDtypeStructs matching alloc_batch_tables (+ the serve-only
    `owner` column when requested)."""
    i32 = jnp.int32
    specs = {
        "start": jax.ShapeDtypeStruct((T, G, F), i32),
        "length": jax.ShapeDtypeStruct((T, G, F), i32),
        "offset": jax.ShapeDtypeStruct((T, G, F), i32),
        "req_dist": jax.ShapeDtypeStruct((T, G, F), i32),
        "max_abs": jax.ShapeDtypeStruct((T, G, F), i32),
        "pivot_from_dist": jax.ShapeDtypeStruct((T, G, F), jnp.bool_),
        "score_from_dist": jax.ShapeDtypeStruct((T, G, F), jnp.bool_),
        "band": jax.ShapeDtypeStruct((T, G), i32),
        "active": jax.ShapeDtypeStruct((T, G), jnp.bool_),
        "doc_task": jax.ShapeDtypeStruct((T,), jnp.bool_),
        "shard_base": jax.ShapeDtypeStruct((T,), i32),
        "score_bias": jax.ShapeDtypeStruct((T,), jnp.float32),
        "ns_packed": jax.ShapeDtypeStruct((T, C, M), jnp.int16),
        "ns_valid": jax.ShapeDtypeStruct((T, C, M), jnp.bool_),
    }
    if owner:
        specs["owner"] = jax.ShapeDtypeStruct((T,), i32)
    return specs


def alloc_batch_tables(T: int, G: int, F: int, C: int, M: int) -> dict:
    """Zero-initialized numpy tables per the batch-executor schema."""
    return {
        "start": np.zeros((T, G, F), np.int32),
        "length": np.zeros((T, G, F), np.int32),
        "offset": np.zeros((T, G, F), np.int32),
        "req_dist": np.full((T, G, F), NO_DIST, np.int32),
        "max_abs": np.full((T, G, F), NO_MAX_ABS, np.int32),
        "pivot_from_dist": np.zeros((T, G, F), bool),
        "score_from_dist": np.zeros((T, G, F), bool),
        "band": np.zeros((T, G), np.int32),
        "active": np.zeros((T, G), bool),
        "doc_task": np.zeros((T,), bool),
        "shard_base": np.zeros((T,), np.int32),
        "score_bias": np.zeros((T,), np.float32),
        "ns_packed": np.full((T, C, M), -1, np.int16),
        "ns_valid": np.zeros((T, C, M), bool),
    }


def pack_ns_checks(tables: dict, ti: int, stop_checks, max_distance: int):
    """Fill ns_packed/ns_valid row `ti` from planner (delta, ids) checks."""
    C, M = tables["ns_packed"].shape[1:]
    for ci, (delta, ids) in enumerate(stop_checks[:C]):
        for mi, sid in enumerate(ids[:M]):
            tables["ns_packed"][ti, ci, mi] = ((delta + max_distance) << NS_SHIFT) | sid
            tables["ns_valid"][ti, ci, mi] = True

"""Tensorized fetch tables — the shared plan→device schema.

The planner resolves every posting fetch to an explicit (start, length) slice
(planner.py); both batched execution paths consume those plans as fixed-shape
integer tables instead of Python loops:

* the **serve** path (serve/search_serve.py) packs one conjunctive plan per
  query into `[Q, G]` tables (one fetch per group, primary form) and runs
  them inside shard_map over document shards;
* the **engine** path (core/batch_executor.py) packs every subplan of every
  query into richer `[T, G, F]` tables (T tasks = subplans, F fetch slots
  per group, so unions of morphological forms / stop-phrase parts ride along)
  and runs the whole batch in one jit'd call.

Both share the same key domain: compact per-shard int32 keys

    key = doc_local << TABLE_POS_BITS | (pos - offset + TABLE_BIAS)

with doc_local = doc - shard * DOCS_PER_SHARD, which is the domain the Pallas
`banded_intersect` kernel operates on (TPU vector units have no native int64
lane type).  DOCS_PER_SHARD is chosen so packed keys stay below 2**30 and
`key ± band` can never wrap int32 (the kernel's dense compare adds the band).

Serve-table schema ([Q, G] per query batch; replicated to every shard):

    start/length/offset/req_dist/band : int32 [Q, G]
    active                            : bool  [Q, G]
    ns_packed                         : int16 [Q, C]   (type-4 pivot checks)

Batch-executor schema ([T, G, F] per task batch; see batch_executor.py):

    start/length/offset/req_dist/max_abs : int32 [T, G, F]
    pivot_from_dist                      : bool  [T, G, F]
    band                                 : int32 [T, G]
    active                               : bool  [T, G]
    doc_task                             : bool  [T]       (doc-level fallback)
    ns_packed                            : int16 [T, C, M]
    ns_valid                             : bool  [T, C, M]

Group 0 is always the seed (the pivot / rarest band-0 list, or the
near-stop-checked pivot); groups 1..G-1 constrain it via banded-key
membership (band 0 = precise phrase, band W = word-set window).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.postings import NS_SHIFT

TABLE_POS_BITS = 17            # in-doc position < 131072
TABLE_BIAS = 64                # headroom so (pos - offset) never underflows
SENT32 = np.int32(2**30 - 1)   # < int32 max so key + band never wraps
NO_DIST = np.int32(-128)       # req_dist wildcard (int8 dist can't reach it)
NO_MAX_ABS = np.int32(2**20)   # |dist| cap wildcard (always satisfied)

# doc_local must fit (30 - TABLE_POS_BITS) bits so packed keys stay < 2**30
DOCS_PER_SHARD = 1 << (30 - TABLE_POS_BITS)

# serve aliases (the original names; search_serve re-exports them)
SERVE_POS_BITS = TABLE_POS_BITS
SERVE_BIAS = TABLE_BIAS


def query_table_specs(cfg) -> dict:
    """ShapeDtypeStructs for one serve query batch (replicated to every
    shard).  `cfg` needs `.queries`, `.groups`, `.check_slots`."""
    Q, G, C = cfg.queries, cfg.groups, cfg.check_slots
    i32 = jnp.int32
    return {
        "start": jax.ShapeDtypeStruct((Q, G), i32),
        "length": jax.ShapeDtypeStruct((Q, G), i32),
        "offset": jax.ShapeDtypeStruct((Q, G), i32),
        "req_dist": jax.ShapeDtypeStruct((Q, G), i32),
        "band": jax.ShapeDtypeStruct((Q, G), i32),
        "active": jax.ShapeDtypeStruct((Q, G), jnp.bool_),
        "ns_packed": jax.ShapeDtypeStruct((Q, C), jnp.int16),
    }


def tensorize_plans(cfg, plans, stream_bases: dict | None = None,
                    lengths_cap: int | None = None, max_distance: int = 5):
    """Pack QueryPlans (AND-groups, primary fetch per group) into [Q, G]
    serve tables.

    The batched serve path executes the conjunctive plan (one fetch per
    group, primary morphological form); queries needing unions fall back to
    the flexible executor (or the engine's batch_executor, which keeps F
    fetch slots per group).  stream_bases maps fetch.stream -> arena offset
    (from serve.build_arenas).  Returns numpy tables per query_table_specs.

    `cfg` needs `.queries`, `.groups`, `.check_slots`, `.postings_pad`,
    `.p_seed`, `.n_basic`, `.n_expanded`.
    """
    Q, G, C = cfg.queries, cfg.groups, cfg.check_slots
    bases = stream_bases or {"basic": 0, "expanded": cfg.n_basic,
                             "stop": cfg.n_basic + cfg.n_expanded}
    t = {
        "start": np.zeros((Q, G), np.int32),
        "length": np.zeros((Q, G), np.int32),
        "offset": np.zeros((Q, G), np.int32),
        "req_dist": np.full((Q, G), NO_DIST, np.int32),
        "band": np.zeros((Q, G), np.int32),
        "active": np.zeros((Q, G), bool),
        "ns_packed": np.full((Q, C), -1, np.int16),
    }
    cap = lengths_cap or cfg.postings_pad
    for qi, plan in enumerate(plans[:Q]):
        sp = plan.subplans[0]
        groups = [g for g in sp.groups if g.fetches]
        # seed first: the near-stop-checked pivot if any, else a band-0 group
        groups = sorted(groups, key=lambda g: (not g.fetches[0].stop_checks
                                               if g.band == 0 else True, g.band))[: G]
        for gi, g in enumerate(groups):
            f = g.fetches[0]
            if f.stream not in bases:
                continue            # 'first'/'ordinary' stay on the flex path
            t["start"][qi, gi] = f.start + bases[f.stream]
            t["length"][qi, gi] = min(f.length, cfg.p_seed if gi == 0 else cap)
            t["offset"][qi, gi] = f.offset
            t["band"][qi, gi] = g.band
            t["active"][qi, gi] = True
            if f.required_dist is not None:
                t["req_dist"][qi, gi] = f.required_dist
            if gi == 0 and f.stop_checks:
                for ci, (delta, ids) in enumerate(f.stop_checks[:C]):
                    t["ns_packed"][qi, ci] = ((delta + max_distance) << NS_SHIFT) | ids[0]
    return t


def alloc_batch_tables(T: int, G: int, F: int, C: int, M: int) -> dict:
    """Zero-initialized numpy tables per the batch-executor schema."""
    return {
        "start": np.zeros((T, G, F), np.int32),
        "length": np.zeros((T, G, F), np.int32),
        "offset": np.zeros((T, G, F), np.int32),
        "req_dist": np.full((T, G, F), NO_DIST, np.int32),
        "max_abs": np.full((T, G, F), NO_MAX_ABS, np.int32),
        "pivot_from_dist": np.zeros((T, G, F), bool),
        "band": np.zeros((T, G), np.int32),
        "active": np.zeros((T, G), bool),
        "doc_task": np.zeros((T,), bool),
        "ns_packed": np.full((T, C, M), -1, np.int16),
        "ns_valid": np.zeros((T, C, M), bool),
    }


def pack_ns_checks(tables: dict, ti: int, stop_checks, max_distance: int):
    """Fill ns_packed/ns_valid row `ti` from planner (delta, ids) checks."""
    C, M = tables["ns_packed"].shape[1:]
    for ci, (delta, ids) in enumerate(stop_checks[:C]):
        for mi, sid in enumerate(ids[:M]):
            tables["ns_packed"][ti, ci, mi] = ((delta + max_distance) << NS_SHIFT) | sid
            tables["ns_valid"][ti, ci, mi] = True

"""Three-tier lexicon over *basic forms* (lemmas), per Veretennikov 2013.

The paper classifies the basic forms of words (not surface forms) into three
frequency tiers:

  * stop basic forms        (paper: 700 most frequent)
  * frequently-used forms   (paper: next 2 100)
  * ordinary forms          (everything else)

Basic-form IDs are assigned in frequency-rank order (id 0 = most frequent), so
tier membership is a pure range check and never needs a table lookup on device.
"""
from __future__ import annotations

import dataclasses

import numpy as np

TIER_STOP = 0
TIER_FREQUENT = 1
TIER_ORDINARY = 2

TIER_NAMES = {TIER_STOP: "stop", TIER_FREQUENT: "frequent", TIER_ORDINARY: "ordinary"}


@dataclasses.dataclass(frozen=True)
class LexiconConfig:
    """Synthetic-lexicon parameters.

    The paper's absolute tier sizes (700 stop / 2100 frequent) are kept; the
    vocabulary is scaled from Russian's ~200k basic forms to keep test-corpus
    build times reasonable while preserving the Zipf shape that makes the
    technique matter.
    """

    n_surface: int = 50_000       # surface vocabulary size
    n_base: int = 40_000          # number of distinct basic forms
    n_stop: int = 700             # paper: 700
    n_frequent: int = 2_100       # paper: 2100
    multi_form_frac: float = 0.12  # fraction of surfaces with 2 basic forms
    zipf_s: float = 1.0           # Zipf exponent for token sampling
    seed: int = 0

    def __post_init__(self):
        assert self.n_stop + self.n_frequent < self.n_base
        assert self.n_base <= self.n_surface


class Lexicon:
    """Tier structure over basic forms.

    Attributes
    ----------
    base_tier : [n_base] int8 — tier of each basic form.
    stop_local : [n_base] int32 — dense local id (0..n_stop-1) for stop forms,
        -1 otherwise.  Local ids are what gets packed into stop-phrase keys
        (10 bits each; requires n_stop <= 1024).
    """

    def __init__(self, config: LexiconConfig):
        self.config = config
        n = config.n_base
        self.base_tier = np.full(n, TIER_ORDINARY, dtype=np.int8)
        self.base_tier[: config.n_stop] = TIER_STOP
        self.base_tier[config.n_stop : config.n_stop + config.n_frequent] = TIER_FREQUENT
        self.stop_local = np.full(n, -1, dtype=np.int32)
        self.stop_local[: config.n_stop] = np.arange(config.n_stop, dtype=np.int32)
        if config.n_stop > 1024:
            raise ValueError("stop-phrase key packing supports at most 1024 stop forms")

    # -- tier predicates (vectorized over basic-form id arrays) --------------
    def tier(self, base_ids: np.ndarray) -> np.ndarray:
        return self.base_tier[base_ids]

    def is_stop(self, base_ids: np.ndarray) -> np.ndarray:
        return base_ids < self.config.n_stop

    def is_frequent(self, base_ids: np.ndarray) -> np.ndarray:
        c = self.config
        return (base_ids >= c.n_stop) & (base_ids < c.n_stop + c.n_frequent)

    def is_ordinary(self, base_ids: np.ndarray) -> np.ndarray:
        return base_ids >= self.config.n_stop + self.config.n_frequent

    def processing_distance(self, base_ids: np.ndarray) -> np.ndarray:
        """Paper: ProcessingDistance depends on the frequency of w (5..7).

        More frequent words get a *larger* window (they appear in more set
        phrases); we linearly step 7 -> 5 across the frequent tier.
        """
        c = self.config
        rank_in_tier = np.clip(base_ids - c.n_stop, 0, c.n_frequent - 1)
        third = c.n_frequent // 3  # thirds of the frequent tier
        pd = 7 - rank_in_tier // max(third, 1)
        return np.clip(pd, 5, 7).astype(np.int32)

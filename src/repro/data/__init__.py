"""Host-side input pipelines (numpy generators, device prefetch at the loop)."""

"""Synthetic click-log generator for the recsys zoo.

Labels come from a hidden factorization-machine teacher so training curves
move; behavior sequences are Markovian over the item vocabulary so MIND's
interest capsules have structure to find.
"""
from __future__ import annotations

import numpy as np


# Criteo-flavored vocabulary ladder: a mix of tiny and huge fields.
def criteo_vocabs(n_fields: int = 39, max_vocab: int = 1_000_000,
                  seed: int = 0) -> tuple:
    rng = np.random.default_rng(seed)
    ladder = [4, 16, 64, 256, 1024, 8192, 65536, 262144, max_vocab]
    return tuple(int(ladder[i % len(ladder)]) for i in range(n_fields))


class ClickLog:
    def __init__(self, field_vocabs: tuple, embed_dim: int = 8,
                 item_vocab: int = 100_000, seq_len: int = 20, seed: int = 0):
        self.field_vocabs = field_vocabs
        self.item_vocab = item_vocab
        self.seq_len = seq_len
        self.rng = np.random.default_rng(seed)
        # hidden teacher
        self.teacher = {
            f: self.rng.normal(scale=0.3, size=(v, embed_dim)).astype(np.float32)
            for f, v in enumerate(field_vocabs)
        }
        self.item_teacher = self.rng.normal(
            scale=0.3, size=(item_vocab, embed_dim)).astype(np.float32)

    def _field_ids(self, batch: int) -> np.ndarray:
        ids = np.empty((batch, len(self.field_vocabs)), np.int32)
        for f, v in enumerate(self.field_vocabs):
            # Zipf-ish within each field
            ids[:, f] = (self.rng.zipf(1.3, batch) - 1) % v
        return ids

    def ctr_batch(self, batch: int) -> dict:
        ids = self._field_ids(batch)
        z = np.zeros((batch, next(iter(self.teacher.values())).shape[1]), np.float32)
        for f in range(ids.shape[1]):
            z += self.teacher[f][ids[:, f]]
        logit = (z * z).sum(-1) - np.median((z * z).sum(-1))
        label = (self.rng.random(batch) < 1 / (1 + np.exp(-logit))).astype(np.int32)
        return {"ids": ids, "label": label}

    def seq_batch(self, batch: int) -> dict:
        """Behavior sequences + target item (+ profile fields + label)."""
        ids = self._field_ids(batch)
        # two "interest" anchors per user; items near anchors
        anchors = self.rng.integers(0, self.item_vocab, (batch, 2))
        which = self.rng.integers(0, 2, (batch, self.seq_len))
        noise = self.rng.integers(-50, 51, (batch, self.seq_len))
        hist = (np.take_along_axis(anchors, which, axis=1) + noise) % self.item_vocab
        pad = self.rng.random((batch, self.seq_len)) < 0.1
        hist = np.where(pad, -1, hist).astype(np.int32)
        pos = (anchors[:, 0] + self.rng.integers(-50, 51, batch)) % self.item_vocab
        neg = self.rng.integers(0, self.item_vocab, batch)
        take_pos = self.rng.random(batch) < 0.5
        target = np.where(take_pos, pos, neg).astype(np.int32)
        label = take_pos.astype(np.int32)
        return {"ids": ids, "hist": hist, "target": target, "label": label}

    def retrieval_batch(self, batch: int, n_candidates: int) -> dict:
        b = self.seq_batch(batch)
        b["cand"] = self.rng.integers(0, self.item_vocab, n_candidates).astype(np.int32)
        return b

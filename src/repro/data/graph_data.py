"""Graph generation + fanout neighbor sampling (GraphSAGE-style).

The generator builds homophilous cluster graphs (labels = clusters, features
= noisy prototypes, edges mostly intra-cluster) so GIN training actually
learns; the sampler produces fixed-shape padded subgraphs for jit.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class GraphData:
    features: np.ndarray     # [N, F] float32
    labels: np.ndarray       # [N] int32
    src: np.ndarray          # [E] int32 (directed; both directions present)
    dst: np.ndarray          # [E] int32
    adj_offsets: np.ndarray  # [N + 1] CSR over dst-sorted edges
    adj_nbrs: np.ndarray     # [E] neighbor ids (sources) per node

    @property
    def n_nodes(self) -> int:
        return len(self.features)

    @property
    def n_edges(self) -> int:
        return len(self.src)


def generate_graph(n_nodes: int, n_edges: int, d_feat: int, n_classes: int,
                   homophily: float = 0.85, seed: int = 0) -> GraphData:
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    protos = rng.normal(size=(n_classes, d_feat)).astype(np.float32)
    feats = protos[labels] + 0.8 * rng.normal(size=(n_nodes, d_feat)).astype(np.float32)

    m = n_edges // 2
    u = rng.integers(0, n_nodes, m)
    same = rng.random(m) < homophily
    # intra-cluster partner: random node of the same label (via per-label pools)
    order = np.argsort(labels, kind="stable")
    label_sorted = labels[order]
    starts = np.searchsorted(label_sorted, np.arange(n_classes))
    ends = np.searchsorted(label_sorted, np.arange(n_classes), side="right")
    lu = labels[u]
    span = np.maximum(ends[lu] - starts[lu], 1)
    v_same = order[starts[lu] + rng.integers(0, 1 << 62, m) % span]
    v_rand = rng.integers(0, n_nodes, m)
    v = np.where(same, v_same, v_rand).astype(np.int32)
    keep = u != v
    u, v = u[keep].astype(np.int32), v[keep]

    src = np.concatenate([u, v])
    dst = np.concatenate([v, u])
    order = np.argsort(dst, kind="stable")
    src, dst = src[order], dst[order]
    adj_offsets = np.zeros(n_nodes + 1, dtype=np.int64)
    np.cumsum(np.bincount(dst, minlength=n_nodes), out=adj_offsets[1:])
    return GraphData(features=feats, labels=labels, src=src, dst=dst,
                     adj_offsets=adj_offsets, adj_nbrs=src)


def full_graph_batch(g: GraphData, train_frac: float = 0.6, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    mask = rng.random(g.n_nodes) < train_frac
    return {
        "nodes": g.features,
        "src": g.src, "dst": g.dst,
        "edge_mask": np.ones(g.n_edges, bool),
        "labels": g.labels,
        "label_mask": mask,
        "node_mask": np.ones(g.n_nodes, bool),
    }


def sample_subgraph(g: GraphData, seeds: np.ndarray, fanouts: tuple,
                    rng: np.random.Generator) -> dict:
    """Fanout-sampled padded subgraph.  Local node order: seeds first, then
    each hop's sampled frontier (with duplicates merged).  Edges point
    sampled-neighbor -> target, both endpoints local."""
    max_nodes = len(seeds)
    f_prod = 1
    for f in fanouts:
        f_prod *= f
        max_nodes += len(seeds) * f_prod
    max_edges = max_nodes - len(seeds)

    local = {int(s): i for i, s in enumerate(seeds)}
    nodes = list(seeds)
    src_l, dst_l = [], []
    frontier = list(seeds)
    for f in fanouts:
        nxt = []
        for t in frontier:
            lo, hi = g.adj_offsets[t], g.adj_offsets[t + 1]
            deg = hi - lo
            if deg == 0:
                continue
            take = rng.integers(0, deg, size=min(f, int(deg)))
            for j in np.unique(take):
                nbr = int(g.adj_nbrs[lo + j])
                if nbr not in local:
                    local[nbr] = len(nodes)
                    nodes.append(nbr)
                src_l.append(local[nbr])
                dst_l.append(local[t])
                nxt.append(nbr)
        frontier = nxt
    n, e = len(nodes), len(src_l)
    nodes_arr = np.asarray(nodes, dtype=np.int64)
    feats = np.zeros((max_nodes, g.features.shape[1]), np.float32)
    feats[:n] = g.features[nodes_arr]
    src = np.zeros(max_edges, np.int32)
    dst = np.zeros(max_edges, np.int32)
    src[:e], dst[:e] = src_l, dst_l
    emask = np.zeros(max_edges, bool)
    emask[:e] = True
    labels = np.zeros(max_nodes, np.int32)
    labels[:n] = g.labels[nodes_arr]
    lmask = np.zeros(max_nodes, bool)
    lmask[: len(seeds)] = True          # supervise seeds only
    nmask = np.zeros(max_nodes, bool)
    nmask[:n] = True
    return {"nodes": feats, "src": src, "dst": dst, "edge_mask": emask,
            "labels": labels, "label_mask": lmask, "node_mask": nmask}


def partition_for_halo(g: GraphData, n_shards: int,
                       order_by_label: bool = True) -> dict:
    """Locality-aware partition for the halo-exchange GIN.

    Nodes are relabeled (cluster/label-sorted) and split into contiguous
    shards; each edge is assigned to its dst's shard; sources outside the
    shard go through the boundary exchange.  Returns stacked padded arrays
    (leading dim = n_shards) + the measured edge-cut fraction.
    """
    N = g.n_nodes
    order = np.argsort(g.labels, kind="stable") if order_by_label \
        else np.arange(N)
    new_id = np.empty(N, np.int64)
    new_id[order] = np.arange(N)
    Nl = (N + n_shards - 1) // n_shards
    src = new_id[g.src]
    dst = new_id[g.dst]
    shard_of = dst // Nl
    cut = float((src // Nl != dst // Nl).mean())

    El = int(np.bincount(shard_of, minlength=n_shards).max())
    # per-shard boundary lists: remote sources needed, deduped
    feats = np.zeros((n_shards, Nl, g.features.shape[1]), np.float32)
    labels = np.zeros((n_shards, Nl), np.int32)
    lmask = np.zeros((n_shards, Nl), bool)
    srcs = np.zeros((n_shards, El), np.int32)
    dsts = np.zeros((n_shards, El), np.int32)
    emask = np.zeros((n_shards, El), bool)
    halos = []
    feats_sorted = g.features[order]
    labels_sorted = g.labels[order]
    for s in range(n_shards):
        lo, hi = s * Nl, min((s + 1) * Nl, N)
        feats[s, : hi - lo] = feats_sorted[lo:hi]
        labels[s, : hi - lo] = labels_sorted[lo:hi]
        lmask[s, : hi - lo] = True
        esel = np.nonzero(shard_of == s)[0]
        e_src, e_dst = src[esel], dst[esel] - lo
        remote = e_src[(e_src < lo) | (e_src >= hi)]
        halo_nodes = np.unique(remote)
        halos.append(halo_nodes)
        srcs[s, : len(esel)] = 0   # filled after B is known
        dsts[s, : len(esel)] = e_dst
        emask[s, : len(esel)] = True
    B = max(int(max((len(h) for h in halos), default=1)), 1)
    send_idx = np.full((n_shards, B), -1, np.int32)
    # shard s needs halo_nodes; the OWNER shard must send them.  Build the
    # global boundary table as the union per owner, then point edge sources
    # at [local || all_gather(sends)] positions.
    need_by_owner: list[set] = [set() for _ in range(n_shards)]
    for s in range(n_shards):
        for nid in halos[s]:
            need_by_owner[int(nid // Nl)].add(int(nid))
    slot_of = {}
    for o in range(n_shards):
        rows = sorted(need_by_owner[o])[:B]
        for j, nid in enumerate(rows):
            send_idx[o, j] = nid - o * Nl
            slot_of[nid] = o * B + j
    for s in range(n_shards):
        lo, hi = s * Nl, min((s + 1) * Nl, N)
        esel = np.nonzero(shard_of == s)[0]
        e_src = src[esel]
        local = (e_src >= lo) & (e_src < hi)
        out = np.where(local, e_src - lo,
                       np.array([slot_of.get(int(x), 0) for x in e_src]) + Nl)
        srcs[s, : len(esel)] = out
        # drop edges whose remote source overflowed the boundary budget
        ok = local | np.array([int(x) in slot_of for x in e_src])
        emask[s, : len(esel)] &= ok
    return {"nodes": feats, "src": srcs, "dst": dsts, "edge_mask": emask,
            "labels": labels, "label_mask": lmask, "send_idx": send_idx,
            "cut_fraction": cut, "n_local": Nl, "boundary": B}


def molecule_batch(batch: int, n_nodes: int, n_edges: int, d_feat: int,
                   n_classes: int, seed: int = 0) -> dict:
    """Batched disjoint small graphs with graph-level labels (sum readout)."""
    rng = np.random.default_rng(seed)
    N, E = batch * n_nodes, batch * n_edges
    glabels = rng.integers(0, n_classes, batch).astype(np.int32)
    feats = rng.normal(size=(N, d_feat)).astype(np.float32)
    feats += glabels.repeat(n_nodes)[:, None] * 0.5
    base = np.arange(batch).repeat(n_edges) * n_nodes
    src = (rng.integers(0, n_nodes, E) + base).astype(np.int32)
    dst = (rng.integers(0, n_nodes, E) + base).astype(np.int32)
    return {"nodes": feats, "src": src, "dst": dst,
            "edge_mask": np.ones(E, bool),
            "labels": glabels, "label_mask": np.ones(batch, bool),
            "node_mask": np.ones(N, bool),
            "graph_id": np.arange(batch).repeat(n_nodes).astype(np.int32),
            "n_graphs": batch}

"""LM token pipeline: packs the framework's synthetic Zipf corpus (the same
generator the search engine indexes) into fixed-length training batches."""
from __future__ import annotations

import numpy as np

from repro.core.corpus import Corpus, CorpusConfig, generate_corpus
from repro.core.lexicon import LexiconConfig


def lm_batches(vocab: int, batch: int, seq_len: int, seed: int = 0,
               n_tokens: int | None = None):
    """Yields dict(tokens [B, S] int32, labels [B, S] int32) forever."""
    lex_cfg = LexiconConfig(n_surface=vocab, n_base=max(vocab // 2, 16),
                            n_stop=min(64, vocab // 8),
                            n_frequent=min(256, vocab // 4), seed=seed)
    need = n_tokens or (batch * (seq_len + 1) * 64)
    n_docs = max(need // 800, 8)
    corpus = generate_corpus(lex_cfg, CorpusConfig(n_docs=n_docs, mean_doc_len=800,
                                                   seed=seed))
    stream = corpus.tokens % vocab
    rng = np.random.default_rng(seed + 1)
    T = len(stream)
    while True:
        starts = rng.integers(0, T - seq_len - 1, size=batch)
        idx = starts[:, None] + np.arange(seq_len + 1)[None, :]
        window = stream[idx]
        yield {"tokens": window[:, :-1].astype(np.int32),
               "labels": window[:, 1:].astype(np.int32)}

"""Explicit collectives.

`make_ring_all_reduce` — bidirectional-naive ring reduce built from
`lax.ppermute` inside shard_map: the building block XLA lowers psum to on a
torus; spelled out here so the dry-run can account per-hop traffic and the
tests can compare against the fused psum.

`quantize_int8`/`dequantize_int8` + `compressed_psum_with_feedback` — int8
gradient all-reduce with error feedback (the residual carries this step's
quantization error into the next step, so compression noise is unbiased over
time and DP training still converges; see test_dist.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8: q = round(x / scale), scale = max|x|/127.

    Returns (q int8, scale f32 scalar).  Error is bounded by scale/2."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(xf / safe), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_with_feedback(grads, residual, axis_name: str):
    """int8-compressed psum over `axis_name` with error feedback.

    Per leaf: x = g + residual is quantized to int8; the reconstruction is
    all-reduced; the quantization error (x - dequant) becomes the new
    residual.  Returns (summed_grads fp32 tree, new_residual tree).  Callers
    divide by the axis size for the mean (train_loop does)."""
    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, scale = quantize_int8(x)
        deq = dequantize_int8(q, scale)
        summed = jax.lax.psum(deq, axis_name)
        return summed, x - deq

    pairs = jax.tree_util.tree_map(one, grads, residual)
    summed = jax.tree_util.tree_map(lambda _, p: p[0], grads, pairs)
    new_res = jax.tree_util.tree_map(lambda _, p: p[1], grads, pairs)
    return summed, new_res


def make_ring_all_reduce(mesh, axis_name: str):
    """Returns fn(x) -> all-reduced x; x sharded P(axis_name, ...) on `mesh`.

    n-1 ppermute hops, each shard accumulating its neighbour's block — the
    explicit spelling of a (naive) ring all-reduce.  Output is the full sum,
    still laid out P(axis_name, ...) (every shard's block holds the total)."""
    n = mesh.shape[axis_name]
    perm = [(i, (i + 1) % n) for i in range(n)]

    def local(x):
        acc, cur = x, x
        for _ in range(n - 1):
            cur = jax.lax.ppermute(cur, axis_name, perm)
            acc = acc + cur
        return acc

    return shard_map(local, mesh=mesh,
                     in_specs=P(axis_name), out_specs=P(axis_name),
                     check_vma=False)

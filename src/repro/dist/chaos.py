"""Fault injection for the serving stack (drives tests/test_front.py).

Production failure modes are reproduced deterministically, in-process:

  * shard stalls      — `ChaosShard(stall_s=...)` sleeps before answering,
                        longer than the dispatcher timeout when the test
                        wants a straggler dropped;
  * shard failures    — `ChaosShard(fail=True)` raises `ChaosError`;
  * queue floods      — tests submit `flood()` batches far above
                        `FrontDoorConfig.max_queue` while a stalled shard
                        pins the dispatcher, forcing admission control to
                        shed;
  * clock skew        — `SkewedClock` stands in for `time.monotonic` inside
                        the front door; jumping `skew_s` mid-run makes
                        previously-admitted deadlines unmeetable, the way a
                        stepped NTP clock or a GC/preemption pause does.

Everything is mutable mid-run (`set(...)`): a test can fail a primary for
one dispatch and heal it for the retry.  All state changes are plain
attribute writes guarded by the GIL — the dispatcher's worker threads only
ever read.
"""
from __future__ import annotations

import threading
import time


class ChaosError(RuntimeError):
    """The injected shard failure (distinguishable from real bugs)."""


class SkewedClock:
    """Monotonic clock with an injectable offset.  Callable — drop-in for
    `time.monotonic` wherever a component accepts a `clock=` parameter."""

    def __init__(self, skew_s: float = 0.0):
        self.skew_s = float(skew_s)

    def __call__(self) -> float:
        return time.monotonic() + self.skew_s


class ChaosShard:
    """Wrap a shard callable with injectable stall / failure behavior.

    >>> shard = ChaosShard(backend)        # healthy passthrough
    >>> shard.set(stall_s=1.0)             # straggler: sleeps, then answers
    >>> shard.set(fail=True, stall_s=0.0)  # raises ChaosError instead
    >>> shard.set()                        # heal

    `calls` counts every invocation (including failed ones) so tests can
    assert a replica actually absorbed the re-dispatch.
    """

    def __init__(self, fn, stall_s: float = 0.0, fail: bool = False):
        self.fn = fn
        self.stall_s = float(stall_s)
        self.fail = bool(fail)
        self.calls = 0
        self._lock = threading.Lock()

    def set(self, stall_s: float = 0.0, fail: bool = False):
        self.stall_s = float(stall_s)
        self.fail = bool(fail)

    def __call__(self, batch):
        with self._lock:
            self.calls += 1
        if self.stall_s > 0:
            time.sleep(self.stall_s)
        if self.fail:
            raise ChaosError(f"injected failure after {self.calls} calls")
        return self.fn(batch)


def flood(front, requests, client: str = "flood", wait: bool = True):
    """Submit every request as fast as possible (no pacing — the 4x-capacity
    queue-flood scenario) and return the tickets; `wait=True` blocks until
    every ticket resolves, which is exactly the no-silent-drop property: a
    dropped request would hang here forever (tests run under timeouts)."""
    tickets = [front.submit(r, client=client) for r in requests]
    if wait:
        for t in tickets:
            t.result()
    return tickets

"""Fault tolerance: supervised training with checkpoint restart, and
straggler-mitigating dispatch over replicated document shards.

`TrainSupervisor` — wraps a deterministic step function with periodic
checkpointing; an injected (or real) failure rolls back to the latest
checkpoint and replays.  Deterministic steps => exact state replay (tested).

`ShardDispatcher` — serving-side: every index shard has replicas; a shard
call that fails or exceeds `timeout` is re-dispatched to its replica, and
per-shard top-k results are merged (`merge_topk`).  This is the paper-system
analogue of search-cluster fan-out with stragglers.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as FutTimeout
from typing import Callable, Optional, Sequence

import numpy as np


# ---------------------------------------------------------------------------
# training supervision
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FailureReport:
    failures: int
    final_step: int
    restores: int = 0


class TrainSupervisor:
    """Run `step_fn` for n_steps under a checkpoint manager, surviving
    failures by rolling back to the latest checkpoint and replaying."""

    def __init__(self, ckpt_manager, save_every: int = 100,
                 max_restores: int = 100):
        self.mgr = ckpt_manager
        self.save_every = save_every
        self.max_restores = max_restores

    def run(self, state, step_fn: Callable, n_steps: int,
            failure_hook: Optional[Callable[[int], bool]] = None):
        """step_fn(state, step) -> state; failure_hook(step) -> True injects
        a failure *before* that step executes.  Returns (state, report).
        Raises RuntimeError after `max_restores` rollbacks — a fault that
        recurs at the same step would otherwise loop forever."""
        init_state = state
        self.mgr.save(0, state)
        step, failures, restores = 0, 0, 0
        while step < n_steps:
            nxt = step + 1
            if failure_hook is not None and failure_hook(nxt):
                failures += 1
                if restores >= self.max_restores:
                    raise RuntimeError(
                        f"unrecoverable: {restores} restores without "
                        f"completing step {nxt}")
                got_step, got = self.mgr.restore_latest(state)
                if got is None:
                    step, state = 0, init_state
                else:
                    step, state = int(got_step), got
                restores += 1
                continue
            state = step_fn(state, nxt)
            step = nxt
            if step % self.save_every == 0:
                self.mgr.save(step, state)
        if step % self.save_every != 0:
            self.mgr.save(step, state)
        return state, FailureReport(failures=failures, final_step=step,
                                    restores=restores)


# ---------------------------------------------------------------------------
# serving-side shard dispatch
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DispatchStats:
    total: int = 0           # dispatch() calls
    redispatched: int = 0    # shard calls that fell over to a replica
    failed: int = 0          # shard calls with no healthy replica either


class ShardDispatcher:
    """Fan a query batch out to every shard; failed/straggling shards are
    re-dispatched to their replicas.  shard_fns[i] and replica_fns[i] must
    answer for the same document range."""

    def __init__(self, shard_fns: Sequence[Callable],
                 replica_fns: Optional[Sequence[Callable]] = None,
                 timeout: float = 30.0):
        self.shard_fns = list(shard_fns)
        self.replica_fns = list(replica_fns) if replica_fns is not None else None
        self.timeout = timeout
        self.stats = DispatchStats()
        # 2x: a hung primary keeps occupying its worker thread past the
        # timeout, and its replica must still find a free one
        self._pool = ThreadPoolExecutor(max_workers=max(2 * len(self.shard_fns), 1))

    def dispatch(self, batch, shards: Optional[Sequence[int]] = None,
                 on_late: Optional[Callable] = None) -> list:
        """Returns one result per shard (replica result where the primary
        failed; None when both did).  The list is always len(shard_fns);
        `shards` restricts the fan-out to a subset of shard indices (the
        front door's bounded retry re-dispatches only the shards still
        missing), leaving every other slot None.

        All primaries are submitted up front and waited against a single
        shared deadline per phase (primaries, then replicas), so a dispatch
        costs at most 2*timeout wall clock no matter how many shards hang —
        max(latency), not sum(latency).  Caveat: Python threads can't be
        killed, so a shard fn that NEVER returns leaks its worker thread;
        the 2N-sized pool absorbs one such generation, persistent zombies
        need process-level supervision.

        `on_late(shard_i, result)` — when given, a shard call that merely
        EXCEEDED the deadline (as opposed to raising) gets a done-callback
        that delivers its eventual result after the dispatch returned: the
        straggler's work is not thrown away, the caller can backfill
        (serve.front re-merges it into the response cache).  Called from the
        straggler's worker thread; exceptions in the callback are swallowed
        (late delivery is best-effort by construction)."""
        self.stats.total += 1
        idxs = range(len(self.shard_fns)) if shards is None else shards
        futures = {i: self._pool.submit(self.shard_fns[i], batch)
                   for i in idxs}
        out: list = [None] * len(self.shard_fns)

        def collect(pending: dict) -> dict:
            """pending: {shard_i: future}; returns the shards that failed."""
            deadline = time.monotonic() + self.timeout
            failed = {}
            for i, fut in pending.items():
                try:
                    out[i] = fut.result(
                        timeout=max(0.0, deadline - time.monotonic()))
                except FutTimeout:
                    failed[i] = fut
                    if on_late is not None:
                        def _deliver(f, i=i):
                            try:
                                if f.cancelled() or f.exception() is not None:
                                    return
                                on_late(i, f.result())
                            except Exception:
                                pass
                        fut.add_done_callback(_deliver)
                except Exception:
                    failed[i] = fut
            return failed

        down = collect(futures)
        self.stats.redispatched += len(down)
        if self.replica_fns is None:
            self.stats.failed += len(down)
            return out
        retries = {i: self._pool.submit(self.replica_fns[i], batch)
                   for i in down}
        self.stats.failed += len(collect(retries))
        return out

    def close(self):
        """Release the worker pool without waiting on hung shard calls."""
        self._pool.shutdown(wait=False)


def merge_topk(results: Sequence, k: int) -> np.ndarray:
    """Merge per-shard [n_i, 2] (score, id) arrays into the global top-k by
    score (descending, stable)."""
    rows = [np.asarray(r, np.float64).reshape(-1, 2)
            for r in results if r is not None]
    if not rows:
        return np.empty((0, 2), np.float64)
    allrows = np.concatenate(rows, axis=0)
    order = np.argsort(-allrows[:, 0], kind="stable")
    return allrows[order][:k]

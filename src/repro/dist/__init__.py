"""Distribution substrate: explicit collectives (ring all-reduce, int8
gradient compression with error feedback), production sharding specs for the
launch cells, and fault tolerance (supervised training with restart +
straggler-mitigating shard dispatch)."""

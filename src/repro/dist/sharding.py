"""Production sharding specs for the launch cells (launch/steps.py).

Spec trees are derived from the *actual* parameter structure
(`jax.eval_shape` over init_params) so every leaf is covered regardless of
config flags (qkv_bias, MoE, tied embeddings, recsys model family), and a
dimension is only sharded when it divides the mesh axis — otherwise that
leaf falls back to replication instead of failing to lower.

Layouts:
  transformer 2d (default): Megatron TP on 'model' (wq/wk/wv/wg/wu column-
      parallel, wo/wd row-parallel, vocab-sharded embedding), DP on
      'data' (x 'pod').
  transformer fsdp: every leaf sharded over ALL mesh axes on its largest
      divisible dimension (ZeRO-3-style).
  recsys: embedding tables row-sharded over all axes; the dense tower is
      tiny and stays replicated.
  gnn: rows (nodes/edges) partitioned over every axis — the graph doesn't
      have a 'model' dimension worth TP.
"""
from __future__ import annotations

import functools

import jax
from jax.sharding import PartitionSpec as P


def dp_axis(mesh):
    """The data-parallel mesh axes ('pod' folds into DP when present)."""
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def gnn_dp_axis(mesh):
    """GNNs partition rows on ALL axes (no tensor-parallel dimension)."""
    return tuple(mesh.axis_names)


def _axes_size(mesh, axes) -> int:
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _shard_dim(shape, dim: int, axes, n: int) -> P:
    """P sharding `dim` over `axes` when divisible, else fully replicated."""
    if dim < len(shape) and shape[dim] % n == 0 and shape[dim] >= n:
        spec = [None] * len(shape)
        spec[dim] = axes
        return P(*spec)
    return P(*([None] * len(shape)))


def _largest_divisible(shape, axes, n: int) -> P:
    dims = sorted(range(len(shape)), key=lambda d: -shape[d])
    for d in dims:
        if shape[d] % n == 0 and shape[d] >= n:
            return _shard_dim(shape, d, axes, n)
    return P(*([None] * len(shape)))


def _spec_tree(struct, rule):
    """Map (key-path, ShapeDtypeStruct) -> P over the whole param tree."""
    return jax.tree_util.tree_map_with_path(rule, struct)


def _leaf_name(path) -> str:
    for entry in reversed(path):
        key = getattr(entry, "key", None)
        if isinstance(key, str):
            return key
    return ""


# ---------------------------------------------------------------------------
# transformer
# ---------------------------------------------------------------------------

# Megatron roles: which dim of the (layer-stacked) weight carries the
# TP-sharded axis.  Column-parallel = output dim; row-parallel = input dim.
_TFM_COL = {"wq", "wk", "wv", "wg", "wu", "bq", "bk", "bv"}
_TFM_ROW = {"wo", "wd"}


def transformer_param_specs(cfg, mesh, layout: str = "2d"):
    from repro.models import transformer as tfm
    struct = jax.eval_shape(functools.partial(tfm.init_params, cfg),
                            jax.random.PRNGKey(0))
    if layout == "fsdp":
        axes = tuple(mesh.axis_names)
        n = _axes_size(mesh, axes)
        return _spec_tree(struct, lambda p, l: _largest_divisible(l.shape, axes, n))

    tp = "model" if "model" in mesh.axis_names else None
    if tp is None:
        return _spec_tree(struct, lambda p, l: P(*([None] * l.ndim)))
    n = mesh.shape[tp]
    moe = bool(getattr(cfg, "moe", None))

    def rule(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        if name in ("embed", "lm_head"):
            # vocab-sharded (embed: [V, D] dim 0; lm_head: [D, V] dim 1)
            vdim = 0 if name == "embed" else 1
            return _shard_dim(shape, vdim, tp, n)
        in_layer = any(getattr(e, "key", None) == "layers" for e in path)
        if in_layer and name in _TFM_COL:
            return _shard_dim(shape, len(shape) - 1, tp, n)
        if in_layer and name in _TFM_ROW:
            # MoE experts: [Lx, E, F, D] -> prefer expert dim, else F
            if moe and len(shape) == 4:
                sp = _shard_dim(shape, 1, tp, n)
                return sp if sp != P(*([None] * 4)) else _shard_dim(shape, 2, tp, n)
            return _shard_dim(shape, len(shape) - 2, tp, n)
        if in_layer and moe and name in ("wg", "wu"):
            sp = _shard_dim(shape, 1, tp, n)
            return sp if sp != P(*([None] * len(shape))) else _shard_dim(shape, len(shape) - 1, tp, n)
        return P(*([None] * len(shape)))      # norms, router, biases w/o TP

    return _spec_tree(struct, rule)


def transformer_batch_specs(mesh) -> dict:
    dp = dp_axis(mesh)
    return {"tokens": P(dp, None), "labels": P(dp, None)}


def transformer_cache_specs(cfg, mesh, batch: int) -> dict:
    """KV cache [Lx, B, S, Hkv, hd]: batch-sharded on DP when divisible,
    else replicated (serving small batches on big meshes)."""
    dp = dp_axis(mesh)
    n = _axes_size(mesh, dp)
    bspec = dp if (batch % n == 0 and batch >= n) else None
    spec = P(None, bspec, None, None, None)
    return {"k": spec, "v": spec}


# ---------------------------------------------------------------------------
# recsys
# ---------------------------------------------------------------------------

_REC_TABLES = {"table", "item_table", "w_lin"}


def recsys_param_specs(cfg, mesh):
    from repro.models import recsys as rec
    struct = jax.eval_shape(functools.partial(rec.init_params, cfg),
                            jax.random.PRNGKey(0))
    axes = tuple(mesh.axis_names)
    n = _axes_size(mesh, axes)

    def rule(path, leaf):
        if _leaf_name(path) in _REC_TABLES:
            return _shard_dim(leaf.shape, 0, axes, n)
        return P(*([None] * leaf.ndim))

    return _spec_tree(struct, rule)


def recsys_batch_specs(cfg, mesh, retrieval: bool = False) -> dict:
    dp = dp_axis(mesh)
    out = {"ids": P(dp, None), "label": P(dp),
           "hist": P(dp, None), "target": P(dp)}
    if retrieval:
        out["cand"] = P()        # candidate set replicated; scores DP-sharded
    return out


# ---------------------------------------------------------------------------
# gnn
# ---------------------------------------------------------------------------

def gin_batch_specs(mesh) -> dict:
    ax = gnn_dp_axis(mesh)
    return {
        "nodes": P(ax, None),
        "src": P(ax),
        "dst": P(ax),
        "edge_mask": P(ax),
        "labels": P(ax),
        "label_mask": P(ax),
        "node_mask": P(ax),
        "send_idx": P(ax),
        "graph_id": P(ax),
    }

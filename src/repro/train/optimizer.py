"""Optimizers from scratch (no optax in this environment).

AdamW     — default for the LM / recsys / GNN examples and train_step.
SGDM      — plain momentum (baseline ablations).
Adafactor — factored second moments for memory-lean large-model training.

All states are pytrees mirroring the parameter tree, so they shard with the
same PartitionSpecs as the parameters (ZeRO-style sharding falls out of the
pjit in_shardings; see dist/sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"          # adamw | sgdm | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_ratio * lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda g: (g.astype(jnp.float32) * scale), tree), norm


# ---------------------------------------------------------------------------


def init_state(cfg: OptimizerConfig, params) -> dict[str, Any]:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    if cfg.name == "adamw":
        return {"step": jnp.zeros((), jnp.int32),
                "mu": jax.tree_util.tree_map(zeros, params),
                "nu": jax.tree_util.tree_map(zeros, params)}
    if cfg.name == "sgdm":
        return {"step": jnp.zeros((), jnp.int32),
                "mu": jax.tree_util.tree_map(zeros, params)}
    if cfg.name == "adafactor":
        def factored(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}
        return {"step": jnp.zeros((), jnp.int32),
                "v": jax.tree_util.tree_map(factored, params,
                                            is_leaf=lambda x: isinstance(x, jax.Array))}
    raise ValueError(cfg.name)


def apply_updates(cfg: OptimizerConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)

    if cfg.name == "adamw":
        b1, b2 = cfg.b1, cfg.b2
        mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                                    state["mu"], grads)
        nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                                    state["nu"], grads)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            u = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
            u = u + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, mu, nu)
        return new_params, {"step": step, "mu": mu, "nu": nu}, {"lr": lr, "grad_norm": gnorm}

    if cfg.name == "sgdm":
        mu = jax.tree_util.tree_map(lambda m, g: cfg.b1 * m + g, state["mu"], grads)
        new_params = jax.tree_util.tree_map(
            lambda p, m: (p.astype(jnp.float32)
                          - lr * (m + cfg.weight_decay * p.astype(jnp.float32))).astype(p.dtype),
            params, mu)
        return new_params, {"step": step, "mu": mu}, {"lr": lr, "grad_norm": gnorm}

    if cfg.name == "adafactor":
        d = 1 - cfg.b2 ** step.astype(jnp.float32)

        def upd(p, g, v):
            g2 = g * g + 1e-30
            if p.ndim >= 2:
                vr = cfg.b2 * v["vr"] + (1 - cfg.b2) * g2.mean(axis=-1)
                vc = cfg.b2 * v["vc"] + (1 - cfg.b2) * g2.mean(axis=-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(axis=-1, keepdims=True)[..., None], 1e-30))
                u = g / (jnp.sqrt(denom / d) + cfg.eps)
                nv = {"vr": vr, "vc": vc}
            else:
                nv = {"v": cfg.b2 * v["v"] + (1 - cfg.b2) * g2}
                u = g / (jnp.sqrt(nv["v"] / d) + cfg.eps)
            newp = (p.astype(jnp.float32) - lr * (u + cfg.weight_decay * p.astype(jnp.float32)))
            return newp.astype(p.dtype), nv

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = jax.tree_util.tree_leaves(grads)
        flat_v = tdef.flatten_up_to(state["v"])
        outs = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
        new_params = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
        new_v = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
        return new_params, {"step": step, "v": new_v}, {"lr": lr, "grad_norm": gnorm}

    raise ValueError(cfg.name)

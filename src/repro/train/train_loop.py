"""Training loop substrate.

make_train_step      — jit'd (params, opt_state, batch) step with gradient
                       accumulation via lax.scan over microbatches (donated
                       buffers; DP collectives overlap with the next
                       microbatch's backward under XLA latency hiding).
make_sharded_train_step — explicit shard_map DP variant whose gradient
                       all-reduce can be int8-compressed with error feedback
                       (dist/collectives.py); used for the distributed-
                       optimization ablations + tests.
fit                  — driver: data iterator, checkpoint manager, metrics.
"""
from __future__ import annotations

import functools
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.checkpoint import CheckpointManager
from repro.dist.collectives import compressed_psum_with_feedback
from repro.train import optimizer as opt


def make_train_step(loss_fn: Callable, opt_cfg: opt.OptimizerConfig,
                    accum_steps: int = 1, donate: bool = True):
    """loss_fn(params, batch) -> (loss, metrics dict).

    With accum_steps > 1, batch leaves must have a leading microbatch axis
    [accum, ...]; gradients are averaged across microbatches.
    """

    def step(params, opt_state, batch):
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def micro(carry, mb):
                acc = carry
                (l, m), g = grad_fn(params, mb)
                acc = jax.tree_util.tree_map(jnp.add, acc,
                                             jax.tree_util.tree_map(
                                                 lambda x: x.astype(jnp.float32), g))
                return acc, (l, m)
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            gsum, (losses, ms) = jax.lax.scan(micro, zero, batch)
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, gsum)
            loss = losses.mean()
            metrics = jax.tree_util.tree_map(lambda x: x.mean(), ms)
        new_params, new_state, om = opt.apply_updates(opt_cfg, params, grads, opt_state)
        metrics = dict(metrics, loss=loss, **om)
        return new_params, new_state, metrics

    return jax.jit(step, donate_argnums=(0, 1) if donate else ())


def make_sharded_train_step(loss_fn: Callable, opt_cfg: opt.OptimizerConfig,
                            mesh, dp_axis: str = "data",
                            compression: Optional[str] = None):
    """Explicit-DP step: params replicated, batch sharded over `dp_axis`;
    the gradient all-reduce is explicit (psum or int8+error feedback)."""

    def local_step(params, opt_state, residual, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        n = mesh.shape[dp_axis]
        if compression == "int8":
            grads, residual = compressed_psum_with_feedback(grads, residual, dp_axis)
            grads = jax.tree_util.tree_map(lambda g: g / n, grads)
        else:
            grads = jax.tree_util.tree_map(
                lambda g: jax.lax.psum(g.astype(jnp.float32), dp_axis) / n, grads)
        loss = jax.lax.psum(loss, dp_axis) / n
        new_params, new_state, om = opt.apply_updates(opt_cfg, params, grads, opt_state)
        return new_params, new_state, residual, dict(metrics, loss=loss, **om)

    rep = P()
    dp = P(dp_axis)
    fn = shard_map(local_step, mesh=mesh,
                   in_specs=(rep, rep, rep, dp),
                   out_specs=(rep, rep, rep, rep),
                   check_vma=False)
    return jax.jit(fn)


def init_residual(params):
    return jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def fit(params, loss_fn, opt_cfg: opt.OptimizerConfig, data_iter, n_steps: int,
        ckpt: Optional[CheckpointManager] = None, log_every: int = 10,
        accum_steps: int = 1, log_fn=print):
    """CPU-scale end-to-end driver used by the examples."""
    opt_state = opt.init_state(opt_cfg, params)
    step_fn = make_train_step(loss_fn, opt_cfg, accum_steps=accum_steps)
    start = 0
    if ckpt is not None:
        got = ckpt.restore_latest({"params": params, "opt": opt_state})
        if got[1] is not None:
            start, state = got
            params, opt_state = state["params"], state["opt"]
            log_fn(f"[fit] resumed from step {start}")
    history = []
    t0 = time.perf_counter()
    for step in range(start, n_steps):
        batch = next(data_iter)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (step + 1) % log_every == 0 or step == n_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            dt = (time.perf_counter() - t0) / (step - start + 1)
            history.append({"step": step + 1, **m})
            log_fn(f"[fit] step {step+1}/{n_steps} loss={m['loss']:.4f} "
                   f"({dt*1e3:.0f} ms/step)")
        if ckpt is not None and (step + 1) % (log_every * 5) == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt_state})
    if ckpt is not None:
        ckpt.save(n_steps, {"params": params, "opt": opt_state})
    return params, opt_state, history

from repro.train.optimizer import OptimizerConfig, apply_updates, init_state, lr_schedule

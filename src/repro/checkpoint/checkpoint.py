"""Fault-tolerant checkpointing.

Design points for the 1000-node posture:

  * atomic publish: write to `step_XXXX.tmp/`, fsync, rename — a crashed
    writer never corrupts the latest checkpoint;
  * keep-k retention with a monotonic step registry;
  * mesh-agnostic storage: arrays are saved as full (unsharded) numpy with
    their pytree structure, so a job can restore onto a *different* mesh
    (elastic resume) — the restore path re-shards via device_put with the
    target sharding tree;
  * per-leaf npz + a JSON manifest (structure, shapes, dtypes) so partial
    reads (e.g. params-only for serving) don't touch optimizer state.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out, treedef


def save_pytree(path: str, tree, step: int | None = None) -> None:
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "leaves": []}
    arrays = {}
    for i, (key, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        arrays[f"a{i}"] = arr
        manifest["leaves"].append({"key": key, "idx": i,
                                   "shape": list(arr.shape), "dtype": str(arr.dtype)})
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)


def restore_pytree(path: str, like, shardings=None):
    """Restore into the structure of `like`; optionally apply a sharding tree
    (elastic resume onto a new mesh)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_like, treedef = _flatten_with_paths(like)
    by_key = {l["key"]: l for l in manifest["leaves"]}
    leaves = []
    shard_flat = None
    if shardings is not None:
        shard_flat = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: x is None or hasattr(x, "spec"))
    for j, (key, leaf) in enumerate(flat_like):
        entry = by_key[key]
        arr = data[f"a{entry['idx']}"]
        if shard_flat is not None and shard_flat[j] is not None:
            leaves.append(jax.device_put(arr, shard_flat[j]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, [l for l in leaves])


class CheckpointManager:
    """keep-k retention + latest discovery over a checkpoint directory."""

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _step_dirs(self) -> list[tuple[int, str]]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append((int(name[5:]), os.path.join(self.dir, name)))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        dirs = self._step_dirs()
        return dirs[-1][0] if dirs else None

    def save(self, step: int, tree) -> str:
        path = os.path.join(self.dir, f"step_{step:08d}")
        save_pytree(path, tree, step=step)
        for s, p in self._step_dirs()[: -self.keep]:
            shutil.rmtree(p, ignore_errors=True)
        return path

    def restore_latest(self, like, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, None
        path = os.path.join(self.dir, f"step_{step:08d}")
        return step, restore_pytree(path, like, shardings)

from repro.checkpoint.checkpoint import CheckpointManager, restore_pytree, save_pytree

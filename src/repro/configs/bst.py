"""bst [recsys] embed_dim=32 seq_len=20 n_blocks=1 n_heads=8
mlp=1024-512-256 interaction=transformer-seq (Alibaba) [arXiv:1905.06874]."""
from repro.configs.registry import ArchSpec, RECSYS_SHAPES
from repro.data.recsys_data import criteo_vocabs
from repro.models.recsys import RecSysConfig


def make_config() -> RecSysConfig:
    return RecSysConfig(name="bst", model="bst",
                        field_vocabs=criteo_vocabs(8, max_vocab=200_000),
                        embed_dim=32, seq_len=20, n_blocks=1, bst_heads=8,
                        mlp_dims=(1024, 512, 256), item_vocab=1_000_000)


def make_smoke_config() -> RecSysConfig:
    return RecSysConfig(name="bst-smoke", model="bst",
                        field_vocabs=criteo_vocabs(4, max_vocab=200),
                        embed_dim=16, seq_len=8, n_blocks=1, bst_heads=4,
                        mlp_dims=(64, 32), item_vocab=1000)


SPEC = ArchSpec(arch_id="bst", family="recsys", make_config=make_config,
                make_smoke_config=make_smoke_config, shapes=RECSYS_SHAPES)

"""veretennikov [search] — the paper's own system as a serving architecture.

Per-shard arena sizes model the paper's 45 GB / ~130k-document corpus
(259 GB total index) document-partitioned over the dp axis; see
serve/search_serve.py.  Shapes cover interactive, bulk, and worst-case
(frequent-word-heavy) query mixes.
"""
from repro.configs.registry import ArchSpec
from repro.serve.search_serve import SearchServeConfig

# paper-scale postings per shard at 512 shards (scaled from measured
# postings-per-token ratios of the synthetic build; see benchmarks)
_BASE = dict(n_basic=10_000_000, n_expanded=17_000_000, n_stop=23_000_000,
             n_multi=12_000_000)

SEARCH_SHAPES = {
    "serve_batch": {"kind": "search_serve", "queries": 64, "postings_pad": 32768,
                    **_BASE},
    "serve_p99": {"kind": "search_serve", "queries": 8, "postings_pad": 8192,
                  **_BASE},
    "serve_heavy": {"kind": "search_serve", "queries": 16, "postings_pad": 262144,
                    **_BASE},
    "serve_bulk": {"kind": "search_serve", "queries": 256, "postings_pad": 16384,
                   **_BASE},
    # proximity-ranked serving (arXiv:2108.00410): the bucket step lowers
    # with the fused scoring pass and a float32 score output per row
    "serve_ranked": {"kind": "search_serve", "queries": 64,
                     "postings_pad": 32768, "ranked": True, **_BASE},
}


def make_config() -> SearchServeConfig:
    return SearchServeConfig(name="veretennikov", **_BASE)


def make_smoke_config() -> SearchServeConfig:
    return SearchServeConfig(name="veretennikov-smoke", queries=4, groups=3,
                             fetch_slots=2, postings_pad=256, check_slots=2,
                             n_basic=4096, n_expanded=4096, n_stop=4096,
                             n_first=1024, n_multi=4096)


SPEC = ArchSpec(arch_id="veretennikov", family="search", make_config=make_config,
                make_smoke_config=make_smoke_config, shapes=SEARCH_SHAPES)

"""autoint [recsys] n_sparse=39 embed_dim=16 n_attn_layers=3 n_heads=2
d_attn=32 interaction=self-attn [arXiv:1810.11921]."""
from repro.configs.registry import ArchSpec, RECSYS_SHAPES
from repro.data.recsys_data import criteo_vocabs
from repro.models.recsys import RecSysConfig


def make_config() -> RecSysConfig:
    return RecSysConfig(name="autoint", model="autoint",
                        field_vocabs=criteo_vocabs(39, max_vocab=1_000_000),
                        embed_dim=16, n_attn_layers=3, n_heads=2, d_attn=32)


def make_smoke_config() -> RecSysConfig:
    return RecSysConfig(name="autoint-smoke", model="autoint",
                        field_vocabs=criteo_vocabs(6, max_vocab=500),
                        embed_dim=16, n_attn_layers=2, n_heads=2, d_attn=8)


SPEC = ArchSpec(arch_id="autoint", family="recsys", make_config=make_config,
                make_smoke_config=make_smoke_config, shapes=RECSYS_SHAPES)

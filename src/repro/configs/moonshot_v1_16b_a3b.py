"""moonshot-v1-16b-a3b [moe] 48L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=163840, MoE 64 experts top-6 [hf:moonshotai/Moonlight-16B-A3B]."""
from repro.configs.registry import ArchSpec, LM_SHAPES
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig


def make_config() -> TransformerConfig:
    return TransformerConfig(
        name="moonshot-v1-16b-a3b", n_layers=48, d_model=2048, n_heads=16,
        n_kv_heads=16, d_ff=1408, vocab=163840, rope_theta=50_000.0,
        head_dim=128,
        moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408))


def make_smoke_config() -> TransformerConfig:
    import jax.numpy as jnp
    return TransformerConfig(
        name="moonshot-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, d_ff=96, vocab=512, rope_theta=50_000.0,
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=96), dtype=jnp.float32)


SPEC = ArchSpec(arch_id="moonshot-v1-16b-a3b", family="lm",
                make_config=make_config, make_smoke_config=make_smoke_config,
                shapes=LM_SHAPES)

"""fm [recsys] n_sparse=39 embed_dim=10 interaction=fm-2way — pairwise
<v_i, v_j> x_i x_j via the O(nk) sum-square trick [Rendle ICDM'10]."""
from repro.configs.registry import ArchSpec, RECSYS_SHAPES
from repro.data.recsys_data import criteo_vocabs
from repro.models.recsys import RecSysConfig


def make_config() -> RecSysConfig:
    return RecSysConfig(name="fm", model="fm",
                        field_vocabs=criteo_vocabs(39, max_vocab=1_000_000),
                        embed_dim=10)


def make_smoke_config() -> RecSysConfig:
    return RecSysConfig(name="fm-smoke", model="fm",
                        field_vocabs=criteo_vocabs(6, max_vocab=500),
                        embed_dim=10)


SPEC = ArchSpec(arch_id="fm", family="recsys", make_config=make_config,
                make_smoke_config=make_smoke_config, shapes=RECSYS_SHAPES)

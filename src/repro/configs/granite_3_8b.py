"""granite-3-8b [dense] 40L d_model=4096 32H (GQA kv=8) d_ff=12800
vocab=49155 — GQA [hf:ibm-granite/granite-3.0-2b-base; hf]."""
from repro.configs.registry import ArchSpec, LM_SHAPES
from repro.models.transformer import TransformerConfig


def make_config() -> TransformerConfig:
    return TransformerConfig(
        name="granite-3-8b", n_layers=40, d_model=4096, n_heads=32,
        n_kv_heads=8, d_ff=12800, vocab=49155, rope_theta=10_000.0)


def make_smoke_config() -> TransformerConfig:
    import jax.numpy as jnp
    return TransformerConfig(
        name="granite-3-8b-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=1, d_ff=384, vocab=512, rope_theta=10_000.0,
        dtype=jnp.float32)


SPEC = ArchSpec(arch_id="granite-3-8b", family="lm", make_config=make_config,
                make_smoke_config=make_smoke_config, shapes=LM_SHAPES)

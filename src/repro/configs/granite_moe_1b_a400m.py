"""granite-moe-1b-a400m [moe] 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from repro.configs.registry import ArchSpec, LM_SHAPES
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig


def make_config() -> TransformerConfig:
    return TransformerConfig(
        name="granite-moe-1b-a400m", n_layers=24, d_model=1024, n_heads=16,
        n_kv_heads=8, d_ff=512, vocab=49155, rope_theta=10_000.0,
        moe=MoEConfig(n_experts=32, top_k=8, d_expert=512))


def make_smoke_config() -> TransformerConfig:
    import jax.numpy as jnp
    return TransformerConfig(
        name="granite-moe-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=64, vocab=512, rope_theta=10_000.0,
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=64), dtype=jnp.float32)


SPEC = ArchSpec(arch_id="granite-moe-1b-a400m", family="lm",
                make_config=make_config, make_smoke_config=make_smoke_config,
                shapes=LM_SHAPES)

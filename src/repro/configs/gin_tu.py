"""gin-tu [gnn] n_layers=5 d_hidden=64 aggregator=sum eps=learnable
[arXiv:1810.00826].  d_feat / n_classes are shape-specific (each graph shape
is its own dataset); dataclasses.replace patches them per cell."""
from repro.configs.registry import ArchSpec, GNN_SHAPES
from repro.models.gnn import GINConfig


def make_config() -> GINConfig:
    return GINConfig(name="gin-tu", n_layers=5, d_hidden=64,
                     d_feat=1433, n_classes=7)


def make_smoke_config() -> GINConfig:
    return GINConfig(name="gin-tu-smoke", n_layers=2, d_hidden=16,
                     d_feat=8, n_classes=3)


SPEC = ArchSpec(arch_id="gin-tu", family="gnn", make_config=make_config,
                make_smoke_config=make_smoke_config, shapes=GNN_SHAPES)

"""llama3-8b [dense] 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — GQA 128k vocab [arXiv:2407.21783]."""
from repro.configs.registry import ArchSpec, LM_SHAPES
from repro.models.transformer import TransformerConfig


def make_config() -> TransformerConfig:
    return TransformerConfig(
        name="llama3-8b", n_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=8, d_ff=14336, vocab=128256, rope_theta=500_000.0)


def make_smoke_config() -> TransformerConfig:
    import jax.numpy as jnp
    return TransformerConfig(
        name="llama3-8b-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=1, d_ff=352, vocab=512, rope_theta=500_000.0,
        dtype=jnp.float32)


SPEC = ArchSpec(arch_id="llama3-8b", family="lm", make_config=make_config,
                make_smoke_config=make_smoke_config, shapes=LM_SHAPES)

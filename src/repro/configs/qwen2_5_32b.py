"""qwen2.5-32b [dense] 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064 — GQA, QKV bias [hf:Qwen/Qwen2.5-0.5B; hf]."""
from repro.configs.registry import ArchSpec, LM_SHAPES
from repro.models.transformer import TransformerConfig


def make_config() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2.5-32b", n_layers=64, d_model=5120, n_heads=40,
        n_kv_heads=8, d_ff=27648, vocab=152064, qkv_bias=True,
        rope_theta=1_000_000.0)


def make_smoke_config() -> TransformerConfig:
    import jax.numpy as jnp
    return TransformerConfig(
        name="qwen2.5-32b-smoke", n_layers=2, d_model=160, n_heads=5,
        n_kv_heads=1, d_ff=448, vocab=512, qkv_bias=True,
        rope_theta=1_000_000.0, dtype=jnp.float32)


SPEC = ArchSpec(arch_id="qwen2.5-32b", family="lm", make_config=make_config,
                make_smoke_config=make_smoke_config, shapes=LM_SHAPES)

from repro.configs.registry import ALL_ARCHS, ArchSpec, get_arch

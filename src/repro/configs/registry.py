"""Architecture registry: --arch <id> resolves here.

Every assigned architecture (plus the paper's own search engine) registers an
ArchSpec: full-scale config factory, reduced smoke config, and its shape set.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable

ALL_ARCHS = [
    "granite-3-8b", "qwen2.5-32b", "llama3-8b",
    "granite-moe-1b-a400m", "moonshot-v1-16b-a3b",
    "gin-tu",
    "fm", "mind", "autoint", "bst",
    "veretennikov",
]

_MODULES = {
    "granite-3-8b": "repro.configs.granite_3_8b",
    "qwen2.5-32b": "repro.configs.qwen2_5_32b",
    "llama3-8b": "repro.configs.llama3_8b",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b_a400m",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "gin-tu": "repro.configs.gin_tu",
    "fm": "repro.configs.fm",
    "mind": "repro.configs.mind",
    "autoint": "repro.configs.autoint",
    "bst": "repro.configs.bst",
    "veretennikov": "repro.configs.veretennikov",
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                       # lm | gnn | recsys | search
    make_config: Callable[[], Any]
    make_smoke_config: Callable[[], Any]
    shapes: dict                      # shape name -> shape params dict


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).SPEC


# Shared shape sets ---------------------------------------------------------

LM_SHAPES = {
    "train_4k": {"kind": "train", "seq_len": 4096, "global_batch": 256},
    "prefill_32k": {"kind": "prefill", "seq_len": 32768, "global_batch": 32},
    "decode_32k": {"kind": "decode", "seq_len": 32768, "global_batch": 128},
    "long_500k": {"kind": "decode", "seq_len": 524288, "global_batch": 1},
}

RECSYS_SHAPES = {
    "train_batch": {"kind": "train", "batch": 65536},
    "serve_p99": {"kind": "serve", "batch": 512},
    "serve_bulk": {"kind": "serve", "batch": 262144},
    "retrieval_cand": {"kind": "retrieval", "batch": 1, "n_candidates": 1_000_000},
}

GNN_SHAPES = {
    "full_graph_sm": {"kind": "train_full", "n_nodes": 2708, "n_edges": 10556,
                      "d_feat": 1433, "n_classes": 7},
    "minibatch_lg": {"kind": "train_minibatch", "n_nodes": 232965,
                     "n_edges": 114_615_892, "batch_nodes": 1024,
                     "fanout": (15, 10), "d_feat": 602, "n_classes": 41},
    "ogb_products": {"kind": "train_full", "n_nodes": 2_449_029,
                     "n_edges": 61_859_140, "d_feat": 100, "n_classes": 47},
    "molecule": {"kind": "train_graphs", "n_nodes": 30, "n_edges": 64,
                 "batch": 128, "d_feat": 16, "n_classes": 2},
}

"""mind [recsys] embed_dim=64 n_interests=4 capsule_iters=3
interaction=multi-interest [arXiv:1904.08030]."""
from repro.configs.registry import ArchSpec, RECSYS_SHAPES
from repro.data.recsys_data import criteo_vocabs
from repro.models.recsys import RecSysConfig


def make_config() -> RecSysConfig:
    return RecSysConfig(name="mind", model="mind",
                        field_vocabs=criteo_vocabs(8, max_vocab=200_000),
                        embed_dim=64, n_interests=4, capsule_iters=3,
                        seq_len=50, item_vocab=1_000_000)


def make_smoke_config() -> RecSysConfig:
    return RecSysConfig(name="mind-smoke", model="mind",
                        field_vocabs=criteo_vocabs(4, max_vocab=200),
                        embed_dim=16, n_interests=2, capsule_iters=2,
                        seq_len=8, item_vocab=1000)


SPEC = ArchSpec(arch_id="mind", family="recsys", make_config=make_config,
                make_smoke_config=make_smoke_config, shapes=RECSYS_SHAPES)

"""Serving launcher.

Two modes:
  search — build the paper's indexes over a synthetic corpus and serve a
           batched query stream through the tensorized serve step (the same
           step the dry-run lowers at 512 chips).
  lm     — greedy decode from a smoke LM with the KV cache serve_step.

    PYTHONPATH=src python -m repro.launch.serve --mode search --queries 32
    PYTHONPATH=src python -m repro.launch.serve --mode search --ranked --top-k 5
    PYTHONPATH=src python -m repro.launch.serve --mode lm --arch llama3-8b
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch


def serve_search(n_queries: int, ranked: bool = False, top_k: int = 10):
    from repro.core import (CorpusConfig, LexiconConfig, MODE_NEAR,
                            SearchRequest, build_all, generate_corpus,
                            make_lexicon_and_analyzer)
    from repro.launch.mesh import make_host_mesh
    from repro.serve.search_serve import SearchServe, SearchServeConfig
    lex_cfg = LexiconConfig(n_surface=20_000, n_base=15_000, n_stop=400,
                            n_frequent=1200, seed=0)
    lex, ana = make_lexicon_and_analyzer(lex_cfg)
    corpus = generate_corpus(lex_cfg, CorpusConfig(n_docs=300, seed=0))
    index = build_all(corpus, lex, ana)
    mesh = make_host_mesh(data=1, model=1)
    cfg = SearchServeConfig(queries=n_queries, postings_pad=8192,
                            seed_pad=2048, n_basic=1, n_expanded=1,
                            n_stop=1, n_first=1, n_multi=1)
    serve = SearchServe(index, cfg, mesh)

    rng = np.random.default_rng(0)
    requests = []
    while len(requests) < n_queries:
        d = int(rng.integers(corpus.n_docs))
        toks = corpus.doc(d)
        if len(toks) < 10:
            continue
        st = int(rng.integers(len(toks) - 6))
        if ranked:
            requests.append(SearchRequest(toks[st:st + 6:2].tolist(),
                                          mode=MODE_NEAR, rank=True,
                                          top_k=top_k))
        else:
            requests.append(SearchRequest(toks[st:st + 3].tolist()))
    results = serve.search_batch(requests)   # warm
    t0 = time.perf_counter()
    results = serve.search_batch(requests)
    dt = time.perf_counter() - t0
    label = "ranked top-%d" % top_k if ranked else "phrase"
    print(f"[serve/search] {n_queries} {label} queries in {dt*1e3:.1f} ms "
          f"({dt/n_queries*1e6:.0f} us/query, CPU, {serve.n_dp} doc shard(s)); "
          f"hit counts: {[len(r.doc) for r in results[:8]]}...")
    if ranked:
        r = next((r for r in results if r.doc_ids is not None
                  and len(r.doc_ids)), None)
        if r is not None:
            print(f"[serve/search] sample ranking: "
                  f"{[(h.doc, round(h.score, 3)) for h in r.hits[:5]]}")


def serve_lm(arch: str, n_tokens: int):
    from repro.models import transformer as tfm
    cfg = get_arch(arch).make_smoke_config()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    B, S_max = 2, 128
    cache = tfm.init_cache(cfg, B, S_max)
    tok = jnp.zeros((B,), jnp.int32)
    step = jax.jit(lambda p, c, t, i: tfm.decode_step(cfg, p, c, t, i))
    t0 = time.perf_counter()
    out = []
    for i in range(n_tokens):
        logits, cache = step(params, cache, tok, jnp.int32(i))
        tok = jnp.argmax(logits[:, : cfg.vocab], axis=-1).astype(jnp.int32)
        out.append(int(tok[0]))
    dt = time.perf_counter() - t0
    print(f"[serve/lm] {arch} decoded {n_tokens} tokens x batch {B} in "
          f"{dt*1e3:.0f} ms ({dt/n_tokens*1e3:.1f} ms/token, CPU smoke); "
          f"first 10: {out[:10]}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["search", "lm"], default="search")
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--queries", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--ranked", action="store_true",
                    help="near-mode queries with proximity ranking")
    ap.add_argument("--top-k", type=int, default=10)
    args = ap.parse_args()
    if args.mode == "search":
        serve_search(args.queries, ranked=args.ranked, top_k=args.top_k)
    else:
        serve_lm(args.arch, args.tokens)


if __name__ == "__main__":
    main()

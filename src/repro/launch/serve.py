"""Serving launcher.

Two modes:
  search — build the paper's indexes over a synthetic corpus and serve a
           query stream.  Default is closed-loop batch timing through the
           tensorized serve step (the same step the dry-run lowers at 512
           chips); passing --qps switches to an OPEN-LOOP Poisson arrival
           process through the serving front door (serve.front.FrontDoor)
           and reports what a latency SLO actually sees — p50/p95/p99 of
           per-request latency under load, plus shed/degraded counts —
           instead of closed-loop us/query (which hides queueing delay
           entirely: a closed loop only offers the next request after the
           previous one finished).
  lm     — greedy decode from a smoke LM with the KV cache serve_step.

    PYTHONPATH=src python -m repro.launch.serve --mode search --queries 32
    PYTHONPATH=src python -m repro.launch.serve --mode search --ranked --top-k 5
    PYTHONPATH=src python -m repro.launch.serve --mode search --qps 50 --duration 5
    PYTHONPATH=src python -m repro.launch.serve --mode lm --arch llama3-8b
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch


def _search_world(n_queries: int, ranked: bool, top_k: int):
    """The launcher's synthetic serving world: lexicon, corpus, full index
    set, and a repeatable query workload (shared by both loop modes)."""
    from repro.core import (CorpusConfig, LexiconConfig, MODE_NEAR,
                            SearchRequest, build_all, generate_corpus,
                            make_lexicon_and_analyzer)
    lex_cfg = LexiconConfig(n_surface=20_000, n_base=15_000, n_stop=400,
                            n_frequent=1200, seed=0)
    lex, ana = make_lexicon_and_analyzer(lex_cfg)
    corpus = generate_corpus(lex_cfg, CorpusConfig(n_docs=300, seed=0))
    index = build_all(corpus, lex, ana)
    rng = np.random.default_rng(0)
    requests = []
    while len(requests) < n_queries:
        d = int(rng.integers(corpus.n_docs))
        toks = corpus.doc(d)
        if len(toks) < 10:
            continue
        st = int(rng.integers(len(toks) - 6))
        if ranked:
            requests.append(SearchRequest(toks[st:st + 6:2].tolist(),
                                          mode=MODE_NEAR, rank=True,
                                          top_k=top_k))
        else:
            requests.append(SearchRequest(toks[st:st + 3].tolist()))
    return index, requests


def serve_search(n_queries: int, ranked: bool = False, top_k: int = 10):
    from repro.launch.mesh import make_host_mesh
    from repro.serve.search_serve import SearchServe, SearchServeConfig
    index, requests = _search_world(n_queries, ranked, top_k)
    mesh = make_host_mesh(data=1, model=1)
    cfg = SearchServeConfig(queries=n_queries, postings_pad=8192,
                            seed_pad=2048, n_basic=1, n_expanded=1,
                            n_stop=1, n_first=1, n_multi=1)
    serve = SearchServe(index, cfg, mesh)
    results = serve.search_batch(requests)   # warm
    t0 = time.perf_counter()
    results = serve.search_batch(requests)
    dt = time.perf_counter() - t0
    label = "ranked top-%d" % top_k if ranked else "phrase"
    print(f"[serve/search] {n_queries} {label} queries in {dt*1e3:.1f} ms "
          f"({dt/n_queries*1e6:.0f} us/query, CPU, {serve.n_dp} doc shard(s)); "
          f"hit counts: {[len(r.doc) for r in results[:8]]}...")
    if ranked:
        r = next((r for r in results if r.doc_ids is not None
                  and len(r.doc_ids)), None)
        if r is not None:
            print(f"[serve/search] sample ranking: "
                  f"{[(h.doc, round(h.score, 3)) for h in r.hits[:5]]}")


def serve_search_open_loop(qps: float, duration: float, deadline_ms: float,
                           ranked: bool = False, top_k: int = 10,
                           n_queries: int = 64):
    """Open-loop load: Poisson arrivals at `qps` through the front door for
    `duration` seconds.  Unlike the closed loop above, arrivals do NOT wait
    for completions, so queueing delay is measured, not hidden — the
    latencies reported here are what a client-side SLO would see."""
    import dataclasses as _dc

    from repro.serve import FrontDoor, FrontDoorConfig
    index, requests = _search_world(n_queries, ranked, top_k)
    cfg = FrontDoorConfig(default_deadline_ms=deadline_ms, cache_capacity=0,
                          shard_timeout_s=max(60.0, 4 * deadline_ms / 1000.0))
    front = FrontDoor(index, cfg=cfg)
    # warm the jit caches outside the measured window (generous deadline).
    # Open-loop micro-batches come in many sizes, and the serve executor
    # pow2-buckets its task rows — ramp the warm batches so every chunk
    # shape the measured window can hit is already compiled.
    warm = [_dc.replace(r, deadline_ms=600_000.0) for r in requests]
    n = 1
    while n < len(warm):
        front.search_batch(warm[:n])
        n *= 2
    front.search_batch(warm)
    front.stats = type(front.stats)()

    rng = np.random.default_rng(1)
    tickets = []
    t0 = time.monotonic()
    t_end = t0 + duration
    i = 0
    while time.monotonic() < t_end:
        tickets.append(front.submit(requests[i % len(requests)]))
        i += 1
        time.sleep(rng.exponential(1.0 / qps))
    resps = [t.result() for t in tickets]
    elapsed = time.monotonic() - t0
    front.close()
    lat = np.array([r.latency_ms for r in resps])
    p50, p95, p99 = np.percentile(lat, [50, 95, 99])
    st = front.stats
    label = "ranked top-%d" % top_k if ranked else "phrase"
    print(f"[serve/search] open-loop {label}: offered "
          f"{len(resps) / elapsed:.1f} qps for {elapsed:.1f} s "
          f"({len(resps)} requests, deadline {deadline_ms:.0f} ms): "
          f"p50 {p50:.1f} ms, p95 {p95:.1f} ms, p99 {p99:.1f} ms; "
          f"exact {st.served_exact}, degraded {st.served_degraded}, "
          f"shed {st.shed} (shed_rate {st.shed_rate:.3f})")


def serve_lm(arch: str, n_tokens: int):
    from repro.models import transformer as tfm
    cfg = get_arch(arch).make_smoke_config()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    B, S_max = 2, 128
    cache = tfm.init_cache(cfg, B, S_max)
    tok = jnp.zeros((B,), jnp.int32)
    step = jax.jit(lambda p, c, t, i: tfm.decode_step(cfg, p, c, t, i))
    t0 = time.perf_counter()
    out = []
    for i in range(n_tokens):
        logits, cache = step(params, cache, tok, jnp.int32(i))
        tok = jnp.argmax(logits[:, : cfg.vocab], axis=-1).astype(jnp.int32)
        out.append(int(tok[0]))
    dt = time.perf_counter() - t0
    print(f"[serve/lm] {arch} decoded {n_tokens} tokens x batch {B} in "
          f"{dt*1e3:.0f} ms ({dt/n_tokens*1e3:.1f} ms/token, CPU smoke); "
          f"first 10: {out[:10]}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["search", "lm"], default="search")
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--queries", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--ranked", action="store_true",
                    help="near-mode queries with proximity ranking")
    ap.add_argument("--top-k", type=int, default=10)
    ap.add_argument("--qps", type=float, default=0.0,
                    help="open-loop Poisson arrival rate through the front "
                         "door (0 = closed-loop batch timing)")
    ap.add_argument("--duration", type=float, default=5.0,
                    help="open-loop measurement window, seconds")
    ap.add_argument("--deadline-ms", type=float, default=500.0,
                    help="open-loop per-request deadline")
    args = ap.parse_args()
    if args.mode == "search":
        if args.qps > 0:
            serve_search_open_loop(args.qps, args.duration, args.deadline_ms,
                                   ranked=args.ranked, top_k=args.top_k,
                                   n_queries=args.queries)
        else:
            serve_search(args.queries, ranked=args.ranked, top_k=args.top_k)
    else:
        serve_lm(args.arch, args.tokens)


if __name__ == "__main__":
    main()

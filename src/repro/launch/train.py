"""Training launcher: `--arch <id>` resolves the registry, builds the data
pipeline for the family, and trains under checkpoint/restart supervision.

CPU-scale runs use the smoke config by default (`--full` selects the real
one — on this container that is only practical for the dry-run, which is
`repro.launch.dryrun`'s job).

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --steps 50
    PYTHONPATH=src python -m repro.launch.train --arch gin-tu --shape molecule
    PYTHONPATH=src python -m repro.launch.train --arch fm --steps 30
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.registry import get_arch
from repro.train import OptimizerConfig
from repro.train.train_loop import fit


def _lm_setup(cfg, batch, seq):
    from repro.data.lm_data import lm_batches
    from repro.models import transformer as tfm
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    data = lm_batches(cfg.vocab, batch=batch, seq_len=seq, seed=0)
    return params, data, lambda p, b: tfm.loss_fn(cfg, p, b)


def _gnn_setup(cfg, shape_name):
    from repro.data import graph_data
    from repro.models import gnn
    if shape_name == "molecule":
        cfg = dataclasses.replace(cfg, graph_readout=True)
        params = gnn.init_params(cfg, jax.random.PRNGKey(0))

        def gen():
            seed = 0
            while True:
                b = graph_data.molecule_batch(8, 12, 24, cfg.d_feat,
                                              cfg.n_classes, seed=seed)
                seed += 1
                yield {k: v for k, v in b.items() if k != "n_graphs"}

        extra = {"n_graphs": 8}
        return params, gen(), (lambda p, b: gnn.loss_fn(cfg, p, dict(b, **extra)))
    g = graph_data.generate_graph(600, 4000, cfg.d_feat, cfg.n_classes, seed=0)
    params = gnn.init_params(cfg, jax.random.PRNGKey(0))
    if shape_name == "minibatch_lg":
        rng = np.random.default_rng(0)

        def gen():
            while True:
                seeds = rng.integers(0, g.n_nodes, 32)
                yield graph_data.sample_subgraph(g, seeds, (5, 3), rng)

        return params, gen(), (lambda p, b: gnn.loss_fn(cfg, p, b))

    full = graph_data.full_graph_batch(g)

    def gen():
        while True:
            yield full

    return params, gen(), (lambda p, b: gnn.loss_fn(cfg, p, b))


def _recsys_setup(cfg, batch):
    from repro.data.recsys_data import ClickLog
    from repro.models import recsys
    params = recsys.init_params(cfg, jax.random.PRNGKey(0))
    log = ClickLog(cfg.field_vocabs, item_vocab=cfg.item_vocab,
                   seq_len=cfg.seq_len, seed=0)
    seq = cfg.model in ("bst", "mind")

    def gen():
        while True:
            yield log.seq_batch(batch) if seq else log.ctr_batch(batch)

    return params, gen(), (lambda p, b: recsys.loss_fn(cfg, p, b))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None, help="gnn: which graph regime")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full", action="store_true",
                    help="full-scale config (dry-run scale; not for CPU)")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = spec.make_config() if args.full else spec.make_smoke_config()
    if spec.family == "lm":
        params, data, loss_fn = _lm_setup(cfg, args.batch, args.seq)
    elif spec.family == "gnn":
        params, data, loss_fn = _gnn_setup(cfg, args.shape or "full_graph_sm")
    elif spec.family == "recsys":
        params, data, loss_fn = _recsys_setup(cfg, args.batch)
    else:
        raise SystemExit(f"--arch {args.arch}: family {spec.family} is served, "
                         "not trained (see repro.launch.serve)")

    n_params = sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))
    print(f"[train] arch={args.arch} family={spec.family} params={n_params:,}")
    ckpt = CheckpointManager(args.ckpt, keep=2) if args.ckpt else None
    _, _, hist = fit(params, loss_fn,
                     OptimizerConfig(lr=args.lr, warmup_steps=5,
                                     decay_steps=max(args.steps, 10)),
                     data, n_steps=args.steps, ckpt=ckpt, log_every=10)
    print(f"[train] loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()

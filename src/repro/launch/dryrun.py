import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell on
the production meshes, record memory/cost analysis + collective schedule.

The two lines above MUST stay the first statements in this module: jax locks
the device count on first init, and the dry-run needs 512 host devices.
Nothing else in the framework sets XLA_FLAGS (smoke tests see 1 device).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --arch all            # 40+ cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs.registry import ALL_ARCHS, get_arch
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", ".."))


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             out_dir: str | None = None, verbose: bool = True,
             layout: str = "2d") -> dict:
    from benchmarks import roofline as rl

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    cell = build_cell(arch_id, shape_name, mesh, layout=layout)
    t0 = time.time()
    with mesh:
        jitted = jax.jit(cell.step, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate)
        lowered = jitted.lower(*cell.in_specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):      # older jax: one dict per device
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = rl.parse_collectives(hlo)

    # XLA:CPU cost_analysis counts while bodies once; use the loop-aware
    # HLO analyzer for the roofline terms and keep the raw numbers alongside.
    looped = rl.parse_hlo_costs(hlo)
    flops_dev = float(looped["flops"])
    bytes_dev = float(looped["bytes"])
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    terms = rl.roofline_terms(flops_dev, bytes_dev, float(coll.total_bytes), chips)
    spec = get_arch(arch_id)
    mflops = rl.model_flops_for(dict(cell.meta, ns_k=20), spec.family, cell.kind)

    record = {
        "arch": arch_id, "shape": shape_name, "kind": cell.kind,
        "layout": layout,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "t_lower_s": round(t_lower, 1), "t_compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "temp_size_in_bytes", 0) or 0)
                          + (getattr(mem, "argument_size_in_bytes", 0) or 0)
                          + (getattr(mem, "output_size_in_bytes", 0) or 0),
        },
        "cost": {"flops_per_device": flops_dev, "bytes_per_device": bytes_dev,
                 "raw_cost_analysis_flops": raw_flops,
                 "raw_cost_analysis_bytes": raw_bytes},
        "collectives": {"bytes_by_type": coll.bytes_by_type,
                        "op_counts": coll.op_counts,
                        "total_bytes_per_device": coll.total_bytes},
        "roofline": terms,
        "model_flops": mflops,
        "useful_ratio": (mflops / terms["hlo_flops_global"]
                         if terms["hlo_flops_global"] else None),
        "meta": cell.meta,
    }
    if verbose:
        print(f"=== {arch_id} / {shape_name} / {record['mesh']} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        print(f"  memory_analysis: {record['memory']}")
        print(f"  cost_analysis: flops/dev={flops_dev:.3e} bytes/dev={bytes_dev:.3e}")
        print(f"  collectives: {coll.bytes_by_type}")
        print(f"  roofline: compute={terms['t_compute_s']:.3e}s "
              f"memory={terms['t_memory_s']:.3e}s "
              f"collective={terms['t_collective_s']:.3e}s "
              f"-> dominant={terms['dominant']}")
        ratio = record["useful_ratio"]
        print(f"  MODEL_FLOPS={mflops:.3e} useful_ratio="
              f"{ratio:.3f}" if ratio is not None else "  MODEL_FLOPS n/a")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch_id}__{shape_name}__{record['mesh'].replace('x', '_')}"
        if layout != "2d":
            tag += f"__{layout}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(record, f, indent=1)
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--layout", default="2d", choices=["2d", "fsdp"],
                    help="LM train sharding: 2d = TPxFSDP; fsdp = pure ZeRO-3")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--stop-on-error", action="store_true")
    args = ap.parse_args()

    archs = ALL_ARCHS if args.arch == "all" else [args.arch]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    failures = []
    for arch in archs:
        spec = get_arch(arch)
        shapes = list(spec.shapes) if args.shape == "all" else [args.shape]
        for shape in shapes:
            for mp in meshes:
                try:
                    run_cell(arch, shape, mp, out_dir=args.out,
                             layout=args.layout)
                except Exception as e:
                    failures.append((arch, shape, mp, repr(e)))
                    print(f"!!! FAILED {arch}/{shape}/mp={mp}: {e}")
                    traceback.print_exc()
                    if args.stop_on_error:
                        raise
    print(f"\ndone; {len(failures)} failures")
    for f in failures:
        print("  FAILED:", f)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

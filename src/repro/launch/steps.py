"""Cell builder for the multi-pod dry-run: for every (arch x shape x mesh)
returns the step function, ShapeDtypeStruct inputs, and sharding trees.

Kinds per family:
  lm:     train (train_step: fwd+bwd+AdamW), prefill (forward_with_cache),
          decode (decode_step over a KV cache; SP when batch < |dp|)
  gnn:    train_full / train_minibatch / train_graphs (all train_step)
  recsys: train (train_step), serve (serve_scores), retrieval (top-k scoring)
  search: search_serve (document-sharded batched phrase queries)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import get_arch
from repro.dist import sharding as shr
from repro.models import gnn as gnn_m
from repro.models import recsys as rec_m
from repro.models import transformer as tfm
from repro.serve import search_serve as ss
from repro.train import optimizer as opt


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    kind: str
    step: Callable
    in_specs: tuple                  # ShapeDtypeStructs (positional)
    in_shardings: tuple
    out_shardings: Any               # None = auto
    donate: tuple = ()
    meta: dict = dataclasses.field(default_factory=dict)


OPT_CFG = opt.OptimizerConfig(name="adamw")


def _ns(mesh, tree_specs, like_tree):
    """PartitionSpec tree -> NamedSharding tree shaped like like_tree."""
    def to_ns(spec):
        return NamedSharding(mesh, spec)
    if tree_specs is None:
        return jax.tree_util.tree_map(
            lambda l: NamedSharding(mesh, P(*([None] * l.ndim))), like_tree)
    # broadcast spec tree against the value tree (specs at internal nodes)
    def walk(spec, like):
        if isinstance(spec, P):
            return jax.tree_util.tree_map(lambda _: to_ns(spec), like)
        if isinstance(spec, dict):
            return {k: walk(spec[k], like[k]) for k in like}
        if isinstance(spec, (list, tuple)):
            return type(like)(walk(s, l) for s, l in zip(spec, like))
        raise TypeError(type(spec))
    return walk(tree_specs, like_tree)


def _opt_shardings(mesh, param_shardings, opt_state_struct):
    step_ns = NamedSharding(mesh, P())
    out = {"step": step_ns}
    for k in opt_state_struct:
        if k == "step":
            continue
        out[k] = param_shardings
    return out


def _dp_size(mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_cell(arch_id, shape_name, shape, mesh, smoke=False,
             layout: str = "2d") -> Cell:
    spec = get_arch(arch_id)
    cfg = spec.make_smoke_config() if smoke else spec.make_config()
    if layout == "fsdp" and not smoke and shape["kind"] == "train":
        # pure ZeRO-3: every mesh axis is data parallelism
        ax = tuple(mesh.axis_names)
        n_all = mesh.size
        assert shape["global_batch"] % n_all == 0, "batch must divide mesh"
        cfg = dataclasses.replace(
            cfg, act_pspec=NamedSharding(mesh, P(ax, None, None)),
            pre_cast_layers=True)
        key = jax.random.PRNGKey(0)
        params_struct = jax.eval_shape(functools.partial(tfm.init_params, cfg), key)
        p_shard = _ns(mesh, shr.transformer_param_specs(cfg, mesh, "fsdp"),
                      params_struct)
        opt_struct = jax.eval_shape(
            functools.partial(opt.init_state, OPT_CFG), params_struct)
        o_shard = _opt_shardings(mesh, p_shard, opt_struct)
        B, S = shape["global_batch"], shape["seq_len"]
        batch_struct = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        b_shard = {k: NamedSharding(mesh, P(ax, None)) for k in batch_struct}

        def step(params, opt_state, batch):
            def loss(p):
                return tfm.loss_fn(cfg, p, batch)
            (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
            new_p, new_o, om = opt.apply_updates(OPT_CFG, params, grads, opt_state)
            return new_p, new_o, dict(metrics, loss=l, **om)

        meta = {"params": cfg.param_count(), "active_params": cfg.active_param_count(),
                "seq_len": S, "global_batch": B, "n_layers": cfg.n_layers,
                "d_model": cfg.d_model, "n_heads": cfg.n_heads, "hd": cfg.hd}
        return Cell(arch_id, shape_name, "train", step,
                    (params_struct, opt_struct, batch_struct),
                    (p_shard, o_shard, b_shard), (p_shard, o_shard, None),
                    donate=(0, 1), meta=meta)
    if not smoke and shape["kind"] in ("train", "prefill"):
        # Megatron-SP: shard the scanned residual stream on sequence so the
        # per-layer carry is [B/dp, S/model, D] (bounds remat memory).
        # For chunked (long-S) attention, K/V are materialized replicated
        # once per layer — q stays S-sharded, so score blocks partition on
        # the q dimension with no per-chunk collectives and no head-count
        # divisibility constraints (qwen's 40 heads don't divide 16).
        # NamedSharding (not bare PartitionSpec) so tracing works mesh-free.
        dp0 = shr.dp_axis(mesh)
        cfg = dataclasses.replace(
            cfg, act_pspec=NamedSharding(mesh, P(dp0, "model", None)))
        if cfg.n_heads % mesh.shape["model"] == 0:
            # pin attention heads to TP — otherwise SPMD picks inconsistent
            # layouts for the S x S score tensors and replicates activations
            # at the boundaries (catastrophic on the multi-pod mesh)
            cfg = dataclasses.replace(
                cfg, q_pspec=NamedSharding(mesh, P(dp0, None, "model", None)),
                attn_pspec=NamedSharding(mesh, P(dp0, "model", None, None)))
        else:
            # heads don't divide TP (qwen's 40): scores pin on the q-sequence
            cfg = dataclasses.replace(
                cfg, attn_pspec=NamedSharding(mesh, P(dp0, None, "model", None)))
        if shape["seq_len"] > cfg.attn_chunk.threshold:
            cfg = dataclasses.replace(
                cfg, kv_pspec=NamedSharding(mesh, P(dp0, None, None, None)))
        if cfg.moe:
            # GShard grouping: group-local routing sorts, [G, E, C, D]
            # buffers sharded G x dp / E x model (dispatch = all-to-all)
            cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
                cfg.moe, n_groups=_dp_size(mesh),
                # tokens within a group stay sharded over 'model' (aligned
                # with the S-sharded residual stream)
                group_pspec=NamedSharding(mesh, P(dp0, "model", None)),
                expert_pspec=NamedSharding(mesh, P(dp0, "model", None, None))))
    key = jax.random.PRNGKey(0)
    params_struct = jax.eval_shape(functools.partial(tfm.init_params, cfg), key)
    p_specs = shr.transformer_param_specs(cfg, mesh)
    p_shard = _ns(mesh, p_specs, params_struct)
    dp = shr.dp_axis(mesh)
    B, S = shape["global_batch"], shape["seq_len"]
    meta = {"params": cfg.param_count(), "active_params": cfg.active_param_count(),
            "seq_len": S, "global_batch": B, "n_layers": cfg.n_layers,
            "d_model": cfg.d_model, "n_heads": cfg.n_heads, "hd": cfg.hd}

    if shape["kind"] == "train":
        opt_struct = jax.eval_shape(
            functools.partial(opt.init_state, OPT_CFG), params_struct)
        o_shard = _opt_shardings(mesh, p_shard, opt_struct)
        batch_struct = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        b_shard = {k: NamedSharding(mesh, v)
                   for k, v in shr.transformer_batch_specs(mesh).items()}

        def step(params, opt_state, batch):
            def loss(p):
                return tfm.loss_fn(cfg, p, batch)
            (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
            new_p, new_o, om = opt.apply_updates(OPT_CFG, params, grads, opt_state)
            return new_p, new_o, dict(metrics, loss=l, **om)

        return Cell(arch_id, shape_name, "train", step,
                    (params_struct, opt_struct, batch_struct),
                    (p_shard, o_shard, b_shard),
                    (p_shard, o_shard, None), donate=(0, 1), meta=meta)

    if shape["kind"] == "prefill":
        tok_struct = jax.ShapeDtypeStruct((B, S), jnp.int32)
        tok_shard = NamedSharding(mesh, P(dp, None))
        cache_spec = shr.transformer_cache_specs(cfg, mesh, B)

        def step(params, tokens):
            logits, cache = forward_with_cache(cfg, params, tokens)
            return logits, cache

        cache_struct = jax.eval_shape(
            lambda p, t: forward_with_cache(cfg, p, t)[1], params_struct, tok_struct)
        c_shard = {k: NamedSharding(mesh, cache_spec[k]) for k in cache_struct}
        return Cell(arch_id, shape_name, "prefill", step,
                    (params_struct, tok_struct),
                    (p_shard, tok_shard),
                    (None, c_shard), meta=meta)

    # decode
    cache_struct = jax.eval_shape(
        functools.partial(tfm.init_cache, cfg, B, S), )
    cache_spec = shr.transformer_cache_specs(cfg, mesh, B)
    c_shard = {k: NamedSharding(mesh, cache_spec[k]) for k in cache_struct}
    dp_n = _dp_size(mesh)
    tok_spec = P(dp) if (B % dp_n == 0 and B >= dp_n) else P(None)
    tok_struct = jax.ShapeDtypeStruct((B,), jnp.int32)
    len_struct = jax.ShapeDtypeStruct((), jnp.int32)

    def step(params, cache, tokens, cur_len):
        return tfm.decode_step(cfg, params, cache, tokens, cur_len)

    return Cell(arch_id, shape_name, "decode", step,
                (params_struct, cache_struct, tok_struct, len_struct),
                (p_shard, c_shard, NamedSharding(mesh, tok_spec),
                 NamedSharding(mesh, P())),
                (None, c_shard), donate=(1,), meta=meta)


def forward_with_cache(cfg: tfm.TransformerConfig, params, tokens):
    """Prefill: forward pass that also emits the per-layer KV cache and the
    last-position logits (what a serving prefill actually returns)."""
    B, S = tokens.shape
    dt = cfg.dtype
    x = params["embed"].astype(dt)[tokens]
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]

    def body(x, p):
        from repro.models import layers as L
        Hq, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        h = L.rms_norm(x, p["ln1"])
        q = jnp.einsum("bsd,dh->bsh", h, p["wq"].astype(dt))
        k = jnp.einsum("bsd,dh->bsh", h, p["wk"].astype(dt))
        v = jnp.einsum("bsd,dh->bsh", h, p["wv"].astype(dt))
        if cfg.qkv_bias:
            q, k, v = q + p["bq"].astype(dt), k + p["bk"].astype(dt), v + p["bv"].astype(dt)
        q = L.apply_rope(q.reshape(B, S, Hq, hd), positions, cfg.rope_theta)
        k = L.apply_rope(k.reshape(B, S, Hkv, hd), positions, cfg.rope_theta)
        v = v.reshape(B, S, Hkv, hd)
        if cfg.kv_pspec is not None:
            k = jax.lax.with_sharding_constraint(k, cfg.kv_pspec)
            v = jax.lax.with_sharding_constraint(v, cfg.kv_pspec)
        cq, ckv = cfg.attn_chunk.for_seq(S)
        o = L.causal_attention(q, k, v, chunk_q=cq, chunk_kv=ckv)
        x = x + jnp.einsum("bsh,hd->bsd", o.reshape(B, S, Hq * hd), p["wo"].astype(dt))
        h2 = L.rms_norm(x, p["ln2"])
        if cfg.moe:
            from repro.models.moe import moe_ffn
            y, _ = moe_ffn(h2, p["router"], p["wg"],
                           p["wu"], p["wd"], cfg.moe, dt, dropless=True)
        else:
            y = L.swiglu(h2, p["wg"], p["wu"], p["wd"], dt)
        x = x + y
        if cfg.act_pspec is not None:
            x = jax.lax.with_sharding_constraint(x, cfg.act_pspec)
        return x, (k, v)

    if cfg.remat:
        body = jax.checkpoint(body)
    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    from repro.models import layers as L
    x = L.rms_norm(x[:, -1], params["final_norm"])
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"]).astype(dt)
    logits = jnp.einsum("bd,dv->bv", x, head, preferred_element_type=jnp.float32)
    return logits, {"k": ks.transpose(0, 1, 2, 3, 4), "v": vs}


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _gnn_cell(arch_id, shape_name, shape, mesh, smoke=False) -> Cell:
    spec = get_arch(arch_id)
    base = spec.make_smoke_config() if smoke else spec.make_config()
    cfg = dataclasses.replace(base, d_feat=shape["d_feat"],
                              n_classes=shape["n_classes"],
                              graph_readout=shape["kind"] == "train_graphs")
    key = jax.random.PRNGKey(0)
    params_struct = jax.eval_shape(functools.partial(gnn_m.init_params, cfg), key)
    p_shard = _ns(mesh, None, params_struct)     # replicated (tiny)
    dp = shr.gnn_dp_axis(mesh)                   # GNN partitions on ALL axes
    dp_n = mesh.size

    if shape["kind"] == "train_minibatch":
        seeds = shape["batch_nodes"]
        f_prod, max_nodes = 1, seeds
        for f in shape["fanout"]:
            f_prod *= f
            max_nodes += seeds * f_prod
        N, E = max_nodes, max_nodes - seeds
        meta_edges = E
    elif shape["kind"] == "train_graphs":
        N = shape["batch"] * shape["n_nodes"]
        E = shape["batch"] * shape["n_edges"]
        meta_edges = E
    else:
        N, E = shape["n_nodes"], shape["n_edges"]
        meta_edges = E
    # pad to dp multiples so row sharding is even
    N = ((N + dp_n - 1) // dp_n) * dp_n
    E = ((E + dp_n - 1) // dp_n) * dp_n

    batch_struct = {
        "nodes": jax.ShapeDtypeStruct((N, shape["d_feat"]), jnp.float32),
        "src": jax.ShapeDtypeStruct((E,), jnp.int32),
        "dst": jax.ShapeDtypeStruct((E,), jnp.int32),
        "edge_mask": jax.ShapeDtypeStruct((E,), jnp.bool_),
        "labels": jax.ShapeDtypeStruct((N,), jnp.int32),
        "label_mask": jax.ShapeDtypeStruct((N,), jnp.bool_),
        "node_mask": jax.ShapeDtypeStruct((N,), jnp.bool_),
    }
    if shape["kind"] == "train_graphs":
        batch_struct["labels"] = jax.ShapeDtypeStruct((shape["batch"],), jnp.int32)
        batch_struct["label_mask"] = jax.ShapeDtypeStruct((shape["batch"],), jnp.bool_)
        batch_struct["graph_id"] = jax.ShapeDtypeStruct((N,), jnp.int32)
    b_specs = shr.gin_batch_specs(mesh)
    b_shard = {}
    for k, v in batch_struct.items():
        spc = b_specs.get(k, P(*([None] * v.ndim)))
        if shape["kind"] == "train_graphs" and k in ("labels", "label_mask"):
            spc = P(dp)
        # replicate when the sharded dim doesn't divide the axes product
        if spc and spc[0] is not None and v.shape[0] % dp_n != 0:
            spc = P(*((None,) + tuple(spc)[1:]))
        b_shard[k] = NamedSharding(mesh, spc)

    opt_struct = jax.eval_shape(functools.partial(opt.init_state, OPT_CFG), params_struct)
    o_shard = _opt_shardings(mesh, p_shard, opt_struct)

    extra = {"n_graphs": shape.get("batch")} if shape["kind"] == "train_graphs" else {}

    def step(params, opt_state, batch):
        def loss(p):
            return gnn_m.loss_fn(cfg, p, dict(batch, **extra))
        (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
        new_p, new_o, om = opt.apply_updates(OPT_CFG, params, grads, opt_state)
        return new_p, new_o, dict(metrics, loss=l, **om)

    meta = {"params": cfg.param_count(), "n_nodes": N, "n_edges": meta_edges,
            "d_feat": shape["d_feat"], "d_hidden": cfg.d_hidden,
            "n_layers": cfg.n_layers}
    return Cell(arch_id, shape_name, shape["kind"], step,
                (params_struct, opt_struct, batch_struct),
                (p_shard, o_shard, b_shard), (p_shard, o_shard, None),
                donate=(0, 1), meta=meta)


# ---------------------------------------------------------------------------
# recsys cells
# ---------------------------------------------------------------------------

def _recsys_cell(arch_id, shape_name, shape, mesh, smoke=False) -> Cell:
    spec = get_arch(arch_id)
    cfg = spec.make_smoke_config() if smoke else spec.make_config()
    key = jax.random.PRNGKey(0)
    params_struct = jax.eval_shape(functools.partial(rec_m.init_params, cfg), key)
    p_shard = _ns(mesh, shr.recsys_param_specs(cfg, mesh), params_struct)
    dp = shr.dp_axis(mesh)
    B = shape["batch"]
    meta = {"params": cfg.param_count(), "batch": B, "model": cfg.model,
            "embed_dim": cfg.embed_dim, "n_fields": cfg.n_fields}

    def batch_structs(batch, retrieval=False):
        d = {"ids": jax.ShapeDtypeStruct((batch, cfg.n_fields), jnp.int32),
             "label": jax.ShapeDtypeStruct((batch,), jnp.int32)}
        if cfg.model in ("bst", "mind"):
            d["hist"] = jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32)
            d["target"] = jax.ShapeDtypeStruct((batch,), jnp.int32)
        if retrieval:
            d["cand"] = jax.ShapeDtypeStruct((shape["n_candidates"],), jnp.int32)
        return d

    if shape["kind"] == "train":
        opt_struct = jax.eval_shape(functools.partial(opt.init_state, OPT_CFG),
                                    params_struct)
        o_shard = _opt_shardings(mesh, p_shard, opt_struct)
        bs = batch_structs(B)
        b_shard = {k: NamedSharding(mesh, v) for k, v in
                   shr.recsys_batch_specs(cfg, mesh).items() if k in bs}

        def step(params, opt_state, batch):
            def loss(p):
                return rec_m.loss_fn(cfg, p, batch)
            (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
            new_p, new_o, om = opt.apply_updates(OPT_CFG, params, grads, opt_state)
            return new_p, new_o, dict(metrics, loss=l, **om)

        return Cell(arch_id, shape_name, "train", step,
                    (params_struct, opt_struct, bs),
                    (p_shard, o_shard, b_shard), (p_shard, o_shard, None),
                    donate=(0, 1), meta=meta)

    if shape["kind"] == "serve":
        bs = batch_structs(B)
        bs.pop("label")
        b_shard = {k: NamedSharding(mesh, v) for k, v in
                   shr.recsys_batch_specs(cfg, mesh).items() if k in bs}

        def step(params, batch):
            return rec_m.serve_scores(cfg, params, batch)

        return Cell(arch_id, shape_name, "serve", step,
                    (params_struct, bs), (p_shard, b_shard), None, meta=meta)

    # retrieval
    bs = batch_structs(B, retrieval=True)
    bs.pop("label")
    specs = shr.recsys_batch_specs(cfg, mesh, retrieval=True)
    b_shard = {k: NamedSharding(mesh, specs[k]) for k in bs}
    meta["n_candidates"] = shape["n_candidates"]

    def step(params, batch):
        scores = rec_m.retrieval_scores(cfg, params, batch)
        return jax.lax.top_k(scores, 128)

    return Cell(arch_id, shape_name, "retrieval", step,
                (params_struct, bs), (p_shard, b_shard), None, meta=meta)


# ---------------------------------------------------------------------------
# search cells
# ---------------------------------------------------------------------------

def _search_cell(arch_id, shape_name, shape, mesh, smoke=False) -> Cell:
    spec = get_arch(arch_id)
    base = spec.make_smoke_config() if smoke else spec.make_config()
    cfg = dataclasses.replace(
        base, queries=shape.get("queries", base.queries),
        postings_pad=shape.get("postings_pad", base.postings_pad),
        n_basic=shape.get("n_basic", base.n_basic),
        n_expanded=shape.get("n_expanded", base.n_expanded),
        n_stop=shape.get("n_stop", base.n_stop),
        n_multi=shape.get("n_multi", base.n_multi),
        ranked=shape.get("ranked", base.ranked))
    dp_n = _dp_size(mesh)
    arenas = ss.arena_specs(cfg, dp_n)
    queries = ss.query_table_specs(cfg)
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    a_shard = {k: NamedSharding(mesh, P(dp)) for k in arenas}
    q_shard = {k: NamedSharding(mesh, P()) for k in queries}
    step = ss.make_search_serve_step(cfg, mesh)
    meta = {"queries": cfg.queries, "groups": cfg.groups,
            "postings_pad": cfg.postings_pad, "arena_per_shard": cfg.n_arena,
            "n_shards": dp_n, "ranked": cfg.ranked}
    return Cell(arch_id, shape_name, "search_serve", step,
                (arenas, queries), (a_shard, q_shard), None, meta=meta)


# ---------------------------------------------------------------------------

def build_cell(arch_id: str, shape_name: str, mesh, smoke: bool = False,
               layout: str = "2d") -> Cell:
    spec = get_arch(arch_id)
    shape = spec.shapes[shape_name]
    if spec.family == "lm":
        return _lm_cell(arch_id, shape_name, shape, mesh, smoke, layout=layout)
    if spec.family == "gnn":
        return _gnn_cell(arch_id, shape_name, shape, mesh, smoke)
    if spec.family == "recsys":
        return _recsys_cell(arch_id, shape_name, shape, mesh, smoke)
    if spec.family == "search":
        return _search_cell(arch_id, shape_name, shape, mesh, smoke)
    raise ValueError(spec.family)

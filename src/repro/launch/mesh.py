"""Production mesh construction.

A function, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import)."""
from __future__ import annotations

import jax

from repro.compat import auto_axis_types, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds the 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=auto_axis_types(len(axes)))


def make_host_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = data or (n // model)
    return make_mesh((data, model), ("data", "model"),
                     axis_types=auto_axis_types(2))

"""Pallas TPU kernels (validated in interpret mode on CPU; see tests/).

banded_intersect — posting-list intersection / positional window join
unpack_fields    — packed-postings bit extract (block store decode)
segment_bag      — EmbeddingBag gather-reduce (recsys)
flash_decode     — single-token decode attention over long KV caches
flash_prefill    — causal GQA prefill with VMEM-resident score tiles
"""
from repro.kernels.ops import (banded_intersect, flash_decode, flash_prefill,
                               segment_bag, unpack_fields, unpack_postings)

__all__ = ["banded_intersect", "flash_decode", "flash_prefill", "segment_bag",
           "unpack_fields", "unpack_postings"]

"""jit'd public wrappers around the Pallas kernels.

Each op takes `implementation='pallas' | 'ref'` (+ `interpret=` for the
pallas path; on this CPU container interpret=True is the default and the
TPU-lowering path is exercised by the dry-run).  Tests sweep shapes/dtypes
and assert the two implementations agree exactly (integer ops) or to bf16
tolerance (attention).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_decode import flash_decode_pallas
from repro.kernels.flash_prefill import flash_prefill_pallas
from repro.kernels.intersect import (I32_SENTINEL, banded_delta_mask_rows_pallas,
                                     banded_intersect_pallas,
                                     banded_intersect_rows_pallas,
                                     banded_min_delta_rows_pallas)
from repro.kernels.segment_bag import segment_bag_pallas
from repro.kernels.unpack import ROWS_PER_TILE, unpack_fields_pallas

_SDB = 4      # delta bits of the (key << 4 | delta) scoring composite
              # (== core.fetch_tables.SCORE_DELTA_BITS; kept literal here so
              # the kernel layer stays import-free of core)

# packed-postings block geometry (== core.postings.BLOCK/BLOCK_LOG2 and
# PACK_WIDTH_BITS; literal for the same core-import-free reason as _SDB)
_BLOCK_LOG2 = 7
_BLOCK = 1 << _BLOCK_LOG2
_WBITS = 6


# ---------------------------------------------------------------------------
# packed-postings unpack
# ---------------------------------------------------------------------------

def unpack_fields(words: jax.Array, shifts: jax.Array, widths: jax.Array,
                  anchors: jax.Array, *, implementation: str = "pallas",
                  interpret: bool = True) -> jax.Array:
    """anchor + ((word >> shift) & mask(width)) elementwise — the bit-extract
    half of the packed-postings decode (any int32 shape; the Pallas path
    pads/reshapes to [R, 128] tiles)."""
    if implementation == "ref":
        mask = jnp.where(widths >= 32, jnp.int32(-1),
                         (jnp.int32(1) << jnp.minimum(widths, 31)) - 1)
        return anchors + ((words >> shifts) & mask)
    shape = words.shape
    n = words.size
    tile = ROWS_PER_TILE * 128
    pad = (-n) % tile

    def prep(x):
        x = x.reshape(-1)
        if pad:
            x = jnp.concatenate([x, jnp.zeros((pad,), jnp.int32)])
        return x.reshape(-1, 128)

    out = unpack_fields_pallas(prep(words), prep(shifts), prep(widths),
                               prep(anchors), interpret=interpret)
    return out.reshape(-1)[:n].reshape(shape)


def unpack_postings(arena: dict, idx: jax.Array, *,
                    implementation: str = "ref", interpret: bool = True):
    """(doc, pos, dist) int32 for posting ordinals `idx` of a packed arena.

    arena: device dict with `lanes` [W] int32 packed delta words and
    `blk_meta` [NB, 5] int32 per-block metadata (column 0 = base lane word,
    1 = packed field widths, 2..4 = doc/pos/dist anchors — see
    core.postings.PackedPostings.meta_matrix; NB * 128 is the addressable
    ordinal range).  One metadata row gather + one lane gather per field are
    plain XLA gathers; the bit extract runs through `unpack_fields` (ref
    math or the Pallas kernel).  Out-of-range lane reads (width-0 tail
    blocks) rely on jnp's clamping gather semantics."""
    lanes = arena["lanes"]
    blk = idx >> _BLOCK_LOG2
    off = idx & (_BLOCK - 1)
    meta = arena["blk_meta"][blk]              # [..., 5] one gather
    base, bw = meta[..., 0], meta[..., 1]
    m = (1 << _WBITS) - 1
    ws = [bw & m, (bw >> _WBITS) & m, (bw >> (2 * _WBITS)) & m]
    fbs = [base, base + (ws[0] << 2), base + ((ws[0] + ws[1]) << 2)]
    words, shifts = [], []
    for w, fb in zip(ws, fbs):
        bit = off * w
        words.append(lanes[fb + (bit >> 5)])
        shifts.append(bit & 31)
    out = unpack_fields(jnp.stack(words), jnp.stack(shifts), jnp.stack(ws),
                        jnp.stack([meta[..., 2], meta[..., 3], meta[..., 4]]),
                        implementation=implementation, interpret=interpret)
    return out[0], out[1], out[2]


def _pad_to(x: jax.Array, mult: int, fill) -> jax.Array:
    n = x.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return x
    return jnp.concatenate([x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)])


# ---------------------------------------------------------------------------
# banded intersection
# ---------------------------------------------------------------------------

def banded_intersect(a: jax.Array, b_sorted: jax.Array, band: int, *,
                     implementation: str = "pallas", interpret: bool = True,
                     block_a: int = 1024, block_b: int = 1024,
                     max_tiles: int | None = None) -> jax.Array:
    """found[i] = exists j with |a[i] - b_sorted[j]| <= band.

    a: [Na] int32 (any order); b_sorted: [Nb] int32 ascending.  Returns
    bool [Na].  Entries equal to I32_SENTINEL never match (padding).
    """
    assert a.dtype == jnp.int32 and b_sorted.dtype == jnp.int32
    if implementation == "ref":
        found = ref.banded_intersect_ref(a, b_sorted, band)
        return found & (a != I32_SENTINEL)

    na, nb = a.shape[0], b_sorted.shape[0]
    if na == 0 or nb == 0:
        return jnp.zeros((na,), jnp.bool_)
    a_pad = _pad_to(a, block_a, I32_SENTINEL)
    b_pad = _pad_to(b_sorted, block_b, I32_SENTINEL)
    nab = a_pad.shape[0] // block_a
    nbb = b_pad.shape[0] // block_b

    a_tiles = a_pad.reshape(nab, block_a)
    # int64 bounds: sentinel +/- band must not wrap (keys are < 2**30)
    amin = a_tiles.min(axis=1).astype(jnp.int64)
    amax = a_tiles.max(axis=1).astype(jnp.int64)
    b_block_min = b_pad.reshape(nbb, block_b)[:, 0].astype(jnp.int64)
    # side='left': a block whose min equals amin-band may be preceded by a
    # block ending in the same value (duplicates straddling the boundary)
    lo = jnp.clip(jnp.searchsorted(b_block_min, amin - band, side="left") - 1, 0, nbb - 1)
    hi = jnp.searchsorted(b_block_min, amax + band, side="right")
    n_tiles = jnp.maximum(hi - lo, 0).astype(jnp.int32)
    lo = lo.astype(jnp.int32)

    if max_tiles is None:
        if isinstance(n_tiles, jax.core.Tracer):
            max_tiles = nbb                         # static worst case under jit
        else:
            max_tiles = max(int(n_tiles.max()), 1)
    max_tiles = max(min(max_tiles, nbb), 1)

    out2d = banded_intersect_pallas(
        a_pad.reshape(-1, 128), b_pad.reshape(-1, 128), lo, n_tiles,
        band=band, block_a=block_a, block_b=block_b, max_tiles=max_tiles,
        interpret=interpret)
    found = out2d.reshape(-1)[:na] > 0
    return found & (a != I32_SENTINEL)


def banded_intersect_rows(a: jax.Array, b_sorted: jax.Array, bands: jax.Array,
                          *, implementation: str = "pallas",
                          interpret: bool = True, block_a: int = 1024,
                          block_b: int = 1024) -> jax.Array:
    """Batched banded membership: found[n, i] = exists j with
    |a[n, i] - b_sorted[n, j]| <= bands[n].

    a: [N, Pa] int32 (any order); b_sorted: [N, Pb] int32, ascending per row;
    bands: [N] int32 (DYNAMIC — one pallas program serves mixed band widths
    via scalar prefetch, so the batch executor never recompiles per band
    pattern).  Pa/Pb must be multiples of 128.  I32_SENTINEL entries of `a`
    never match.  This is the engine hot path: each row is one (seed group,
    constraint group) membership test of a shard-segmented batch-executor
    row — the same call the serve tier runs inside shard_map, where every
    logical row's keys are re-based against its own doc shard.
    """
    assert a.dtype == jnp.int32 and b_sorted.dtype == jnp.int32
    N, pa = a.shape
    pb = b_sorted.shape[1]
    if implementation == "ref":
        def row(av, bv, band):
            lo = jnp.searchsorted(bv, av - band, side="left")
            hi = jnp.searchsorted(bv, av + band, side="right")
            return hi > lo
        found = jax.vmap(row)(a, b_sorted, bands.astype(jnp.int32))
        return found & (a != I32_SENTINEL)

    if N == 0 or pa == 0 or pb == 0:
        return jnp.zeros((N, pa), jnp.bool_)

    def pick_block(p, req):
        # largest multiple of 128 that divides the row width (tiles must not
        # straddle rows: each logical row owns whole blocks)
        for blk in range(max(min(req, p) // 128 * 128, 128), 127, -128):
            if p % blk == 0:
                return blk
        raise ValueError(f"row width {p} not a multiple of 128")

    block_a = pick_block(pa, block_a)
    block_b = pick_block(pb, block_b)
    nab_pp = pa // block_a            # a-blocks per row
    nbb_pp = pb // block_b            # b-blocks per row

    # per-a-block value range (int64: sentinel +/- band must not wrap)
    a_t = a.reshape(N, nab_pp, block_a)
    amin = a_t.min(axis=2).astype(jnp.int64)           # [N, nab_pp]
    amax = a_t.max(axis=2).astype(jnp.int64)
    b_block_min = b_sorted.reshape(N, nbb_pp, block_b)[:, :, 0].astype(jnp.int64)
    band64 = bands.astype(jnp.int64)[:, None]
    # side='left' - 1: duplicates straddling a block boundary (see
    # banded_intersect); clip keeps the range inside the owning row
    lo = jax.vmap(lambda bm, q: jnp.searchsorted(bm, q, side="left"))(
        b_block_min, amin - band64)
    lo = jnp.clip(lo - 1, 0, nbb_pp - 1)
    hi = jax.vmap(lambda bm, q: jnp.searchsorted(bm, q, side="right"))(
        b_block_min, amax + band64)
    n_tiles = jnp.maximum(hi - lo, 0).astype(jnp.int32)
    # absolute b-block index: offset into the row's own b segment
    row_base = (jnp.arange(N, dtype=jnp.int64) * nbb_pp)[:, None]
    lo_abs = (lo + row_base).astype(jnp.int32)
    band_per_block = jnp.broadcast_to(bands.astype(jnp.int32)[:, None],
                                      (N, nab_pp))

    out2d = banded_intersect_rows_pallas(
        a.reshape(-1, 128), b_sorted.reshape(-1, 128),
        lo_abs.reshape(-1), n_tiles.reshape(-1), band_per_block.reshape(-1),
        block_a=block_a, block_b=block_b, max_tiles=nbb_pp,
        interpret=interpret)
    found = out2d.reshape(N, pa) > 0
    return found & (a != I32_SENTINEL)


_KW_MAX_BAND = 15   # device kword window cap: bit (d + band) <= 30 per lane


def banded_delta_mask_rows(a: jax.Array, b_sorted: jax.Array,
                           bands: jax.Array, *,
                           implementation: str = "pallas",
                           interpret: bool = True, block_a: int = 1024,
                           block_b: int = 1024) -> jax.Array:
    """Batched signed-delta bitmask (the K-word join twin of
    `banded_intersect_rows`, core/kword.py): out[n, i] has bit
    (d + bands[n]) set iff exists j with b_sorted[n, j] - a[n, i] == d and
    |d| <= bands[n] — one int32 per anchor encoding WHICH offsets of the
    [-band, band] window hold a candidate for this constraint group.  The
    K-way combine then scans window starts t in [0, band]: a query matches
    at an anchor iff some t has every active group's mask non-zero in bits
    [t, t + band] (see `kword_window_hits` / bucket_step_math's kword pass).

    a: [N, Pa] int32 (any order); b_sorted: [N, Pb] int32 ascending per
    row; bands: [N] int32, each <= 15 (wider kword windows ride the flex
    escape — batch_executor._task_fits).  I32_SENTINEL entries of `a` map
    to mask 0.
    """
    assert a.dtype == jnp.int32 and b_sorted.dtype == jnp.int32
    N, pa = a.shape
    pb = b_sorted.shape[1]
    if implementation == "ref":
        def row(av, bv, band):
            mask = jnp.zeros_like(av)
            for d in range(-_KW_MAX_BAND, _KW_MAX_BAND + 1):
                lo = jnp.searchsorted(bv, av + d, side="left")
                hi = jnp.searchsorted(bv, av + d, side="right")
                present = (hi > lo) & (jnp.abs(d) <= band)
                mask = mask | jnp.where(
                    present, jnp.int32(1) << jnp.clip(d + band, 0, 31),
                    jnp.int32(0))
            return mask
        out = jax.vmap(row)(a, b_sorted, bands.astype(jnp.int32))
        return jnp.where(a == I32_SENTINEL, 0, out)

    if N == 0 or pa == 0 or pb == 0:
        return jnp.zeros((N, pa), jnp.int32)

    def pick_block(p, req):
        for blk in range(max(min(req, p) // 128 * 128, 128), 127, -128):
            if p % blk == 0:
                return blk
        raise ValueError(f"row width {p} not a multiple of 128")

    block_a = pick_block(pa, block_a)
    block_b = pick_block(pb, block_b)
    nab_pp = pa // block_a
    nbb_pp = pb // block_b

    a_t = a.reshape(N, nab_pp, block_a)
    amin = a_t.min(axis=2).astype(jnp.int64)
    amax = a_t.max(axis=2).astype(jnp.int64)
    b_block_min = b_sorted.reshape(N, nbb_pp, block_b)[:, :, 0].astype(jnp.int64)
    band64 = bands.astype(jnp.int64)[:, None]
    lo = jax.vmap(lambda bm, q: jnp.searchsorted(bm, q, side="left"))(
        b_block_min, amin - band64)
    lo = jnp.clip(lo - 1, 0, nbb_pp - 1)
    hi = jax.vmap(lambda bm, q: jnp.searchsorted(bm, q, side="right"))(
        b_block_min, amax + band64)
    n_tiles = jnp.maximum(hi - lo, 0).astype(jnp.int32)
    row_base = (jnp.arange(N, dtype=jnp.int64) * nbb_pp)[:, None]
    lo_abs = (lo + row_base).astype(jnp.int32)
    band_per_block = jnp.broadcast_to(bands.astype(jnp.int32)[:, None],
                                      (N, nab_pp))
    out2d = banded_delta_mask_rows_pallas(
        a.reshape(-1, 128), b_sorted.reshape(-1, 128),
        lo_abs.reshape(-1), n_tiles.reshape(-1), band_per_block.reshape(-1),
        block_a=block_a, block_b=block_b, max_tiles=nbb_pp,
        interpret=interpret)
    out = out2d.reshape(N, pa)
    return jnp.where(a == I32_SENTINEL, 0, out)


def delta_mask_t_bits(mask: jax.Array, bands: jax.Array) -> jax.Array:
    """Per-group window scan of a delta mask: bit t of the result is set iff
    the group's mask has a candidate inside the window starting at offset
    t - W from the anchor, i.e. ((mask >> t) & low(W + 1)) != 0 for
    t in [0, W].  mask: [N, Pa] int32 from `banded_delta_mask_rows`;
    bands: [N] int32 (W <= 15).  The K-way combine is a plain AND of these
    per-group bit sets: the query matches at an anchor iff the AND over all
    active groups is non-zero (some shared window start survives)."""
    low = ((jnp.int32(1) << (bands + 1)) - 1)[:, None]     # (W+1) low bits
    bits = jnp.zeros_like(mask)
    for t in range(_KW_MAX_BAND + 1):
        hit = (((mask >> t) & low) != 0) & (t <= bands)[:, None]
        bits = bits | jnp.where(hit, jnp.int32(1) << t, jnp.int32(0))
    return bits


def kword_window_hits(masks: jax.Array, active: jax.Array,
                      bands: jax.Array) -> jax.Array:
    """Combine per-group delta masks into the K-word match bit.

    masks: [G, N, Pa] int32 delta masks (one per constraint group, from
    `banded_delta_mask_rows`); active: [G, N] bool (dead groups never
    constrain); bands: [N] int32 window W per row.  Returns bool [N, Pa]:
    anchor i matches iff some window start t in [0, W] intersects EVERY
    active group's mask in bits [t, t + W] — i.e. all K words fit inside
    one (W + 1)-wide window containing the anchor."""
    t_ok = None
    for g in range(masks.shape[0]):
        bits = delta_mask_t_bits(masks[g], bands)
        bits = jnp.where(active[g][:, None], bits, jnp.int32(-1))
        t_ok = bits if t_ok is None else (t_ok & bits)
    if t_ok is None:
        return jnp.zeros(masks.shape[1:], jnp.bool_)
    return t_ok != 0


def banded_min_delta_rows(a: jax.Array, b_key_sorted: jax.Array,
                          b_delta: jax.Array, bands: jax.Array, *,
                          implementation: str = "pallas",
                          interpret: bool = True, block_a: int = 1024,
                          block_b: int = 1024) -> jax.Array:
    """Batched banded min-delta (the proximity-scoring twin of
    `banded_intersect_rows`): out[n, i] = min over j with
    |a[n, i] - b_key[n, j]| <= bands[n] of (|a[n, i] - b_key[n, j]| +
    b_delta[n, j]), or I32_SENTINEL when no such j — so `< I32_SENTINEL` is
    exactly the banded-membership bit and the value feeds w(d) = 1/(1+d).

    b rows must be sorted by (key, delta) — the composite order the batch
    executor sorts into — and, per plan construction, rows with bands[n] > 0
    carry all-zero deltas (dist-carrying fetches are always band-0): the
    two-probe ref path is exact exactly on that domain, while the Pallas
    dense-tile path computes the general min.  deltas in [0, 15]
    (SCORE_DELTA_BITS); I32_SENTINEL entries of `a` never match.
    """
    assert a.dtype == jnp.int32 and b_key_sorted.dtype == jnp.int32
    N, pa = a.shape
    pb = b_key_sorted.shape[1]
    if implementation == "ref":
        pad = jnp.int64(1) << 40
        comp = jnp.where(b_key_sorted == I32_SENTINEL, pad,
                         (b_key_sorted.astype(jnp.int64) << _SDB)
                         | b_delta.astype(jnp.int64))
        probe = jnp.where(a == I32_SENTINEL, pad, a.astype(jnp.int64) << _SDB)

        def row(cv, pv, band):
            idx = jnp.searchsorted(cv, pv, side="left")
            hi = jnp.clip(idx, 0, pb - 1)
            lo = jnp.clip(idx - 1, 0, pb - 1)
            e_hi, e_lo = cv[hi], cv[lo]
            a_key = pv >> _SDB
            kd_hi = (e_hi >> _SDB) - a_key
            kd_lo = a_key - (e_lo >> _SDB)
            ok_hi = (idx < pb) & (kd_hi <= band)
            ok_lo = (idx > 0) & (kd_lo <= band)
            big = jnp.int32(I32_SENTINEL)
            mask = jnp.int64((1 << _SDB) - 1)
            c_hi = jnp.where(ok_hi, kd_hi.astype(jnp.int32)
                             + (e_hi & mask).astype(jnp.int32), big)
            c_lo = jnp.where(ok_lo, kd_lo.astype(jnp.int32)
                             + (e_lo & mask).astype(jnp.int32), big)
            return jnp.minimum(c_hi, c_lo)

        out = jax.vmap(row)(comp, probe, bands.astype(jnp.int64))
        return jnp.where(a == I32_SENTINEL, I32_SENTINEL, out)

    if N == 0 or pa == 0 or pb == 0:
        return jnp.full((N, pa), I32_SENTINEL, jnp.int32)

    def pick_block(p, req):
        for blk in range(max(min(req, p) // 128 * 128, 128), 127, -128):
            if p % blk == 0:
                return blk
        raise ValueError(f"row width {p} not a multiple of 128")

    block_a = pick_block(pa, block_a)
    block_b = pick_block(pb, block_b)
    nab_pp = pa // block_a
    nbb_pp = pb // block_b

    a_t = a.reshape(N, nab_pp, block_a)
    amin = a_t.min(axis=2).astype(jnp.int64)
    amax = a_t.max(axis=2).astype(jnp.int64)
    b_block_min = b_key_sorted.reshape(N, nbb_pp, block_b)[:, :, 0].astype(jnp.int64)
    band64 = bands.astype(jnp.int64)[:, None]
    lo = jax.vmap(lambda bm, q: jnp.searchsorted(bm, q, side="left"))(
        b_block_min, amin - band64)
    lo = jnp.clip(lo - 1, 0, nbb_pp - 1)
    hi = jax.vmap(lambda bm, q: jnp.searchsorted(bm, q, side="right"))(
        b_block_min, amax + band64)
    n_tiles = jnp.maximum(hi - lo, 0).astype(jnp.int32)
    row_base = (jnp.arange(N, dtype=jnp.int64) * nbb_pp)[:, None]
    lo_abs = (lo + row_base).astype(jnp.int32)
    band_per_block = jnp.broadcast_to(bands.astype(jnp.int32)[:, None],
                                      (N, nab_pp))
    out2d = banded_min_delta_rows_pallas(
        a.reshape(-1, 128), b_key_sorted.reshape(-1, 128),
        b_delta.astype(jnp.int32).reshape(-1, 128),
        lo_abs.reshape(-1), n_tiles.reshape(-1), band_per_block.reshape(-1),
        block_a=block_a, block_b=block_b, max_tiles=nbb_pp,
        interpret=interpret)
    out = out2d.reshape(N, pa)
    return jnp.where(a == I32_SENTINEL, I32_SENTINEL, out)


# ---------------------------------------------------------------------------
# embedding bag
# ---------------------------------------------------------------------------

def segment_bag(table: jax.Array, ids: jax.Array, weights: jax.Array | None = None,
                combine: str = "sum", *, implementation: str = "pallas",
                interpret: bool = True) -> jax.Array:
    """EmbeddingBag(table, ids) -> [B, D]; ids [B, F] int32, -1 = pad."""
    if implementation == "ref":
        return ref.segment_bag_ref(table, ids, weights, combine)
    B, F = ids.shape
    w = weights if weights is not None else jnp.ones((B, F), table.dtype)
    out = segment_bag_pallas(table, ids.astype(jnp.int32), w.astype(table.dtype),
                             interpret=interpret)       # fp32 accumulator
    if combine == "mean":
        denom = jnp.maximum((ids >= 0).sum(axis=1, keepdims=True), 1).astype(jnp.float32)
        out = out / denom
    return out.astype(table.dtype)


# ---------------------------------------------------------------------------
# flash prefill attention
# ---------------------------------------------------------------------------

def flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  block_q: int = 512, block_kv: int = 512,
                  implementation: str = "pallas",
                  interpret: bool = True) -> jax.Array:
    """Causal GQA prefill.  q: [B, S, Hq, D]; k, v: [B, S, Hkv, D].

    The Pallas path keeps each (block_q x block_kv) score tile in VMEM
    (the §Roofline fix for the prefill memory term)."""
    if implementation == "ref":
        return ref.flash_prefill_ref(q, k, v)
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    bq = min(block_q, S)
    bkv = min(block_kv, S)
    # rows ordered (q_block, g, q_within) per KV head — see flash_prefill.py
    q6 = q.reshape(B, S // bq, bq, Hkv, G, D).transpose(0, 3, 1, 4, 2, 5)
    q5 = q6.reshape(B, Hkv, S * G, D)
    out5 = flash_prefill_pallas(q5, k, v, block_q=bq, block_kv=bkv,
                                interpret=interpret)
    out = out5.reshape(B, Hkv, S // bq, G, bq, D).transpose(0, 2, 4, 1, 3, 5)
    return out.reshape(B, S, Hq, D)


# ---------------------------------------------------------------------------
# flash decode attention
# ---------------------------------------------------------------------------

def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 kv_len: jax.Array | int, *, block_s: int = 512,
                 implementation: str = "pallas", interpret: bool = True) -> jax.Array:
    """q: [B, Hq, D]; k, v: [B, S, Hkv, D]; kv_len: [B] or scalar."""
    if implementation == "ref":
        return ref.flash_decode_ref(q, k, v, kv_len)
    B, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    kv_len = jnp.asarray(kv_len, jnp.int32)
    if kv_len.ndim == 0:
        kv_len = jnp.full((B,), kv_len, jnp.int32)
    bs = min(block_s, S)
    pad = (-S) % bs
    if pad:
        zeros = jnp.zeros((B, pad, Hkv, D), k.dtype)
        k = jnp.concatenate([k, zeros], axis=1)
        v = jnp.concatenate([v, zeros], axis=1)
    q4 = q.reshape(B, Hkv, G, D)
    out = flash_decode_pallas(q4, k, v, kv_len, block_s=bs, interpret=interpret)
    return out.reshape(B, Hq, D)

"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each kernel's test sweeps shapes/dtypes
and asserts allclose (exact for integer kernels) against these functions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def banded_intersect_ref(a: jax.Array, b_sorted: jax.Array, band: int) -> jax.Array:
    """found[i] = exists j: b_sorted[j] in [a[i] - band, a[i] + band].

    `b_sorted` must be sorted ascending (sentinel pads allowed at the end:
    the caller masks sentinel entries of `a` itself).
    """
    lo = jnp.searchsorted(b_sorted, a - band, side="left")
    hi = jnp.searchsorted(b_sorted, a + band, side="right")
    return hi > lo


def segment_bag_ref(table: jax.Array, ids: jax.Array, weights: jax.Array | None = None,
                    combine: str = "sum") -> jax.Array:
    """EmbeddingBag: out[b] = combine_f table[ids[b, f]] (* weights[b, f]).

    ids: [B, F] int32 (negative id = padding -> contributes zero).
    table: [V, D].  combine in {'sum', 'mean'}.
    """
    valid = ids >= 0
    rows = table[jnp.maximum(ids, 0)]                     # [B, F, D]
    w = valid.astype(table.dtype)
    if weights is not None:
        w = w * weights.astype(table.dtype)
    out = jnp.einsum("bfd,bf->bd", rows, w,
                     preferred_element_type=jnp.float32)  # fp32 accumulation
    if combine == "mean":
        denom = jnp.maximum(valid.sum(axis=1, keepdims=True), 1).astype(jnp.float32)
        out = out / denom
    return out.astype(table.dtype)


def flash_prefill_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Causal GQA prefill attention.  q: [B, S, Hq, D]; k, v: [B, S, Hkv, D].
    Head index convention: head = h * G + g (repeat_kv).  fp32 softmax."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    kk = jnp.repeat(k, G, axis=2).astype(jnp.float32)
    vv = jnp.repeat(v, G, axis=2).astype(jnp.float32)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kk)
    logits = logits / jnp.sqrt(D).astype(jnp.float32)
    mask = jnp.tril(jnp.ones((S, S), jnp.bool_))
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vv)
    return out.astype(q.dtype)


def flash_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_len: jax.Array | int) -> jax.Array:
    """Single-token decode attention with a (possibly padded) KV cache.

    q: [B, Hq, D]; k, v: [B, S, Hkv, D]; kv_len: [B] or scalar -- number of
    valid cache entries per batch row.  GQA: Hq = G * Hkv.
    Softmax in fp32 regardless of input dtype; output matches q dtype.
    """
    B, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bhgd,bshd->bhgs", qf, kf) / jnp.sqrt(D).astype(jnp.float32)
    kv_len = jnp.asarray(kv_len)
    if kv_len.ndim == 0:
        kv_len = jnp.full((B,), kv_len)
    mask = jnp.arange(S)[None, :] < kv_len[:, None]        # [B, S]
    logits = jnp.where(mask[:, None, None, :], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, vf)
    return out.reshape(B, Hq, D).astype(q.dtype)

"""Flash-attention prefill kernel: causal GQA attention with VMEM-resident
score blocks (the §Roofline fix for the prefill_32k memory term: the XLA
chunked path round-trips f32 score blocks through HBM; here a (block_q x
block_kv) tile lives only in VMEM).

Grid: (batch, kv-head, q-blocks, kv-blocks), kv innermost with the online-
softmax running state (m, l, acc) in VMEM scratch.  Causality is enforced
two ways: kv blocks strictly above the diagonal are skipped via pl.when
(compute predication), and the diagonal block gets the elementwise mask.
Layout matches flash_decode: q pre-reshaped [B, Hkv, G, S, D] so one grid
step serves a whole query-head group of one KV head.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, block_q: int, block_kv: int, scale: float, n_groups: int):
    qb = pl.program_id(2)
    kb = pl.program_id(3)
    n_kb = pl.num_programs(3)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: kv block strictly above the q block's diagonal -> skip
    @pl.when(kb * block_kv <= qb * block_q + block_q - 1)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (G*BQ, D) flattened
        k = k_ref[0, :, 0].astype(jnp.float32)         # (BKV, D)
        v = v_ref[0, :, 0].astype(jnp.float32)         # (BKV, D)
        G = n_groups
        BQ = block_q
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        # rows are (g, q) pairs; causal mask on the q coordinate only
        row_q = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % BQ
        q_pos = qb * BQ + row_q
        k_pos = kb * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

        m_old = m_ref[...]                             # (G*BQ, 128)
        m_blk = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_old, jnp.broadcast_to(m_blk, m_old.shape))
        alpha = jnp.exp(m_old - m_new)
        p = jnp.exp(s - m_new[:, :1])
        l_ref[...] = l_ref[...] * alpha + jnp.broadcast_to(
            jnp.sum(p, axis=1, keepdims=True), m_old.shape)
        acc_ref[...] = acc_ref[...] * alpha[:, :1] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kb == n_kb - 1)
    def _finish():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_prefill_pallas(q5: jax.Array, k: jax.Array, v: jax.Array, *,
                         block_q: int = 512, block_kv: int = 512,
                         interpret: bool = True) -> jax.Array:
    """q5: [B, Hkv, G*S, D] (G query heads per KV head, flattened with S);
    k, v: [B, S, Hkv, D].  Returns [B, Hkv, G*S, D] in q5.dtype.

    S must divide by both block sizes.  The flattened (G, S) rows let the
    MXU see (G*BQ, D) x (D, BKV) matmuls.
    """
    B, Hkv, GS, D = q5.shape
    S = k.shape[1]
    G = GS // S
    assert S % block_q == 0 and S % block_kv == 0, (S, block_q, block_kv)
    scale = 1.0 / (D ** 0.5)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(B, Hkv, S // block_q, S // block_kv),
        in_specs=[
            # q rows for block qb: all G groups x the qb-th block of S
            pl.BlockSpec((1, 1, G * block_q, D),
                         lambda b, h, qb, kb: (b, h, qb, 0)),
            pl.BlockSpec((1, block_kv, 1, D),
                         lambda b, h, qb, kb: (b, kb, h, 0)),
            pl.BlockSpec((1, block_kv, 1, D),
                         lambda b, h, qb, kb: (b, kb, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G * block_q, D),
                               lambda b, h, qb, kb: (b, h, qb, 0)),
        scratch_shapes=[
            pltpu.VMEM((G * block_q, 128), jnp.float32),   # m
            pltpu.VMEM((G * block_q, 128), jnp.float32),   # l
            pltpu.VMEM((G * block_q, D), jnp.float32),     # acc
        ],
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, block_q=block_q, block_kv=block_kv,
                          scale=scale, n_groups=G),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, GS, D), q5.dtype),
        interpret=interpret,
    )
    return fn(q5, k, v)

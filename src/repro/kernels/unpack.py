"""Pallas bit-unpack for the packed postings store (core/postings.py).

The packed arena stores each posting column as per-block anchors + bit-packed
deltas in width classes that divide the 32-bit lane, so a value never
straddles lane words and decode is branch-free VPU math:

    value = anchor + ((word >> shift) & mask(width))

The executors gather the lane words / per-block metadata with a plain XLA
gather (ops.unpack_postings) and hand this kernel the *dense, aligned*
(word, shift, width, anchor) planes — the dense-compute twin of the banded
intersect kernels next door, fusing the whole unpack of a gathered slab into
one elementwise pass.  Arithmetic right shift is safe: a packed value at bit
`shift` has width ≤ 32 - shift (widths divide 32), so the sign-extension
bits land above the mask; width 32 uses the all-ones mask and reproduces the
word itself.  Values are exact modulo 2**32, i.e. bit-exact for every int32
posting column.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
ROWS_PER_TILE = 8


def _kernel(words_ref, shift_ref, width_ref, anchor_ref, o_ref):
    w = width_ref[...]
    # width 32 -> all-ones; the (1 << w) - 1 branch is only selected for
    # w <= 16 (the clamp keeps the unselected branch's shift in-range)
    mask = jnp.where(w >= 32, jnp.int32(-1),
                     (jnp.int32(1) << jnp.minimum(w, 31)) - 1)
    val = (words_ref[...] >> shift_ref[...]) & mask
    o_ref[...] = anchor_ref[...] + val


def unpack_fields_pallas(words: jax.Array, shifts: jax.Array,
                         widths: jax.Array, anchors: jax.Array, *,
                         interpret: bool = True) -> jax.Array:
    """anchor + ((words >> shifts) & mask(widths)), elementwise int32.

    All inputs [R, 128] int32 with R a multiple of ROWS_PER_TILE (ops.py
    pads); widths in core.postings.PACK_WIDTHS."""
    R = words.shape[0]
    grid = (R // ROWS_PER_TILE,)
    spec = pl.BlockSpec((ROWS_PER_TILE, LANES), lambda i: (i, 0))
    fn = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec, spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(words.shape, jnp.int32),
        interpret=interpret,
    )
    return fn(words, shifts, widths, anchors)

"""EmbeddingBag gather-reduce kernel (recsys hot path; kernel_taxonomy §B.6).

JAX has no native EmbeddingBag; the framework substrate implements it as
take + segment_sum (ref.py).  On TPU the lookup is DMA-bound, so the Pallas
kernel drives the table-row DMA directly from *scalar-prefetched* ids: the
BlockSpec index map reads ids[b, f] and fetches exactly that row block into
VMEM per grid step — the TPU analogue of FBGEMM's table-batched embedding.

Padding ids (< 0) are clamped to row 0 and predicated off the accumulation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _kernel(ids_ref, q_ref, w_ref, o_ref):
    b = pl.program_id(0)
    f = pl.program_id(1)

    @pl.when(f == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    valid = ids_ref[b, f] >= 0
    w = w_ref[0, f].astype(jnp.float32)
    row = q_ref[...].astype(jnp.float32)      # (1, D) — the ids[b, f] table row
    o_ref[...] += jnp.where(valid, w, 0.0) * row   # fp32 accumulation


def segment_bag_pallas(table: jax.Array, ids: jax.Array, weights: jax.Array,
                       *, interpret: bool = True) -> jax.Array:
    """table: [V, D]; ids: [B, F] int32 (-1 pad); weights: [B, F] table.dtype.

    Returns [B, D] weighted bag sums.  Mean combine is applied by the ops.py
    wrapper (divide by valid count) so the kernel stays a pure gather-MAC.
    """
    B, F = ids.shape
    V, D = table.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, F),
        in_specs=[
            # the table row selected by the prefetched id (clamped for pads)
            pl.BlockSpec((1, D), lambda b, f, ids: (jnp.maximum(ids[b, f], 0), 0)),
            pl.BlockSpec((1, F), lambda b, f, ids: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda b, f, ids: (b, 0)),
    )
    fn = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), jnp.float32),
        interpret=interpret,
    )
    return fn(ids, table, weights)

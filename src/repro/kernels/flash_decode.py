"""Flash-decode attention kernel: one new token vs a long KV cache.

The LM zoo's serving hot spot (decode_32k / long_500k cells): per decoded
token the work is a [G, D] x [S, D] stream over the cache — memory-bound, so
the kernel tiles S into VMEM-sized blocks and keeps the online-softmax
running state (m, l, acc) in VMEM scratch across grid steps (FlashAttention
recurrence, adapted to TPU: the MXU sees (G, D) x (D, BS) matmuls, the VPU
does the rescaling).

GQA layout: q is pre-reshaped to [B, Hkv, G, D] so one grid step serves the
whole query-head group of one KV head — k/v rows are fetched once per group,
not once per query head.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _kernel(kvlen_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, block_s: int, scale: float):
    b = pl.program_id(0)
    kb = pl.program_id(2)
    n_kb = pl.num_programs(2)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = kvlen_ref[b]

    @pl.when(kb * block_s < kv_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)         # (BS, D)
        v = v_ref[0, :, 0].astype(jnp.float32)         # (BS, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale  # (G, BS)
        span = kb * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(span < kv_len, s, NEG_INF)

        m_old = m_ref[...]                             # (G, 128) replicated
        m_blk = jnp.max(s, axis=1, keepdims=True)      # (G, 1)
        m_new = jnp.maximum(m_old, jnp.broadcast_to(m_blk, m_old.shape))
        alpha = jnp.exp(m_old - m_new)                 # (G, 128)
        p = jnp.exp(s - m_new[:, :1])                  # (G, BS)
        l_ref[...] = l_ref[...] * alpha + jnp.broadcast_to(
            jnp.sum(p, axis=1, keepdims=True), m_old.shape)
        acc_ref[...] = acc_ref[...] * alpha[:, :1] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kb == n_kb - 1)
    def _finish():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_decode_pallas(q4: jax.Array, k: jax.Array, v: jax.Array,
                        kv_len: jax.Array, *, block_s: int = 512,
                        interpret: bool = True) -> jax.Array:
    """q4: [B, Hkv, G, D]; k, v: [B, S, Hkv, D]; kv_len: [B] int32.

    Returns [B, Hkv, G, D] in q4.dtype.  S must be a multiple of block_s.
    """
    B, Hkv, G, D = q4.shape
    S = k.shape[1]
    assert S % block_s == 0, (S, block_s)
    scale = 1.0 / (D ** 0.5)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, S // block_s),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, kb, kvlen: (b, h, 0, 0)),
            pl.BlockSpec((1, block_s, 1, D), lambda b, h, kb, kvlen: (b, kb, h, 0)),
            pl.BlockSpec((1, block_s, 1, D), lambda b, h, kb, kvlen: (b, kb, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, kb, kvlen: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),   # m (running max, lane-replicated)
            pltpu.VMEM((G, 128), jnp.float32),   # l (running denominator)
            pltpu.VMEM((G, D), jnp.float32),     # acc (unnormalized output)
        ],
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, block_s=block_s, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q4.dtype),
        interpret=interpret,
    )
    return fn(kv_len, q4, k, v)

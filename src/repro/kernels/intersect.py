"""Banded sorted-set intersection — the search engine's hot kernel.

TPU adaptation of posting-list merge (DESIGN.md §2): instead of pointer
chasing, both key lists are tiled; for each tile of `a` only the `b` tiles
whose value range can overlap [a_min - band, a_max + band] are DMA'd into
VMEM (tile bounds are scalar-prefetched, so the BlockSpec index map skips
non-overlapping tiles entirely — the TPU analogue of galloping).  Inside a
tile pair the membership test is a dense broadcast compare on the VPU:
branch-free, fully vectorized, O(matching-band) tile fetches overall.

Keys are *compact per-shard* int32 (doc_local << pos_bits | pos): TPU vector
units have no native int64 lane type, so the batched executor's global
63-bit keys are re-based against each row's own doc-shard base before
hitting this kernel (ops.py).  Rows arrive shard-segmented
(batch_executor._build_rows): every (a, b, band) row pair holds exactly one
doc shard's postings, for both the engine's jit'd bucket step and the serve
tier's shard_map'd step — the kernel itself never sees a shard loop.

band = 0  -> exact membership (precise phrase matching via shifted keys)
band = W  -> positional window join (word-set-with-distance queries)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

LANES = 128
I32_SENTINEL = jnp.iinfo(jnp.int32).max


def _kernel_rows(lo_ref, nt_ref, band_ref, a_ref, b_ref, o_ref):
    """Dense banded membership on one (a-block, b-block) tile pair: any b
    within [a - band, a + band].  The band is scalar-prefetched per a-block,
    so one pallas_call serves both the single-list op (constant band
    broadcast over blocks) and a whole batch of independent (a, b, band) row
    pairs (the batch executor's layout: each row = one fetch-group
    membership test, bands mixing 0 (phrase) and W (word-set window))."""
    i = pl.program_id(0)
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(k < nt_ref[i])
    def _compute():
        band = band_ref[i]
        a = a_ref[...]                       # (RA, 128) int32
        b = b_ref[...]                       # (RB, 128) int32
        ge = a[:, :, None, None] >= (b[None, None, :, :] - band)
        le = a[:, :, None, None] <= (b[None, None, :, :] + band)
        hit = jnp.logical_and(ge, le).any(axis=(2, 3))
        o_ref[...] = o_ref[...] | hit.astype(jnp.int32)


def banded_intersect_rows_pallas(a2d: jax.Array, b2d: jax.Array,
                                 lo_tiles: jax.Array, n_tiles: jax.Array,
                                 bands: jax.Array, *, block_a: int,
                                 block_b: int, max_tiles: int,
                                 interpret: bool = True) -> jax.Array:
    """Raw pallas_call for batched rows (a2d/b2d: [R, 128] int32; b sorted
    within each logical row).

    lo_tiles/n_tiles/bands are per-a-block: first b-block index (absolute,
    i.e. already offset to the owning row's b segment), number of b blocks to
    visit, and the row's band width (see ops.banded_intersect_rows)."""
    ra, rb = block_a // LANES, block_b // LANES
    n_a_blocks = a2d.shape[0] // ra
    n_b_blocks = b2d.shape[0] // rb

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_a_blocks, max_tiles),
        in_specs=[
            pl.BlockSpec((ra, LANES), lambda i, k, lo, nt, bd: (i, 0)),
            pl.BlockSpec((rb, LANES),
                         lambda i, k, lo, nt, bd: (jnp.minimum(lo[i] + k, n_b_blocks - 1), 0)),
        ],
        out_specs=pl.BlockSpec((ra, LANES), lambda i, k, lo, nt, bd: (i, 0)),
    )
    fn = pl.pallas_call(
        _kernel_rows,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(a2d.shape, jnp.int32),
        interpret=interpret,
    )
    return fn(lo_tiles, n_tiles, bands, a2d, b2d)


def _kernel_rows_min_delta(lo_ref, nt_ref, band_ref, a_ref, bk_ref, bd_ref,
                           o_ref):
    """Scoring twin of `_kernel_rows` (proximity relevance, api.py): for each
    a element, the MINIMUM over in-band b of (|a - b_key| + b_delta) — key
    distance plus the posting's stored slot delta — accumulated as an int32
    min across the visited b tiles.  I32_SENTINEL = no in-band b (the
    membership bit and the score read the same output)."""
    i = pl.program_id(0)
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, I32_SENTINEL)

    @pl.when(k < nt_ref[i])
    def _compute():
        band = band_ref[i]
        a = a_ref[...]                       # (RA, 128) int32
        bk = bk_ref[...]                     # (RB, 128) int32
        bd = bd_ref[...]                     # (RB, 128) int32
        kd = jnp.abs(a[:, :, None, None] - bk[None, None, :, :])
        cand = jnp.where(kd <= band, kd + bd[None, None, :, :], I32_SENTINEL)
        o_ref[...] = jnp.minimum(o_ref[...], cand.min(axis=(2, 3)))


def banded_min_delta_rows_pallas(a2d: jax.Array, bk2d: jax.Array,
                                 bd2d: jax.Array, lo_tiles: jax.Array,
                                 n_tiles: jax.Array, bands: jax.Array, *,
                                 block_a: int, block_b: int, max_tiles: int,
                                 interpret: bool = True) -> jax.Array:
    """Raw pallas_call for the batched min-delta rows (layout identical to
    banded_intersect_rows_pallas, plus the aligned b_delta planes)."""
    ra, rb = block_a // LANES, block_b // LANES
    n_a_blocks = a2d.shape[0] // ra
    n_b_blocks = bk2d.shape[0] // rb

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_a_blocks, max_tiles),
        in_specs=[
            pl.BlockSpec((ra, LANES), lambda i, k, lo, nt, bd: (i, 0)),
            pl.BlockSpec((rb, LANES),
                         lambda i, k, lo, nt, bd: (jnp.minimum(lo[i] + k, n_b_blocks - 1), 0)),
            pl.BlockSpec((rb, LANES),
                         lambda i, k, lo, nt, bd: (jnp.minimum(lo[i] + k, n_b_blocks - 1), 0)),
        ],
        out_specs=pl.BlockSpec((ra, LANES), lambda i, k, lo, nt, bd: (i, 0)),
    )
    fn = pl.pallas_call(
        _kernel_rows_min_delta,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(a2d.shape, jnp.int32),
        interpret=interpret,
    )
    return fn(lo_tiles, n_tiles, bands, a2d, bk2d, bd2d)


def _kernel_rows_delta_mask(lo_ref, nt_ref, band_ref, a_ref, b_ref, o_ref):
    """K-word join twin of `_kernel_rows` (kword mode, core/kword.py): for
    each a element, a bitmask over the signed delta d = b - a of the in-band
    b's — bit (d + band) set iff some b sits exactly at a + d.  The caller
    AND-combines per-group window scans of these masks to decide whether all
    K words of a query fit one window (ops.banded_delta_mask_rows).  band
    <= 15 so every bit index (d + band) <= 30 fits an int32 lane."""
    i = pl.program_id(0)
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(k < nt_ref[i])
    def _compute():
        band = band_ref[i]
        a = a_ref[...]                       # (RA, 128) int32
        b = b_ref[...]                       # (RB, 128) int32
        d = b[None, None, :, :] - a[:, :, None, None]
        inband = jnp.abs(d) <= band
        bit = jnp.int32(1) << jnp.clip(d + band, 0, 31)
        cand = jnp.where(inband, bit, jnp.int32(0))
        acc = jax.lax.reduce(cand, jnp.int32(0), jax.lax.bitwise_or, (2, 3))
        o_ref[...] = o_ref[...] | acc


def banded_delta_mask_rows_pallas(a2d: jax.Array, b2d: jax.Array,
                                  lo_tiles: jax.Array, n_tiles: jax.Array,
                                  bands: jax.Array, *, block_a: int,
                                  block_b: int, max_tiles: int,
                                  interpret: bool = True) -> jax.Array:
    """Raw pallas_call for the batched delta-mask rows (layout identical to
    banded_intersect_rows_pallas)."""
    ra, rb = block_a // LANES, block_b // LANES
    n_a_blocks = a2d.shape[0] // ra
    n_b_blocks = b2d.shape[0] // rb

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_a_blocks, max_tiles),
        in_specs=[
            pl.BlockSpec((ra, LANES), lambda i, k, lo, nt, bd: (i, 0)),
            pl.BlockSpec((rb, LANES),
                         lambda i, k, lo, nt, bd: (jnp.minimum(lo[i] + k, n_b_blocks - 1), 0)),
        ],
        out_specs=pl.BlockSpec((ra, LANES), lambda i, k, lo, nt, bd: (i, 0)),
    )
    fn = pl.pallas_call(
        _kernel_rows_delta_mask,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(a2d.shape, jnp.int32),
        interpret=interpret,
    )
    return fn(lo_tiles, n_tiles, bands, a2d, b2d)


def banded_intersect_pallas(a2d: jax.Array, b2d: jax.Array, lo_tiles: jax.Array,
                            n_tiles: jax.Array, *, band: int, block_a: int,
                            block_b: int, max_tiles: int,
                            interpret: bool = True) -> jax.Array:
    """Raw pallas_call (a2d: [Ra, 128] int32; b2d: [Rb, 128] int32 sorted).

    lo_tiles/n_tiles: per-a-block first b-block index and number of b blocks
    to visit (host- or trace-computed; see ops.banded_intersect).  The
    constant band is broadcast per a-block into the rows kernel — one kernel
    body serves both entry points.
    """
    n_a_blocks = a2d.shape[0] // (block_a // LANES)
    bands = jnp.full((n_a_blocks,), band, jnp.int32)
    return banded_intersect_rows_pallas(
        a2d, b2d, lo_tiles, n_tiles, bands, block_a=block_a,
        block_b=block_b, max_tiles=max_tiles, interpret=interpret)

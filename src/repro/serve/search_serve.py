"""Production batched phrase-query serving over a document-sharded index.

Distributed-IR layout (DESIGN.md §5): documents are partitioned over the
dp = pod x data mesh axes; every shard holds its own posting arena (all three
indexes concatenated into one (doc, pos, dist) structure-of-arrays so a fetch
is a single gather) and executes the full query batch; per-shard hits are
all-gathered and merged.  The `model` axis replicates the index and serves to
scale query throughput (the launcher round-robins query batches over it).

The planner's resolved plans are tensorized into fixed-shape fetch tables
(schema + tensorization shared with the engine's batch executor via
core/fetch_tables.py):

    start/length/offset/req_dist/band/active : [Q, G]
    ns_packed                                : [Q, C]  (type-4 pivot checks)

Group 0 is the seed (the pivot / rarest list); groups 1..G-1 constrain it via
banded-key membership (band 0 = precise phrase, band W = word-set window).
Keys are compact per-shard int32 (doc_local << 17 | pos) — the domain the
Pallas `banded_intersect` kernel operates on.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.fetch_tables import (NO_DIST, SENT32, SERVE_BIAS,
                                     SERVE_POS_BITS, query_table_specs,
                                     tensorize_plans)

__all__ = ["SERVE_POS_BITS", "SERVE_BIAS", "SENT32", "NO_DIST",
           "SearchServeConfig", "query_table_specs", "arena_specs",
           "make_search_serve_step", "build_arenas", "tensorize_plans"]


@dataclasses.dataclass(frozen=True)
class SearchServeConfig:
    name: str = "veretennikov-serve"
    queries: int = 64              # Q: batch size
    groups: int = 4                # G: fetch groups per query
    postings_pad: int = 32768      # P: padded postings per constraint fetch
    seed_pad: int = 0              # seed (pivot) fetch pad; 0 = postings_pad.
                                   # The planner seeds with the RAREST list,
                                   # so a small pad bounds the stream-3
                                   # gather + membership searches (§Perf)
    top_m: int = 128               # hits returned per query
    check_slots: int = 4           # C: near-stop checks on the pivot group
    ns_k: int = 20                 # stream-3 slots per posting
    sort_free: bool = False        # cummax-fill instead of sorting dist holes
    packed_keys: bool = False      # arena stores doc<<17|pos+BIAS pre-packed
                                   # (one i32 gather per fetch instead of two)
    # per-shard arena sizes (basic | expanded | stop segments concatenated)
    n_basic: int = 10_000_000
    n_expanded: int = 17_000_000
    n_stop: int = 23_000_000
    impl: str = "ref"              # intersect implementation (ref | pallas)

    @property
    def n_arena(self) -> int:
        return self.n_basic + self.n_expanded + self.n_stop

    @property
    def p_seed(self) -> int:
        return self.seed_pad or self.postings_pad


def arena_specs(cfg: SearchServeConfig, n_shards: int) -> dict:
    """ShapeDtypeStructs for the stacked per-shard index arenas."""
    i32 = jnp.int32
    if cfg.packed_keys:
        return {
            "arena_key": jax.ShapeDtypeStruct((n_shards, cfg.n_arena), i32),
            "arena_dist": jax.ShapeDtypeStruct((n_shards, cfg.n_arena), jnp.int8),
            "basic_ns": jax.ShapeDtypeStruct((n_shards, cfg.n_basic, cfg.ns_k), jnp.int16),
        }
    return {
        "arena_doc": jax.ShapeDtypeStruct((n_shards, cfg.n_arena), i32),
        "arena_pos": jax.ShapeDtypeStruct((n_shards, cfg.n_arena), i32),
        "arena_dist": jax.ShapeDtypeStruct((n_shards, cfg.n_arena), jnp.int8),
        "basic_ns": jax.ShapeDtypeStruct((n_shards, cfg.n_basic, cfg.ns_k), jnp.int16),
    }


# ---------------------------------------------------------------------------


def _one_query(cfg: SearchServeConfig, arena_doc, arena_pos, arena_dist,
               basic_ns, q):
    n = arena_doc.shape[0]    # packed mode passes arena_key as arena_doc

    def fetch(g, pad):
        iota = jnp.arange(pad, dtype=jnp.int32)
        idx = jnp.clip(q["start"][g] + iota, 0, n - 1)
        ok = iota < q["length"][g]
        dist = arena_dist[idx].astype(jnp.int32)
        rd = q["req_dist"][g]
        ok = ok & ((rd == NO_DIST) | (dist == rd))
        if arena_pos is None:
            # packed arena: key already doc<<17|pos+BIAS; offset shifts pos
            keys = arena_doc[idx] - q["offset"][g]
        else:
            doc = arena_doc[idx]
            pos = arena_pos[idx]
            keys = (doc << SERVE_POS_BITS) | (pos - q["offset"][g] + SERVE_BIAS)
        return jnp.where(ok, keys.astype(jnp.int32), SENT32), idx

    keys0, idx0 = fetch(0, cfg.p_seed)
    found = keys0 < SENT32

    # type-4 pivot verification against stream 3 (near-stop slots)
    if cfg.check_slots > 0:
        ns = basic_ns[jnp.clip(idx0, 0, basic_ns.shape[0] - 1)]     # [P0, K]
        targets = q["ns_packed"]                                    # [C]
        t_active = targets >= 0
        hit = (ns[:, :, None] == targets[None, None, :]).any(axis=1)  # [P0, C]
        ok_checks = (hit | ~t_active[None, :]).all(axis=1)
        found = found & jnp.where(t_active.any(), ok_checks, True)

    for g in range(1, cfg.groups):
        kg, _ = fetch(g, cfg.postings_pad)
        if cfg.sort_free:
            # dist-filter holes: fill with a running max — stays sorted, and
            # duplicating an existing key never creates a false member;
            # leading holes become int32-min (matches nothing: keys >= 0).
            # O(P) scan instead of an O(P log P) sort.
            lowest = jnp.int32(-(2**31) + 1)
            kg = jax.lax.cummax(jnp.where(kg == SENT32, lowest, kg))
        else:
            kg = jnp.sort(kg)          # dist-filter holes break sortedness
        band = q["band"][g]
        lo = jnp.searchsorted(kg, keys0 - band, side="left")
        hi = jnp.searchsorted(kg, keys0 + band, side="right")
        member = hi > lo
        found = found & jnp.where(q["active"][g], member, True)

    ranked = jnp.where(found, keys0, SENT32)
    hits = jnp.sort(ranked)[: cfg.top_m]
    return hits, found.sum(dtype=jnp.int32)


def make_search_serve_step(cfg: SearchServeConfig, mesh):
    """Returns step(arenas, queries) -> (merged_hits [Q, M], total [Q]).

    arenas: dict of stacked per-shard arrays (leading dim = n_dp shards),
    sharded P(dp); queries: dict of [Q, G] tables, replicated.
    """
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)

    def merge(hits, counts):
        # merge across shards: total count + global top-M of gathered hits
        total = jax.lax.psum(counts, dp)
        all_hits = jax.lax.all_gather(hits, dp, axis=0, tiled=False)
        all_hits = all_hits.reshape(-1, hits.shape[0], cfg.top_m)
        merged = jnp.sort(all_hits.transpose(1, 0, 2).reshape(hits.shape[0], -1),
                          axis=-1)[:, : cfg.top_m]
        return merged, total

    spec_shard = P(dp)
    spec_rep = P()
    q_specs = {k: spec_rep for k in query_table_specs(cfg)}

    if cfg.packed_keys:
        def local(arena_key, arena_dist, basic_ns, queries):
            run = functools.partial(_one_query, cfg, arena_key[0], None,
                                    arena_dist[0], basic_ns[0])
            hits, counts = jax.vmap(run)(queries)
            return merge(hits, counts)

        fn = shard_map(local, mesh=mesh,
                       in_specs=(spec_shard, spec_shard, spec_shard, q_specs),
                       out_specs=(spec_rep, spec_rep), check_vma=False)

        def step(arenas: dict, queries: dict):
            return fn(arenas["arena_key"], arenas["arena_dist"],
                      arenas["basic_ns"], queries)
        return step

    def local(arena_doc, arena_pos, arena_dist, basic_ns, queries):
        run = functools.partial(_one_query, cfg, arena_doc[0], arena_pos[0],
                                arena_dist[0], basic_ns[0])
        hits, counts = jax.vmap(run)(queries)
        return merge(hits, counts)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(spec_shard, spec_shard, spec_shard, spec_shard,
                             q_specs),
                   out_specs=(spec_rep, spec_rep), check_vma=False)

    def step(arenas: dict, queries: dict):
        return fn(arenas["arena_doc"], arenas["arena_pos"],
                  arenas["arena_dist"], arenas["basic_ns"], queries)
    return step


# ---------------------------------------------------------------------------
# host-side: build real arenas from an IndexSet (tests / small-scale serving)
# ---------------------------------------------------------------------------

def build_arenas(index_set, cfg: SearchServeConfig):
    """Concatenate the three indexes into one per-shard posting arena.

    Layout: [basic | expanded | stop]; returns (arenas dict with a leading
    shard dim of 1, stream_bases dict for tensorize_plans).  Sizes are
    clipped/padded to the cfg arena segment sizes.
    """
    b = index_set.basic.occurrences
    e = index_set.expanded.pairs
    s = index_set.stop_phrase.phrases

    def seg(doc, pos, dist, n):
        out_d = np.zeros(n, np.int32)
        out_p = np.zeros(n, np.int32)
        out_x = np.zeros(n, np.int8)
        m = min(len(doc), n)
        out_d[:m], out_p[:m] = doc[:m], pos[:m]
        if dist is not None:
            out_x[:m] = dist[:m]
        return out_d, out_p, out_x

    bd, bp, bx = seg(b.columns["doc"], b.columns["pos"], None, cfg.n_basic)
    ed, ep, ex = seg(e.columns["doc"], e.columns["pos"], e.columns["dist"],
                     cfg.n_expanded)
    sd, sp, sx = seg(s.columns["doc"], s.columns["pos"], None, cfg.n_stop)

    ns = np.full((cfg.n_basic, cfg.ns_k), -1, np.int16)
    src_ns = index_set.basic.near_stop
    m = min(len(src_ns), cfg.n_basic)
    k = min(src_ns.shape[1], cfg.ns_k)
    ns[:m, :k] = src_ns[:m, :k]

    doc = np.concatenate([bd, ed, sd])
    pos = np.concatenate([bp, ep, sp])
    if cfg.packed_keys:
        key = (doc.astype(np.int32) << SERVE_POS_BITS) | (pos + SERVE_BIAS)
        arenas = {
            "arena_key": jnp.asarray(key[None]),
            "arena_dist": jnp.asarray(np.concatenate([bx, ex, sx])[None]),
            "basic_ns": jnp.asarray(ns[None]),
        }
    else:
        arenas = {
            "arena_doc": jnp.asarray(doc[None]),
            "arena_pos": jnp.asarray(pos[None]),
            "arena_dist": jnp.asarray(np.concatenate([bx, ex, sx])[None]),
            "basic_ns": jnp.asarray(ns[None]),
        }
    bases = {"basic": 0, "expanded": cfg.n_basic,
             "stop": cfg.n_basic + cfg.n_expanded}
    return arenas, bases


# tensorize_plans (host-side plan->table packing) lives in
# core/fetch_tables.py, shared with the engine's batch executor; it is
# re-exported above for callers of this module.

"""Production batched phrase-query serving over a document-sharded index.

This tier runs the SAME execution engine as the in-process engines: plans
are tensorized into the batch-executor row tables (core/fetch_tables.py,
core/batch_executor.py) — full subplan unions, all lemma forms, doc-only
fallbacks, near-stop checks — and executed with the same `bucket_step_math`
the engine jit's, wrapped in shard_map over document shards.  The old
serve-only single-subplan executor (first subplan, primary form per group)
is gone; serve results are bit-identical to `engine.search_batch`.

Distributed-IR layout: documents are partitioned contiguously over the
dp = pod x data mesh axes; every dp shard holds only its own slice of the
posting arena (all six streams concatenated so a fetch is a single gather —
re-packed per shard into the bit-packed block store of core/postings.py, so
each device holds packed lanes + per-block anchor/width metadata instead of
raw int32 columns) plus the matching near-stop rows.  Host-side
tensorization is shard-segmented (batch_executor._build_rows): each
execution row targets exactly one doc shard, so a row's fetches live wholly
inside one dp shard's arena and carry an `owner` column.  Inside shard_map every device executes only
its own rows (others are masked inactive), and the per-row results — each
produced on exactly one device — are combined with a single `pmin` over the
dp axes.  The `model` axis replicates the index and serves to scale query
throughput (the launcher round-robins query batches over it).

Per-row work is O(the row's own postings): no device ever re-sorts another
shard's slab, so adding doc shards adds rows (capacity) without inflating
per-shard step cost.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.api import SearchRequest, SearchResponse, as_request
from repro.core.batch_executor import P_FLOOR, BatchExecutor, bucket_step_math
from repro.core.builder import IndexSet
from repro.core.engine import _coerce_requests
from repro.core.executor import SENTINEL, _next_pow2
from repro.core.fetch_tables import batch_table_specs
from repro.core.kword import MODE_KWORD
from repro.core.planner import MODE_PHRASE, Planner

__all__ = ["SearchServeConfig", "SearchServe", "arena_specs",
           "query_table_specs", "make_search_serve_step"]


@dataclasses.dataclass(frozen=True)
class SearchServeConfig:
    name: str = "veretennikov-serve"
    # groups/fetch_slots/postings_pad/seed_pad are CAPS: they size the
    # dry-run cells and bound tensorization, but live steps run through a
    # <=3-tier (G, F, P0, P) ladder derived from the first batch's actual
    # row population (plus pow2-tight T), so a smoke-scale workload is not
    # billed for the full production slab
    queries: int = 64              # query batch size (sizing hint for rows)
    rows: int = 0                  # T cap: execution rows per step; 0 = 2*queries
    groups: int = 8                # G cap: fetch groups per row (seed + G-1)
    fetch_slots: int = 8           # F cap: union slots per group (forms + splits)
    postings_pad: int = 32768      # P cap: padded postings per constraint slot
    seed_pad: int = 0              # P0: seed (pivot) slot pad; 0 = postings_pad.
                                   # The planner seeds with the RAREST list,
                                   # so a small pad bounds the seed gather +
                                   # membership searches (§Perf)
    check_slots: int = 4           # C: near-stop checks on the pivot group
    check_forms: int = 2           # M: stop forms per near-stop check
    ns_k: int = 20                 # stream-3 slots per posting
    # per-shard arena sizes (basic|expanded|stop|first|multi segments
    # concatenated), in POSTINGS — the packed block store derives its block
    # count from this and its lane-word budget from `lane_words`
    n_basic: int = 10_000_000
    n_expanded: int = 17_000_000
    n_stop: int = 23_000_000
    n_first: int = 4_000_000
    n_multi: int = 12_000_000      # multi-component key postings (pairs+triples)
    lane_words: int = 0            # int32 words of packed posting deltas per
                                   # shard; 0 = n_arena (a ~32-bit/posting
                                   # budget — generous: doc/pos/dist widths
                                   # at bench scale average well under that)
    impl: str = "ref"              # intersect implementation (ref | pallas)
    interpret: bool = True         # pallas interpreter (True on CPU hosts)
    ranked: bool = False           # dry-run cells: lower the proximity-scored
                                   # step variant (serving always compiles
                                   # both lazily as ranked requests arrive)

    @property
    def n_arena(self) -> int:
        return (self.n_basic + self.n_expanded + self.n_stop + self.n_first
                + self.n_multi)

    @property
    def n_blocks(self) -> int:
        """Packed blocks per shard (BLOCK postings each)."""
        from repro.core.postings import BLOCK
        return max(1, -(-self.n_arena // BLOCK))

    @property
    def n_lane_words(self) -> int:
        return self.lane_words or self.n_arena

    @property
    def p_seed(self) -> int:
        return self.seed_pad or self.postings_pad

    @property
    def task_rows(self) -> int:
        return self.rows or 2 * self.queries


def _dp_axes(mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _dp_size(mesh) -> int:
    return math.prod(mesh.shape[a] for a in _dp_axes(mesh))


def arena_specs(cfg: SearchServeConfig, n_shards: int) -> dict:
    """ShapeDtypeStructs for the stacked per-shard index arenas: the packed
    block store (lanes + per-block base/width/anchor metadata, see
    core/postings.PackedPostings) plus the raw stream-3 near-stop slots."""
    i32 = jnp.int32
    nb = cfg.n_blocks
    return {
        "lanes": jax.ShapeDtypeStruct((n_shards, cfg.n_lane_words), i32),
        "blk_meta": jax.ShapeDtypeStruct((n_shards, nb, 5), i32),
        "basic_ns": jax.ShapeDtypeStruct((n_shards, cfg.n_basic, cfg.ns_k),
                                         jnp.int16),
    }


def query_table_specs(cfg: SearchServeConfig) -> dict:
    """ShapeDtypeStructs for one serve row batch (replicated to every shard):
    the batch-executor schema plus the per-row `owner` column."""
    return batch_table_specs(cfg.task_rows, cfg.groups, cfg.fetch_slots,
                             cfg.check_slots, cfg.check_forms, owner=True)


# ---------------------------------------------------------------------------
# the serve step: shard_map'd bucket math + one pmin merge
# ---------------------------------------------------------------------------


def make_search_serve_step(cfg: SearchServeConfig, mesh,
                           ranked: bool | None = None,
                           p_seed: int | None = None,
                           postings_pad: int | None = None,
                           kword: bool = False):
    """Returns step(arenas, tables) -> (keys [T, F*P0] int64, found bool)
    — plus proximity scores [T, F*P0] float32 when `ranked` (default:
    cfg.ranked), computed by the SAME bucket math the engine jit's and
    merged across shards right after the int64 pmin (scores ride a pmax:
    every row is owned by exactly one dp shard, so both collectives are
    pure "take the owner's result").

    arenas: dict of stacked per-shard arrays (leading dim = n_dp shards),
    sharded P(dp); tables: dict per query_table_specs, replicated — each
    row's fetch starts are LOCAL to its owner shard's arena.  Outputs are
    replicated: `keys` holds the seed's global 63-bit keys where `found`,
    SENTINEL elsewhere — exactly what the batch executor's merge consumes.
    """
    if ranked is None:
        ranked = cfg.ranked
    dp = _dp_axes(mesh)
    # cfg gives the CAP pads (the dry-run cell shapes); the serve executor's
    # tier ladder lowers tighter variants for the live plan population
    P0 = p_seed or cfg.p_seed
    Pc = postings_pad or cfg.postings_pad

    def local(arenas, t):
        me = jax.lax.axis_index(dp[0])
        for a in dp[1:]:
            me = me * mesh.shape[a] + jax.lax.axis_index(a)
        own = t["owner"] == me
        tt = {k: v for k, v in t.items() if k != "owner"}
        tt["active"] = t["active"] & own[:, None]
        # this shard's packed arena (leading stacked-shard dim is 1 inside
        # shard_map), keyed the way bucket_step_math expects
        arena = {k: v[0] for k, v in arenas.items() if k != "basic_ns"}
        arena["near_stop"] = arenas["basic_ns"][0]
        out = bucket_step_math(
            arena, tt,
            P0=P0, P=Pc, impl=cfg.impl, interpret=cfg.interpret,
            ranked=ranked, kword=kword)
        if ranked:
            a64, found, scores = out
        else:
            a64, found = out
        a64 = jnp.where(found & own[:, None], a64, SENTINEL)
        a64 = jax.lax.pmin(a64, dp)
        if not ranked:
            return a64, a64 < SENTINEL
        scores = jnp.where(found & own[:, None], scores, -1.0)
        scores = jax.lax.pmax(scores, dp)
        hit = a64 < SENTINEL
        return a64, hit, jnp.where(hit, scores, 0.0)

    spec_shard = P(dp)
    spec_rep = P()
    a_specs = {k: spec_shard for k in arena_specs(cfg, 1)}
    q_specs = {k: spec_rep for k in query_table_specs(cfg)}
    out_specs = (spec_rep, spec_rep, spec_rep) if ranked \
        else (spec_rep, spec_rep)
    fn = shard_map(local, mesh=mesh, in_specs=(a_specs, q_specs),
                   out_specs=out_specs, check_vma=False)

    def step(arenas: dict, tables: dict):
        return fn(arenas, tables)
    return step


# ---------------------------------------------------------------------------
# host side: doc-partitioned arenas + the serve batch executor
# ---------------------------------------------------------------------------


class _ServeBatchExecutor(BatchExecutor):
    """BatchExecutor whose rows execute through the shard_map'd serve step.

    Inherits tensorization (seed ordering, shard segmentation, long-list
    splitting), flex-escape routing, and the merge tail — overriding only
    the caps (fixed table shapes from cfg) and `_run_rows` (fixed-shape
    chunks through the jit'd distributed step, with fetch starts remapped
    into each owner shard's local arena)."""

    def __init__(self, index: IndexSet, cfg: SearchServeConfig, mesh,
                 docs_per_shard: int | None = None):
        self.cfg = cfg
        self.mesh = mesh
        self.n_dp = _dp_size(mesh)
        super().__init__(index, impl=cfg.impl, interpret=cfg.interpret,
                         docs_per_shard=docs_per_shard)
        # re-grain the segmentation so every doc shard nests inside one dp
        # shard (rows must never straddle a device's arena slice)
        d = self.dev
        dps = min(d.docs_per_shard, max(1, -(-d.n_docs // self.n_dp)))
        d.docs_per_shard = dps
        d.n_shards = max(1, -(-d.n_docs // dps))
        self.shards_per_dp = max(1, -(-d.n_shards // self.n_dp))
        self.docs_per_dp = dps * self.shards_per_dp
        self._build_dp_arenas(index)
        self._tiers: list | None = None
        self.slab_stats = {"steps": 0, "slab_rows": 0, "live_rows": 0,
                           "slab_elems": 0, "live_elems": 0}
        self._steps = {(False, False, cfg.p_seed, cfg.postings_pad):
                       jax.jit(make_search_serve_step(cfg, mesh,
                                                      ranked=False))}

    def _step_for(self, ranked: bool, p_seed: int | None = None,
                  postings_pad: int | None = None, kword: bool = False):
        cfg = self.cfg
        key = (ranked, kword, p_seed or cfg.p_seed,
               postings_pad or cfg.postings_pad)
        if key not in self._steps:
            self._steps[key] = jax.jit(
                make_search_serve_step(cfg, self.mesh, ranked=ranked,
                                       p_seed=p_seed,
                                       postings_pad=postings_pad,
                                       kword=kword))
        return self._steps[key]

    # -- tier-ladder persistence (warm restarts) ----------------------------

    def dump_tiers(self, path):
        """Write the learned (G, F, P0, P) tier ladder to `path` (JSON) so a
        fresh executor can warm from it instead of re-deriving (and
        re-compiling) from its first live batch.  No-op before the ladder
        exists."""
        import json
        if self._tiers is None:
            return False
        with open(path, "w") as fh:
            json.dump({"tiers": [list(t) for t in self._tiers]}, fh)
        return True

    def load_tiers(self, path) -> bool:
        """Adopt a previously dumped tier ladder.  Shapes are re-clipped to
        THIS config's caps (a ladder learned under larger caps stays valid —
        the caps remain the emergency tier), deduped, and volume-sorted, so a
        stale file can degrade compile warmth but never correctness."""
        import json
        import os
        if not os.path.exists(path):
            return False
        with open(path) as fh:
            state = json.load(fh)
        cfg = self.cfg
        cap = (cfg.groups, cfg.fetch_slots, cfg.p_seed, cfg.postings_pad)
        tiers = []
        for t in state.get("tiers", ()):
            if len(t) != 4 or any(int(x) < 1 for x in t):
                continue
            t = tuple(min(int(x), c) for x, c in zip(t, cap))
            if t not in tiers:
                tiers.append(t)
        if not tiers:
            return False
        self._tiers = sorted(tiers, key=self._tier_volume)
        return True

    def _build_dp_arenas(self, index: IndexSet):
        """Bucket the global arena to its owning dp shard host-side: shard d
        keeps exactly the postings of docs [d*docs_per_dp, (d+1)*docs_per_dp),
        in global order — so every stream stays a contiguous local segment
        and a global fetch slice maps to one local slice per shard.  Each
        shard's selection is re-packed into its own block store (local
        posting ordinals address it, exactly what the remapped fetch starts
        produce); block-pad ordinals of the global arena are excluded from
        the selection so local ordinals stay dense."""
        from repro.core.postings import PackedPostings
        d = self.dev
        doc_np = d.arena_doc_np
        ns_np = d.near_stop_np
        nb = ns_np.shape[0]                      # basic stream length
        own = doc_np // self.docs_per_dp
        self._sel = [np.nonzero(d.arena_real_np & (own == dd))[0]
                     for dd in range(self.n_dp)]
        packs = [PackedPostings.from_columns(
            {"doc": doc_np[sel], "pos": d.arena_pos_np[sel],
             "dist": d.arena_dist_np[sel]}, fields=("doc", "pos", "dist"))
            for sel in self._sel]
        lw_pad = max(max(len(p.lanes) for p in packs), 1)
        nblk_pad = max(max(p.n_blocks for p in packs), 1)
        nb_l = [int(np.searchsorted(s, nb)) for s in self._sel]
        nb_pad = max(max(nb_l, default=0), 1)
        k = ns_np.shape[1]
        lanes_l = np.zeros((self.n_dp, lw_pad), np.int32)
        meta_l = np.zeros((self.n_dp, nblk_pad, 5), np.int32)
        ns_l = np.full((self.n_dp, nb_pad, k), -1, np.int16)
        for dd, (sel, p) in enumerate(zip(self._sel, packs)):
            lanes_l[dd, :len(p.lanes)] = p.lanes
            meta_l[dd, :p.n_blocks] = p.meta_matrix()
            ns_l[dd, :nb_l[dd]] = ns_np[sel[:nb_l[dd]]]
        dp = _dp_axes(self.mesh)
        shard = NamedSharding(self.mesh, P(dp))
        self.arenas = {
            "lanes": jax.device_put(lanes_l, shard),
            "blk_meta": jax.device_put(meta_l, shard),
            "basic_ns": jax.device_put(ns_l, shard),
        }

    def _caps(self):
        cfg = self.cfg
        return (cfg.groups, cfg.fetch_slots, cfg.fetch_slots,
                cfg.p_seed, cfg.postings_pad)

    def _task_fits(self, groups, kword: bool = False) -> bool:
        if not super()._task_fits(groups, kword=kword):
            return False
        # fixed near-stop slots: checks that don't fit can't be truncated
        # (dropping a check loosens type-4 verification) -> flex
        cfg = self.cfg
        for g in groups:
            for f in g.fetches:
                if len(f.stop_checks) > cfg.check_slots:
                    return False
                if any(len(ids) > cfg.check_forms for _, ids in f.stop_checks):
                    return False
        return True

    def _run_rows(self, rows: list):
        # ranked/unranked and kword/pairwise rows run through separate
        # fixed-shape step variants (scoring and the span join are different
        # programs); each keeps the chunking and start-remapping of the base
        # executor
        for ranked in (False, True):
            for kword in (False, True):
                self._run_rows_variant(
                    [r for r in rows if r.task.ranked == ranked
                     and (r.task.mode == MODE_KWORD) == kword],
                    ranked, kword)

    def _row_shape(self, row) -> tuple:
        """Pow2-padded (G, F, P0, P) this row actually needs, clipped to the
        cfg caps (tensorization already guarantees the raw requirements
        fit them)."""
        cfg = self.cfg
        G = max(2, _next_pow2(len(row.groups), floor=2))
        F = _next_pow2(max(len(g.slots) for g in row.groups), floor=1)
        P0 = _next_pow2(max((ln for _, _, ln in row.groups[0].slots),
                            default=1), floor=P_FLOOR)
        Pc = _next_pow2(max((ln for g in row.groups[1:] for _, _, ln in g.slots),
                            default=1), floor=P_FLOOR)
        return (min(G, cfg.groups), min(F, cfg.fetch_slots),
                min(P0, cfg.p_seed), min(Pc, cfg.postings_pad))

    @staticmethod
    def _tier_volume(s: tuple) -> int:
        G, F, P0, Pc = s
        return F * P0 + (G - 1) * F * Pc

    def _tier_ladder(self, rows: list) -> list:
        """Derive <= 3 nested (G, F, P0, P) tiers from the first batch's row
        population (the auto_docs_per_shard move applied to table shapes):
        rows volume-sorted, elementwise max over tertiles, running max keeps
        the ladder monotone.  cfg's slab sizes stay pure CAPS — the dry-run
        cell contract — and serve as the emergency tier for later rows that
        outgrow the population the ladder was derived from."""
        if self._tiers is None:
            shapes = sorted((self._row_shape(r) for r in rows),
                            key=self._tier_volume)
            n = len(shapes)
            tiers, prev = [], (0, 0, 0, 0)
            for third in (shapes[:max(n // 3, 1)],
                          shapes[max(n // 3, 1):max(2 * n // 3, 1)],
                          shapes[max(2 * n // 3, 1):]):
                if not third:
                    continue
                t = tuple(max(prev[i], max(s[i] for s in third))
                          for i in range(4))
                prev = t
                if t not in tiers:
                    tiers.append(t)
            self._tiers = tiers
        return self._tiers

    def _run_rows_variant(self, rows: list, ranked: bool, kword: bool = False):
        if not rows:
            return
        cfg = self.cfg
        cap = (cfg.groups, cfg.fetch_slots, cfg.p_seed, cfg.postings_pad)
        tiers = self._tier_ladder(rows)
        assign: dict = {}
        for row in rows:
            req = self._row_shape(row)
            tier = next((t for t in tiers
                         if all(a <= b for a, b in zip(req, t))), cap)
            assign.setdefault(tier, []).append(row)
        for (G, F, P0, Pc), rs in assign.items():
            step = self._step_for(ranked, p_seed=P0, postings_pad=Pc,
                                  kword=kword)
            for lo in range(0, len(rs), cfg.task_rows):
                part = rs[lo:lo + cfg.task_rows]
                # tight T: pow2-chunked instead of the full fixed slab, so a
                # smoke-sized batch no longer drags task_rows dead rows
                # through the packed unpack + gather + sort
                T = min(cfg.task_rows, _next_pow2(len(part), floor=4))
                t = self._tensorize_bucket(part, G, F, cfg.check_slots,
                                           cfg.check_forms, T)
                owner = np.zeros(T, np.int32)
                owner[:len(part)] = [row.shard // self.shards_per_dp
                                     for row in part]
                # remap global fetch starts into each owner shard's local
                # arena: one vectorized searchsorted per dp shard touched
                live = t["length"] > 0
                for dd in np.unique(owner[:len(part)]):
                    m = (owner == dd)[:, None, None] & live
                    t["start"][m] = np.searchsorted(self._sel[dd],
                                                    t["start"][m])
                t["owner"] = owner
                st = self.slab_stats
                st["steps"] += 1
                st["slab_rows"] += T
                st["live_rows"] += len(part)
                st["slab_elems"] += T * self._tier_volume((G, F, P0, Pc))
                st["live_elems"] += sum(
                    ln for row in part for g in row.groups
                    for _, _, ln in g.slots)
                tj = {k: jnp.asarray(v) for k, v in t.items()}
                with self.mesh:
                    out = step(self.arenas, tj)
                if ranked:
                    a64, found, scores = out
                    self._scatter_row_keys(part, np.asarray(a64),
                                           np.asarray(found),
                                           np.asarray(scores))
                else:
                    a64, found = out
                    self._scatter_row_keys(part, np.asarray(a64),
                                           np.asarray(found))


class SearchServe:
    """End-to-end distributed serving facade: SearchRequests → plan → serve
    tables → shard_map step → merged SearchResponses, bit-identical to
    `engine.search_batch` — ranked top-k included (the scoring pass is the
    same bucket math, merged right after the cross-shard pmin).

    Plans that exceed the fixed table shapes run through the flexible
    executor host-side (the same escape hatch the engine uses)."""

    def __init__(self, index: IndexSet, cfg: SearchServeConfig, mesh,
                 docs_per_shard: int | None = None, occ_counts=None):
        self.index = index
        self.cfg = cfg
        self.mesh = mesh
        # occ_counts: cluster-global occurrence stats when this serve tier
        # holds one doc shard / segment of a larger corpus (see Planner)
        self.planner = Planner(index, occ_counts=occ_counts)
        self.executor = _ServeBatchExecutor(index, cfg, mesh,
                                            docs_per_shard=docs_per_shard)

    @property
    def n_dp(self) -> int:
        return self.executor.n_dp

    def refresh_occ_counts(self, occ_counts=None):
        """Re-snapshot planner pivot statistics (see Planner.refresh_occ_counts)."""
        self.planner.refresh_occ_counts(occ_counts)

    def plan_request(self, request: SearchRequest):
        return self.planner.plan(list(request.surface_ids),
                                 mode=request.mode, window=request.window,
                                 ranked=request.rank)

    def plan(self, surface_ids, mode: str = MODE_PHRASE,
             window: int | None = None, ranked: bool = False):
        """Host-side plan introspection (not a search entry point)."""
        return self.planner.plan(list(surface_ids), mode=mode, window=window,
                                 ranked=ranked)

    def execute_batch(self, plans, requests=None,
                      max_results: int | None = None) -> list[SearchResponse]:
        return self.executor.execute_batch(plans, requests=requests,
                                           max_results=max_results)

    def search(self, request, mode: str = MODE_PHRASE,
               window: int | None = None,
               max_results: int | None = None) -> SearchResponse:
        if not isinstance(request, SearchRequest):
            request = as_request(request, mode, window, max_results,
                                 what="SearchServe.search")
        return self.search_batch([request])[0]

    def search_batch(self, requests, modes: str | list = MODE_PHRASE,
                     window: int | None = None,
                     max_results: int | None = None) -> list[SearchResponse]:
        """A batch of SearchRequests through the distributed step.  The
        positional (queries, modes=...) form is a deprecated shim."""
        requests = list(requests)
        if not all(isinstance(r, SearchRequest) for r in requests):
            requests = _coerce_requests(requests, modes, window, max_results,
                                        what="SearchServe.search_batch")
        plans = [self.plan_request(r) for r in requests]
        return self.execute_batch(plans, requests=requests)

"""Serving front door: deadline-aware micro-batching, admission control,
and graceful degradation over doc-sharded search engines.

The paper's traffic model (arXiv:1801.09079) is heavy concurrent phrase
traffic from millions of users; this module is the path from concurrent
single `SearchRequest`s to the plan-compiled batched engine.  Individual
requests are coalesced into deadline-bounded micro-batches, routed by plan
shape (so one flex-escape straggler cannot drag a whole batch off the jit'd
path), fanned out over document shards through
`dist.fault_tolerance.ShardDispatcher`, and merged bit-identically to
`engine.search_batch` — or degraded *explicitly* when shards die or
deadlines pass.

Request state machine
---------------------
::

    submit(request, client)
      │
      ├─ client token bucket dry ────────────► SHED   (rate_limited)
      ├─ result cache hit (plan signature) ──► SERVED_EXACT  (cached=True)
      ├─ queue full ─────────────────────────► SHED   (queue_full)
      ▼
    QUEUED ── deadline passed before dispatch ─► SHED (deadline)
      │   dispatcher thread coalesces ≤ max_batch requests within
      │   batch_window_ms, window clipped to the earliest admitted deadline
      ▼
    ROUTED ── per-request shape bucket:
      │         · batched-unranked  ─┐ the 2–3 jit variants the engine's
      │         · batched-ranked   ─┘ pow2 shape buckets compile to
      │         · flex escape (over-cap plans), admitted only while the
      │           remaining deadline slack covers flex_budget_ms
      ▼
    EXECUTE ── ShardDispatcher fan-out (timeout + replica re-dispatch),
      │        then ≤ max_retries bounded re-dispatches of still-missing
      │        shards with exponential backoff
      ├─ every shard contributed, on time ───► SERVED_EXACT  (+ cache fill)
      ├─ partial shards or past deadline ────► SERVED_DEGRADED
      │                                        (`shards` = contributors,
      │                                         shed_reason = shards|late)
      └─ no shard contributed ───────────────► SERVED_DEGRADED (empty,
                                               shed_reason = no_shards)

Every `submit()` returns a ticket whose `result()` resolves with exactly one
of the three statuses — no request is ever silently dropped (the chaos suite
in tests/test_front.py floods, stalls, fails, and clock-skews this machine
to prove it).

Bit-identity across shards
--------------------------
`SERVED_EXACT` responses are bit-identical to `engine.search_batch` on the
unsharded index.  Three mechanisms make that true with doc-sharded backends:

  * every shard plans with CLUSTER-GLOBAL occurrence counts
    (`Planner(occ_counts=...)`), so pivot selection agrees everywhere;
  * ranked seed ordering is plan-order deterministic
    (`order_groups_seed_first(ranked=True)`), so float32 score accumulation
    agrees everywhere despite shard-local posting lengths;
  * the merge reconstructs the *global* fallback decision from per-subplan
    positional-hit counts (`SearchResponse.subplan_pos_hits`): a subplan
    falls back iff it has fallback groups and zero positional keys across
    ALL shards — shard-local fallback verdicts are never trusted.  Postings
    accounting replays the same rule against the front's own global plan,
    so even `postings_read` matches the unsharded engine.

Document ranges partition the corpus, so shard-ascending concatenation of
(doc, pos)-sorted anchors is globally sorted, per-doc score sums live wholly
inside one shard, and per-shard top-k always contains the global top-k.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.api import (STATUS_SERVED_DEGRADED, STATUS_SERVED_EXACT,
                            STATUS_SHED, SearchRequest, SearchResponse)
from repro.core.builder import IndexSet, build_all
from repro.core.corpus import Corpus
from repro.core.engine import AdditionalIndexEngine
from repro.core.executor import _rank_docs
from repro.core.planner import Planner, QueryPlan
from repro.dist.fault_tolerance import ShardDispatcher


@dataclasses.dataclass(frozen=True)
class FrontDoorConfig:
    """Admission, batching, and degradation knobs of the front door."""
    max_queue: int = 512           # bounded queue; overflow => SHED
    max_batch: int = 64            # micro-batch size cap
    batch_window_ms: float = 2.0   # coalescing window (clipped to deadlines)
    default_deadline_ms: float = 1000.0   # when request.deadline_ms is None
    cache_capacity: int = 1024     # hot-query result cache entries; 0 = off
    rate_per_s: float = 0.0        # per-client token refill; 0 = unlimited
    rate_burst: int = 64           # per-client bucket depth
    shard_timeout_s: float = 5.0   # ShardDispatcher per-phase timeout
    max_retries: int = 1           # bounded re-dispatch of missing shards
    retry_backoff_ms: float = 20.0  # backoff base (doubles per retry)
    flex_budget_ms: float = 250.0  # min deadline slack to admit a flex plan


class TokenBucket:
    """Per-client rate limiter: `rate` tokens/s, `burst` depth."""

    def __init__(self, rate: float, burst: float, clock: Callable[[], float]):
        self.rate = float(rate)
        self.burst = float(burst)
        self.clock = clock
        self.tokens = float(burst)
        self.last = clock()
        self._lock = threading.Lock()

    def take(self) -> bool:
        with self._lock:
            now = self.clock()
            self.tokens = min(self.burst,
                              self.tokens + (now - self.last) * self.rate)
            self.last = now
            if self.tokens >= 1.0:
                self.tokens -= 1.0
                return True
            return False


@dataclasses.dataclass
class FrontStats:
    """Counters + latency reservoir; the no-silent-drop ledger
    (submitted == served_exact + served_degraded + shed, always)."""
    submitted: int = 0
    served_exact: int = 0
    served_degraded: int = 0
    shed: int = 0
    cache_hits: int = 0
    stale_cache_hits: int = 0   # pre-invalidation entry served post-bump
                                # (structurally 0: the CI staleness gate)
    backfilled: int = 0         # late-shard results re-merged into the cache
    generation_bumps: int = 0   # segment-manager invalidations observed
    flex_routed: int = 0
    batches: int = 0
    retries: int = 0
    shed_reasons: dict = dataclasses.field(default_factory=dict)
    latencies_ms: list = dataclasses.field(default_factory=list)

    @property
    def responded(self) -> int:
        return self.served_exact + self.served_degraded + self.shed

    @property
    def shed_rate(self) -> float:
        return self.shed / max(self.submitted, 1)

    def percentile(self, p: float) -> float:
        if not self.latencies_ms:
            return 0.0
        return float(np.percentile(np.asarray(self.latencies_ms), p))


class _Ticket:
    """One in-flight request: resolves exactly once with a SearchResponse."""

    __slots__ = ("request", "client", "arrival", "deadline", "plan",
                 "response", "_event")

    def __init__(self, request: SearchRequest, client: str, arrival: float,
                 deadline: float):
        self.request = request
        self.client = client
        self.arrival = arrival
        self.deadline = deadline
        self.plan: QueryPlan | None = None
        self.response: SearchResponse | None = None
        self._event = threading.Event()

    def result(self, timeout: float | None = None) -> SearchResponse:
        if not self._event.wait(timeout):
            raise TimeoutError("front door ticket not resolved in time")
        return self.response

    def done(self) -> bool:
        return self._event.is_set()


# ---------------------------------------------------------------------------
# doc-shard backends
# ---------------------------------------------------------------------------


class ShardBackend:
    """One document partition: its own index + engine, answering for docs
    [doc_base, doc_base + index.n_docs).  Callable with a list of
    SearchRequests (the ShardDispatcher contract); responses come back with
    doc ids re-based into the global space.

    `occ_counts` MUST be the cluster-global counts when more than one shard
    exists — see the module docstring's bit-identity contract."""

    def __init__(self, index: IndexSet, doc_base: int = 0, occ_counts=None,
                 batch_impl: str = "ref", interpret: bool = True):
        self.doc_base = int(doc_base)
        self.n_docs = index.n_docs
        # doc_base reaches the engine too: its batched rows then sit on the
        # GLOBAL doc-shard grid (same bucket boundaries for every shard /
        # segment of the corpus) — results are identical at any grid
        self.engine = AdditionalIndexEngine(index, batch_impl=batch_impl,
                                            interpret=interpret,
                                            occ_counts=occ_counts,
                                            doc_base=doc_base)

    def __call__(self, requests: Sequence[SearchRequest]) -> list[SearchResponse]:
        resps = self.engine.search_batch(list(requests))
        if self.doc_base:
            base = np.int32(self.doc_base)
            for r in resps:
                r.doc = r.doc + base
                if r.doc_ids is not None:
                    r.doc_ids = r.doc_ids + base
        return resps


def build_doc_shards(corpus: Corpus, index: IndexSet, n_shards: int,
                     replicate: bool = False,
                     batch_impl: str = "ref", interpret: bool = True):
    """Split `corpus` into `n_shards` contiguous doc ranges, build a full
    IndexSet per range, and wrap each in a ShardBackend planning with the
    GLOBAL index's occurrence counts.  Returns (backends, replicas) —
    replicas answer for the same ranges (shared per-range index, separate
    engine) or None when `replicate` is False."""
    n_shards = max(1, min(int(n_shards), corpus.n_docs))
    occ = index.base_occ_counts()
    edges = [round(i * corpus.n_docs / n_shards) for i in range(n_shards + 1)]
    backends, replicas = [], [] if replicate else None
    for lo, hi in zip(edges[:-1], edges[1:]):
        offs = corpus.doc_offsets
        sub = Corpus(doc_offsets=(offs[lo:hi + 1] - offs[lo]).copy(),
                     tokens=corpus.tokens[offs[lo]:offs[hi]].copy())
        idx = build_all(sub, index.lexicon, index.analyzer, index.params)
        backends.append(ShardBackend(idx, doc_base=lo, occ_counts=occ,
                                     batch_impl=batch_impl,
                                     interpret=interpret))
        if replicate:
            replicas.append(ShardBackend(idx, doc_base=lo, occ_counts=occ,
                                         batch_impl=batch_impl,
                                         interpret=interpret))
    return backends, replicas


# ---------------------------------------------------------------------------
# shard merge (bit-identical to executor.merge_subplan_results)
# ---------------------------------------------------------------------------


def merge_shard_responses(request: SearchRequest, plan: QueryPlan,
                          per_shard: list) -> SearchResponse:
    """Merge one query's per-shard responses (list of (shard_i, resp),
    shard-ascending) into the response the unsharded engine would return.

    Mirrors `merge_subplan_results` exactly: positional hits (anywhere) win
    over doc-only fallback docs; the fallback decision and postings
    accounting replay per-subplan against the GLOBAL plan using the summed
    `subplan_pos_hits`; concatenation in shard order preserves global
    (doc, pos) key order because shards partition contiguous doc ranges."""
    sup = [sp for sp in plan.subplans if sp.supported]
    ranked = request.rank
    top_k = request.top_k
    hits = [0] * len(sup)
    for _i, r in per_shard:
        h = r.subplan_pos_hits
        if len(h) != len(sup):      # shard planned a different structure —
            raise RuntimeError(     # the global-occ-counts contract is broken
                f"shard subplan mismatch: {len(h)} != {len(sup)}")
        for j, n in enumerate(h):
            hits[j] += int(n)
    used_fallback = any(sp.fallback_groups and hits[j] == 0
                        for j, sp in enumerate(sup))
    postings = sum(sp.postings_read for sp in sup)
    postings += sum(sum(g.postings_read for g in sp.fallback_groups)
                    for j, sp in enumerate(sup)
                    if sp.fallback_groups and hits[j] == 0)
    resp = SearchResponse(
        doc=np.empty(0, np.int32), pos=np.empty(0, np.int32),
        postings_read=postings, used_fallback=used_fallback, doc_only=False,
        subplan_types=tuple(sp.qtype for sp in sup), ranked=ranked,
        request=request, subplan_pos_hits=tuple(hits))
    if ranked:
        resp.anchor_scores = np.empty(0, np.float32)
        resp.doc_ids = np.empty(0, np.int32)
        resp.doc_scores = np.empty(0, np.float32)
    if any(hits):
        parts = [r for _i, r in per_shard if len(r.doc) and not r.doc_only]
        if parts:
            resp.doc = np.concatenate([r.doc for r in parts])
            resp.pos = np.concatenate([r.pos for r in parts])
            if ranked:
                resp.anchor_scores = np.concatenate(
                    [r.anchor_scores for r in parts])
                masks = [r.anchor_subplans for r in parts]
                if all(m is not None for m in masks):
                    resp.anchor_subplans = np.concatenate(masks)
                d = np.concatenate([r.doc_ids for r in parts])
                s = np.concatenate([r.doc_scores for r in parts])
                # per-shard top-k always contains the global top-k (each doc
                # is whole within one shard); re-ranking the doc-ascending
                # union reproduces the global _rank_docs order bit-exactly
                order = np.argsort(d, kind="stable")
                resp.doc_ids, resp.doc_scores = _rank_docs(
                    d[order], s[order], top_k)
            elif top_k is not None:
                resp.doc, resp.pos = resp.doc[:top_k], resp.pos[:top_k]
        return resp
    if used_fallback:
        parts = [r for _i, r in per_shard if r.doc_only and len(r.doc)]
        docs = (np.concatenate([r.doc for r in parts]) if parts
                else np.empty(0, np.int32))
        resp.doc = docs.astype(np.int32)
        resp.pos = np.full(len(resp.doc), -1, dtype=np.int32)
        resp.doc_only = True
        if ranked:
            resp.anchor_scores = np.full(
                len(resp.doc), request.ranking.doc_only_score, np.float32)
            resp.doc_ids = resp.doc.copy()
            resp.doc_scores = resp.anchor_scores.copy()
            if top_k is not None:
                resp.doc_ids = resp.doc_ids[:top_k]
                resp.doc_scores = resp.doc_scores[:top_k]
        elif top_k is not None:
            resp.doc, resp.pos = resp.doc[:top_k], resp.pos[:top_k]
    return resp


# ---------------------------------------------------------------------------
# the front door
# ---------------------------------------------------------------------------


class FrontDoor:
    """See the module docstring for the full state machine.

    `backends`/`replicas` default to one ShardBackend over the whole index
    (the bench configuration: single-shard fronts are bit-identical to the
    engine INCLUDING postings accounting).  `clock` is injectable
    (dist.chaos.SkewedClock) for the clock-skew chaos scenario.

    `segments` plugs in a `core.segments.SegmentManager` instead of a fixed
    index: backends and planner come from the manager's live segments, and
    the front subscribes to generation bumps — every ingest/merge
    invalidates the result cache (the stale-cache bugfix) and re-syncs
    backends + cluster-global occ counts before the next micro-batch."""

    def __init__(self, index: IndexSet | None = None,
                 backends: Optional[Sequence[ShardBackend]] = None,
                 replicas: Optional[Sequence[ShardBackend]] = None,
                 cfg: FrontDoorConfig = FrontDoorConfig(),
                 clock: Callable[[], float] = time.monotonic,
                 batch_impl: str = "ref", interpret: bool = True,
                 segments=None):
        self.cfg = cfg
        self.clock = clock
        self.segments = segments
        if segments is not None:
            if not segments.segments:
                raise ValueError(
                    "FrontDoor(segments=...) needs >= 1 ingested segment")
            backends = segments.engine_backends()
            replicas = None       # segment backends re-sync; no replica tier
            self.planner = segments.current_planner()
        else:
            if backends is None:
                backends = [ShardBackend(index, batch_impl=batch_impl,
                                         interpret=interpret)]
            self.planner = Planner(index)
        self.backends = list(backends)
        self.n_shards = len(self.backends)
        self.dispatcher = ShardDispatcher(
            self.backends, replica_fns=replicas, timeout=cfg.shard_timeout_s)
        self.stats = FrontStats()
        self._stats_lock = threading.Lock()
        self._queue: queue.Queue = queue.Queue(maxsize=cfg.max_queue)
        self._cache: dict = {}
        self._cache_order: list = []    # LRU order, oldest first
        self._cache_lock = threading.Lock()
        self._generation = 0            # bumped by invalidate_cache()
        self._resync = False            # segment set changed: rebuild backends
        self._buckets: dict[str, TokenBucket] = {}
        self._buckets_lock = threading.Lock()
        self._closed = False
        if segments is not None:
            segments.subscribe(self._on_generation)
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="front-door")
        self._thread.start()

    # -- public API ---------------------------------------------------------

    def submit(self, request: SearchRequest, client: str = "default") -> _Ticket:
        """Admit (or shed) one request; returns immediately with a ticket."""
        now = self.clock()
        budget = (request.deadline_ms if request.deadline_ms is not None
                  else self.cfg.default_deadline_ms)
        t = _Ticket(request, client, now, now + budget / 1000.0)
        with self._stats_lock:
            self.stats.submitted += 1
        if self.cfg.rate_per_s > 0 and not self._bucket(client).take():
            self._shed(t, "rate_limited")
            return t
        hit = self._cache_get(request)
        if hit is not None:
            hit.latency_ms = (self.clock() - now) * 1000.0
            self._fulfill(t, hit, cache_hit=True)
            return t
        try:
            self._queue.put_nowait(t)
        except queue.Full:
            self._shed(t, "queue_full")
        return t

    def search(self, request: SearchRequest, client: str = "default",
               timeout: float | None = None) -> SearchResponse:
        return self.submit(request, client=client).result(timeout)

    def search_batch(self, requests: Sequence[SearchRequest],
                     client: str = "default",
                     timeout: float | None = None) -> list[SearchResponse]:
        tickets = [self.submit(r, client=client) for r in requests]
        return [t.result(timeout) for t in tickets]

    def close(self):
        """Stop the dispatcher thread; queued requests shed (never dropped)."""
        self._closed = True
        self._thread.join(timeout=30.0)
        while True:
            try:
                t = self._queue.get_nowait()
            except queue.Empty:
                break
            self._shed(t, "shutdown")
        self.dispatcher.close()

    # -- admission helpers --------------------------------------------------

    def _bucket(self, client: str) -> TokenBucket:
        with self._buckets_lock:
            b = self._buckets.get(client)
            if b is None:
                b = TokenBucket(self.cfg.rate_per_s, self.cfg.rate_burst,
                                self.clock)
                self._buckets[client] = b
            return b

    def invalidate_cache(self) -> None:
        """Drop every cached result and advance the cache generation — any
        index change (segment ingest / merge) makes every cached response
        potentially stale.  New entries key on the NEW generation, and
        results computed against the old segment set can no longer land
        (`_cache_put` checks the generation it was planned under)."""
        with self._cache_lock:
            self._generation += 1
            self._cache.clear()
            self._cache_order.clear()

    def _on_generation(self, gen: int) -> None:
        """SegmentManager subscription: invalidate + schedule a backend
        re-sync (picked up by the dispatcher thread before the next batch)."""
        with self._stats_lock:
            self.stats.generation_bumps += 1
        self._resync = True
        self.invalidate_cache()

    def _cache_generation(self) -> int:
        with self._cache_lock:
            return self._generation

    def _cache_get(self, request: SearchRequest) -> SearchResponse | None:
        if self.cfg.cache_capacity <= 0:
            return None
        stale = False
        with self._cache_lock:
            key = (request.plan_signature(), self._generation)
            entry = self._cache.get(key)
            if entry is not None:
                gen, resp = entry
                if gen != self._generation:
                    # structurally unreachable (invalidation clears the dict
                    # and the key embeds the generation) — kept as the
                    # regression tripwire behind stats.stale_cache_hits
                    self._cache.pop(key, None)
                    if key in self._cache_order:
                        self._cache_order.remove(key)
                    entry, stale = None, True
                else:
                    self._cache_order.remove(key)
                    self._cache_order.append(key)
        if stale:
            with self._stats_lock:
                self.stats.stale_cache_hits += 1
        if entry is None:
            return None
        # shallow copy: result arrays are shared (treated immutable), the
        # transport fields are per-delivery; the caller's request (possibly
        # a different deadline — excluded from the key) rides along
        return dataclasses.replace(entry[1], cached=True, request=request)

    def _cache_put(self, request: SearchRequest, resp: SearchResponse,
                   gen: int | None = None):
        """`gen` is the cache generation the response was COMPUTED under
        (captured at dispatch); a bump that landed mid-flight means the
        result may predate the newest segments — skip, never cache it."""
        if self.cfg.cache_capacity <= 0:
            return
        with self._cache_lock:
            if gen is not None and gen != self._generation:
                return
            key = (request.plan_signature(), self._generation)
            if key in self._cache:
                self._cache_order.remove(key)
            elif len(self._cache) >= self.cfg.cache_capacity:
                self._cache.pop(self._cache_order.pop(0), None)
            self._cache[key] = (self._generation, resp)
            self._cache_order.append(key)

    # -- resolution ---------------------------------------------------------

    def _shed(self, t: _Ticket, reason: str):
        resp = SearchResponse(
            doc=np.empty(0, np.int32), pos=np.empty(0, np.int32),
            postings_read=0, used_fallback=False, doc_only=False,
            request=t.request, status=STATUS_SHED, shed_reason=reason,
            latency_ms=(self.clock() - t.arrival) * 1000.0)
        with self._stats_lock:
            self.stats.shed += 1
            self.stats.shed_reasons[reason] = \
                self.stats.shed_reasons.get(reason, 0) + 1
        t.response = resp
        t._event.set()

    def _fulfill(self, t: _Ticket, resp: SearchResponse,
                 cache_hit: bool = False):
        if resp.latency_ms is None:
            resp.latency_ms = (self.clock() - t.arrival) * 1000.0
        with self._stats_lock:
            if resp.status == STATUS_SERVED_EXACT:
                self.stats.served_exact += 1
            else:
                self.stats.served_degraded += 1
            if cache_hit:
                self.stats.cache_hits += 1
            self.stats.latencies_ms.append(resp.latency_ms)
        t.response = resp
        t._event.set()

    # -- dispatcher thread --------------------------------------------------

    def _loop(self):
        while not self._closed:
            try:
                first = self._queue.get(timeout=0.05)
            except queue.Empty:
                continue
            batch = [first]
            window_end = min(self.clock() + self.cfg.batch_window_ms / 1000.0,
                             first.deadline)
            while len(batch) < self.cfg.max_batch:
                rem = window_end - self.clock()
                if rem <= 0:
                    break
                try:
                    t = self._queue.get(timeout=rem)
                except queue.Empty:
                    break
                batch.append(t)
                window_end = min(window_end, t.deadline)
            try:
                if self._resync:
                    self._sync_segments()
                self._dispatch_batch(batch)
            except Exception:                        # pragma: no cover
                # a dispatcher bug must not silently strand tickets
                for t in batch:
                    if not t.done():
                        self._shed(t, "internal_error")

    def _sync_segments(self):
        """Rebuild backends/planner from the segment manager's current
        generation (dispatcher thread only).  The resync flag clears FIRST
        so a bump landing mid-sync re-triggers.  The old dispatcher is
        closed without waiting: its in-flight late futures may still fire,
        but backfill is generation-guarded so they can never pollute the
        new generation's cache."""
        self._resync = False
        segs = self.segments
        backends = segs.engine_backends()
        planner = segs.current_planner()
        old = self.dispatcher
        self.dispatcher = ShardDispatcher(backends, replica_fns=None,
                                          timeout=self.cfg.shard_timeout_s)
        self.backends = backends
        self.n_shards = len(backends)
        self.planner = planner
        old.close()

    def _is_overflow(self, plan: QueryPlan) -> bool:
        """Routing hint: would this plan escape the batched executor's shape
        caps?  (The shard engines route per-plan themselves — this only
        decides WHICH dispatch bucket the request rides in, so the cheap
        group/fetch-count check suffices.)"""
        from repro.core.batch_executor import F_CAP, G_CAP
        from repro.core.kword import KW_DEVICE_MAX_WINDOW
        for sp in plan.subplans:
            if not sp.supported:
                continue
            # kword windows wider than the int32 delta masks run flex-side
            if sp.kw_window is not None \
                    and int(sp.kw_window) > KW_DEVICE_MAX_WINDOW:
                return True
            for gs in (sp.groups, sp.fallback_groups):
                if len(gs) > G_CAP or any(len(g.fetches) > F_CAP for g in gs):
                    return True
        return False

    def _dispatch_batch(self, batch: list):
        with self._stats_lock:
            self.stats.batches += 1
        now = self.clock()
        buckets: dict[str, list] = {"unranked": [], "ranked": [], "flex": []}
        for t in batch:
            if now > t.deadline:
                self._shed(t, "deadline")
                continue
            r = t.request
            t.plan = self.planner.plan(list(r.surface_ids), mode=r.mode,
                                       window=r.window, ranked=r.rank)
            if self._is_overflow(t.plan):
                # flex escape: the slow path only runs while the deadline
                # slack still covers its per-request time budget
                if (t.deadline - now) * 1000.0 < self.cfg.flex_budget_ms:
                    self._shed(t, "deadline")
                    continue
                with self._stats_lock:
                    self.stats.flex_routed += 1
                buckets["flex"].append(t)
            elif r.rank:
                buckets["ranked"].append(t)
            else:
                buckets["unranked"].append(t)
        # jit'd shape buckets first; flex stragglers run after, one by one,
        # so they can never hold a batched bucket's responses hostage
        for key in ("unranked", "ranked"):
            if buckets[key]:
                self._execute(buckets[key])
        for t in buckets["flex"]:
            self._execute([t])

    def _execute(self, items: list):
        reqs = [t.request for t in items]
        gen0 = self._cache_generation()
        slot = _BackfillSlot(items, gen0, self.n_shards)
        on_late = None
        if self.cfg.cache_capacity > 0:
            on_late = lambda i, res: self._backfill(slot, i, res)  # noqa: E731
        results = self.dispatcher.dispatch(reqs, on_late=on_late)
        missing = [i for i, r in enumerate(results) if r is None]
        attempt = 0
        while missing and attempt < self.cfg.max_retries:
            time.sleep(self.cfg.retry_backoff_ms / 1000.0 * (2 ** attempt))
            attempt += 1
            with self._stats_lock:
                self.stats.retries += 1
            sub = self.dispatcher.dispatch(reqs, shards=missing,
                                           on_late=on_late)
            for i in missing:
                if sub[i] is not None:
                    results[i] = sub[i]
            missing = [i for i, r in enumerate(results) if r is None]
        live = [i for i, r in enumerate(results) if r is not None]
        # arm (or close) the backfill slot: late-shard results re-merge into
        # the cache only while shards are actually missing
        early = []
        with slot.lock:
            if missing:
                slot.results = list(results)
                early, slot.early = slot.early, []
            else:
                slot.done = True
        for i, res in early:        # stragglers that beat the finalize
            self._backfill(slot, i, res)
        for q_i, t in enumerate(items):
            if not live:
                resp = SearchResponse(
                    doc=np.empty(0, np.int32), pos=np.empty(0, np.int32),
                    postings_read=0, used_fallback=False, doc_only=False,
                    ranked=t.request.rank, request=t.request,
                    status=STATUS_SERVED_DEGRADED, shed_reason="no_shards")
                if t.request.rank:
                    resp.anchor_scores = np.empty(0, np.float32)
                    resp.doc_ids = np.empty(0, np.int32)
                    resp.doc_scores = np.empty(0, np.float32)
                self._fulfill(t, resp)
                continue
            per_shard = [(s, results[s][q_i]) for s in live]
            resp = merge_shard_responses(t.request, t.plan, per_shard)
            resp.shards = tuple(live)
            late = self.clock() > t.deadline
            if len(live) == self.n_shards and not late:
                resp.status = STATUS_SERVED_EXACT
                self._cache_put(t.request, resp, gen=gen0)
            else:
                resp.status = STATUS_SERVED_DEGRADED
                resp.shed_reason = "shards" if len(live) < self.n_shards \
                    else "late"
            self._fulfill(t, resp)

    def _backfill(self, slot: "_BackfillSlot", shard_i: int, res):
        """A shard answered AFTER its dispatch timed out (ShardDispatcher
        `on_late`): fold its per-query responses into the slot.  The
        delivered SERVED_DEGRADED responses stay final — what heals is the
        CACHE: once every shard has contributed, the full merge is cached
        (generation-guarded) so the next identical query is EXACT."""
        with slot.lock:
            if slot.done or slot.results is None:
                if not slot.done:
                    slot.early.append((shard_i, res))
                return
            if slot.results[shard_i] is not None:
                return                        # replica/retry already answered
            slot.results[shard_i] = res
            complete = all(r is not None for r in slot.results)
            results = list(slot.results) if complete else None
            if complete:
                slot.done = True
        with self._stats_lock:
            self.stats.backfilled += 1
        if results is None:
            return
        live = list(range(slot.n_shards))
        for q_i, t in enumerate(slot.items):
            if t.plan is None:                # pragma: no cover
                continue
            resp = merge_shard_responses(t.request, t.plan,
                                         [(s, results[s][q_i]) for s in live])
            resp.shards = tuple(live)
            resp.status = STATUS_SERVED_EXACT
            self._cache_put(t.request, resp, gen=slot.gen)


class _BackfillSlot:
    """Shared state between one `_execute` dispatch and the late-shard
    callbacks it may receive afterwards (see FrontDoor._backfill)."""

    __slots__ = ("lock", "items", "gen", "n_shards", "results", "early",
                 "done")

    def __init__(self, items: list, gen: int, n_shards: int):
        self.lock = threading.Lock()
        self.items = items
        self.gen = gen                 # cache generation at dispatch time
        self.n_shards = n_shards
        self.results = None            # [n_shards] per-shard response lists
        self.early: list = []          # lates that arrived before finalize
        self.done = False

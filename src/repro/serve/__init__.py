"""Serving layer: batched phrase-query serving + LM decode serving."""
from repro.serve.front import (FrontDoor, FrontDoorConfig,  # noqa: F401
                               FrontStats, ShardBackend, TokenBucket,
                               build_doc_shards, merge_shard_responses)
from repro.serve.search_serve import (SearchServe, SearchServeConfig,  # noqa: F401
                                      arena_specs, make_search_serve_step,
                                      query_table_specs)

"""Serving layer: batched phrase-query serving + LM decode serving."""

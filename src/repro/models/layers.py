"""Shared transformer layers: RMSNorm, RoPE, GQA attention (full + chunked
online-softmax for long context), SwiGLU MLP, decode-step attention.

Dtype policy: parameters live in `param_dtype` (fp32 for training), all
matmul compute runs in `dtype` (bf16 on TPU) with fp32 softmax/normalizer
accumulators (`preferred_element_type`).  Everything takes explicit dtypes —
the package enables x64 globally, so nothing may rely on dtype defaults.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * scale.astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """[head_dim // 2] inverse frequencies (fp32)."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S] (int32)."""
    D = x.shape[-1]
    inv = rope_freqs(D, theta)                              # [D/2]
    ang = positions.astype(jnp.float32)[..., None] * inv    # [..., S, D/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : D // 2], x[..., D // 2 :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    """[B, S, Hkv, D] -> [B, S, Hkv * groups, D] (head index = h * G + g)."""
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def causal_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     chunk_q: int = 0, chunk_kv: int = 1024,
                     scores_pspec=None) -> jax.Array:
    """Causal GQA attention.  q: [B, S, Hq, D]; k, v: [B, S, Hkv, D].

    chunk_q == 0: full S x S score materialization (short sequences).
    chunk_q > 0:  memory-bounded online-softmax over kv chunks per q chunk
    (pure-JAX flash structure; peak activation [B, H, chunk_q, chunk_kv]).
    Causality is exploited structurally: q chunk i only visits kv chunks
    <= i (a Python loop over static slices, so compiled FLOPs ~= S^2 / 2).

    scores_pspec (a Sharding or None) pins the [B, H, Sq, Skv] score/prob
    tensors; with_sharding_constraint transposes to itself, so this also
    pins the softmax *backward* (SPMD otherwise picks inconsistent layouts
    under remat and replicates activations at the boundaries).
    """
    B, S, Hq, D = q.shape
    G = Hq // k.shape[2]
    k, v = _repeat_kv(k, G), _repeat_kv(v, G)
    scale = 1.0 / (D ** 0.5)

    def pin(x):
        if scores_pspec is not None:
            return jax.lax.with_sharding_constraint(x, scores_pspec)
        return x

    if chunk_q == 0 or S <= chunk_q:
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                            preferred_element_type=jnp.float32) * scale
        mask = jnp.tril(jnp.ones((S, S), dtype=jnp.bool_))
        logits = pin(jnp.where(mask[None, None], logits, -1e30))
        probs = pin(jax.nn.softmax(logits, axis=-1)).astype(q.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", probs, v,
                          preferred_element_type=jnp.float32).astype(q.dtype)

    assert S % chunk_q == 0 and S % chunk_kv == 0, (S, chunk_q, chunk_kv)
    nq = S // chunk_q
    out_chunks = []
    for i in range(nq):
        qi = q[:, i * chunk_q : (i + 1) * chunk_q]          # [B, cq, H, D]
        q_pos = i * chunk_q + jnp.arange(chunk_q)
        kv_hi = (i + 1) * chunk_q                           # causal horizon
        kv_hi = ((kv_hi + chunk_kv - 1) // chunk_kv) * chunk_kv
        m = jnp.full((B, Hq, chunk_q, 1), -1e30, jnp.float32)
        l = jnp.zeros((B, Hq, chunk_q, 1), jnp.float32)
        acc = jnp.zeros((B, Hq, chunk_q, D), jnp.float32)

        def kv_step(carry, idx):
            m, l, acc = carry
            kj = jax.lax.dynamic_slice_in_dim(k, idx * chunk_kv, chunk_kv, axis=1)
            vj = jax.lax.dynamic_slice_in_dim(v, idx * chunk_kv, chunk_kv, axis=1)
            s = jnp.einsum("bqhd,bkhd->bhqk", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            k_pos = idx * chunk_kv + jnp.arange(chunk_kv)
            causal = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(causal[None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new)
            l_new = l * alpha + p.sum(axis=-1, keepdims=True)
            acc_new = acc * alpha + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(q.dtype), vj,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(kv_step, (m, l, acc),
                                      jnp.arange(kv_hi // chunk_kv))
        oi = (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)  # [B, H, cq, D]
        out_chunks.append(oi.transpose(0, 2, 1, 3))
    return jnp.concatenate(out_chunks, axis=1)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     kv_len: jax.Array, impl: str = "xla") -> jax.Array:
    """One-token decode.  q: [B, Hq, D]; caches: [B, Smax, Hkv, D];
    kv_len: [B] valid lengths.  impl: 'xla' | 'flash' (Pallas interpret)."""
    if impl == "flash":
        from repro.kernels import ops
        return ops.flash_decode(q, k_cache, v_cache, kv_len)
    from repro.kernels import ref
    return ref.flash_decode_ref(q, k_cache, v_cache, kv_len)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array, dtype) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, w_gate.astype(dtype))
    u = jnp.einsum("...d,df->...f", x, w_up.astype(dtype))
    return jnp.einsum("...f,fd->...d", jax.nn.silu(h) * u, w_down.astype(dtype))


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


@dataclasses.dataclass(frozen=True)
class AttnChunking:
    """Chunking policy: full attention below the threshold, chunked above."""
    threshold: int = 8192
    chunk_q: int = 1024
    chunk_kv: int = 1024

    def for_seq(self, s: int) -> tuple[int, int]:
        if s <= self.threshold:
            return (0, 0)
        return (self.chunk_q, self.chunk_kv)

"""Decoder-only LM (dense GQA or MoE) — granite / qwen / llama / moonshot.

Layers are weight-stacked and scanned (compile time and HLO size stay flat in
depth); per-layer remat is the default activation-checkpoint policy.  All
math takes explicit dtypes: params in `param_dtype`, matmuls in `dtype`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.moe import MoEConfig, moe_ffn


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    qkv_bias: bool = False                  # qwen2.5
    rope_theta: float = 500_000.0
    moe: Optional[MoEConfig] = None
    tie_embeddings: bool = False
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    remat: bool = True
    attn_chunk: L.AttnChunking = L.AttnChunking()
    act_pspec: Any = None        # with_sharding_constraint on the residual
                                 # stream [B, S, D] (set by the launcher)
    q_pspec: Any = None          # [B, S, Hq, hd] layout inside attention
    kv_pspec: Any = None         # [B, S, Hkv, hd] layout inside attention
    attn_pspec: Any = None       # [B, H, Sq, Skv] score/prob pin (fwd + bwd)
    pre_cast_layers: bool = False  # cast stacked weights to compute dtype
                                   # once OUTSIDE the scan (behind an
                                   # optimization barrier, or XLA sinks the
                                   # convert back into the loop): FSDP
                                   # all-gathers then move bf16, not f32
    bf16_grads: bool = False       # bf16 logits => the backward's activation
                                   # grads (and their TP collectives) run
                                   # bf16; softmax math stays f32 (§Perf)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Embedding rows padded to 256 so vocab shards evenly on any mesh
        axis (padding logits are masked out of the loss)."""
        return ((self.vocab + 255) // 256) * 256

    def param_count(self) -> int:
        """Total parameters (for 6ND roofline bookkeeping)."""
        D, Hq, Hkv, hd = self.d_model, self.n_heads, self.n_kv_heads, self.hd
        attn = D * (Hq + 2 * Hkv) * hd + Hq * hd * D
        if self.qkv_bias:
            attn += (Hq + 2 * Hkv) * hd
        if self.moe:
            ff = D * self.moe.n_experts + 3 * self.moe.n_experts * D * self.moe.d_expert
        else:
            ff = 3 * D * self.d_ff
        per_layer = attn + ff + 2 * D
        emb = self.vocab * D * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + D

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.moe:
            return self.param_count()
        D = self.d_model
        dense = self.param_count()
        ff_all = 3 * self.moe.n_experts * D * self.moe.d_expert
        ff_act = 3 * self.moe.top_k * D * self.moe.d_expert
        return dense - self.n_layers * (ff_all - ff_act)


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def init_params(cfg: TransformerConfig, key: jax.Array) -> dict:
    D, Hq, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    Lx = cfg.n_layers
    ks = jax.random.split(key, 12)
    pd = cfg.param_dtype
    init = L.dense_init

    lp = {
        "ln1": jnp.ones((Lx, D), pd),
        "ln2": jnp.ones((Lx, D), pd),
        "wq": init(ks[0], (Lx, D, Hq * hd), pd),
        "wk": init(ks[1], (Lx, D, Hkv * hd), pd),
        "wv": init(ks[2], (Lx, D, Hkv * hd), pd),
        "wo": init(ks[3], (Lx, Hq * hd, D), pd, scale=(Hq * hd) ** -0.5 / (2 * Lx) ** 0.5),
    }
    if cfg.qkv_bias:
        lp["bq"] = jnp.zeros((Lx, Hq * hd), pd)
        lp["bk"] = jnp.zeros((Lx, Hkv * hd), pd)
        lp["bv"] = jnp.zeros((Lx, Hkv * hd), pd)
    if cfg.moe:
        E, Fe = cfg.moe.n_experts, cfg.moe.d_expert
        lp["router"] = init(ks[4], (Lx, D, E), jnp.float32)
        lp["wg"] = init(ks[5], (Lx, E, D, Fe), pd)
        lp["wu"] = init(ks[6], (Lx, E, D, Fe), pd)
        lp["wd"] = init(ks[7], (Lx, E, Fe, D), pd, scale=Fe ** -0.5 / (2 * Lx) ** 0.5)
    else:
        F = cfg.d_ff
        lp["wg"] = init(ks[5], (Lx, D, F), pd)
        lp["wu"] = init(ks[6], (Lx, D, F), pd)
        lp["wd"] = init(ks[7], (Lx, F, D), pd, scale=F ** -0.5 / (2 * Lx) ** 0.5)

    params = {
        "embed": init(ks[8], (cfg.vocab_padded, D), pd, scale=1.0),
        "layers": lp,
        "final_norm": jnp.ones((D,), pd),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init(ks[9], (D, cfg.vocab_padded), pd)
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _layer_fwd(cfg: TransformerConfig, x: jax.Array, p: dict,
               positions: jax.Array, train: bool = False) -> tuple[jax.Array, jax.Array]:
    """One decoder layer.  x: [B, S, D] in cfg.dtype."""
    B, S, D = x.shape
    Hq, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = cfg.dtype

    h = L.rms_norm(x, p["ln1"])
    q = jnp.einsum("bsd,dh->bsh", h, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dh->bsh", h, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dh->bsh", h, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(B, S, Hq, hd)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hd)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    if cfg.q_pspec is not None:
        q = jax.lax.with_sharding_constraint(q, cfg.q_pspec)
    if cfg.kv_pspec is not None:
        # chunked attention: materialize K/V once per layer (one gather)
        # instead of re-gathering per kv-chunk inside the scan
        k = jax.lax.with_sharding_constraint(k, cfg.kv_pspec)
        v = jax.lax.with_sharding_constraint(v, cfg.kv_pspec)

    cq, ckv = cfg.attn_chunk.for_seq(S)
    o = L.causal_attention(q, k, v, chunk_q=cq, chunk_kv=ckv,
                           scores_pspec=cfg.attn_pspec)
    o = jnp.einsum("bsh,hd->bsd", o.reshape(B, S, Hq * hd), p["wo"].astype(dt))
    x = x + o
    if cfg.act_pspec is not None:
        x = jax.lax.with_sharding_constraint(x, cfg.act_pspec)

    h = L.rms_norm(x, p["ln2"])
    if cfg.moe:
        y, aux = moe_ffn(h, p["router"], p["wg"], p["wu"],
                         p["wd"], cfg.moe, dt, dropless=not train)
    else:
        y = L.swiglu(h, p["wg"], p["wu"], p["wd"], dt)
        aux = jnp.zeros((), jnp.float32)
    x = x + y
    if cfg.act_pspec is not None:
        # bound the scanned residual carry (Megatron-SP style sequence shard)
        x = jax.lax.with_sharding_constraint(x, cfg.act_pspec)
    return x, aux


def forward(cfg: TransformerConfig, params: dict, tokens: jax.Array,
            positions: Optional[jax.Array] = None,
            train: bool = False) -> tuple[jax.Array, jax.Array]:
    """tokens: [B, S] int32 -> (logits [B, S, V] fp32, aux_loss scalar).

    `train=True` enables capacity-based MoE token dropping (the training
    dispatch); eval/serving runs dropless so decode_step matches exactly."""
    B, S = tokens.shape
    dt = cfg.dtype
    x = params["embed"].astype(dt)[tokens]
    if cfg.act_pspec is not None:
        x = jax.lax.with_sharding_constraint(x, cfg.act_pspec)
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]

    def body(x, p):
        y, aux = _layer_fwd(cfg, x, p, positions, train=train)
        return y, aux

    if cfg.remat:
        body = jax.checkpoint(body)
    layers = params["layers"]
    if cfg.pre_cast_layers:
        layers = jax.tree_util.tree_map(
            lambda w: w.astype(dt) if w.dtype == jnp.float32 else w, layers)
        layers = jax.lax.optimization_barrier(layers)
    x, auxs = jax.lax.scan(body, x, layers)
    x = L.rms_norm(x, params["final_norm"])
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"]).astype(dt)
    logits = jnp.einsum("bsd,dv->bsv", x, head,
                        preferred_element_type=dt if cfg.bf16_grads
                        else jnp.float32)
    return logits, auxs.sum()


def loss_fn(cfg: TransformerConfig, params: dict, batch: dict) -> tuple[jax.Array, dict]:
    """batch: tokens [B, S] int32, labels [B, S] int32 (-100 = ignore)."""
    logits, aux = forward(cfg, params, batch["tokens"], train=True)
    logits = logits.astype(jnp.float32)  # softmax math always fp32
    if cfg.vocab_padded != cfg.vocab:   # mask padding rows out of the softmax
        pad_mask = jnp.arange(cfg.vocab_padded) < cfg.vocab
        logits = jnp.where(pad_mask[None, None, :], logits, -1e30)
    labels = batch["labels"]
    valid = labels >= 0
    safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * valid.astype(jnp.float32)
    loss = nll.sum() / jnp.maximum(valid.sum(), 1)
    return loss + aux, {"nll": loss, "aux": aux}


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------

def init_cache(cfg: TransformerConfig, batch: int, max_len: int,
               dtype=None) -> dict:
    dt = dtype or cfg.dtype
    Hkv, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_len, Hkv, hd), dt),
        "v": jnp.zeros((cfg.n_layers, batch, max_len, Hkv, hd), dt),
    }


def decode_step(cfg: TransformerConfig, params: dict, cache: dict,
                tokens: jax.Array, cur_len: jax.Array,
                attn_impl: str = "xla") -> tuple[jax.Array, dict]:
    """One-token decode.  tokens: [B] int32; cur_len: scalar int32 (tokens
    already in the cache).  Returns (logits [B, V] fp32, updated cache)."""
    B = tokens.shape[0]
    D, Hq, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = cfg.dtype
    x = params["embed"].astype(dt)[tokens]                    # [B, D]
    pos = jnp.full((B, 1), cur_len, jnp.int32)
    kv_len = jnp.full((B,), cur_len + 1, jnp.int32)

    def body(x, scanned):
        p, ck, cv = scanned
        h = L.rms_norm(x, p["ln1"])
        q = jnp.einsum("bd,dh->bh", h, p["wq"].astype(dt))
        k = jnp.einsum("bd,dh->bh", h, p["wk"].astype(dt))
        v = jnp.einsum("bd,dh->bh", h, p["wv"].astype(dt))
        if cfg.qkv_bias:
            q, k, v = q + p["bq"].astype(dt), k + p["bk"].astype(dt), v + p["bv"].astype(dt)
        q = L.apply_rope(q.reshape(B, 1, Hq, hd), pos, cfg.rope_theta)[:, 0]
        k = L.apply_rope(k.reshape(B, 1, Hkv, hd), pos, cfg.rope_theta)[:, 0]
        v = v.reshape(B, Hkv, hd)
        zero = jnp.zeros((), jnp.int32)
        idx = (zero, jnp.asarray(cur_len, jnp.int32), zero, zero)
        ck = jax.lax.dynamic_update_slice(ck, k[:, None].astype(ck.dtype), idx)
        cv = jax.lax.dynamic_update_slice(cv, v[:, None].astype(cv.dtype), idx)
        o = L.decode_attention(q, ck, cv, kv_len, impl=attn_impl)  # [B, Hq, hd]
        x = x + jnp.einsum("bh,hd->bd", o.reshape(B, Hq * hd), p["wo"].astype(dt))
        h2 = L.rms_norm(x, p["ln2"])
        if cfg.moe:
            y, _ = moe_ffn(h2, p["router"], p["wg"], p["wu"], p["wd"], cfg.moe,
                           dt, dropless=True)
        else:
            y = L.swiglu(h2, p["wg"], p["wu"], p["wd"], dt)
        return x + y, (ck, cv)

    x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = L.rms_norm(x, params["final_norm"])
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"]).astype(dt)
    logits = jnp.einsum("bd,dv->bv", x, head, preferred_element_type=jnp.float32)
    return logits, {"k": nk, "v": nv}

"""Mixture-of-Experts FFN with top-k routing and capacity-based dispatch.

Dispatch is the argsort permutation form (tokens sorted by expert, padded to
a static per-expert capacity, overflow dropped), applied **per group**: at
scale the token batch is reshaped to [G, T/G] with G = the data-parallel
shard count (GShard grouping), so routing sorts are group-local (no global
argsort) and the expert buffers [G, E, C, D] shard as G x dp, E x model —
the gather/scatter between them lowers to the expected all-to-all pair.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden size
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    normalize_gates: bool = True
    n_groups: int = 1             # routing groups (= dp shards at scale)
    group_pspec: Any = None       # NamedSharding for [G, Tg, D] token blocks
    expert_pspec: Any = None      # NamedSharding for [G, E, C, D] buffers


def router_aux_loss(probs: jax.Array, expert_idx: jax.Array, n_experts: int) -> jax.Array:
    """Switch-style load-balancing loss: E * sum_e f_e * P_e."""
    counts = jnp.zeros((n_experts,), jnp.float32).at[expert_idx.reshape(-1)].add(1.0)
    frac = counts / jnp.maximum(expert_idx.size, 1)
    mean_prob = probs.reshape(-1, n_experts).mean(axis=0)
    return n_experts * jnp.sum(frac * mean_prob)


def _dispatch_group(x: jax.Array, gate_idx: jax.Array, C: int, E: int, K: int):
    """x: [Tg, D]; gate_idx: [Tg, K] -> (slot [Tg*K], keep [Tg*K], token [Tg*K])."""
    Tg = x.shape[0]
    flat_e = gate_idx.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    run_starts = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    rank = jnp.arange(Tg * K) - run_starts[sorted_e]
    keep = rank < C
    slot = jnp.where(keep, sorted_e * C + rank, E * C)      # E*C = trash row
    token = order // K
    return slot, keep, token, order


def moe_ffn(x: jax.Array, router_w: jax.Array, w_gate: jax.Array, w_up: jax.Array,
            w_down: jax.Array, cfg: MoEConfig, dtype,
            dropless: bool = False) -> tuple[jax.Array, jax.Array]:
    """x: [..., D] tokens (e.g. [B, S, D] — groups split the LEADING dim so
    dp-sharded batches reshape to [G, Tg, D] without crossing mesh axes);
    router_w: [D, E]; w_*: [E, D, Fe] / [E, Fe, D].

    `dropless=True` sizes the expert buffers to the worst case (an expert can
    receive at most Tg assignments — top_k experts are distinct per token) so
    no assignment is ever dropped.  Inference must run dropless: capacity
    overflow is resolved in token order across the whole group, so a dropped
    assignment depends on *other* tokens in the batch — semantics incremental
    decode cannot reproduce (and the source of decode-vs-forward mismatches).
    Training keeps the capacity-factor dispatch.

    Returns (y with x's shape, aux_loss scalar fp32).
    """
    lead = x.shape[:-1]
    D = x.shape[-1]
    T = 1
    for d in lead:
        T *= d
    E, K = cfg.n_experts, cfg.top_k
    G = cfg.n_groups
    if G > 1 and (lead[0] % G != 0):
        G = 1                        # groups must split the leading dim
    Tg = T // G
    C = Tg if dropless else int((Tg * K / E) * cfg.capacity_factor) + 1

    xg = x.reshape(G, Tg, D)
    if cfg.group_pspec is not None:
        xg = jax.lax.with_sharding_constraint(xg, cfg.group_pspec)

    # router in compute dtype with fp32 accumulation (no fp32 token copy)
    logits = jnp.einsum("gtd,de->gte", xg, router_w.astype(xg.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # [G, Tg, E] fp32
    gate_vals, gate_idx = jax.lax.top_k(probs, K)            # [G, Tg, K]
    if cfg.normalize_gates:
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    aux = router_aux_loss(probs, gate_idx, E) * cfg.aux_loss_weight

    slot, keep, token, order = jax.vmap(
        lambda xx, gi: _dispatch_group(xx, gi, C, E, K))(xg, gate_idx)

    def scatter_group(xx, sl, tok):
        return jnp.zeros((E * C + 1, D), dtype).at[sl].set(xx[tok])[: E * C]

    xe = jax.vmap(scatter_group)(xg, slot, token).reshape(G, E, C, D)
    if cfg.expert_pspec is not None:
        xe = jax.lax.with_sharding_constraint(xe, cfg.expert_pspec)

    # ---- expert computation (SwiGLU), experts sharded over `model` --------
    h = jnp.einsum("gecd,edf->gecf", xe, w_gate.astype(dtype))
    u = jnp.einsum("gecd,edf->gecf", xe, w_up.astype(dtype))
    ye = jnp.einsum("gecf,efd->gecd", jax.nn.silu(h) * u, w_down.astype(dtype))
    if cfg.expert_pspec is not None:
        ye = jax.lax.with_sharding_constraint(ye, cfg.expert_pspec)

    # ---- combine -----------------------------------------------------------
    def combine_group(ye_g, sl, kp, tok, gv, od):
        flat = ye_g.reshape(E * C, D)
        gathered = flat[jnp.minimum(sl, E * C - 1)] * kp[:, None].astype(dtype)
        gs = gv.reshape(-1)[od].astype(dtype)
        return jnp.zeros((Tg, D), dtype).at[tok].add(gathered * gs[:, None])

    yg = jax.vmap(combine_group)(ye, slot, keep, token, gate_vals, order)
    if cfg.group_pspec is not None:
        yg = jax.lax.with_sharding_constraint(yg, cfg.group_pspec)
    return yg.reshape(x.shape), aux

"""GIN (Graph Isomorphism Network, arXiv:1810.00826).

Message passing is implemented with `jax.ops.segment_sum` over an explicit
edge index (JAX has no CSR SpMM — the scatter IS the SpMM; see kernel
taxonomy §GNN).  One forward serves all four assigned shapes:

  * full-graph node classification (full_graph_sm / ogb_products),
  * fanout-sampled minibatch training (minibatch_lg; sampler in
    data/graph_data.py produces padded subgraphs),
  * batched small molecule graphs with sum-pool readout (molecule).

h' = MLP((1 + eps) * h + sum_{j in N(i)} h_j), eps learnable per layer.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


@dataclasses.dataclass(frozen=True)
class GINConfig:
    name: str
    n_layers: int = 5
    d_hidden: int = 64
    d_feat: int = 1433
    n_classes: int = 40
    graph_readout: bool = False     # molecule: sum-pool per graph
    message_dtype: Any = None       # cast h for the gather/scatter step
                                    # (bf16 halves the cross-shard volume)
    dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32

    def param_count(self) -> int:
        mlp0 = self.d_feat * self.d_hidden + self.d_hidden
        mlp = 2 * (self.d_hidden * self.d_hidden + self.d_hidden)
        per = mlp + 1
        return mlp0 + self.d_hidden * self.d_hidden + self.d_hidden + \
            (self.n_layers - 1) * per + self.n_layers + \
            self.d_hidden * self.n_classes + self.n_classes


def init_params(cfg: GINConfig, key: jax.Array) -> dict:
    pd = cfg.param_dtype
    ks = jax.random.split(key, 2 + 4 * cfg.n_layers)
    layers = []
    d_in = cfg.d_feat
    for i in range(cfg.n_layers):
        layers.append({
            "eps": jnp.zeros((), jnp.float32),
            "w1": dense_init(ks[4 * i], (d_in, cfg.d_hidden), pd),
            "b1": jnp.zeros((cfg.d_hidden,), pd),
            "w2": dense_init(ks[4 * i + 1], (cfg.d_hidden, cfg.d_hidden), pd),
            "b2": jnp.zeros((cfg.d_hidden,), pd),
        })
        d_in = cfg.d_hidden
    return {
        "layers": layers,
        "head_w": dense_init(ks[-2], (cfg.d_hidden, cfg.n_classes), pd),
        "head_b": jnp.zeros((cfg.n_classes,), pd),
    }


def forward(cfg: GINConfig, params: dict, batch: dict) -> jax.Array:
    """batch: nodes [N, F], src [E], dst [E], edge_mask [E] bool,
    optional graph_id [N] (readout), node_mask [N] bool.

    Returns logits: [N, C] (node) or [G, C] (graph readout)."""
    dt = cfg.dtype
    h = batch["nodes"].astype(dt)
    src = batch["src"]
    dst = batch["dst"]
    emask = batch["edge_mask"]
    N = h.shape[0]
    for p in params["layers"]:
        if cfg.message_dtype:
            # barriers pin the casts AROUND the cross-shard gather/scatter,
            # so both collectives move bf16, not f32 (XLA hoists otherwise)
            hm = jax.lax.optimization_barrier(h.astype(cfg.message_dtype))
            msg = jax.ops.segment_sum(hm[src] * emask.astype(hm.dtype)[:, None],
                                      dst, num_segments=N)
            msg = jax.lax.optimization_barrier(msg).astype(dt)
        else:
            msg = jax.ops.segment_sum(h[src] * emask.astype(dt)[:, None],
                                      dst, num_segments=N)
        z = (1.0 + p["eps"]).astype(dt) * h + msg
        z = jnp.einsum("nd,dh->nh", z, p["w1"].astype(dt)) + p["b1"].astype(dt)
        z = jax.nn.relu(z)
        z = jnp.einsum("nh,hk->nk", z, p["w2"].astype(dt)) + p["b2"].astype(dt)
        h = jax.nn.relu(z)
    if cfg.graph_readout:
        G = int(batch["n_graphs"])
        pooled = jax.ops.segment_sum(h * batch["node_mask"].astype(dt)[:, None],
                                     batch["graph_id"], num_segments=G)
        h = pooled
    logits = jnp.einsum("nd,dc->nc", h, params["head_w"].astype(dt)) + \
        params["head_b"].astype(dt)
    return logits.astype(jnp.float32)


# ---------------------------------------------------------------------------
# halo-exchange variant (explicit shard_map; §Perf hillclimb for ogb_products)
# ---------------------------------------------------------------------------
#
# Locality-aware partition: nodes are split into contiguous shards (cluster-
# sorted, so most edges are intra-shard); each layer exchanges ONLY the
# boundary rows other shards reference, in bf16, via one all_gather of
# [n_shards, B, d] — instead of SPMD's full [N, d] f32 gather + scatter
# all-reduce.  Edge sources index [local || boundary-table].

def halo_layer(h, p, src_local, dst, emask, send_idx, axis_name, dt, msg_dt):
    """h: [Nl, d]; send_idx: [B] local rows contributed to the exchange."""
    sends = (h * 1.0).astype(msg_dt)[jnp.maximum(send_idx, 0)]
    sends = sends * (send_idx >= 0).astype(msg_dt)[:, None]
    bnd = jax.lax.all_gather(sends, axis_name)              # [S, B, d] bf16
    table = jnp.concatenate([h.astype(msg_dt),
                             bnd.reshape(-1, h.shape[1])], axis=0)
    msg = jax.ops.segment_sum(table[src_local] * emask.astype(msg_dt)[:, None],
                              dst, num_segments=h.shape[0]).astype(dt)
    z = (1.0 + p["eps"]).astype(dt) * h + msg
    z = jnp.einsum("nd,dh->nh", z, p["w1"].astype(dt)) + p["b1"].astype(dt)
    z = jax.nn.relu(z)
    z = jnp.einsum("nh,hk->nk", z, p["w2"].astype(dt)) + p["b2"].astype(dt)
    return jax.nn.relu(z)


def halo_loss_fn(cfg: GINConfig, params: dict, shard: dict,
                 axis_name="data") -> tuple[jax.Array, dict]:
    """Per-shard loss inside shard_map.  shard arrays carry a leading
    singleton (the split shard dim): nodes [1, Nl, F], src/dst [1, El],
    send_idx [1, B], labels/label_mask [1, Nl]."""
    dt = cfg.dtype
    msg_dt = cfg.message_dtype or dt
    h = shard["nodes"][0].astype(dt)
    for p in params["layers"]:
        h = halo_layer(h, p, shard["src"][0], shard["dst"][0],
                       shard["edge_mask"][0], shard["send_idx"][0],
                       axis_name, dt, msg_dt)
    logits = jnp.einsum("nd,dc->nc", h, params["head_w"].astype(dt)) \
        + params["head_b"].astype(dt)
    labels = shard["labels"][0]
    mask = shard["label_mask"][0].astype(jnp.float32)
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               jnp.maximum(labels, 0)[:, None], axis=1)[:, 0]
    nll = ((logz - gold) * mask).sum()
    denom = jnp.maximum(jax.lax.psum(mask.sum(), axis_name), 1.0)
    loss = jax.lax.psum(nll, axis_name) / denom
    acc = jax.lax.psum(((logits.argmax(-1) == labels) * mask).sum(),
                       axis_name) / denom
    return loss, {"acc": acc}


def loss_fn(cfg: GINConfig, params: dict, batch: dict) -> tuple[jax.Array, dict]:
    """labels: [N] or [G]; label_mask selects supervised nodes (e.g. seeds)."""
    logits = forward(cfg, params, batch)
    labels = batch["labels"]
    mask = batch["label_mask"].astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[:, None], axis=1)[:, 0]
    nll = (logz - gold) * mask
    loss = nll.sum() / jnp.maximum(mask.sum(), 1)
    acc = (((logits.argmax(-1) == labels) * mask).sum() / jnp.maximum(mask.sum(), 1))
    return loss, {"acc": acc}

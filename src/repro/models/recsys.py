"""RecSys architectures over a shared sparse-embedding substrate.

fm      — Factorization Machine (Rendle ICDM'10): O(nk) sum-square trick.
autoint — self-attention over field embeddings (arXiv:1810.11921).
bst     — Behavior Sequence Transformer (arXiv:1905.06874).
mind    — Multi-Interest Network with Dynamic (capsule) Routing
          (arXiv:1904.08030): B2I routing -> K interest capsules,
          label-aware attention for training, max-dot for retrieval.

Substrate: all categorical fields share ONE concatenated embedding table
([total_rows, dim], row-sharded over the `model` mesh axis at scale) with
per-field row offsets — the huge-table layout the kernel taxonomy calls out.
Lookups are `jnp.take`; bag-reductions go through kernels.segment_bag (or
its jnp oracle, selectable) since JAX has no native EmbeddingBag.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


@dataclasses.dataclass(frozen=True)
class RecSysConfig:
    name: str
    model: str                       # fm | autoint | bst | mind
    field_vocabs: tuple              # rows per categorical field
    embed_dim: int
    # autoint
    n_attn_layers: int = 3
    n_heads: int = 2
    d_attn: int = 32
    # bst
    seq_len: int = 20
    n_blocks: int = 1
    bst_heads: int = 8
    mlp_dims: tuple = (1024, 512, 256)
    # mind
    n_interests: int = 4
    capsule_iters: int = 3
    item_vocab: int = 1_000_000      # bst/mind behavior item vocabulary
    dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32

    @property
    def n_fields(self) -> int:
        return len(self.field_vocabs)

    @property
    def total_rows(self) -> int:
        return int(sum(self.field_vocabs))

    @property
    def table_rows(self) -> int:
        """Rows padded to 256 so the table row-shards on any mesh axis."""
        return ((self.total_rows + 255) // 256) * 256

    def field_offsets(self) -> jnp.ndarray:
        import numpy as np
        off = np.zeros(self.n_fields, dtype=np.int64)
        off[1:] = np.cumsum(self.field_vocabs)[:-1]
        return jnp.asarray(off)

    def param_count(self) -> int:
        n = self.total_rows * self.embed_dim
        if self.model == "fm":
            n += self.total_rows + 1
        if self.model in ("bst", "mind"):
            n += self.item_vocab * self.embed_dim
        return n


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg: RecSysConfig, key: jax.Array) -> dict:
    pd = cfg.param_dtype
    d = cfg.embed_dim
    ks = jax.random.split(key, 24)
    p: dict = {"table": dense_init(ks[0], (cfg.table_rows, d), pd, scale=0.01)}
    if cfg.model == "fm":
        p["w_lin"] = dense_init(ks[1], (cfg.table_rows, 1), pd, scale=0.01)
        p["b"] = jnp.zeros((), pd)
    elif cfg.model == "autoint":
        lys = []
        d_in = d
        for i in range(cfg.n_attn_layers):
            lys.append({
                "wq": dense_init(ks[2 + i], (d_in, cfg.n_heads * cfg.d_attn), pd),
                "wk": dense_init(ks[5 + i], (d_in, cfg.n_heads * cfg.d_attn), pd),
                "wv": dense_init(ks[8 + i], (d_in, cfg.n_heads * cfg.d_attn), pd),
                "wres": dense_init(ks[11 + i], (d_in, cfg.n_heads * cfg.d_attn), pd),
            })
            d_in = cfg.n_heads * cfg.d_attn
        p["attn"] = lys
        p["head_w"] = dense_init(ks[15], (cfg.n_fields * d_in, 1), pd)
        p["head_b"] = jnp.zeros((), pd)
    elif cfg.model == "bst":
        p["item_table"] = dense_init(ks[1], (cfg.item_vocab, d), pd, scale=0.01)
        p["pos_embed"] = dense_init(ks[2], (cfg.seq_len + 1, d), pd, scale=0.01)
        blocks = []
        for i in range(cfg.n_blocks):
            blocks.append({
                "wq": dense_init(ks[3 + i], (d, d), pd),
                "wk": dense_init(ks[5 + i], (d, d), pd),
                "wv": dense_init(ks[7 + i], (d, d), pd),
                "wo": dense_init(ks[9 + i], (d, d), pd),
                "ln1": jnp.ones((d,), pd),
                "ln2": jnp.ones((d,), pd),
                "ff1": dense_init(ks[11 + i], (d, 4 * d), pd),
                "ff2": dense_init(ks[13 + i], (4 * d, d), pd),
            })
        p["blocks"] = blocks
        mlp_in = (cfg.seq_len + 1) * d + cfg.n_fields * d
        dims, mlp = (mlp_in,) + cfg.mlp_dims, []
        for i in range(len(cfg.mlp_dims)):
            mlp.append({"w": dense_init(ks[15 + i], (dims[i], dims[i + 1]), pd),
                        "b": jnp.zeros((dims[i + 1],), pd)})
        p["mlp"] = mlp
        p["head_w"] = dense_init(ks[20], (cfg.mlp_dims[-1], 1), pd)
        p["head_b"] = jnp.zeros((), pd)
    elif cfg.model == "mind":
        p["item_table"] = dense_init(ks[1], (cfg.item_vocab, d), pd, scale=0.01)
        p["s_matrix"] = dense_init(ks[2], (d, d), pd)     # B2I shared bilinear map
        p["out_w"] = dense_init(ks[3], (d, d), pd)        # interest transform
    else:
        raise ValueError(cfg.model)
    return p


# ---------------------------------------------------------------------------
# shared substrate
# ---------------------------------------------------------------------------

def field_embed(cfg: RecSysConfig, table: jax.Array, ids: jax.Array) -> jax.Array:
    """ids: [B, F] per-field local ids -> [B, F, d] (one big row-sharded table)."""
    rows = ids.astype(jnp.int64) + cfg.field_offsets()[None, :]
    return jnp.take(table, rows, axis=0)


def _ln(x, scale):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-6) * scale


# ---------------------------------------------------------------------------
# model forwards: logits for CTR models, (interests, item_emb) for mind
# ---------------------------------------------------------------------------

def fm_forward(cfg: RecSysConfig, p: dict, ids: jax.Array) -> jax.Array:
    dt = cfg.dtype
    rows = ids.astype(jnp.int64) + cfg.field_offsets()[None, :]
    v = jnp.take(p["table"], rows, axis=0).astype(dt)          # [B, F, d]
    lin = jnp.take(p["w_lin"], rows, axis=0)[..., 0].astype(dt).sum(-1)
    s = v.sum(axis=1)                                          # [B, d]
    pair = 0.5 * (s * s - (v * v).sum(axis=1)).sum(-1)         # sum-square trick
    return (p["b"].astype(dt) + lin + pair).astype(jnp.float32)


def autoint_forward(cfg: RecSysConfig, p: dict, ids: jax.Array) -> jax.Array:
    dt = cfg.dtype
    x = field_embed(cfg, p["table"], ids).astype(dt)           # [B, F, d]
    B, F, _ = x.shape
    H, da = cfg.n_heads, cfg.d_attn
    for lp in p["attn"]:
        q = jnp.einsum("bfd,dh->bfh", x, lp["wq"].astype(dt)).reshape(B, F, H, da)
        k = jnp.einsum("bfd,dh->bfh", x, lp["wk"].astype(dt)).reshape(B, F, H, da)
        v = jnp.einsum("bfd,dh->bfh", x, lp["wv"].astype(dt)).reshape(B, F, H, da)
        a = jax.nn.softmax(jnp.einsum("bqhd,bkhd->bhqk", q, k,
                                      preferred_element_type=jnp.float32), axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", a.astype(dt), v).reshape(B, F, H * da)
        x = jax.nn.relu(o + jnp.einsum("bfd,dh->bfh", x, lp["wres"].astype(dt)))
    flat = x.reshape(B, -1)
    return (jnp.einsum("bf,fo->bo", flat, p["head_w"].astype(dt))[:, 0]
            + p["head_b"].astype(dt)).astype(jnp.float32)


def bst_forward(cfg: RecSysConfig, p: dict, ids: jax.Array, hist: jax.Array,
                target: jax.Array) -> jax.Array:
    """ids: [B, F] profile fields; hist: [B, S] item ids (-1 pad); target: [B]."""
    dt = cfg.dtype
    d = cfg.embed_dim
    B, S = hist.shape
    seq_ids = jnp.concatenate([hist, target[:, None]], axis=1)      # [B, S+1]
    valid = seq_ids >= 0
    seq = jnp.take(p["item_table"], jnp.maximum(seq_ids, 0), axis=0).astype(dt)
    seq = seq * valid[..., None].astype(dt) + p["pos_embed"].astype(dt)[None]
    for bp in p["blocks"]:
        h = _ln(seq, bp["ln1"].astype(dt))
        q = jnp.einsum("bsd,de->bse", h, bp["wq"].astype(dt))
        k = jnp.einsum("bsd,de->bse", h, bp["wk"].astype(dt))
        v = jnp.einsum("bsd,de->bse", h, bp["wv"].astype(dt))
        hd = d // cfg.bst_heads
        q = q.reshape(B, S + 1, cfg.bst_heads, hd)
        k = k.reshape(B, S + 1, cfg.bst_heads, hd)
        v = v.reshape(B, S + 1, cfg.bst_heads, hd)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                            preferred_element_type=jnp.float32) / (hd ** 0.5)
        logits = jnp.where(valid[:, None, None, :], logits, -1e30)
        a = jax.nn.softmax(logits, axis=-1).astype(dt)
        o = jnp.einsum("bhqk,bkhd->bqhd", a, v).reshape(B, S + 1, d)
        seq = seq + jnp.einsum("bsd,de->bse", o, bp["wo"].astype(dt))
        h = _ln(seq, bp["ln2"].astype(dt))
        seq = seq + jnp.einsum("bse,ef->bsf",
                               jax.nn.relu(jnp.einsum("bsd,de->bse", h, bp["ff1"].astype(dt))),
                               bp["ff2"].astype(dt))
    other = field_embed(cfg, p["table"], ids).astype(dt).reshape(B, -1)
    x = jnp.concatenate([seq.reshape(B, -1), other], axis=-1)
    for m in p["mlp"]:
        x = jax.nn.leaky_relu(jnp.einsum("bi,io->bo", x, m["w"].astype(dt))
                              + m["b"].astype(dt))
    return (jnp.einsum("bi,io->bo", x, p["head_w"].astype(dt))[:, 0]
            + p["head_b"].astype(dt)).astype(jnp.float32)


def mind_interests(cfg: RecSysConfig, p: dict, hist: jax.Array) -> jax.Array:
    """Dynamic (B2I) capsule routing: hist [B, S] -> interests [B, K, d]."""
    dt = cfg.dtype
    B, S = hist.shape
    K = cfg.n_interests
    valid = (hist >= 0)
    e = jnp.take(p["item_table"], jnp.maximum(hist, 0), axis=0).astype(dt)
    e = e * valid[..., None].astype(dt)
    u = jnp.einsum("bsd,de->bse", e, p["s_matrix"].astype(dt))      # behavior caps
    # routing logits b_ks: fixed random init (paper) -> here zeros + iterate
    blog = jnp.zeros((B, K, S), jnp.float32)
    interests = jnp.zeros((B, K, cfg.embed_dim), dt)
    for _ in range(cfg.capsule_iters):
        w = jax.nn.softmax(jnp.where(valid[:, None, :], blog, -1e30), axis=1)
        z = jnp.einsum("bks,bsd->bkd", w.astype(dt), u)             # [B, K, d]
        # squash
        n2 = jnp.sum(jnp.square(z.astype(jnp.float32)), -1, keepdims=True)
        interests = (z * (n2 / (1 + n2) / jnp.sqrt(n2 + 1e-9)).astype(dt))
        blog = blog + jnp.einsum("bkd,bsd->bks", interests,
                                 u, preferred_element_type=jnp.float32)
    return jnp.einsum("bkd,de->bke", interests, p["out_w"].astype(dt))


def mind_train_logits(cfg: RecSysConfig, p: dict, hist: jax.Array,
                      target: jax.Array) -> jax.Array:
    """Label-aware attention + in-batch sampled softmax logits [B, B]."""
    dt = cfg.dtype
    interests = mind_interests(cfg, p, hist)                        # [B, K, d]
    tgt = jnp.take(p["item_table"], jnp.maximum(target, 0), axis=0).astype(dt)
    att = jax.nn.softmax(
        jnp.einsum("bkd,bd->bk", interests, tgt,
                   preferred_element_type=jnp.float32) * 2.0, axis=-1)  # pow~2
    user = jnp.einsum("bk,bkd->bd", att.astype(dt), interests)      # [B, d]
    return jnp.einsum("bd,cd->bc", user, tgt, preferred_element_type=jnp.float32)


def mind_retrieval_scores(cfg: RecSysConfig, p: dict, hist: jax.Array,
                          cand: jax.Array) -> jax.Array:
    """hist [B, S]; cand [C] -> scores [B, C] = max over interests."""
    interests = mind_interests(cfg, p, hist)
    ce = jnp.take(p["item_table"], cand, axis=0).astype(cfg.dtype)
    s = jnp.einsum("bkd,cd->bkc", interests, ce, preferred_element_type=jnp.float32)
    return s.max(axis=1)


# ---------------------------------------------------------------------------
# unified train loss / serve / retrieval
# ---------------------------------------------------------------------------

def loss_fn(cfg: RecSysConfig, params: dict, batch: dict) -> tuple[jax.Array, dict]:
    if cfg.model == "mind":
        logits = mind_train_logits(cfg, params, batch["hist"], batch["target"])
        B = logits.shape[0]
        labels = jnp.arange(B)
        nll = jax.nn.logsumexp(logits, -1) - jnp.take_along_axis(
            logits, labels[:, None], axis=1)[:, 0]
        loss = nll.mean()
        acc = (logits.argmax(-1) == labels).mean()
        return loss, {"acc": acc}
    logit = serve_scores(cfg, params, batch)
    y = batch["label"].astype(jnp.float32)
    loss = jnp.mean(jnp.maximum(logit, 0) - logit * y + jnp.log1p(jnp.exp(-jnp.abs(logit))))
    return loss, {"auc_proxy": jnp.corrcoef(jax.nn.sigmoid(logit), y)[0, 1]}


def serve_scores(cfg: RecSysConfig, params: dict, batch: dict) -> jax.Array:
    if cfg.model == "fm":
        return fm_forward(cfg, params, batch["ids"])
    if cfg.model == "autoint":
        return autoint_forward(cfg, params, batch["ids"])
    if cfg.model == "bst":
        return bst_forward(cfg, params, batch["ids"], batch["hist"], batch["target"])
    if cfg.model == "mind":
        return mind_train_logits(cfg, params, batch["hist"], batch["target"]).diagonal()
    raise ValueError(cfg.model)


def retrieval_scores(cfg: RecSysConfig, params: dict, batch: dict) -> jax.Array:
    """Score n_candidates items for one (or few) users -> [B, C] fp32."""
    cand = batch["cand"]                                   # [C]
    if cfg.model == "mind":
        return mind_retrieval_scores(cfg, params, batch["hist"], cand)
    C = cand.shape[0]
    if cfg.model in ("fm", "autoint"):
        # vary the last categorical field over the candidates
        ids = batch["ids"]                                 # [B, F]
        B = ids.shape[0]
        idsC = jnp.broadcast_to(ids[:, None, :], (B, C, ids.shape[1]))
        idsC = idsC.at[:, :, -1].set(cand[None, :] % cfg.field_vocabs[-1])
        flat = idsC.reshape(B * C, -1)
        f = fm_forward if cfg.model == "fm" else autoint_forward
        return f(cfg, params, flat).reshape(B, C)
    if cfg.model == "bst":
        ids, hist = batch["ids"], batch["hist"]
        B = ids.shape[0]
        idsC = jnp.broadcast_to(ids[:, None, :], (B, C, ids.shape[1])).reshape(B * C, -1)
        histC = jnp.broadcast_to(hist[:, None, :], (B, C, hist.shape[1])).reshape(B * C, -1)
        tgtC = jnp.broadcast_to(cand[None, :], (B, C)).reshape(B * C)
        return bst_forward(cfg, params, idsC, histC, tgtC).reshape(B, C)
    raise ValueError(cfg.model)

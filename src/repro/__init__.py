"""repro: multi-pod JAX framework reproducing Veretennikov's additional-index
phrase search, plus the assigned architecture zoo.

x64 policy: the search-engine executor packs (doc, pos[, dist]) into 63-bit
integer keys, so 64-bit types must be available.  We enable them globally at
package import; ALL numeric code in this framework therefore specifies dtypes
explicitly (models run bf16/f32 regardless of the x64 flag).
"""
import jax

jax.config.update("jax_enable_x64", True)

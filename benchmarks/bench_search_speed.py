"""Paper tables: SEARCH SPEED — mean/max query time and postings read, for
the additional-index engine vs the ordinary (Sphinx-style) inverted index,
on the paper's query workload.  Also verifies every query finds its source
document (the paper's correctness check).

Near-mode queries that contain a stop form used to be confined to
sequential matching by the paper's Type-4 rule ("the search is confined to
sequential words"); the multi-component key index (core/multi_key_index.py,
QTYPE_MULTI plans) now gives them TRUE windowed semantics, so their misses
— still reported as `near_stop_confined_misses` for trajectory continuity —
must be 0, like `missed_source_docs`.  The before-number is re-measured
each run with a Type-4-confined planner as
`near_stop_confined_misses_type4_before`.  The ONLY remaining exempt
population is near queries whose every word form is a stop form
(`near_stop_seq_only_misses`): those have only the Type-1 contiguous
interpretation and no doc-level fallback, exactly per the paper.

Beyond the paper:
  * a batched-throughput (QPS) measurement of the plan-compiled
    `search_batch` path (core/batch_executor.py) against the per-query loop
    on the same workload — the result set must be identical;
  * a serve-tier pass (`serve/search_serve.py`): the same workload through
    the shard_map'd distributed step, which must also be bit-identical and
    miss no promised source docs;
  * a doc-shard scaling sweep: batched step time at 1 / ~19 / ~75 doc
    shards.  With the segmented gather the total gather work is O(arena)
    (the old path was strictly linear in the shard count); the windowed
    QTYPE_MULTI plans add many short multi-key fetches, so over-sharding
    now multiplies row overhead (~1.3-2x at 75 shards) while ~19 shards stays
    near parity — the auto-pick default targets the longest-list slab
    bound, not this sweep's minimum.

All written to BENCH_search.json for the perf trajectory across PRs,
including a `ci_smoke` baseline the CI perf gate compares against."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import bench_world, paper_query_stream

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_search.json")


def _seq_only(w, q, mode) -> bool:
    """Near query whose EVERY word form is a stop form: only the Type-1
    contiguous interpretation exists, so source-doc recall is not promised."""
    from repro.core import near_query_stop_confined
    return near_query_stop_confined(w["lex"], w["ana"], q, mode)


def _contains_stop(w, q, mode) -> bool:
    """Near query containing a stop form — the population Type-4 used to
    confine and the multi-key index now serves windowed."""
    from repro.core import near_query_contains_stop
    return near_query_contains_stop(w["lex"], w["ana"], q, mode)


def _recall_buckets(w, queries, results):
    """(missed, confined_misses, seq_only_misses): source-doc misses split
    by promise class — the first two are gated at 0."""
    missed = confined = seq_only = 0
    for (q, mode, src), r in zip(queries, results):
        found = src in set(r.doc.tolist())
        if _seq_only(w, q, mode):
            seq_only += int(not found)
        elif _contains_stop(w, q, mode):
            confined += int(not found)
        else:
            missed += int(not found)
    return missed, confined, seq_only


def run_batched(eng, queries, batch_size: int = 64,
                per_query_results=None) -> dict:
    """Batched-throughput pass: the same workload in `batch_size` chunks
    through search_batch; checks result-set identity vs. the per-query
    results when given."""
    qs = [q for q, _m, _s in queries]
    ms = [m for _q, m, _s in queries]
    # full warm pass: compile every shape bucket the workload hits (steady-
    # state throughput is what the QPS number means)
    for lo in range(0, len(qs), batch_size):
        eng.search_batch(qs[lo:lo + batch_size], modes=ms[lo:lo + batch_size])
    mismatched = 0
    t0 = time.perf_counter()
    results = []
    for lo in range(0, len(qs), batch_size):
        results.extend(eng.search_batch(qs[lo:lo + batch_size],
                                        modes=ms[lo:lo + batch_size]))
    elapsed = time.perf_counter() - t0
    if per_query_results is not None:
        for r1, r2 in zip(per_query_results, results):
            if not (np.array_equal(r1.doc, r2.doc)
                    and np.array_equal(r1.pos, r2.pos)):
                mismatched += 1
    return {"batch_size": batch_size,
            "time_total_s": elapsed,
            "qps": len(qs) / elapsed,
            "result_mismatches": mismatched,
            "results": results}


def run_serve(w, queries, batch_size: int = 64,
              per_query_results=None) -> dict:
    """Serve-tier pass: the workload through the unified shard_map'd serve
    step (SearchServe), with result identity + promised-recall checks."""
    from repro.launch.mesh import make_host_mesh
    from repro.serve.search_serve import SearchServe, SearchServeConfig

    cfg = SearchServeConfig(queries=batch_size, postings_pad=4096,
                            seed_pad=1024, n_basic=1, n_expanded=1,
                            n_stop=1, n_first=1, n_multi=1)
    serve = SearchServe(w["index"], cfg, make_host_mesh(data=1, model=1))
    qs = [q for q, _m, _s in queries]
    ms = [m for _q, m, _s in queries]
    for lo in range(0, len(qs), batch_size):      # warm
        serve.search_batch(qs[lo:lo + batch_size], modes=ms[lo:lo + batch_size])
    t0 = time.perf_counter()
    results = []
    for lo in range(0, len(qs), batch_size):
        results.extend(serve.search_batch(qs[lo:lo + batch_size],
                                          modes=ms[lo:lo + batch_size]))
    elapsed = time.perf_counter() - t0
    missed, confined, seq_only = _recall_buckets(w, queries, results)
    mismatched = 0
    if per_query_results is not None:
        for r1, r2 in zip(per_query_results, results):
            if not (np.array_equal(r1.doc, r2.doc)
                    and np.array_equal(r1.pos, r2.pos)):
                mismatched += 1
    return {"qps": len(qs) / elapsed,
            "missed_source_docs": missed,
            "near_stop_confined_misses": confined,
            "near_stop_seq_only_misses": seq_only,
            "result_mismatches": mismatched}


def run_shard_scaling(w, queries, batch_size: int = 64,
                      shard_sizes=(8192, 64, 16)) -> dict:
    """Batched steady-state time with the corpus cut into 1 / ~N/64 / ~N/16
    doc shards.  Segmented gather => roughly flat; the pre-segmentation
    executor re-sorted the full slab once per shard (linear)."""
    from repro.core import AdditionalIndexEngine
    qs = [q for q, _m, _s in queries]
    ms = [m for _q, m, _s in queries]
    out = {}
    for dps in shard_sizes:
        eng = AdditionalIndexEngine(w["index"], docs_per_shard=dps)
        for lo in range(0, len(qs), batch_size):      # warm
            eng.search_batch(qs[lo:lo + batch_size],
                             modes=ms[lo:lo + batch_size])
        t0 = time.perf_counter()
        for lo in range(0, len(qs), batch_size):
            eng.search_batch(qs[lo:lo + batch_size],
                             modes=ms[lo:lo + batch_size])
        n_shards = eng.batch_executor.dev.n_shards
        out[str(n_shards)] = time.perf_counter() - t0
    times = list(out.values())
    shards = [int(k) for k in out]
    return {"time_s_by_n_shards": out,
            "cost_ratio": times[-1] / times[0],
            "shard_ratio": shards[-1] / max(shards[0], 1)}


CANONICAL = (1200, 400, 64)    # the BENCH_search.json perf-trajectory scale
CI_SMOKE = (300, 96, 32)       # the CI perf-gate scale


def run(n_docs: int = 1200, n_queries: int = 400, seed: int = 1,
        batch_size: int = 64, write_json: bool | None = None,
        full: bool | None = None) -> dict:
    # default: only a canonical-scale run may touch the committed
    # BENCH_search.json — off-scale numbers aren't comparable across PRs
    if write_json is None:
        write_json = (n_docs, n_queries, batch_size) == CANONICAL
    if full is None:
        full = write_json
    w = bench_world(n_docs)
    eng, base = w["engine"], w["ordinary"]
    queries = paper_query_stream(w["corpus"], n_queries, seed=seed)

    stats = {"add": {"postings": [], "time": []},
             "ord": {"postings": [], "time": []}}
    add_results = []
    # full warm pass (jit compile for EVERY shape bucket the workload hits —
    # same warm discipline as the batched pass, so the speedup compares
    # steady state to steady state), then timed pass
    for q, mode, _src in queries:
        eng.search(q, mode=mode)
        base.search(q, mode=mode)
    for q, mode, src in queries:
        t0 = time.perf_counter()
        r = eng.search(q, mode=mode)
        stats["add"]["time"].append(time.perf_counter() - t0)
        stats["add"]["postings"].append(r.postings_read)
        add_results.append(r)
        t0 = time.perf_counter()
        r2 = base.search(q, mode=mode)
        stats["ord"]["time"].append(time.perf_counter() - t0)
        stats["ord"]["postings"].append(r2.postings_read)
    missed, confined, seq_only = _recall_buckets(w, queries, add_results)

    # before/after: the same stop-containing near queries through a
    # Type-4-confined planner (the paper's rule), per-query — the number
    # the multi-key windowed path exists to drive to 0
    from repro.core import AdditionalIndexEngine
    eng_t4 = AdditionalIndexEngine(w["index"], windowed_near_stop=False)
    before = 0
    for q, mode, src in queries:
        if _contains_stop(w, q, mode) and not _seq_only(w, q, mode):
            before += int(src not in set(
                eng_t4.search(q, mode=mode).doc.tolist()))

    out = {"n_queries": len(queries), "missed_source_docs": missed,
           "near_stop_confined_misses": confined,
           "near_stop_confined_misses_type4_before": before,
           "near_stop_seq_only_misses": seq_only}
    # multi-key index cost vs the paper's Table figures (arXiv:1812.07640
    # trades ~constant-factor index growth for the windowed fast path)
    rep = w["index"].size_report()
    corpus_bytes = int(w["corpus"].n_tokens) * 6
    out["multi_key_index_bytes"] = rep["multi_key_index_bytes"]
    out["multi_key_pair_postings"] = rep["multi_key_pair_postings"]
    out["multi_key_triple_postings"] = rep["multi_key_triple_postings"]
    out["multi_key_over_corpus"] = rep["multi_key_index_bytes"] / corpus_bytes
    out["multi_key_over_ordinary"] = (rep["multi_key_index_bytes"]
                                      / rep["ordinary_index_bytes"])
    # anchor: the source paper's additional-index budget (259 GB / 45 GB
    # corpus) — the multi-key set must stay within the same constant-factor
    # regime the paper already accepts for its additional indexes
    out["paper_additional_over_corpus"] = 259.0 / 45.0
    for k in ("add", "ord"):
        p = np.array(stats[k]["postings"], np.float64)
        t = np.array(stats[k]["time"], np.float64)
        out[f"{k}_postings_mean"] = float(p.mean())
        out[f"{k}_postings_max"] = float(p.max())
        out[f"{k}_time_mean_ms"] = float(t.mean() * 1e3)
        out[f"{k}_time_max_ms"] = float(t.max() * 1e3)
    out["postings_mean_ratio"] = out["ord_postings_mean"] / out["add_postings_mean"]
    out["postings_max_ratio"] = out["ord_postings_max"] / out["add_postings_max"]
    out["time_mean_ratio"] = out["ord_time_mean_ms"] / out["add_time_mean_ms"]
    out["time_max_ratio"] = out["ord_time_max_ms"] / out["add_time_max_ms"]
    # the paper's measured ratios (45 GB corpus, HDD, single thread)
    out["paper_postings_mean_ratio"] = 112e6 / 274e3      # ~409x
    out["paper_postings_max_ratio"] = 505e6 / 6e6         # ~84x
    out["paper_time_mean_ratio"] = 1.01 / 0.13            # ~7.8x
    out["paper_time_max_ratio"] = 17.82 / 1.31            # ~13.6x

    # batched-throughput: search_batch vs the per-query loop, same workload
    per_query_time = float(np.sum(stats["add"]["time"]))
    b = run_batched(eng, queries, batch_size=batch_size,
                    per_query_results=add_results)
    out["batch_size"] = b["batch_size"]
    out["add_qps_per_query"] = len(queries) / per_query_time
    out["add_qps_batched"] = b["qps"]
    out["batched_speedup"] = b["qps"] * per_query_time / len(queries)
    out["batched_result_mismatches"] = b["result_mismatches"]

    if full:
        # serve tier: bit-identical to search_batch, promised recall intact
        s = run_serve(w, queries, batch_size=batch_size,
                      per_query_results=add_results)
        out["serve_qps"] = s["qps"]
        out["serve_missed_source_docs"] = s["missed_source_docs"]
        out["serve_near_stop_confined_misses"] = s["near_stop_confined_misses"]
        out["serve_near_stop_seq_only_misses"] = s["near_stop_seq_only_misses"]
        out["serve_result_mismatches"] = s["result_mismatches"]
        # segmented gather: per-shard cost roughly flat, not linear
        out["shard_scaling"] = run_shard_scaling(w, queries,
                                                 batch_size=batch_size)

    if write_json:
        # smoke-scale baseline for the CI perf gate (recursion reuses the
        # bench_world cache; write_json=False so it can't clobber this file)
        ci = run(n_docs=CI_SMOKE[0], n_queries=CI_SMOKE[1],
                 batch_size=CI_SMOKE[2], write_json=False, full=False)
        out["ci_smoke"] = {"n_docs": CI_SMOKE[0], "n_queries": CI_SMOKE[1],
                           "batch_size": CI_SMOKE[2],
                           "add_qps_batched": ci["add_qps_batched"],
                           # the per-query path is the runner-speed yardstick
                           # the CI gate normalizes against
                           "add_qps_per_query": ci["add_qps_per_query"]}
        with open(BENCH_JSON, "w") as fh:
            json.dump({k: v for k, v in out.items()}, fh, indent=2, sort_keys=True)
    return out


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=1200)
    ap.add_argument("--queries", type=int, default=400)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--no-json", action="store_true",
                    help="don't overwrite BENCH_search.json (smoke runs)")
    ap.add_argument("--full", action="store_true",
                    help="include the serve + shard-scaling passes")
    args = ap.parse_args()
    res = run(n_docs=args.docs, n_queries=args.queries, batch_size=args.batch,
              write_json=False if args.no_json else None,
              full=True if args.full else None)
    for k, v in res.items():
        print(f"search_speed.{k},{v:.6g}" if isinstance(v, float)
              else f"search_speed.{k},{v}")


if __name__ == "__main__":
    main()

"""Paper tables: SEARCH SPEED — mean/max query time and postings read, for
the additional-index engine vs the ordinary (Sphinx-style) inverted index,
on the paper's query workload.  Also verifies every query finds its source
document (the paper's correctness check).

Near-mode queries that contain a stop form used to be confined to
sequential matching by the paper's Type-4 rule ("the search is confined to
sequential words"); the multi-component key index (core/multi_key_index.py,
QTYPE_MULTI plans) now gives them TRUE windowed semantics, so their misses
— still reported as `near_stop_confined_misses` for trajectory continuity —
must be 0, like `missed_source_docs`.  The before-number is re-measured
each run with a Type-4-confined planner as
`near_stop_confined_misses_type4_before`.  The ONLY remaining exempt
population is near queries whose every word form is a stop form
(`near_stop_seq_only_misses`): those have only the Type-1 contiguous
interpretation and no doc-level fallback, exactly per the paper.

Beyond the paper:
  * a batched-throughput (QPS) measurement of the plan-compiled
    `search_batch` path (core/batch_executor.py) against the per-query loop
    on the same workload — the result set must be identical;
  * a serve-tier pass (`serve/search_serve.py`): the same workload through
    the shard_map'd distributed step, which must also be bit-identical and
    miss no promised source docs;
  * a RANKED pass (`ranked_qps_batched`): the same workload with
    SearchRequest(rank=True) — proximity relevance per arXiv:2108.00410
    computed in the fused bucket step — engine vs serve bit-identical
    (`ranked_result_mismatches`), scores oracle-checked against
    `brute_force_ranked` (`ranked_oracle_mismatches`), and the unranked
    batched path must stay within 10% of its previous QPS (CI gate);
  * a doc-shard scaling sweep: batched step time at 1 / ~19 / ~75 doc
    shards.  With the segmented gather the total gather work is O(arena)
    (the old path was strictly linear in the shard count); the windowed
    QTYPE_MULTI plans add many short multi-key fetches, so over-sharding
    now multiplies row overhead (~1.3-2x at 75 shards) while ~19 shards stays
    near parity — the auto-pick default targets the longest-list slab
    bound, not this sweep's minimum.

All written to BENCH_search.json for the perf trajectory across PRs,
including a `ci_smoke` baseline the CI perf gate compares against."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import (bench_world, kword_query_stream,
                               paper_query_stream)
from repro.core import SearchRequest


def _requests(queries, rank: bool = False, top_k=None) -> list:
    return [SearchRequest(q, mode=m, rank=rank, top_k=top_k)
            for q, m, _s in queries]

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_search.json")


def _seq_only(w, q, mode) -> bool:
    """Near query whose EVERY word form is a stop form: only the Type-1
    contiguous interpretation exists, so source-doc recall is not promised."""
    from repro.core import near_query_stop_confined
    return near_query_stop_confined(w["lex"], w["ana"], q, mode)


def _contains_stop(w, q, mode) -> bool:
    """Near query containing a stop form — the population Type-4 used to
    confine and the multi-key index now serves windowed."""
    from repro.core import near_query_contains_stop
    return near_query_contains_stop(w["lex"], w["ana"], q, mode)


def _recall_buckets(w, queries, results):
    """(missed, confined_misses, seq_only_misses): source-doc misses split
    by promise class — the first two are gated at 0."""
    missed = confined = seq_only = 0
    for (q, mode, src), r in zip(queries, results):
        found = src in set(r.doc.tolist())
        if _seq_only(w, q, mode):
            seq_only += int(not found)
        elif _contains_stop(w, q, mode):
            confined += int(not found)
        else:
            missed += int(not found)
    return missed, confined, seq_only


def run_batched(eng, queries, batch_size: int = 64,
                per_query_results=None, rank: bool = False) -> dict:
    """Batched-throughput pass: the same workload in `batch_size` chunks
    through search_batch; checks result-set identity vs. the per-query
    results when given.  `rank=True` measures the proximity-ranked path."""
    reqs = _requests(queries, rank=rank)
    # full warm pass: compile every shape bucket the workload hits (steady-
    # state throughput is what the QPS number means); then best-of-3 timed
    # passes — the QPS gate compares across runs, and single-pass timings
    # swing far more than the path under test does
    for lo in range(0, len(reqs), batch_size):
        eng.search_batch(reqs[lo:lo + batch_size])
    mismatched = 0
    elapsed = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        results = []
        for lo in range(0, len(reqs), batch_size):
            results.extend(eng.search_batch(reqs[lo:lo + batch_size]))
        elapsed = min(elapsed, time.perf_counter() - t0)
    if per_query_results is not None:
        for r1, r2 in zip(per_query_results, results):
            if not (np.array_equal(r1.doc, r2.doc)
                    and np.array_equal(r1.pos, r2.pos)):
                mismatched += 1
    return {"batch_size": batch_size,
            "time_total_s": elapsed,
            "qps": len(reqs) / elapsed,
            "result_mismatches": mismatched,
            "results": results}


def run_serve(w, queries, batch_size: int = 64,
              per_query_results=None) -> dict:
    """Serve-tier pass: the workload through the unified shard_map'd serve
    step (SearchServe), with result identity + promised-recall checks."""
    from repro.launch.mesh import make_host_mesh
    from repro.serve.search_serve import SearchServe, SearchServeConfig

    cfg = SearchServeConfig(queries=batch_size, postings_pad=4096,
                            seed_pad=1024, n_basic=1, n_expanded=1,
                            n_stop=1, n_first=1, n_multi=1)
    serve = SearchServe(w["index"], cfg, make_host_mesh(data=1, model=1))
    reqs = _requests(queries)
    for lo in range(0, len(reqs), batch_size):      # warm
        serve.search_batch(reqs[lo:lo + batch_size])
    # best-of-3, the same protocol as the batched/ranked passes — a
    # single-shot serve_qps swings with host noise far more than the path
    # under test, which made the serve trajectory incomparable across PRs
    elapsed = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        results = []
        for lo in range(0, len(reqs), batch_size):
            results.extend(serve.search_batch(reqs[lo:lo + batch_size]))
        elapsed = min(elapsed, time.perf_counter() - t0)
    missed, confined, seq_only = _recall_buckets(w, queries, results)
    mismatched = 0
    if per_query_results is not None:
        for r1, r2 in zip(per_query_results, results):
            if not (np.array_equal(r1.doc, r2.doc)
                    and np.array_equal(r1.pos, r2.pos)):
                mismatched += 1
    return {"qps": len(reqs) / elapsed,
            "missed_source_docs": missed,
            "near_stop_confined_misses": confined,
            "near_stop_seq_only_misses": seq_only,
            "result_mismatches": mismatched,
            "serve": serve}


def run_front(w, queries, batch_size: int = 64,
              per_query_results=None) -> dict:
    """Front-door pass (serve/front.py): the workload as INDIVIDUAL
    requests through the serving front door — admission, micro-batch
    coalescing, shape-bucket routing, dispatch, merge — with the result
    cache disabled so the QPS is honest re-execution, not memoization.
    Every response must be SERVED_EXACT and bit-identical to the per-query
    results; nothing may shed at this offered load."""
    from repro.serve.front import FrontDoor, FrontDoorConfig

    cfg = FrontDoorConfig(max_queue=max(512, 2 * len(queries)),
                          max_batch=batch_size,
                          default_deadline_ms=600_000.0,
                          cache_capacity=0, shard_timeout_s=600.0)
    front = FrontDoor(w["index"], cfg=cfg)
    reqs = _requests(queries)
    front.search_batch(reqs)                        # warm every shape bucket
    elapsed, results, stats = float("inf"), None, None
    for _ in range(3):
        front.stats = type(front.stats)()
        t0 = time.perf_counter()
        cur = front.search_batch(reqs)
        dt = time.perf_counter() - t0
        if dt < elapsed:
            elapsed, results, stats = dt, cur, front.stats
    front.close()
    mismatched = 0
    if per_query_results is not None:
        for r1, r2 in zip(per_query_results, results):
            if not (np.array_equal(r1.doc, r2.doc)
                    and np.array_equal(r1.pos, r2.pos)
                    and r1.postings_read == r2.postings_read):
                mismatched += 1
    return {"qps": len(reqs) / elapsed,
            "p50_ms": stats.percentile(50),
            "p95_ms": stats.percentile(95),
            "p99_ms": stats.percentile(99),
            "shed": stats.shed,
            "non_exact": sum(r.status != "SERVED_EXACT" for r in results),
            "result_mismatches": mismatched}


def run_ranked_flex_ab(w, queries, limit: int | None = None) -> dict:
    """A/B for the per-query flex ranked path: pow2-padded jit'd group
    steps (the default) vs the old eager per-group loop
    (`Executor.ranked_jit = False`).  Both sides re-measured live each run,
    same precedent as near_stop_confined_misses_type4_before — recorded
    numbers from dead code drift silently."""
    eng = w["engine"]
    qs = queries if limit is None else queries[:limit]
    reqs = _requests(qs, rank=True)
    out = {}
    try:
        for jit_on, key in ((True, "ranked_qps_flex"),
                            (False, "ranked_qps_flex_eager")):
            eng.executor.ranked_jit = jit_on
            for req in reqs:                        # warm
                eng.search(req)
            best = float("inf")
            for _ in range(2):
                t0 = time.perf_counter()
                for req in reqs:
                    eng.search(req)
                best = min(best, time.perf_counter() - t0)
            out[key] = len(reqs) / best
    finally:
        eng.executor.ranked_jit = True
    out["ranked_flex_jit_speedup"] = (out["ranked_qps_flex"]
                                      / out["ranked_qps_flex_eager"])
    return out


def run_ranked(w, queries, batch_size: int = 64, serve=None,
               oracle_limit: int | None = None) -> dict:
    """Proximity-ranked pass (arXiv:2108.00410): the same workload with
    rank=True through the engine's batched path (QPS) and the serve tier
    (bit-identity on doc_ids / doc_scores / anchor_scores), plus a
    brute_force_ranked score check on up to `oracle_limit` queries."""
    from repro.core import brute_force_ranked
    eng = w["engine"]
    reqs = _requests(queries, rank=True)
    # same warm + best-of-3 protocol as the unranked number it is compared
    # against — literally the same code
    b = run_batched(eng, queries, batch_size=batch_size, rank=True)
    results = b["results"]
    out = {"ranked_qps_batched": b["qps"]}

    mismatched = 0
    if serve is not None:
        sres = []
        for lo in range(0, len(reqs), batch_size):
            sres.extend(serve.search_batch(reqs[lo:lo + batch_size]))
        for r1, r2 in zip(results, sres):
            same = (np.array_equal(r1.doc, r2.doc)
                    and np.array_equal(r1.pos, r2.pos)
                    and np.array_equal(r1.doc_ids, r2.doc_ids)
                    and np.array_equal(r1.doc_scores, r2.doc_scores))
            if r1.anchor_scores is not None or r2.anchor_scores is not None:
                same &= np.array_equal(r1.anchor_scores, r2.anchor_scores)
            mismatched += int(not same)
    out["ranked_result_mismatches"] = mismatched

    oracle_bad = 0
    n_oracle = len(queries) if oracle_limit is None else \
        min(oracle_limit, len(queries))
    for (q, mode, _src), r in list(zip(queries, results))[:n_oracle]:
        a_sc, d_sc, d_lvl = brute_force_ranked(w["corpus"], w["index"], q,
                                               mode=mode)
        if r.doc_only:
            oracle_bad += int(set(r.doc.tolist()) != d_lvl)
            continue
        got = dict(zip(zip(r.doc.tolist(), r.pos.tolist()),
                       r.anchor_scores.tolist()))
        if set(got) != set(a_sc):
            oracle_bad += 1
            continue
        if any(abs(got[k] - a_sc[k]) > 1e-4 * max(1.0, abs(a_sc[k]))
               for k in got):
            oracle_bad += 1
            continue
        dd = dict(zip(r.doc_ids.tolist(), r.doc_scores.tolist()))
        if any(abs(dd[d] - d_sc[d]) > 1e-4 * max(1.0, abs(d_sc[d]))
               for d in dd):
            oracle_bad += 1
    out["ranked_oracle_mismatches"] = oracle_bad
    out["ranked_oracle_checked"] = n_oracle
    return out


def run_kword(w, queries, batch_size: int = 64, serve=None,
              oracle_limit: int | None = None) -> dict:
    """K-word proximity pass (arXiv:2009.02684): the stop-heavy K in {3,4,5}
    workload from `common.kword_query_stream` through every execution tier.

    Records, for BENCH_search.json / the CI gates:
      * kword_qps_batched — engine `search_batch` steady-state throughput;
      * kword_result_mismatches — bit-identity failures across the flexible
        per-query executor, the batched executor, and (when `serve` is
        given) the shard_map'd serve tier, postings accounting and ranked
        scores included — gated at 0;
      * kword_oracle_mismatches — disagreements with the literal
        nested-loop `brute_force_kword` oracle — gated at 0;
      * kword_postings_ratio — ordinary-index postings read over the
        multi-key-cover plan's (the ISSUE-9 acceptance counter: the cover
        must read measurably fewer postings than the baseline)."""
    from repro.core import MODE_KWORD, brute_force_kword
    eng, base = w["engine"], w["ordinary"]
    reqs = [SearchRequest(q, mode=MODE_KWORD, window=win)
            for q, win, _src in queries]
    ranked_reqs = [SearchRequest(q, mode=MODE_KWORD, window=win, rank=True)
                   for q, win, _src in queries]

    flex_results = [eng.search(r) for r in reqs]
    flex_ranked = [eng.search(r) for r in ranked_reqs]
    for lo in range(0, len(reqs), batch_size):                    # warm
        eng.search_batch(reqs[lo:lo + batch_size])
    elapsed = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        results = []
        for lo in range(0, len(reqs), batch_size):
            results.extend(eng.search_batch(reqs[lo:lo + batch_size]))
        elapsed = min(elapsed, time.perf_counter() - t0)
    ranked_results = []
    for lo in range(0, len(ranked_reqs), batch_size):
        ranked_results.extend(eng.search_batch(ranked_reqs[lo:lo + batch_size]))

    def _same(r1, r2, rank=False) -> bool:
        same = (np.array_equal(r1.doc, r2.doc)
                and np.array_equal(r1.pos, r2.pos)
                and r1.postings_read == r2.postings_read
                and r1.used_fallback == r2.used_fallback
                and r1.doc_only == r2.doc_only)
        if rank and same:
            same = (np.array_equal(r1.anchor_scores, r2.anchor_scores)
                    and np.array_equal(r1.doc_ids, r2.doc_ids)
                    and np.array_equal(r1.doc_scores, r2.doc_scores))
        return same

    mismatched = 0
    for r1, r2 in zip(flex_results, results):
        mismatched += int(not _same(r1, r2))
    for r1, r2 in zip(flex_ranked, ranked_results):
        mismatched += int(not _same(r1, r2, rank=True))
    if serve is not None:
        sres, sres_rk = [], []
        for lo in range(0, len(reqs), batch_size):
            sres.extend(serve.search_batch(reqs[lo:lo + batch_size]))
            sres_rk.extend(serve.search_batch(
                ranked_reqs[lo:lo + batch_size]))
        for r1, r2 in zip(results, sres):
            mismatched += int(not _same(r1, r2))
        for r1, r2 in zip(ranked_results, sres_rk):
            mismatched += int(not _same(r1, r2, rank=True))

    oracle_bad = 0
    n_oracle = len(queries) if oracle_limit is None else \
        min(oracle_limit, len(queries))
    for (q, win, _src), r in list(zip(queries, results))[:n_oracle]:
        truth_pos, truth_doc = brute_force_kword(w["corpus"], w["index"], q,
                                                 win)
        if r.doc_only:
            oracle_bad += int(bool(truth_pos)
                              or set(r.doc.tolist()) != truth_doc)
        else:
            oracle_bad += int(
                set(zip(r.doc.tolist(), r.pos.tolist())) != truth_pos)

    # multi-key cover vs ordinary baseline: postings read per query
    add_p = np.array([r.postings_read for r in results], np.float64)
    ord_p = np.array([base.search(r).postings_read for r in reqs], np.float64)
    return {"kword_qps_batched": len(reqs) / elapsed,
            "kword_result_mismatches": mismatched,
            "kword_oracle_mismatches": oracle_bad,
            "kword_oracle_checked": n_oracle,
            "kword_postings_mean": float(add_p.mean()),
            "kword_ord_postings_mean": float(ord_p.mean()),
            "kword_postings_ratio": float(ord_p.mean() / max(add_p.mean(), 1.0))}


def run_shard_scaling(w, queries, batch_size: int = 64,
                      shard_sizes=(8192, 64, 16)) -> dict:
    """Batched steady-state time with the corpus cut into 1 / ~N/64 / ~N/16
    doc shards.  Segmented gather => roughly flat; the pre-segmentation
    executor re-sorted the full slab once per shard (linear)."""
    from repro.core import AdditionalIndexEngine
    reqs = _requests(queries)
    out = {}
    for dps in shard_sizes:
        eng = AdditionalIndexEngine(w["index"], docs_per_shard=dps)
        for lo in range(0, len(reqs), batch_size):      # warm
            eng.search_batch(reqs[lo:lo + batch_size])
        best = float("inf")
        for _ in range(2):                              # best-of (noise)
            t0 = time.perf_counter()
            for lo in range(0, len(reqs), batch_size):
                eng.search_batch(reqs[lo:lo + batch_size])
            best = min(best, time.perf_counter() - t0)
        n_shards = eng.batch_executor.dev.n_shards
        out[str(n_shards)] = best
    times = list(out.values())
    shards = [int(k) for k in out]
    return {"time_s_by_n_shards": out,
            "cost_ratio": times[-1] / times[0],
            "shard_ratio": shards[-1] / max(shards[0], 1)}


def run_ingest(w, queries, batch_size: int = 64, n_batches: int = 4,
               per_query_results=None) -> dict:
    """Incremental-ingestion pass (core/segments.py): feed the corpus in
    `n_batches` batches through a SegmentManager (ingest throughput), search
    the multi-segment union while a merge runs on a background thread
    (availability during compaction), then check the fully-merged manager
    answers the whole workload bit-identically to the per-query engine —
    postings accounting included.  A second manager drives the front-door
    staleness probe: query / cache / ingest / re-query, counting any cached
    response that survives the generation bump (gated at 0 in CI)."""
    import threading

    from repro.core.segments import SegmentManager, corpus_batches
    from repro.serve.front import FrontDoor, FrontDoorConfig

    corpus, index = w["corpus"], w["index"]
    reqs = _requests(queries)
    batches = corpus_batches(corpus, n_batches)
    mgr = SegmentManager(w["lex"], w["ana"], params=index.params,
                         auto_merge=False)
    t0 = time.perf_counter()
    for b in batches:
        mgr.ingest(b)
    ingest_s = time.perf_counter() - t0
    out = {"ingest_batches": n_batches,
           "ingest_docs_per_sec": corpus.n_docs / ingest_s}

    # search the segment union WHILE the merge compacts it (at least one
    # full round always runs, so the QPS is defined even when the merge
    # finishes inside the first round)
    sub = reqs[:batch_size]
    mgr.search_batch(sub)                            # warm
    done = threading.Event()

    def _merge():
        try:
            mgr.merge_now()
        finally:
            done.set()

    th = threading.Thread(target=_merge)
    served = 0
    t0 = time.perf_counter()
    th.start()
    while True:
        mgr.search_batch(sub)
        served += len(sub)
        if done.is_set():
            break
    out["search_qps_during_merge"] = served / (time.perf_counter() - t0)
    th.join()

    # fully merged == the one-shot build: the whole workload, accounting
    # included, against the per-query engine results
    mismatched = 0
    assert len(mgr.segments) == 1, [s.state for s in mgr.segments]
    results = []
    for lo in range(0, len(reqs), batch_size):
        results.extend(mgr.search_batch(reqs[lo:lo + batch_size]))
    if per_query_results is not None:
        for r1, r2 in zip(per_query_results, results):
            if not (np.array_equal(r1.doc, r2.doc)
                    and np.array_equal(r1.pos, r2.pos)
                    and r1.postings_read == r2.postings_read):
                mismatched += 1
    mgr.close()

    # front-door staleness probe: cached responses must die with the
    # generation, and the post-ingest responses must match the full-corpus
    # engine (doc/pos — the union's accounting follows its own global plan)
    mgr2 = SegmentManager(w["lex"], w["ana"], params=index.params,
                          auto_merge=False)
    for b in batches[:-1]:
        mgr2.ingest(b)
    front = FrontDoor(segments=mgr2,
                      cfg=FrontDoorConfig(cache_capacity=64,
                                          default_deadline_ms=600_000.0,
                                          shard_timeout_s=600.0))
    probe = reqs[:min(8, len(reqs))]
    front.search_batch(probe)
    cached = front.search_batch(probe)               # hits the cache
    stale = sum(int(not r.cached) for r in cached)   # warm cache sanity
    mgr2.ingest(batches[-1])                         # the index just changed
    fresh = front.search_batch(probe)
    stale += sum(int(r.cached) for r in fresh)       # survived the bump?
    if per_query_results is not None:
        for r1, r2 in zip(per_query_results, fresh):
            if not (np.array_equal(r1.doc, r2.doc)
                    and np.array_equal(r1.pos, r2.pos)):
                mismatched += 1
    out["ingest_stale_cache_hits"] = front.stats.stale_cache_hits + stale
    out["ingest_result_mismatches"] = mismatched
    front.close()
    mgr2.close()
    return out


CANONICAL = (1200, 400, 64)    # the BENCH_search.json perf-trajectory scale
CI_SMOKE = (300, 96, 32)       # the CI perf-gate scale


def run(n_docs: int = 1200, n_queries: int = 400, seed: int = 1,
        batch_size: int = 64, write_json: bool | None = None,
        full: bool | None = None) -> dict:
    # default: only a canonical-scale run may touch the committed
    # BENCH_search.json — off-scale numbers aren't comparable across PRs
    if write_json is None:
        write_json = (n_docs, n_queries, batch_size) == CANONICAL
    if full is None:
        full = write_json
    w = bench_world(n_docs)
    eng, base = w["engine"], w["ordinary"]
    queries = paper_query_stream(w["corpus"], n_queries, seed=seed)

    add_results = []
    per_query_reqs = _requests(queries)
    # full warm pass (jit compile for EVERY shape bucket the workload hits —
    # same warm discipline as the batched pass, so the speedup compares
    # steady state to steady state), then best-of-3 timed passes — the
    # per-query mean is the yardstick the CI gate normalizes runner speed
    # by, so it must be as noise-resistant as the batched numbers it divides
    for req in per_query_reqs:
        eng.search(req)
        base.search(req)
    stats = None
    for _ in range(3):
        cur = {"add": {"postings": [], "time": []},
               "ord": {"postings": [], "time": []}}
        results = []
        for (q, mode, src), req in zip(queries, per_query_reqs):
            t0 = time.perf_counter()
            r = eng.search(req)
            cur["add"]["time"].append(time.perf_counter() - t0)
            cur["add"]["postings"].append(r.postings_read)
            results.append(r)
            t0 = time.perf_counter()
            r2 = base.search(req)
            cur["ord"]["time"].append(time.perf_counter() - t0)
            cur["ord"]["postings"].append(r2.postings_read)
        if stats is None:
            stats, add_results = cur, results
        else:
            for k in ("add", "ord"):
                if sum(cur[k]["time"]) < sum(stats[k]["time"]):
                    stats[k] = cur[k]
    missed, confined, seq_only = _recall_buckets(w, queries, add_results)

    # before/after: the same stop-containing near queries through a
    # Type-4-confined planner (the paper's rule), per-query — the number
    # the multi-key windowed path exists to drive to 0
    from repro.core import AdditionalIndexEngine
    eng_t4 = AdditionalIndexEngine(w["index"], windowed_near_stop=False)
    before = 0
    for (q, mode, src), req in zip(queries, per_query_reqs):
        if _contains_stop(w, q, mode) and not _seq_only(w, q, mode):
            before += int(src not in set(eng_t4.search(req).doc.tolist()))

    out = {"n_queries": len(queries), "missed_source_docs": missed,
           "near_stop_confined_misses": confined,
           "near_stop_confined_misses_type4_before": before,
           "near_stop_seq_only_misses": seq_only}
    # multi-key index cost vs the paper's Table figures (arXiv:1812.07640
    # trades ~constant-factor index growth for the windowed fast path)
    rep = w["index"].size_report()
    corpus_bytes = int(w["corpus"].n_tokens) * 6
    out["multi_key_index_bytes"] = rep["multi_key_index_bytes"]
    out["multi_key_pair_postings"] = rep["multi_key_pair_postings"]
    out["multi_key_triple_postings"] = rep["multi_key_triple_postings"]
    out["multi_key_over_corpus"] = rep["multi_key_index_bytes"] / corpus_bytes
    out["multi_key_over_ordinary"] = (rep["multi_key_index_bytes"]
                                      / rep["ordinary_index_bytes"])
    # packed block store (core/postings.py): the bytes the device arena now
    # holds for the multi-key / expanded streams, vs the raw CSR they
    # replace — the ISSUE-5 acceptance ratio (>= 3x), gated in CI
    out["multi_key_packed_bytes"] = rep["multi_key_packed_bytes"]
    out["expanded_packed_bytes"] = rep["expanded_packed_bytes"]
    out["multi_key_index_over_packed"] = (
        rep["multi_key_index_bytes"] / max(rep["multi_key_packed_bytes"], 1))
    out["expanded_index_over_packed"] = (
        rep["expanded_index_bytes"] / max(rep["expanded_packed_bytes"], 1))
    out["multi_key_packed_over_corpus"] = \
        rep["multi_key_packed_bytes"] / corpus_bytes
    out["device_arena_bytes"] = eng.batch_executor.dev.device_nbytes()
    # anchor: the source paper's additional-index budget (259 GB / 45 GB
    # corpus) — the multi-key set must stay within the same constant-factor
    # regime the paper already accepts for its additional indexes
    out["paper_additional_over_corpus"] = 259.0 / 45.0
    for k in ("add", "ord"):
        p = np.array(stats[k]["postings"], np.float64)
        t = np.array(stats[k]["time"], np.float64)
        out[f"{k}_postings_mean"] = float(p.mean())
        out[f"{k}_postings_max"] = float(p.max())
        out[f"{k}_time_mean_ms"] = float(t.mean() * 1e3)
        out[f"{k}_time_max_ms"] = float(t.max() * 1e3)
    out["postings_mean_ratio"] = out["ord_postings_mean"] / out["add_postings_mean"]
    out["postings_max_ratio"] = out["ord_postings_max"] / out["add_postings_max"]
    out["time_mean_ratio"] = out["ord_time_mean_ms"] / out["add_time_mean_ms"]
    out["time_max_ratio"] = out["ord_time_max_ms"] / out["add_time_max_ms"]
    # the paper's measured ratios (45 GB corpus, HDD, single thread)
    out["paper_postings_mean_ratio"] = 112e6 / 274e3      # ~409x
    out["paper_postings_max_ratio"] = 505e6 / 6e6         # ~84x
    out["paper_time_mean_ratio"] = 1.01 / 0.13            # ~7.8x
    out["paper_time_max_ratio"] = 17.82 / 1.31            # ~13.6x

    # batched-throughput: search_batch vs the per-query loop, same workload
    per_query_time = float(np.sum(stats["add"]["time"]))
    b = run_batched(eng, queries, batch_size=batch_size,
                    per_query_results=add_results)
    out["batch_size"] = b["batch_size"]
    out["add_qps_per_query"] = len(queries) / per_query_time
    out["add_qps_batched"] = b["qps"]
    out["batched_speedup"] = b["qps"] * per_query_time / len(queries)
    out["batched_result_mismatches"] = b["result_mismatches"]

    # k-word proximity pass (arXiv:2009.02684): stop-heavy K in {3,4,5}
    # windowed word-set queries through flex + batched (+ serve when full),
    # oracle-checked, with the multi-key-cover postings-advantage counter
    kword_queries = kword_query_stream(w, n_queries, seed=seed + 2)

    if full:
        # serve tier: bit-identical to search_batch, promised recall intact
        s = run_serve(w, queries, batch_size=batch_size,
                      per_query_results=add_results)
        out["serve_qps"] = s["qps"]
        out["serve_missed_source_docs"] = s["missed_source_docs"]
        out["serve_near_stop_confined_misses"] = s["near_stop_confined_misses"]
        out["serve_near_stop_seq_only_misses"] = s["near_stop_seq_only_misses"]
        out["serve_result_mismatches"] = s["result_mismatches"]
        # ranked pass: engine QPS, engine==serve bit-identity, oracle scores
        # (capped at full scale — the literal oracle is O(corpus) per query)
        rk = run_ranked(w, queries, batch_size=batch_size, serve=s["serve"],
                        oracle_limit=None if n_queries <= 128 else 120)
        out.update(rk)
        out.update(run_kword(
            w, kword_queries, batch_size=batch_size, serve=s["serve"],
            oracle_limit=None if n_queries <= 128 else 120))
        # front door: individual requests coalesced into shape-bucketed
        # micro-batches — the serve-tier QPS acceptance number (>= 10x the
        # PR 5 fixed-slab serve baseline of 2.8), plus latency percentiles
        f = run_front(w, queries, batch_size=batch_size,
                      per_query_results=add_results)
        out["front_qps"] = f["qps"]
        out["front_p50_ms"] = f["p50_ms"]
        out["front_p95_ms"] = f["p95_ms"]
        out["front_p99_ms"] = f["p99_ms"]
        out["front_shed"] = f["shed"]
        out["front_non_exact"] = f["non_exact"]
        out["front_result_mismatches"] = f["result_mismatches"]
        # flex ranked path A/B: jit'd pow2-padded group steps vs the old
        # eager loop (both measured live, capped — the flex loop is the
        # slow per-query path by construction)
        out.update(run_ranked_flex_ab(
            w, queries, limit=None if n_queries <= 128 else 200))
        # segmented gather: per-shard cost roughly flat, not linear
        out["shard_scaling"] = run_shard_scaling(w, queries,
                                                 batch_size=batch_size)
        # incremental ingestion (core/segments.py): ingest throughput,
        # availability during a background merge, post-merge bit-identity,
        # and the front-door cache-staleness probe
        out.update(run_ingest(w, queries, batch_size=batch_size,
                              per_query_results=add_results))
    else:
        # smoke / CI-baseline runs still measure the kword pass (no serve
        # tier, capped oracle) — the gates need the counters at every scale
        out.update(run_kword(w, kword_queries, batch_size=batch_size,
                             oracle_limit=min(60, n_queries)))

    if write_json:
        out["ci_smoke"] = ci_smoke_baseline()
        try:            # preserve bench_index_size's block (separate writer)
            with open(BENCH_JSON) as fh:
                prev_index_size = json.load(fh).get("index_size")
        except (OSError, ValueError):
            prev_index_size = None
        if prev_index_size is not None:
            out = dict(out, index_size=prev_index_size)
        with open(BENCH_JSON, "w") as fh:
            json.dump({k: v for k, v in out.items()}, fh, indent=2, sort_keys=True)
    return out


def ci_smoke_baseline(n_runs: int = 3) -> dict:
    """The smoke-scale baseline the CI perf gate compares against: the
    per-key MEDIAN over `n_runs` FRESH interpreters (subprocesses).

    Fresh: the gate normalizes future fresh CI runs by the baseline's
    per-query/batched ratio, and a long-lived bench process skews exactly
    that ratio (hundreds of cached jit programs slow the flex path's many
    small dispatches while the batched path's few big programs are
    unaffected — observed ~25% per-query drift by the end of a canonical
    run).  The samples are whole runs (never per-key medians — that can
    pair a fast-mode batched number with a slow-mode per-query number),
    and the pick is the sample with the LOWEST batched/per-query ratio:
    per-query dispatch perturbation on shared CPU hosts is one-sided (the
    flex path only ever loses ground to the batched path, 2x swings
    observed), so the lowest ratio is the least-perturbed, most
    normalization-faithful baseline."""
    import os
    import subprocess
    import sys
    samples = []
    for _ in range(n_runs):
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_search_speed",
             "--ci-baseline"],
            capture_output=True, text=True, timeout=1800,
            env=dict(os.environ,
                     PYTHONPATH=os.pathsep.join(p for p in sys.path if p)),
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        line = [l for l in proc.stdout.splitlines()
                if l.startswith("CI_BASELINE ")]
        assert line, (proc.stdout[-2000:], proc.stderr[-2000:])
        samples.append(json.loads(line[-1].removeprefix("CI_BASELINE ")))
    return min(samples,
               key=lambda s: s["add_qps_batched"] / s["add_qps_per_query"])


def _ci_baseline_main():
    ci = run(n_docs=CI_SMOKE[0], n_queries=CI_SMOKE[1],
             batch_size=CI_SMOKE[2], write_json=False, full=False)
    rk = run_ranked(bench_world(CI_SMOKE[0]),
                    paper_query_stream(bench_world(CI_SMOKE[0])["corpus"],
                                       CI_SMOKE[1], seed=1),
                    batch_size=CI_SMOKE[2], oracle_limit=0)
    print("CI_BASELINE " + json.dumps({
        "n_docs": CI_SMOKE[0], "n_queries": CI_SMOKE[1],
        "batch_size": CI_SMOKE[2],
        "add_qps_batched": ci["add_qps_batched"],
        "ranked_qps_batched": rk["ranked_qps_batched"],
        "kword_qps_batched": ci["kword_qps_batched"],
        # the per-query path is the runner-speed yardstick the CI gate
        # normalizes against
        "add_qps_per_query": ci["add_qps_per_query"],
        # deterministic (build-time) index bytes for the CI index-bytes
        # regression gate — a packed-store regression shows up here exactly,
        # no timing noise involved
        "multi_key_packed_bytes": ci["multi_key_packed_bytes"],
        "expanded_packed_bytes": ci["expanded_packed_bytes"],
        "device_arena_bytes": ci["device_arena_bytes"]}))


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=1200)
    ap.add_argument("--queries", type=int, default=400)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--no-json", action="store_true",
                    help="don't overwrite BENCH_search.json (smoke runs)")
    ap.add_argument("--full", action="store_true",
                    help="include the serve + shard-scaling passes")
    ap.add_argument("--ci-baseline", action="store_true",
                    help="measure and print the fresh-process CI smoke "
                         "baseline, nothing else")
    args = ap.parse_args()
    if args.ci_baseline:
        _ci_baseline_main()
        return
    res = run(n_docs=args.docs, n_queries=args.queries, batch_size=args.batch,
              write_json=False if args.no_json else None,
              full=True if args.full else None)
    for k, v in res.items():
        print(f"search_speed.{k},{v:.6g}" if isinstance(v, float)
              else f"search_speed.{k},{v}")


if __name__ == "__main__":
    main()

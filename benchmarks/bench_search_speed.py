"""Paper tables: SEARCH SPEED — mean/max query time and postings read, for
the additional-index engine vs the ordinary (Sphinx-style) inverted index,
on the paper's query workload.  Also verifies every query finds its source
document (the paper's correctness check)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_world, paper_query_stream


def run(n_docs: int = 1200, n_queries: int = 400, seed: int = 1) -> dict:
    w = bench_world(n_docs)
    eng, base = w["engine"], w["ordinary"]
    queries = paper_query_stream(w["corpus"], n_queries, seed=seed)

    stats = {"add": {"postings": [], "time": []},
             "ord": {"postings": [], "time": []}}
    missed = 0
    # warm pass (jit compile per shape bucket), then timed pass
    for q, mode, _src in queries[: min(len(queries), 64)]:
        eng.search(q, mode=mode)
        base.search(q, mode=mode)
    for q, mode, src in queries:
        t0 = time.perf_counter()
        r = eng.search(q, mode=mode)
        stats["add"]["time"].append(time.perf_counter() - t0)
        stats["add"]["postings"].append(r.postings_read)
        if src not in set(r.doc.tolist()):
            missed += 1
        t0 = time.perf_counter()
        r2 = base.search(q, mode=mode)
        stats["ord"]["time"].append(time.perf_counter() - t0)
        stats["ord"]["postings"].append(r2.postings_read)

    out = {"n_queries": len(queries), "missed_source_docs": missed}
    for k in ("add", "ord"):
        p = np.array(stats[k]["postings"], np.float64)
        t = np.array(stats[k]["time"], np.float64)
        out[f"{k}_postings_mean"] = float(p.mean())
        out[f"{k}_postings_max"] = float(p.max())
        out[f"{k}_time_mean_ms"] = float(t.mean() * 1e3)
        out[f"{k}_time_max_ms"] = float(t.max() * 1e3)
    out["postings_mean_ratio"] = out["ord_postings_mean"] / out["add_postings_mean"]
    out["postings_max_ratio"] = out["ord_postings_max"] / out["add_postings_max"]
    out["time_mean_ratio"] = out["ord_time_mean_ms"] / out["add_time_mean_ms"]
    out["time_max_ratio"] = out["ord_time_max_ms"] / out["add_time_max_ms"]
    # the paper's measured ratios (45 GB corpus, HDD, single thread)
    out["paper_postings_mean_ratio"] = 112e6 / 274e3      # ~409x
    out["paper_postings_max_ratio"] = 505e6 / 6e6         # ~84x
    out["paper_time_mean_ratio"] = 1.01 / 0.13            # ~7.8x
    out["paper_time_max_ratio"] = 17.82 / 1.31            # ~13.6x
    return out


def main():
    for k, v in run().items():
        print(f"search_speed.{k},{v:.6g}" if isinstance(v, float) else f"search_speed.{k},{v}")


if __name__ == "__main__":
    main()

"""Benchmark aggregator: one section per paper table + kernel micro.

Prints ``name,value`` CSV (us_per_call for kernel rows, derived ratios for
the paper-table rows).  Roofline terms come from the dry-run
(src/repro/launch/dryrun.py writes experiments/dryrun/*.json; see
benchmarks/report_roofline.py for the table)."""
from __future__ import annotations

import sys


def main() -> None:
    quick = "--quick" in sys.argv
    from benchmarks import bench_index_size, bench_kernels, bench_search_speed

    print("# kernels (CPU regression numbers; interpret-mode pallas vs jnp ref)")
    for k, v in bench_kernels.run().items():
        print(f"kernels.{k},{v:.1f}")

    n_docs = 400 if quick else 1200
    n_q = 120 if quick else 400
    print("# paper table: index sizes")
    for k, v in bench_index_size.run(n_docs).items():
        print(f"index_size.{k},{v:.6g}" if isinstance(v, float) else f"index_size.{k},{v}")

    print("# paper table: search speed (ours vs ordinary inverted index)")
    for k, v in bench_search_speed.run(n_docs, n_q).items():
        print(f"search_speed.{k},{v:.6g}" if isinstance(v, float) else f"search_speed.{k},{v}")


if __name__ == "__main__":
    main()

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver for llama3-8b / train_4k (collective-bound)."""
import dataclasses
import sys

import jax
import jax.numpy as jnp

from benchmarks import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell


def measure(mesh, arch="llama3-8b", shape="train_4k", layout="2d"):
    cell = build_cell(arch, shape, mesh, layout=layout)
    with mesh:
        compiled = jax.jit(cell.step, in_shardings=cell.in_shardings,
                           out_shardings=cell.out_shardings,
                           donate_argnums=cell.donate).lower(*cell.in_specs).compile()
    hlo = compiled.as_text()
    coll = rl.parse_collectives(hlo)
    looped = rl.parse_hlo_costs(hlo)
    terms = rl.roofline_terms(looped["flops"], looped["bytes"],
                              float(coll.total_bytes), mesh.size)
    mem = compiled.memory_analysis()
    return terms, coll, mem


def report(tag, mesh, arch="llama3-8b", layout="2d"):
    terms, coll, mem = measure(mesh, arch, layout=layout)
    frac = terms["t_compute_s"] / max(terms["t_dominant_s"], 1e-12)
    print(f"{tag:34s} coll={terms['t_collective_s']:7.2f} s "
          f"mem={terms['t_memory_s']:6.2f} s compute={terms['t_compute_s']:5.2f} s "
          f"peak={mem.temp_size_in_bytes/1e9:5.1f} GB "
          f"frac={frac:.3f} "
          f"bytes={ {k: round(v/1e9) for k, v in coll.bytes_by_type.items() if v} }")


def main():
    mesh = make_production_mesh(multi_pod=False)
    import repro.configs.llama3_8b as cfg_mod
    base_make = cfg_mod.make_config

    report("baseline (f32 FSDP gathers)", mesh)

    cfg_mod.SPEC = dataclasses.replace(
        cfg_mod.SPEC, make_config=lambda: dataclasses.replace(
            base_make(), pre_cast_layers=True))
    report("pre-cast layers to bf16", mesh)

    cfg_mod.SPEC = dataclasses.replace(
        cfg_mod.SPEC, make_config=lambda: dataclasses.replace(
            base_make(), pre_cast_layers=True, bf16_grads=True))
    report("pre-cast + bf16 backward", mesh)

    cfg_mod.SPEC = dataclasses.replace(
        cfg_mod.SPEC, make_config=base_make)
    report("pure ZeRO-3 FSDP (no TP)", mesh, layout="fsdp")

    cfg_mod.SPEC = dataclasses.replace(
        cfg_mod.SPEC, make_config=lambda: dataclasses.replace(
            base_make(), bf16_grads=True))
    report("ZeRO-3 + bf16 backward", mesh, layout="fsdp")


if __name__ == "__main__":
    main()

"""Render the §Roofline table from the dry-run JSON records.

Usage:  PYTHONPATH=src python -m benchmarks.report_roofline [dir] [--md]
"""
from __future__ import annotations

import json
import os
import sys


def load_records(d: str = "experiments/dryrun") -> list[dict]:
    out = []
    for name in sorted(os.listdir(d)):
        if name.endswith(".json"):
            with open(os.path.join(d, name)) as f:
                out.append(json.load(f))
    return out


def fmt_row(r: dict) -> dict:
    t = r["roofline"]
    ratio = r.get("useful_ratio")
    peak = r["memory"].get("peak_bytes") or 0
    arch = r["arch"]
    if r.get("layout", "2d") != "2d":
        arch += f" [{r['layout']}]"
    return {
        "arch": arch, "shape": r["shape"], "mesh": r["mesh"],
        "kind": r["kind"],
        "t_compute_s": t["t_compute_s"], "t_memory_s": t["t_memory_s"],
        "t_collective_s": t["t_collective_s"], "dominant": t["dominant"],
        "model_flops": r.get("model_flops"),
        "useful_ratio": ratio,
        "peak_gb": peak / 1e9,
        "frac_of_roofline": (t["t_compute_s"] / t["t_dominant_s"]
                             if t["t_dominant_s"] else None),
    }


def main():
    d = sys.argv[1] if len(sys.argv) > 1 and not sys.argv[1].startswith("-") \
        else "experiments/dryrun"
    md = "--md" in sys.argv
    rows = [fmt_row(r) for r in load_records(d)]
    hdr = ["arch", "shape", "mesh", "dominant", "t_compute_s", "t_memory_s",
           "t_collective_s", "useful_ratio", "frac_of_roofline", "peak_gb"]
    if md:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
    else:
        print(",".join(hdr))
    for r in rows:
        vals = []
        for h in hdr:
            v = r[h]
            vals.append(f"{v:.3g}" if isinstance(v, float) and v is not None
                        else str(v))
        print(("| " + " | ".join(vals) + " |") if md else ",".join(vals))


if __name__ == "__main__":
    main()

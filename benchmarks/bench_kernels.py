"""Kernel microbenchmarks: µs/call for the Pallas kernels (interpret mode)
vs their jnp oracles on CPU.  These are regression numbers, not TPU
performance — TPU-side behaviour is captured by the dry-run roofline."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def _timeit(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> dict:
    rng = np.random.default_rng(0)
    out = {}

    a = jnp.asarray(rng.integers(0, 1 << 22, 16384).astype(np.int32))
    b = jnp.asarray(np.sort(rng.integers(0, 1 << 22, 65536)).astype(np.int32))
    for impl in ("ref", "pallas"):
        f = jax.jit(lambda a, b, impl=impl: ops.banded_intersect(
            a, b, 0, implementation=impl, max_tiles=64))
        out[f"banded_intersect_16k_64k_{impl}_us"] = _timeit(f, a, b)

    table = jnp.asarray(rng.normal(size=(100_000, 64)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 100_000, (256, 39)).astype(np.int32))
    for impl in ("ref", "pallas"):
        f = jax.jit(lambda t, i, impl=impl: ops.segment_bag(
            t, i, implementation=impl))
        out[f"segment_bag_256x39_d64_{impl}_us"] = _timeit(f, table, ids)

    q = jnp.asarray(rng.normal(size=(4, 16, 128)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(4, 4096, 8, 128)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(4, 4096, 8, 128)).astype(np.float32))
    kvl = jnp.full((4,), 4096, jnp.int32)
    for impl in ("ref", "pallas"):
        f = jax.jit(lambda q, k, v, kvl, impl=impl: ops.flash_decode(
            q, k, v, kvl, implementation=impl))
        out[f"flash_decode_b4_s4k_{impl}_us"] = _timeit(f, q, k, v, kvl)
    return out


def main():
    for k, v in run().items():
        print(f"kernels.{k},{v:.1f}")


if __name__ == "__main__":
    main()

"""Roofline term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), per the task spec:

    compute    = HLO_FLOPs / (chips * 197e12)          [bf16 peak / chip]
    memory     = HLO_bytes / (chips * 819e9)           [HBM bw / chip]
    collective = collective_bytes / (chips * 50e9)     [ICI link bw]

cost_analysis() reports the per-device SPMD program; we normalize to global
(x chips) so the formulas above apply directly.  collective_bytes is parsed
from the optimized HLO text, with while-loop bodies scaled by their trip
count (recovered from the loop-condition constant — scans have static trip
counts in this framework).
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e)
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(text: str) -> int:
    """Sum sizes of every dtype[shape] group in `text`."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_type: dict
    total_bytes: int
    op_counts: dict


def parse_collectives(hlo: str) -> CollectiveStats:
    """Parse per-device collective bytes from optimized HLO text, scaling
    while-body collectives by loop trip count."""
    # 1. split into computations: headers are column-0 lines ending in "{"
    #    (signatures may contain /*index=N*/ comments, so no "=" heuristics)
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        if line and not line.startswith(" ") and line.rstrip().endswith("{"):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", line.strip())
            if m and not line.startswith("HloModule"):
                cur = m.group(1)
                comps[cur] = []
                continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)

    # 2. while ops: body -> trip count (max s32 constant in the condition)
    body_trip: dict[str, int] = {}
    cond_of_body: dict[str, str] = {}
    for name, lines in comps.items():
        for line in lines:
            m = re.search(r"while\(.*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)", line)
            if not m:
                m2 = re.search(r"body=%?([\w\.\-]+).*?condition=%?([\w\.\-]+)", line)
                if m2:
                    cond_of_body[m2.group(1)] = m2.group(2)
                continue
            cond_of_body[m.group(2)] = m.group(1)
    for body, cond in cond_of_body.items():
        trip = 1
        for line in comps.get(cond, []):
            for c in re.findall(r"constant\((\d+)\)", line):
                trip = max(trip, int(c))
        body_trip[body] = trip

    # 3. multiplier per computation: entry = 1; while bodies multiply
    mult: dict[str, int] = {}

    def resolve(name: str, seen=()) -> int:
        if name in mult:
            return mult[name]
        if name in seen:
            return 1
        m = 1
        # find callers: computations whose while op uses this body
        for caller, lines in comps.items():
            if caller == name:
                continue
            for line in lines:
                if f"body=%{name}" in line or f"body={name}" in line:
                    m = resolve(caller, seen + (name,)) * body_trip.get(name, 1)
                    mult[name] = m
                    return m
                if f"to_apply=%{name}" in line or re.search(
                        rf"calls=%?{re.escape(name)}\b", line):
                    m = resolve(caller, seen + (name,))
                    mult[name] = m
                    return m
        mult[name] = 1
        return 1

    bytes_by_type: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    op_counts: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        k = resolve(name)
        for line in lines:
            if "-done(" in line:
                continue           # async start/done pairs: count start only
            for coll in _COLLECTIVES:
                if re.search(rf"\b{coll}(?:-start)?\(", line) and "=" in line:
                    # output + any printed operand shapes after the opcode
                    inside = line.split(f"{coll}", 1)[1]
                    b = _shape_bytes(inside)
                    if b == 0:
                        b = _shape_bytes(line.split("=", 1)[1].split(coll)[0])
                    bytes_by_type[coll] += b * k
                    op_counts[coll] += k
                    break
    total = sum(bytes_by_type.values())
    return CollectiveStats(bytes_by_type=bytes_by_type, total_bytes=total,
                           op_counts=op_counts)


# ---------------------------------------------------------------------------
# loop-aware HLO cost analysis
# ---------------------------------------------------------------------------
# XLA:CPU cost_analysis() counts while-loop bodies ONCE, so scanned-layer
# models under-report flops/bytes by ~n_layers x.  We re-derive both from the
# HLO text with per-computation multipliers (trip counts from loop-condition
# constants — scans in this framework have static trips).

_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_FREE_OPS = {"bitcast", "tuple", "get-tuple-element", "parameter", "constant",
             "while", "conditional", "after-all", "bitcast-convert"}


def _split_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        if line and not line.startswith(" ") and line.rstrip().endswith("{"):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", line.strip())
            if m and not line.startswith("HloModule"):
                cur = m.group(1)
                comps[cur] = []
                continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps


def _multipliers(comps: dict[str, list[str]]) -> dict[str, int]:
    """Execution-count multiplier per computation (while trips, fusion calls)."""
    cond_of_body: dict[str, str] = {}
    callers: dict[str, list[tuple[str, str]]] = {}   # callee -> [(caller, kind)]
    for name, lines in comps.items():
        for line in lines:
            m = re.search(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)", line)
            if not m:
                m2 = re.search(r"body=%?([\w\.\-]+),\s*condition=%?([\w\.\-]+)", line)
                if m2:
                    cond_of_body[m2.group(1)] = m2.group(2)
                    callers.setdefault(m2.group(1), []).append((name, "while"))
            else:
                cond_of_body[m.group(2)] = m.group(1)
                callers.setdefault(m.group(2), []).append((name, "while"))
            for cm in re.finditer(r"(?:calls|to_apply|condition|true_computation|"
                                  r"false_computation)=%?([\w\.\-]+)", line):
                callee = cm.group(1)
                if callee not in cond_of_body or cond_of_body.get(callee) != callee:
                    callers.setdefault(callee, []).append((name, "call"))

    trip: dict[str, int] = {}
    for body, cond in cond_of_body.items():
        t = 1
        for line in comps.get(cond, []):
            for c in re.findall(r"constant\((\d+)\)", line):
                t = max(t, int(c))
        trip[body] = t

    mult: dict[str, int] = {}

    def resolve(name, depth=0):
        if name in mult or depth > 50:
            return mult.get(name, 1)
        m = 1
        for caller, kind in callers.get(name, [])[:1]:
            base = resolve(caller, depth + 1)
            m = base * (trip.get(name, 1) if kind == "while" else 1)
        mult[name] = m
        return m

    for name in comps:
        resolve(name)
    return mult


def _defs_of(lines: list[str]) -> dict[str, str]:
    defs = {}
    for line in lines:
        m = _OP_RE.match(line)
        if m:
            defs[m.group(1)] = m.group(2)
    return defs


def parse_hlo_costs(hlo: str) -> dict:
    """Loop-scaled (flops, bytes) from optimized HLO text.

    flops: dot ops only (2 * prod(out) * prod(contracted lhs dims)) — matmuls
    dominate every model in this framework; elementwise flops are noise at
    roofline precision.
    bytes: per op, output + resolvable operand bytes; fusion interiors are
    skipped (only the fusion call's operands/outputs touch HBM).
    """
    comps = _split_computations(hlo)
    mult = _multipliers(comps)
    fusion_comps = set()
    for lines in comps.values():
        for line in lines:
            if " fusion(" in line:
                m = re.search(r"calls=%?([\w\.\-]+)", line)
                if m:
                    fusion_comps.add(m.group(1))

    total_flops = 0.0
    total_bytes = 0.0
    for name, lines in comps.items():
        k = mult.get(name, 1)
        defs = _defs_of(lines)
        in_fusion = name in fusion_comps
        for line in lines:
            m = _OP_RE.match(line)
            if not m:
                continue
            _, out_type, opcode = m.groups()
            if opcode == "dot":
                args = line.split("dot(", 1)[1]
                ops = re.findall(r"%([\w\.\-]+)", args.split(")")[0])
                cdim = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
                flop = 0.0
                if ops and cdim is not None and ops[0] in defs:
                    lhs_dims = _SHAPE_RE.findall(defs[ops[0]])
                    if lhs_dims:
                        dims = [int(d) for d in lhs_dims[0][1].split(",") if d]
                        csz = 1
                        for ci in cdim.group(1).split(","):
                            if ci != "" and int(ci) < len(dims):
                                csz *= dims[int(ci)]
                        out_elems = 1
                        for _, dd in _SHAPE_RE.findall(out_type):
                            for d in dd.split(","):
                                if d:
                                    out_elems *= int(d)
                            break
                        flop = 2.0 * out_elems * csz
                total_flops += flop * k
            if in_fusion or opcode in _FREE_OPS:
                continue
            b = _shape_bytes(out_type)
            args = line.split("(", 1)[1] if "(" in line else ""
            refs = re.findall(r"%([\w\.\-]+)", args.split("), ")[0])[:8]
            if opcode in ("gather", "dynamic-slice"):
                # a gather reads output-many rows + indices, not the table
                refs = refs[1:]
                b *= 2
            elif opcode in ("scatter", "dynamic-update-slice"):
                refs = refs[1:]          # in-place update: skip the operand
                b *= 2
            for ref in refs:
                if ref in defs:
                    b += _shape_bytes(defs[ref])
            total_bytes += b * k
    return {"flops": total_flops, "bytes": total_bytes}


# ---------------------------------------------------------------------------
# analytic model FLOPs (6ND-style bookkeeping per family)
# ---------------------------------------------------------------------------

def lm_model_flops(meta: dict, kind: str) -> float:
    Np = meta["active_params"]
    V_D = 0   # embedding gather has no flops but is inside param_count once
    B, S, Lr = meta["global_batch"], meta["seq_len"], meta["n_layers"]
    Hq, hd = meta["n_heads"], meta["hd"]
    if kind == "train":
        dense = 6.0 * Np * B * S
        attn = 3 * 2.0 * B * S * S * Hq * hd * Lr   # causal half, fwd+bwd(2x)
        return dense + attn
    if kind == "prefill":
        return 2.0 * Np * B * S + 2.0 * B * S * S * Hq * hd * Lr
    # decode: one token
    return 2.0 * Np * B + 4.0 * B * S * Hq * hd * Lr


def gnn_model_flops(meta: dict) -> float:
    N, E = meta["n_nodes"], meta["n_edges"]
    d, L, f = meta["d_hidden"], meta["n_layers"], meta["d_feat"]
    agg = 2.0 * E * d * L
    mlp = 2.0 * N * (f * d + d * d) + (L - 1) * 2.0 * N * (d * d * 2)
    return 3.0 * (agg + mlp)     # train fwd+bwd


def recsys_model_flops(meta: dict, kind: str) -> float:
    B = meta.get("n_candidates", meta["batch"]) if kind == "retrieval" else meta["batch"]
    d, F = meta["embed_dim"], meta["n_fields"]
    model = meta["model"]
    if model == "fm":
        core = 4.0 * B * F * d
    elif model == "autoint":
        core = B * (3 * 2.0 * F * d * 64 + 4.0 * F * F * 64) * 3
    elif model == "bst":
        core = B * (21 * (4 * 2.0 * 32 * 32 + 2 * 2.0 * 32 * 128)
                    + 4.0 * 21 * 21 * 32) + B * 2.0 * 1500 * 1000
    else:  # mind
        core = B * 3 * (2.0 * 50 * d * d + 4.0 * 4 * 50 * d)
    mult = 3.0 if kind == "train" else 1.0
    return core * mult


def search_model_bytes(meta: dict) -> float:
    """The search step is memory-bound: useful bytes = postings streamed.

    Since the packed-postings refactor a gathered posting streams ~40 bits
    of bit-packed doc/pos/dist lanes plus its 1/128 share of the per-block
    anchor/width metadata (≈ 5.2 B) instead of the raw 9-byte int32/int8
    columns."""
    Q, G, Pp = meta["queries"], meta["groups"], meta["postings_pad"]
    per_shard = Q * G * Pp * 5.2 + Q * meta.get("ns_k", 20) * Pp * 4
    return float(per_shard * meta["n_shards"])


def model_flops_for(cell_meta: dict, family: str, kind: str) -> float:
    if family == "lm":
        return lm_model_flops(cell_meta, kind)
    if family == "gnn":
        return gnn_model_flops(cell_meta)
    if family == "recsys":
        return recsys_model_flops(cell_meta, kind)
    if family == "search":
        # compare+search ops over the gathered postings (small by design)
        Q, G, Pp = cell_meta["queries"], cell_meta["groups"], cell_meta["postings_pad"]
        import math
        return float(Q * (G - 1) * Pp * 2 * max(math.log2(Pp), 1)
                     * cell_meta["n_shards"])
    return 0.0


# ---------------------------------------------------------------------------

def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float, chips: int) -> dict:
    flops = flops_per_dev * chips
    mem = bytes_per_dev * chips
    coll = coll_bytes_per_dev * chips
    t_c = flops / (chips * PEAK_FLOPS)
    t_m = mem / (chips * HBM_BW)
    t_l = coll / (chips * LINK_BW)
    dom = max((t_c, "compute"), (t_m, "memory"), (t_l, "collective"))
    return {"hlo_flops_global": flops, "hlo_bytes_global": mem,
            "collective_bytes_global": coll,
            "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_l,
            "dominant": dom[1], "t_dominant_s": dom[0]}

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver for the paper's serve cell (veretennikov).

Sweeps the serve-step variants and reports the three roofline terms per
variant.  Usage: PYTHONPATH=src python -m benchmarks.perf_search
"""
import dataclasses
import sys

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from benchmarks import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.serve import search_serve as ss


def measure(cfg, mesh):
    n_dp = mesh.shape["data"] * (mesh.shape["pod"] if "pod" in mesh.axis_names else 1)
    arenas = ss.arena_specs(cfg, n_dp)
    queries = ss.query_table_specs(cfg)
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    a_sh = {k: NamedSharding(mesh, P(dp)) for k in arenas}
    q_sh = {k: NamedSharding(mesh, P()) for k in queries}
    step = ss.make_search_serve_step(cfg, mesh)
    with mesh:
        compiled = jax.jit(step, in_shardings=(a_sh, q_sh)).lower(
            arenas, queries).compile()
    hlo = compiled.as_text()
    coll = rl.parse_collectives(hlo)
    looped = rl.parse_hlo_costs(hlo)
    terms = rl.roofline_terms(looped["flops"], looped["bytes"],
                              float(coll.total_bytes), mesh.size)
    return terms


def main():
    mesh = make_production_mesh(multi_pod=False)
    variants = [
        ("baseline (P0=32k, F=8)", dict(seed_pad=0)),
        ("seed_pad=8k", dict(seed_pad=8192)),
        ("seed_pad=4k", dict(seed_pad=4096)),
        ("seed_pad=2k", dict(seed_pad=2048)),
        ("seed4k + F=4", dict(seed_pad=4096, fetch_slots=4)),
        ("seed4k + F=4 + G=4", dict(seed_pad=4096, fetch_slots=4, groups=4)),
        ("seed4k + P=16k", dict(seed_pad=4096, postings_pad=16384)),
    ]
    for name, kw in variants:
        cfg = dataclasses.replace(ss.SearchServeConfig(), **kw)
        t = measure(cfg, mesh)
        print(f"{name:28s} mem={t['t_memory_s']*1e3:8.2f} ms  "
              f"coll={t['t_collective_s']*1e3:6.3f} ms  "
              f"compute={t['t_compute_s']*1e3:6.3f} ms  dom={t['dominant']}")


if __name__ == "__main__":
    main()

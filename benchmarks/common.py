"""Shared benchmark world: a corpus + built indexes at a configurable scale.

Default scale keeps the full benchmark run in minutes on one CPU core while
preserving the paper's regime (Zipf tiers, multi-form words, stop mass).
The paper's absolute scale (45 GB, 130k docs) is exercised structurally by
the dry-run arenas; latency/postings ratios are scale-stable (they depend on
posting-list length ratios, not corpus size).
"""
from __future__ import annotations

import functools

import numpy as np

from repro.core import (AdditionalIndexEngine, CorpusConfig, IndexParams,
                        LexiconConfig, OrdinaryEngine, build_all,
                        generate_corpus, make_lexicon_and_analyzer)


@functools.lru_cache(maxsize=2)
def bench_world(n_docs: int = 1200, mean_doc_len: float = 800.0, seed: int = 0,
                stop_mass: float | None = None):
    """`stop_mass` re-weights the Zipf draw to a target stop-token share
    (corpus.CorpusConfig.stop_mass) — the synthetic default lands at ~64%,
    real running text nearer 40%, and every additional-index-over-corpus
    ratio scales with it (the index-size benchmark's realistic mode)."""
    lc = LexiconConfig(seed=seed)         # 50k surface / 40k base / 700 / 2100
    lex, ana = make_lexicon_and_analyzer(lc)
    stop_mask = None
    if stop_mass is not None:
        import numpy as _np
        sec = ana.secondary
        stop_mask = _np.asarray(lex.is_stop(ana.primary)
                                | ((sec >= 0) & lex.is_stop(_np.maximum(sec, 0))))
    corpus = generate_corpus(lc, CorpusConfig(n_docs=n_docs,
                                              mean_doc_len=mean_doc_len,
                                              seed=seed, stop_mass=stop_mass),
                             stop_mask=stop_mask)
    index = build_all(corpus, lex, ana, IndexParams())
    return {"lex": lex, "ana": ana, "corpus": corpus, "index": index,
            "engine": AdditionalIndexEngine(index),
            "ordinary": OrdinaryEngine(index)}


def paper_query_stream(corpus, n_queries: int, seed: int = 1):
    """The paper's experiment procedure (STRUCTURE OF SEARCH EXPERIMENTS):
    random indexed document; 2.1 = consecutive words, 2.2 = every other
    word; 3..5 words per query."""
    rng = np.random.default_rng(seed)
    out = []
    while len(out) < n_queries:
        d = int(rng.integers(corpus.n_docs))
        toks = corpus.doc(d)
        n = int(rng.integers(3, 6))
        if len(toks) < 2 * n + 2:
            continue
        st = int(rng.integers(0, len(toks) - 2 * n))
        out.append((toks[st:st + n].tolist(), "phrase", d))
        if len(out) < n_queries:
            out.append((toks[st:st + 2 * n:2].tolist(), "near", d))
    return out


def kword_query_stream(world, n_queries: int, seed: int = 3,
                       wide_frac: float = 0.1):
    """Stop-heavy K-word proximity workload (arXiv:2009.02684): K in {3,4,5}
    word sets sampled from indexed documents at strides 1..3, ~70% with an
    explicit stop-surface injection, window sized to cover the sampled span
    (plus jitter).  `wide_frac` of the queries get windows beyond the device
    executors' int32 delta masks (W > 15) to keep the flexible escape path
    measured.  Yields (surface_ids, window, source_doc) triples."""
    corpus = world["corpus"]
    lex, ana = world["lex"], world["ana"]
    rng = np.random.default_rng(seed)
    stop_surfaces = [s for s in range(400)
                     if bool(lex.is_stop(np.asarray(ana.forms_of(s))).any())][:8]
    out = []
    while len(out) < n_queries:
        d = int(rng.integers(corpus.n_docs))
        toks = corpus.doc(d)
        k = int(rng.integers(3, 6))
        stride = int(rng.integers(1, 4))
        span = stride * (k - 1) + 1
        if len(toks) <= span:
            continue
        st = int(rng.integers(0, len(toks) - span))
        q = toks[st:st + span:stride].tolist()
        if rng.random() < 0.7:
            q[int(rng.integers(k))] = int(rng.choice(stop_surfaces))
        if rng.random() < wide_frac:
            window = 16 + int(rng.integers(0, 16))      # flex-only range
        else:
            window = min(span - 1 + int(rng.integers(0, 4)), 15)
        out.append((q, max(window, 2), d))
    return out

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver for gin-tu / ogb_products (collective-bound).

Variants: baseline (f32 messages), bf16 messages, halo (boundary-only
exchange — measured separately via the shard_map path in models/gnn.py).
"""
import dataclasses
import functools
import sys

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from benchmarks import roofline as rl
from repro.configs.registry import get_arch
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell


def measure_cell(arch, shape, mesh):
    cell = build_cell(arch, shape, mesh)
    with mesh:
        compiled = jax.jit(cell.step, in_shardings=cell.in_shardings,
                           out_shardings=cell.out_shardings,
                           donate_argnums=cell.donate).lower(*cell.in_specs).compile()
    hlo = compiled.as_text()
    coll = rl.parse_collectives(hlo)
    looped = rl.parse_hlo_costs(hlo)
    terms = rl.roofline_terms(looped["flops"], looped["bytes"],
                              float(coll.total_bytes), mesh.size)
    mem = compiled.memory_analysis()
    return terms, coll, mem


def measure_halo(mesh, n_nodes=2_449_029, n_edges=61_859_140, d_feat=100,
                 n_classes=47, boundary_frac=1.0, edge_imbalance=1.3):
    """Structural dry-run of the halo-exchange GIN train step at ogb scale.

    boundary_frac = B / Nl (1.0 = worst case: every local node is boundary;
    locality-aware partitions measured on scaled graphs reach ~0.6)."""
    from repro.compat import shard_map
    from repro.models import gnn
    from repro.train import optimizer as opt
    from repro.launch.steps import OPT_CFG

    S = mesh.shape["data"] * mesh.shape["model"] * \
        (mesh.shape["pod"] if "pod" in mesh.axis_names else 1)
    Nl = (n_nodes + S - 1) // S
    El = int(n_edges / S * edge_imbalance)
    B = max(int(Nl * boundary_frac), 1)
    cfg = dataclasses.replace(get_arch("gin-tu").make_config(),
                              d_feat=d_feat, n_classes=n_classes,
                              message_dtype=jnp.bfloat16)
    params_struct = jax.eval_shape(
        functools.partial(gnn.init_params, cfg), jax.random.PRNGKey(0))
    f32, i32 = jnp.float32, jnp.int32
    shard_struct = {
        "nodes": jax.ShapeDtypeStruct((S, Nl, d_feat), f32),
        "src": jax.ShapeDtypeStruct((S, El), i32),
        "dst": jax.ShapeDtypeStruct((S, El), i32),
        "edge_mask": jax.ShapeDtypeStruct((S, El), jnp.bool_),
        "labels": jax.ShapeDtypeStruct((S, Nl), i32),
        "label_mask": jax.ShapeDtypeStruct((S, Nl), jnp.bool_),
        "send_idx": jax.ShapeDtypeStruct((S, B), i32),
    }
    opt_struct = jax.eval_shape(
        functools.partial(opt.init_state, OPT_CFG), params_struct)
    axes = tuple(mesh.axis_names)

    def local_step(params, opt_state, shard):
        def loss(p):
            return gnn.halo_loss_fn(cfg, p, shard, axis_name=axes)
        (l, m), grads = jax.value_and_grad(loss, has_aux=True)(params)
        grads = jax.tree_util.tree_map(
            lambda g: jax.lax.psum(g, axes) / S, grads)
        new_p, new_o, om = opt.apply_updates(OPT_CFG, params, grads, opt_state)
        return new_p, new_o, dict(m, loss=l, **om)

    fn = shard_map(local_step, mesh=mesh,
                   in_specs=(P(), P(), {k: P(axes) for k in shard_struct}),
                   out_specs=(P(), P(), P()), check_vma=False)
    rep = NamedSharding(mesh, P())
    p_sh = jax.tree_util.tree_map(lambda _: rep, params_struct)
    o_sh = jax.tree_util.tree_map(lambda _: rep, opt_struct)
    s_sh = {k: NamedSharding(mesh, P(axes)) for k in shard_struct}
    with mesh:
        compiled = jax.jit(fn, in_shardings=(p_sh, o_sh, s_sh)).lower(
            params_struct, opt_struct, shard_struct).compile()
    hlo = compiled.as_text()
    coll = rl.parse_collectives(hlo)
    looped = rl.parse_hlo_costs(hlo)
    terms = rl.roofline_terms(looped["flops"], looped["bytes"],
                              float(coll.total_bytes), mesh.size)
    return terms, coll


def main():
    mesh = make_production_mesh(multi_pod=False)
    import repro.configs.gin_tu as gin_cfg
    base_make = gin_cfg.make_config

    def report(tag):
        terms, coll, mem = measure_cell("gin-tu", "ogb_products", mesh)
        print(f"{tag:30s} coll={terms['t_collective_s']*1e3:8.2f} ms "
              f"mem={terms['t_memory_s']*1e3:8.2f} ms "
              f"compute={terms['t_compute_s']*1e3:6.3f} ms "
              f"bytes={ {k: round(v/1e9,2) for k,v in coll.bytes_by_type.items() if v} }")

    report("baseline f32 messages")

    gin_cfg.SPEC = dataclasses.replace(
        gin_cfg.SPEC, make_config=lambda: dataclasses.replace(
            base_make(), message_dtype=jnp.bfloat16))
    report("bf16 messages (SPMD)")

    for bf, tag in ((1.0, "halo worst-case B=Nl"), (0.6, "halo B=0.6*Nl")):
        terms, coll = measure_halo(mesh, boundary_frac=bf)
        print(f"{tag:30s} coll={terms['t_collective_s']*1e3:8.2f} ms "
              f"mem={terms['t_memory_s']*1e3:8.2f} ms "
              f"compute={terms['t_compute_s']*1e3:6.3f} ms "
              f"bytes={ {k: round(v/1e9,2) for k,v in coll.bytes_by_type.items() if v} }")


if __name__ == "__main__":
    main()

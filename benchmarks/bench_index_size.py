"""Paper table: SIZE OF THE INDEXES.

Reports bytes for each additional index and the ordinary index, plus the
ratios the paper's claim rests on (total additional-index size vs corpus,
~5.7x in the paper at 259 GB / 45 GB) — and the two size dials from the
ROADMAP:

* triples gated to common (s1, s2) stop pairs
  (IndexParams.triple_pair_min_count; the planner answers gated pairs with
  two two-component lookups, semantics identical);
* the packed block store (core/postings.PackedPostings): per-stream
  raw-column vs bit-packed device bytes for the ordinary / expanded /
  multi-key pair / multi-key triple streams — the bytes the executors
  actually hold on device since the packed-postings refactor;
* `--realistic-stops`: re-weight the Zipf draw to a ~40% stop-token share
  (real running text; the synthetic default is ~64%) so the
  additional-over-corpus ratios are comparable to the paper's 5.76x.

`--write-json` merges the report into BENCH_search.json under "index_size"
(the search-speed benchmark preserves that block when it rewrites the file),
which is what the CI index-bytes regression gate reads.
"""
from __future__ import annotations

import json

from benchmarks.common import bench_world

TRIPLE_GATE_MIN_COUNT = 64     # "common pair" threshold for the gated build
REALISTIC_STOP_MASS = 0.40     # ~running-text stop-token share


def run_triple_gate(w, min_count: int = TRIPLE_GATE_MIN_COUNT) -> dict:
    """Rebuild ONLY the multi-key index with triples gated to (s1, s2)
    pairs holding >= min_count postings; report the size delta."""
    import dataclasses

    from repro.core import build_multi_key_index
    from repro.core.builder import expand_token_forms
    idx, corpus = w["index"], w["corpus"]
    tf = expand_token_forms(corpus, idx.lexicon, idx.analyzer)
    params = dataclasses.replace(idx.params, triple_pair_min_count=min_count)
    gated = build_multi_key_index(tf, idx.lexicon, params)
    full_b, gated_b = idx.multi_key.nbytes(), gated.nbytes()
    return {
        "triple_gate_min_count": min_count,
        "multi_key_gated_bytes": gated_b,
        "multi_key_gated_packed_bytes": gated.packed_nbytes(),
        "multi_key_gated_triple_postings": gated.n_triple_postings,
        "multi_key_gated_admitted_pairs": int(len(gated.triple_stop_pairs)),
        "multi_key_gate_bytes_saved": full_b - gated_b,
        "multi_key_gate_shrink": (full_b - gated_b) / max(full_b, 1),
    }


def run_neighbor_distance(w, nd: int = 4) -> dict:
    """Rebuild ONLY the multi-key index at a smaller NeighborDistance (the
    IndexParams.neighbor_distance dial, decoupled from near_window) and
    report the byte delta.  Near windows wider than ND fall back to banded
    full ordinary-index reads (planner guard) — recall is parity-tested in
    tests/test_multi_key.py."""
    import dataclasses

    from repro.core import build_multi_key_index
    from repro.core.builder import expand_token_forms
    idx, corpus = w["index"], w["corpus"]
    tf = expand_token_forms(corpus, idx.lexicon, idx.analyzer)
    params = dataclasses.replace(idx.params, neighbor_distance=nd)
    small = build_multi_key_index(tf, idx.lexicon, params)
    full_b = idx.multi_key.nbytes()
    return {
        "neighbor_distance": nd,
        "multi_key_nd_bytes": small.nbytes(),
        "multi_key_nd_packed_bytes": small.packed_nbytes(),
        "multi_key_nd_pair_postings": small.n_pair_postings,
        "multi_key_nd_triple_postings": small.n_triple_postings,
        "multi_key_nd_shrink": (full_b - small.nbytes()) / max(full_b, 1),
    }


# the four streams the packed-store acceptance tracks (ISSUE 5), plus the
# rest of the arena for completeness
PACKED_STREAMS = ("ordinary", "expanded", "multi_key_pair",
                  "multi_key_triple", "basic", "stop_phrase")


def run(n_docs: int = 1200, stop_mass: float | None = None,
        dials: bool = True) -> dict:
    """`dials=False` skips the triple-gate / neighbor-distance rebuild
    sub-reports (used for the secondary realistic-stop-density block)."""
    w = bench_world(n_docs, stop_mass=stop_mass)
    idx = w["index"]
    corpus = w["corpus"]
    rep = idx.size_report()
    corpus_bytes = int(corpus.n_tokens) * 6     # ~6 bytes/token as stored text
    rows = {
        "stop_phrase_index_bytes": rep["stop_phrase_index_bytes"],
        "expanded_index_bytes": rep["expanded_index_bytes"],
        "multi_key_index_bytes": rep["multi_key_index_bytes"],
        "basic_index_bytes": rep["basic_index_bytes"],
        "additional_total_bytes": (rep["stop_phrase_index_bytes"]
                                   + rep["expanded_index_bytes"]
                                   + rep["multi_key_index_bytes"]
                                   + rep["basic_index_bytes"]),
        "ordinary_index_bytes": rep["ordinary_index_bytes"],
        "corpus_bytes_est": corpus_bytes,
        "n_tokens": int(corpus.n_tokens),
        "n_docs": corpus.n_docs,
        "stop_phrase_postings": rep["stop_phrase_postings"],
        "expanded_postings": rep["expanded_postings"],
        "multi_key_pair_postings": rep["multi_key_pair_postings"],
        "multi_key_triple_postings": rep["multi_key_triple_postings"],
        "basic_postings": rep["basic_postings"],
        "ordinary_postings": rep["ordinary_postings"],
    }
    if stop_mass is not None:
        rows["stop_mass_target"] = stop_mass
        from repro.core.builder import expand_token_forms
        tf = expand_token_forms(corpus, idx.lexicon, idx.analyzer)
        rows["stop_token_share"] = float(tf.stop_mask.mean())
    # raw-vs-packed device bytes per stream (the packed block store)
    for s in PACKED_STREAMS:
        raw, packed = rep[f"{s}_col_bytes"], rep[f"{s}_packed_bytes"]
        rows[f"{s}_col_bytes"] = raw
        rows[f"{s}_packed_bytes"] = packed
        rows[f"{s}_pack_ratio"] = raw / max(packed, 1)
    rows["multi_key_packed_bytes"] = rep["multi_key_packed_bytes"]
    # the acceptance ratio: full raw CSR (keys + offsets + columns) vs the
    # bytes the device now holds for the same streams
    rows["multi_key_index_over_packed"] = \
        rows["multi_key_index_bytes"] / max(rep["multi_key_packed_bytes"], 1)
    rows["expanded_index_over_packed"] = \
        rows["expanded_index_bytes"] / max(rep["expanded_packed_bytes"], 1)
    rows["additional_over_corpus"] = rows["additional_total_bytes"] / corpus_bytes
    rows["multi_key_over_corpus"] = rows["multi_key_index_bytes"] / corpus_bytes
    rows["multi_key_packed_over_corpus"] = \
        rep["multi_key_packed_bytes"] / corpus_bytes
    rows["ordinary_over_corpus"] = rows["ordinary_index_bytes"] / corpus_bytes
    rows["paper_additional_over_corpus"] = 259.0 / 45.0      # 5.76x
    rows["paper_ordinary_over_corpus"] = 18.7 / 45.0         # Sphinx 0.42x
    if dials:
        rows.update(run_triple_gate(w))
        rows["multi_key_gated_over_corpus"] = \
            rows["multi_key_gated_bytes"] / corpus_bytes
        rows.update(run_neighbor_distance(w))
    return rows


def write_json(rows: dict) -> None:
    """Merge the report into BENCH_search.json under "index_size" (preserving
    the search-speed fields; bench_search_speed preserves this block in
    return)."""
    from benchmarks.bench_search_speed import BENCH_JSON
    try:
        with open(BENCH_JSON) as fh:
            data = json.load(fh)
    except (OSError, ValueError):
        data = {}
    data["index_size"] = rows
    with open(BENCH_JSON, "w") as fh:
        json.dump(data, fh, indent=2, sort_keys=True)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=1200)
    ap.add_argument("--realistic-stops", action="store_true",
                    help="re-weight the Zipf draw to a ~40%% stop-token "
                         "share (real-text regime; ratios comparable to the "
                         "paper's 5.76x)")
    ap.add_argument("--write-json", action="store_true",
                    help="merge the report into BENCH_search.json under "
                         "'index_size'")
    args = ap.parse_args()
    rows = run(n_docs=args.docs,
               stop_mass=REALISTIC_STOP_MASS if args.realistic_stops else None)
    if args.write_json:
        if not args.realistic_stops:
            # record the real-text-regime ratios alongside (ratios only —
            # the dials sub-reports stay on the primary corpus)
            rows = dict(rows, realistic=run(
                n_docs=args.docs, stop_mass=REALISTIC_STOP_MASS, dials=False))
        write_json(rows)

    def emit(prefix, d):
        for k, v in d.items():
            if isinstance(v, dict):
                emit(f"{prefix}.{k}", v)
            else:
                print(f"{prefix}.{k},{v:.4g}" if isinstance(v, float)
                      else f"{prefix}.{k},{v}")
    emit("index_size", rows)


if __name__ == "__main__":
    main()

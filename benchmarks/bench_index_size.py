"""Paper table: SIZE OF THE INDEXES.

Reports bytes for each additional index and the ordinary index, plus the
ratios the paper's claim rests on (total additional-index size vs corpus,
~5.7x in the paper at 259 GB / 45 GB) — and the multi-key size dial from
the ROADMAP: triples gated to common (s1, s2) stop pairs
(IndexParams.triple_pair_min_count; the planner answers gated pairs with
two two-component lookups, semantics identical), with the byte/posting
delta the gate buys."""
from __future__ import annotations

from benchmarks.common import bench_world

TRIPLE_GATE_MIN_COUNT = 64     # "common pair" threshold for the gated build


def run_triple_gate(w, min_count: int = TRIPLE_GATE_MIN_COUNT) -> dict:
    """Rebuild ONLY the multi-key index with triples gated to (s1, s2)
    pairs holding >= min_count postings; report the size delta."""
    import dataclasses

    from repro.core import build_multi_key_index
    from repro.core.builder import expand_token_forms
    idx, corpus = w["index"], w["corpus"]
    tf = expand_token_forms(corpus, idx.lexicon, idx.analyzer)
    params = dataclasses.replace(idx.params, triple_pair_min_count=min_count)
    gated = build_multi_key_index(tf, idx.lexicon, params)
    full_b, gated_b = idx.multi_key.nbytes(), gated.nbytes()
    return {
        "triple_gate_min_count": min_count,
        "multi_key_gated_bytes": gated_b,
        "multi_key_gated_triple_postings": gated.n_triple_postings,
        "multi_key_gated_admitted_pairs": int(len(gated.triple_stop_pairs)),
        "multi_key_gate_bytes_saved": full_b - gated_b,
        "multi_key_gate_shrink": (full_b - gated_b) / max(full_b, 1),
    }


def run(n_docs: int = 1200) -> dict:
    w = bench_world(n_docs)
    idx = w["index"]
    corpus = w["corpus"]
    rep = idx.size_report()
    corpus_bytes = int(corpus.n_tokens) * 6     # ~6 bytes/token as stored text
    rows = {
        "stop_phrase_index_bytes": rep["stop_phrase_index_bytes"],
        "expanded_index_bytes": rep["expanded_index_bytes"],
        "multi_key_index_bytes": rep["multi_key_index_bytes"],
        "basic_index_bytes": rep["basic_index_bytes"],
        "additional_total_bytes": (rep["stop_phrase_index_bytes"]
                                   + rep["expanded_index_bytes"]
                                   + rep["multi_key_index_bytes"]
                                   + rep["basic_index_bytes"]),
        "ordinary_index_bytes": rep["ordinary_index_bytes"],
        "corpus_bytes_est": corpus_bytes,
        "n_tokens": int(corpus.n_tokens),
        "n_docs": corpus.n_docs,
        "stop_phrase_postings": rep["stop_phrase_postings"],
        "expanded_postings": rep["expanded_postings"],
        "multi_key_pair_postings": rep["multi_key_pair_postings"],
        "multi_key_triple_postings": rep["multi_key_triple_postings"],
        "basic_postings": rep["basic_postings"],
        "ordinary_postings": rep["ordinary_postings"],
    }
    rows["additional_over_corpus"] = rows["additional_total_bytes"] / corpus_bytes
    rows["multi_key_over_corpus"] = rows["multi_key_index_bytes"] / corpus_bytes
    rows["ordinary_over_corpus"] = rows["ordinary_index_bytes"] / corpus_bytes
    rows["paper_additional_over_corpus"] = 259.0 / 45.0      # 5.76x
    rows["paper_ordinary_over_corpus"] = 18.7 / 45.0         # Sphinx 0.42x
    rows.update(run_triple_gate(w))
    rows["multi_key_gated_over_corpus"] = \
        rows["multi_key_gated_bytes"] / corpus_bytes
    return rows


def main():
    for k, v in run().items():
        print(f"index_size.{k},{v:.4g}" if isinstance(v, float) else f"index_size.{k},{v}")


if __name__ == "__main__":
    main()

"""End-to-end serving driver, now through the serving front door
(serve/front.py): individual SearchRequests are admitted, coalesced into
deadline-bounded micro-batches, routed to shape buckets, fanned out over
replicated document shards (dist/fault_tolerance.ShardDispatcher), and
merged bit-identically to `engine.search_batch` — with explicit
SERVED_EXACT / SERVED_DEGRADED / SHED statuses instead of silent failure
when shards die.

    PYTHONPATH=src python examples/search_serve.py
"""
import numpy as np

from repro.core import (AdditionalIndexEngine, CorpusConfig, LexiconConfig,
                        MODE_NEAR, SearchRequest, build_all, generate_corpus,
                        make_lexicon_and_analyzer)
from repro.dist.chaos import ChaosShard
from repro.serve import FrontDoor, FrontDoorConfig, build_doc_shards


def main():
    lex_cfg = LexiconConfig(n_surface=20_000, n_base=15_000, n_stop=400,
                            n_frequent=1200, seed=0)
    lex, ana = make_lexicon_and_analyzer(lex_cfg)
    corpus = generate_corpus(lex_cfg, CorpusConfig(n_docs=300, seed=0))
    index = build_all(corpus, lex, ana)
    engine = AdditionalIndexEngine(index)

    # two replicated document shards behind the front door; generous
    # timeouts so first-call jit compiles never read as stragglers
    backends, replicas = build_doc_shards(corpus, index, 2, replicate=True)
    chaos = [ChaosShard(b) for b in backends]
    front = FrontDoor(index, backends=chaos, replicas=replicas,
                      cfg=FrontDoorConfig(default_deadline_ms=600_000.0,
                                          shard_timeout_s=120.0,
                                          retry_backoff_ms=5.0))

    # individual queries from indexed documents — the front door does the
    # batching, not the client
    rng = np.random.default_rng(0)
    requests = []
    while len(requests) < 16:
        d = int(rng.integers(corpus.n_docs))
        toks = corpus.doc(d)
        if len(toks) < 10:
            continue
        st = int(rng.integers(len(toks) - 6))
        requests.append(SearchRequest(toks[st:st + 3].tolist()))

    tickets = [front.submit(r, client="example") for r in requests]
    results = [t.result() for t in tickets]
    st = front.stats
    print(f"front door: {st.submitted} submitted -> {st.served_exact} exact "
          f"in {st.batches} micro-batches, p99 {st.percentile(99):.1f} ms")
    for i in range(4):
        r = results[i]
        pairs = list(zip(r.doc.tolist(), r.pos.tolist()))
        print(f"  q{i} {list(requests[i].surface_ids)}: {r.status}, "
              f"shards {r.shards}, {len(r.doc)} hits, first: {pairs[:4]}")

    # SERVED_EXACT must agree with the engine bit-for-bit — including the
    # postings accounting, despite the doc-sharded backends
    wants = engine.search_batch(requests)
    assert all(np.array_equal(w.doc, r.doc) and np.array_equal(w.pos, r.pos)
               and w.postings_read == r.postings_read
               for w, r in zip(wants, results))
    print("front == engine.search_batch on all queries")

    # a repeated query is a plan-signature cache hit
    again = front.search(requests[0], client="example")
    assert again.cached and again.status == "SERVED_EXACT"
    print(f"cache: repeat query served from cache "
          f"({front.stats.cache_hits} hit)")

    # ranked serving through the same door: proximity-scored top-k DocHits,
    # bit-identical to the engine's ranked batch
    ranked_reqs = [SearchRequest(r.surface_ids, mode=MODE_NEAR, rank=True,
                                 top_k=3) for r in requests[:4]]
    ranked = front.search_batch(ranked_reqs, client="example")
    ranked_eng = engine.search_batch(ranked_reqs)
    assert all(np.array_equal(w.doc_ids, g.doc_ids)
               and np.array_equal(w.doc_scores, g.doc_scores)
               for w, g in zip(ranked_eng, ranked))
    print("ranked front == ranked engine; sample top-k:")
    for req, r in zip(ranked_reqs, ranked[:2]):
        print(f"  {list(req.surface_ids)}: "
              f"{[(h.doc, round(h.score, 3)) for h in r.hits]}")

    # kill a primary: the replica absorbs the re-dispatch, still EXACT
    # (a FRESH query — a repeat would be a cache hit and dodge the shards)
    chaos[1].set(fail=True)
    toks = corpus.doc(7)
    fresh = SearchRequest(toks[4:7].tolist())
    rescued = front.search(fresh, client="example")
    assert rescued.status == "SERVED_EXACT"
    print(f"replica rescue: primary 1 down, replica answered "
          f"({front.dispatcher.stats.redispatched} re-dispatched) -> "
          f"{rescued.status}")
    chaos[1].set()
    front.close()


if __name__ == "__main__":
    main()

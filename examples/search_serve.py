"""End-to-end serving driver: batched phrase queries through the tensorized
serve step (the same step the multi-pod dry-run lowers at 512 chips), with
straggler-mitigating dispatch across simulated document shards.

    PYTHONPATH=src python examples/search_serve.py
"""
import time

import jax
import numpy as np

from repro.core import (AdditionalIndexEngine, CorpusConfig, LexiconConfig,
                        build_all, generate_corpus, make_lexicon_and_analyzer)
from repro.core.planner import MODE_PHRASE
from repro.dist.fault_tolerance import ShardDispatcher, merge_topk
from repro.launch.mesh import make_host_mesh
from repro.serve.search_serve import (SENT32, SERVE_BIAS, SERVE_POS_BITS,
                                      SearchServeConfig, build_arenas,
                                      make_search_serve_step, tensorize_plans)


def main():
    lex_cfg = LexiconConfig(n_surface=20_000, n_base=15_000, n_stop=400,
                            n_frequent=1200, seed=0)
    lex, ana = make_lexicon_and_analyzer(lex_cfg)
    corpus = generate_corpus(lex_cfg, CorpusConfig(n_docs=300, seed=0))
    index = build_all(corpus, lex, ana)
    engine = AdditionalIndexEngine(index)

    cfg = SearchServeConfig(
        queries=16, groups=4, postings_pad=8192, top_m=64,
        n_basic=index.basic.occurrences.n_postings,
        n_expanded=index.expanded.pairs.n_postings,
        n_stop=index.stop_phrase.phrases.n_postings)
    arenas, bases = build_arenas(index, cfg)
    mesh = make_host_mesh(data=1, model=1)
    step = jax.jit(make_search_serve_step(cfg, mesh))

    # query batch from indexed documents
    rng = np.random.default_rng(0)
    plans, queries = [], []
    while len(plans) < cfg.queries:
        d = int(rng.integers(corpus.n_docs))
        toks = corpus.doc(d)
        if len(toks) < 10:
            continue
        st = int(rng.integers(len(toks) - 6))
        q = toks[st:st + 3].tolist()
        plan = engine.plan(q, mode=MODE_PHRASE)
        sp = plan.subplans[0]
        if sp.supported and all(len(g.fetches) >= 1 for g in sp.groups):
            plans.append(plan)
            queries.append(q)

    tables = tensorize_plans(cfg, plans, stream_bases=bases)
    tables = {k: jax.numpy.asarray(v) for k, v in tables.items()}
    with mesh:
        t0 = time.perf_counter()
        hits, counts = step(arenas, tables)
        jax.block_until_ready(hits)
        dt = time.perf_counter() - t0
    print(f"serve_step: {cfg.queries} queries in {dt*1e3:.1f} ms "
          f"({dt/cfg.queries*1e3:.2f} ms/query)")
    for i in range(4):
        hs = [(int(h) >> SERVE_POS_BITS, (int(h) & ((1 << SERVE_POS_BITS) - 1)) - SERVE_BIAS)
              for h in np.asarray(hits[i]) if h < SENT32]
        print(f"  q{i} {queries[i]}: {int(counts[i])} hits, first: {hs[:4]}")

    # straggler-mitigating dispatch across simulated shard replicas
    def shard_fn(delay):
        def fn(batch):
            if delay > 0.05:
                raise TimeoutError("straggler")
            return np.array([[1.0, delay]])
        return fn

    disp = ShardDispatcher([shard_fn(0.0), shard_fn(0.1), shard_fn(0.01)],
                           replica_fns=[shard_fn(0.0)] * 3, timeout=0.05)
    res = disp.dispatch("batch")
    print(f"\ndispatcher: {disp.stats.total} batch, "
          f"{disp.stats.redispatched} re-dispatched to replicas, "
          f"top-k merged: {merge_topk(res, 2).tolist()}")


if __name__ == "__main__":
    main()

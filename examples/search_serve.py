"""End-to-end serving driver: batched phrase queries through the unified
serve tier (the same batch-executor tables and bucket math the engine runs,
shard_map'd over document shards — and the same step the multi-pod dry-run
lowers at 512 chips), with straggler-mitigating dispatch across simulated
document shards.

    PYTHONPATH=src python examples/search_serve.py
"""
import time

import numpy as np

from repro.core import (AdditionalIndexEngine, CorpusConfig, LexiconConfig,
                        MODE_NEAR, SearchRequest, build_all, generate_corpus,
                        make_lexicon_and_analyzer)
from repro.dist.fault_tolerance import ShardDispatcher, merge_topk
from repro.launch.mesh import make_host_mesh
from repro.serve.search_serve import SearchServe, SearchServeConfig


def main():
    lex_cfg = LexiconConfig(n_surface=20_000, n_base=15_000, n_stop=400,
                            n_frequent=1200, seed=0)
    lex, ana = make_lexicon_and_analyzer(lex_cfg)
    corpus = generate_corpus(lex_cfg, CorpusConfig(n_docs=300, seed=0))
    index = build_all(corpus, lex, ana)
    engine = AdditionalIndexEngine(index)

    mesh = make_host_mesh(data=1, model=1)
    cfg = SearchServeConfig(queries=16, postings_pad=8192, seed_pad=2048,
                            n_basic=1, n_expanded=1, n_stop=1, n_first=1,
                            n_multi=1)
    serve = SearchServe(index, cfg, mesh)

    # query batch from indexed documents
    rng = np.random.default_rng(0)
    requests = []
    while len(requests) < cfg.queries:
        d = int(rng.integers(corpus.n_docs))
        toks = corpus.doc(d)
        if len(toks) < 10:
            continue
        st = int(rng.integers(len(toks) - 6))
        requests.append(SearchRequest(toks[st:st + 3].tolist()))

    results = serve.search_batch(requests)      # warm
    t0 = time.perf_counter()
    results = serve.search_batch(requests)
    dt = time.perf_counter() - t0
    print(f"serve: {cfg.queries} queries in {dt*1e3:.1f} ms "
          f"({dt/cfg.queries*1e3:.2f} ms/query)")
    for i in range(4):
        r = results[i]
        pairs = list(zip(r.doc.tolist(), r.pos.tolist()))
        print(f"  q{i} {list(requests[i].surface_ids)}: {len(r.doc)} hits, "
              f"first: {pairs[:4]}")

    # the unified tier must agree with the engine bit-for-bit
    wants = engine.search_batch(requests)
    assert all(np.array_equal(w.doc, r.doc) and np.array_equal(w.pos, r.pos)
               for w, r in zip(wants, results))
    print("serve == engine.search_batch on all queries")

    # ranked serving: same postings, proximity-scored top-k DocHits,
    # bit-identical to the engine's ranked batch
    ranked_reqs = [SearchRequest(r.surface_ids, mode=MODE_NEAR, rank=True,
                                 top_k=3) for r in requests[:4]]
    ranked = serve.search_batch(ranked_reqs)
    ranked_eng = engine.search_batch(ranked_reqs)
    assert all(np.array_equal(w.doc_ids, g.doc_ids)
               and np.array_equal(w.doc_scores, g.doc_scores)
               for w, g in zip(ranked_eng, ranked))
    print("ranked serve == ranked engine; sample top-k:")
    for req, r in zip(ranked_reqs, ranked[:2]):
        print(f"  {list(req.surface_ids)}: "
              f"{[(h.doc, round(h.score, 3)) for h in r.hits]}")

    # straggler-mitigating dispatch across simulated shard replicas
    def shard_fn(delay):
        def fn(batch):
            if delay > 0.05:
                raise TimeoutError("straggler")
            return np.array([[1.0, delay]])
        return fn

    disp = ShardDispatcher([shard_fn(0.0), shard_fn(0.1), shard_fn(0.01)],
                           replica_fns=[shard_fn(0.0)] * 3, timeout=0.05)
    res = disp.dispatch("batch")
    print(f"\ndispatcher: {disp.stats.total} batch, "
          f"{disp.stats.redispatched} re-dispatched to replicas, "
          f"top-k merged: {merge_topk(res, 2).tolist()}")


if __name__ == "__main__":
    main()

"""Distributed search demo on 8 simulated devices: document-sharded serving
with shard_map, ring all-reduce, and elastic checkpoint resume.

Run directly (it re-execs itself with XLA_FLAGS for 8 host devices):

    PYTHONPATH=src python examples/distributed_search.py
"""
import os
import sys

if os.environ.get("XLA_FLAGS", "").find("host_platform_device_count") < 0:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import jax                                                        # noqa: E402
import jax.numpy as jnp                                           # noqa: E402
import numpy as np                                                # noqa: E402

import repro.compat                                               # noqa: E402

from repro.core import (CorpusConfig, LexiconConfig, build_all,   # noqa: E402
                        generate_corpus, make_lexicon_and_analyzer)
from repro.dist.collectives import make_ring_all_reduce           # noqa: E402
from repro.serve.search_serve import (SearchServeConfig,          # noqa: E402
                                      make_search_serve_step)


def main():
    print(f"devices: {len(jax.devices())}")
    mesh = repro.compat.make_mesh((8, 1), ("data", "model"),
                         axis_types=repro.compat.auto_axis_types(2))

    # 8 document shards: build one index per shard (separate doc ranges)
    lex_cfg = LexiconConfig(n_surface=8000, n_base=6000, n_stop=200,
                            n_frequent=600, seed=0)
    lex, ana = make_lexicon_and_analyzer(lex_cfg)
    cfg = SearchServeConfig(queries=8, groups=3, postings_pad=2048, top_m=32,
                            n_basic=40_000, n_expanded=60_000, n_stop=80_000)
    shard_arenas = {k: [] for k in
                    ("arena_doc", "arena_pos", "arena_dist", "basic_ns")}
    for shard in range(8):
        corpus = generate_corpus(lex_cfg, CorpusConfig(n_docs=40, seed=shard))
        index = build_all(corpus, lex, ana)
        from repro.serve.search_serve import build_arenas
        arenas, _ = build_arenas(index, cfg)
        for k in shard_arenas:
            shard_arenas[k].append(np.asarray(arenas[k][0]))
    arenas = {k: jnp.asarray(np.stack(v)) for k, v in shard_arenas.items()}

    step = jax.jit(make_search_serve_step(cfg, mesh))
    q = {
        "start": jnp.zeros((cfg.queries, cfg.groups), jnp.int32),
        "length": jnp.full((cfg.queries, cfg.groups), 64, jnp.int32),
        "offset": jnp.tile(jnp.arange(cfg.groups, dtype=jnp.int32),
                           (cfg.queries, 1)),
        "req_dist": jnp.full((cfg.queries, cfg.groups), -128, jnp.int32),
        "band": jnp.zeros((cfg.queries, cfg.groups), jnp.int32),
        "active": jnp.ones((cfg.queries, cfg.groups), bool),
        "ns_packed": jnp.full((cfg.queries, cfg.check_slots), -1, jnp.int32),
    }
    with mesh:
        hits, counts = step(arenas, q)
    print(f"document-sharded serve over 8 shards: counts={np.asarray(counts)}")

    ring = make_ring_all_reduce(mesh, "data")
    X = jnp.asarray(np.random.default_rng(0).normal(size=(8, 32)).astype(np.float32))
    from jax.sharding import NamedSharding, PartitionSpec as P
    Xs = jax.device_put(X, NamedSharding(mesh, P("data", None)))
    with mesh:
        red = jax.jit(ring)(Xs)
    print(f"ring all-reduce max err: "
          f"{float(jnp.abs(red - X.sum(0)[None]).max()):.2e}")


if __name__ == "__main__":
    main()

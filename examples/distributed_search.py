"""Distributed search demo on 8 simulated devices: one corpus document-
partitioned over 8 shards, served through the unified shard_map'd serve tier
(each device holds only its own slice of the posting arena and executes only
its own rows), verified bit-identical against the in-process engine; plus a
ring all-reduce demo.

Run directly (it re-execs itself with XLA_FLAGS for 8 host devices):

    PYTHONPATH=src python examples/distributed_search.py
"""
import os
import sys

if os.environ.get("XLA_FLAGS", "").find("host_platform_device_count") < 0:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import jax                                                        # noqa: E402
import jax.numpy as jnp                                           # noqa: E402
import numpy as np                                                # noqa: E402

import repro.compat                                               # noqa: E402

from repro.core import (AdditionalIndexEngine, CorpusConfig,      # noqa: E402
                        LexiconConfig, MODE_NEAR, SearchRequest, build_all,
                        generate_corpus, make_lexicon_and_analyzer)
from repro.dist.collectives import make_ring_all_reduce           # noqa: E402
from repro.serve.search_serve import (SearchServe,                # noqa: E402
                                      SearchServeConfig)


def main():
    print(f"devices: {len(jax.devices())}")
    mesh = repro.compat.make_mesh((8, 1), ("data", "model"),
                                  axis_types=repro.compat.auto_axis_types(2))

    # ONE corpus, documents partitioned over the 8 dp shards by the serve
    # tier itself (contiguous doc ranges; each shard's arena holds only its
    # own postings)
    lex_cfg = LexiconConfig(n_surface=8000, n_base=6000, n_stop=200,
                            n_frequent=600, seed=0)
    lex, ana = make_lexicon_and_analyzer(lex_cfg)
    corpus = generate_corpus(lex_cfg, CorpusConfig(n_docs=320, seed=0))
    index = build_all(corpus, lex, ana)
    engine = AdditionalIndexEngine(index)

    cfg = SearchServeConfig(queries=8, postings_pad=2048, seed_pad=512,
                            n_basic=1, n_expanded=1, n_stop=1, n_first=1,
                            n_multi=1)
    serve = SearchServe(index, cfg, mesh)
    print(f"document-sharded serve: {serve.n_dp} shards x "
          f"{serve.executor.docs_per_dp} docs")

    rng = np.random.default_rng(0)
    requests = []
    while len(requests) < cfg.queries:
        d = int(rng.integers(corpus.n_docs))
        toks = corpus.doc(d)
        if len(toks) < 10:
            continue
        st = int(rng.integers(len(toks) - 6))
        requests.append(SearchRequest(toks[st:st + 3].tolist()))

    got = serve.search_batch(requests)
    want = engine.search_batch(requests)
    assert all(np.array_equal(w.doc, g.doc) and np.array_equal(w.pos, g.pos)
               for w, g in zip(want, got))
    print(f"serve over 8 shards == engine: counts={[len(r.doc) for r in got]}")

    # ranked across 8 document shards: per-shard scores merge through the
    # same pmin/pmax step and stay bit-identical to the engine
    ranked_reqs = [SearchRequest(r.surface_ids, mode=MODE_NEAR, rank=True,
                                 top_k=3) for r in requests]
    rs, re_ = serve.search_batch(ranked_reqs), engine.search_batch(ranked_reqs)
    assert all(np.array_equal(w.doc_ids, g.doc_ids)
               and np.array_equal(w.doc_scores, g.doc_scores)
               for w, g in zip(re_, rs))
    print(f"ranked serve over 8 shards == engine: "
          f"top docs {[r.doc_ids[:2].tolist() for r in rs[:4]]}")

    ring = make_ring_all_reduce(mesh, "data")
    X = jnp.asarray(np.random.default_rng(0).normal(size=(8, 32)).astype(np.float32))
    from jax.sharding import NamedSharding, PartitionSpec as P
    Xs = jax.device_put(X, NamedSharding(mesh, P("data", None)))
    with mesh:
        red = jax.jit(ring)(Xs)
    print(f"ring all-reduce max err: "
          f"{float(jnp.abs(red - X.sum(0)[None]).max()):.2e}")


if __name__ == "__main__":
    main()

"""Quickstart: build the paper's additional indexes over a synthetic corpus
and run the four query types against them.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (AdditionalIndexEngine, CorpusConfig, LexiconConfig,
                        OrdinaryEngine, build_all, generate_corpus,
                        make_lexicon_and_analyzer)
from repro.core.planner import MODE_NEAR, MODE_PHRASE


def main():
    lex_cfg = LexiconConfig(n_surface=20_000, n_base=15_000, n_stop=400,
                            n_frequent=1200, seed=0)
    lex, ana = make_lexicon_and_analyzer(lex_cfg)
    corpus = generate_corpus(lex_cfg, CorpusConfig(n_docs=400, seed=0))
    print(f"corpus: {corpus.n_docs} docs, {corpus.n_tokens} tokens")

    index = build_all(corpus, lex, ana)
    for k, v in index.size_report().items():
        print(f"  {k}: {v:,}")

    engine = AdditionalIndexEngine(index)
    ordinary = OrdinaryEngine(index)

    # take a phrase straight out of a document (the paper's procedure)
    rng = np.random.default_rng(3)
    doc = int(rng.integers(corpus.n_docs))
    toks = corpus.doc(doc)
    start = int(rng.integers(len(toks) - 12))
    phrase = toks[start:start + 4].tolist()
    word_set = toks[start:start + 8:2].tolist()

    for q, mode in ((phrase, MODE_PHRASE), (word_set, MODE_NEAR)):
        plan = engine.plan(q, mode=mode)
        r = engine.search(q, mode=mode)
        r0 = ordinary.search(q, mode=mode)
        types = [sp.qtype for sp in plan.subplans]
        print(f"\nquery={q} mode={mode} types={types}")
        print(f"  additional-index engine: {len(r.doc)} hits, "
              f"{r.postings_read:,} postings read"
              + (" (doc-level fallback)" if r.doc_only else ""))
        print(f"  ordinary inverted index: {len(r0.doc)} hits, "
              f"{r0.postings_read:,} postings read")
        print(f"  postings saved: {r0.postings_read / max(r.postings_read, 1):.1f}x")
        assert doc in set(r.doc.tolist())
    print("\nsource document found by every query — index verified.")


if __name__ == "__main__":
    main()

"""Quickstart: build the paper's additional indexes over a synthetic corpus
and run the four query types against them — then rank a word-set query by
proximity relevance (SearchRequest(rank=True)).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (AdditionalIndexEngine, CorpusConfig, LexiconConfig,
                        MODE_KWORD, MODE_NEAR, MODE_PHRASE, OrdinaryEngine,
                        SearchRequest, build_all, generate_corpus,
                        make_lexicon_and_analyzer)


def main():
    lex_cfg = LexiconConfig(n_surface=20_000, n_base=15_000, n_stop=400,
                            n_frequent=1200, seed=0)
    lex, ana = make_lexicon_and_analyzer(lex_cfg)
    corpus = generate_corpus(lex_cfg, CorpusConfig(n_docs=400, seed=0))
    print(f"corpus: {corpus.n_docs} docs, {corpus.n_tokens} tokens")

    index = build_all(corpus, lex, ana)
    for k, v in index.size_report().items():
        print(f"  {k}: {v:,}")

    engine = AdditionalIndexEngine(index)
    ordinary = OrdinaryEngine(index)

    # take a phrase straight out of a document (the paper's procedure)
    rng = np.random.default_rng(3)
    doc = int(rng.integers(corpus.n_docs))
    toks = corpus.doc(doc)
    start = int(rng.integers(len(toks) - 12))
    phrase = toks[start:start + 4].tolist()
    word_set = toks[start:start + 8:2].tolist()

    for req in (SearchRequest(phrase, mode=MODE_PHRASE),
                SearchRequest(word_set, mode=MODE_NEAR)):
        plan = engine.plan_request(req)
        r = engine.search(req)
        r0 = ordinary.search(req)
        types = [sp.qtype for sp in plan.subplans]
        print(f"\nquery={list(req.surface_ids)} mode={req.mode} types={types}")
        print(f"  additional-index engine: {len(r.doc)} hits, "
              f"{r.postings_read:,} postings read"
              + (" (doc-level fallback)" if r.doc_only else ""))
        print(f"  ordinary inverted index: {len(r0.doc)} hits, "
              f"{r0.postings_read:,} postings read")
        print(f"  postings saved: {r0.postings_read / max(r.postings_read, 1):.1f}x")
        assert doc in set(r.doc.tolist())
    print("\nsource document found by every query — index verified.")

    # ranked top-k: proximity relevance from the SAME postings (zero extra
    # reads) — tighter word sets and repeated matches rank first
    ranked = engine.search(SearchRequest(word_set, mode=MODE_NEAR, rank=True,
                                         top_k=5))
    print(f"\nranked word-set query (top {len(ranked.hits)} of "
          f"{len(np.unique(ranked.doc))} docs, "
          f"{ranked.postings_read:,} postings read):")
    for hit in ranked.hits:
        print(f"  doc {hit.doc}: score {hit.score:.3f}, "
              f"{len(hit.positions)} anchors, subplans {hit.subplans}")
    assert ranked.hits[0].doc == doc or doc in {h.doc for h in ranked.hits}

    # K-word proximity (arXiv:2009.02684): every query word inside ONE
    # (window + 1)-wide span, any order — the planner covers stop slots
    # with multi-component-key lookups instead of full stop posting scans
    kword = toks[start:start + 5].tolist()
    kreq = SearchRequest(kword, mode=MODE_KWORD, window=8)
    kr = engine.search(kreq)
    kr0 = ordinary.search(kreq)
    print(f"\nkword query={kword} window=8: {len(kr.doc)} anchor hits, "
          f"{kr.postings_read:,} postings read "
          f"(ordinary plan: {kr0.postings_read:,} — "
          f"{kr0.postings_read / max(kr.postings_read, 1):.1f}x more)")
    assert doc in set(kr.doc.tolist())

    # incremental ingestion: the same corpus fed in batches through the
    # segment manager — each batch becomes an immutable segment, the
    # background merger compacts them, and the union search stays identical
    # to the one-shot build at every generation
    from repro.core import SegmentManager, corpus_batches

    mgr = SegmentManager(lex, ana, params=index.params, auto_merge=False)
    for batch in corpus_batches(corpus, 4):
        gen = mgr.ingest(batch)
        print(f"\ningested {batch.n_docs} docs -> generation {gen}, "
              f"{len(mgr.segments)} live segment(s), {mgr.n_docs} docs total")
    req = SearchRequest(phrase, mode=MODE_PHRASE)
    union = mgr.search_batch([req], plan_index=index)[0]
    assert np.array_equal(union.doc, engine.search(req).doc)
    mgr.merge_now()                       # compact 4 segments into 1
    merged = mgr.search_batch([req])[0]
    assert np.array_equal(merged.doc, engine.search(req).doc)
    print(f"after merge: {len(mgr.segments)} segment(s) — union and merged "
          f"results match the one-shot build")
    mgr.close()


if __name__ == "__main__":
    main()

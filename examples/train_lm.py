"""End-to-end LM training: a ~100M-parameter llama-style model for a few
hundred steps on CPU, with checkpoint/restart supervision.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--tiny]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.data.lm_data import lm_batches
from repro.models import transformer as tfm
from repro.train import OptimizerConfig
from repro.train.train_loop import fit


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true",
                    help="2-layer model for a fast demo run")
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    if args.tiny:
        cfg = tfm.TransformerConfig(
            name="lm-tiny", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
            d_ff=384, vocab=2048, dtype=jnp.float32, remat=False)
        batch, seq = 8, 64
    else:
        # ~100M params: 12L x 768d, GQA 12/4, llama3-style
        cfg = tfm.TransformerConfig(
            name="lm-100m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
            d_ff=2048, vocab=32768, dtype=jnp.float32, remat=False)
        batch, seq = 4, 256
    print(f"model: {cfg.name}, params={cfg.param_count():,}")

    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    data = lm_batches(cfg.vocab, batch=batch, seq_len=seq, seed=0)
    ckpt = CheckpointManager(args.ckpt, keep=2)
    params, _, hist = fit(
        params, lambda p, b: tfm.loss_fn(cfg, p, b),
        OptimizerConfig(lr=3e-4, warmup_steps=20, decay_steps=args.steps),
        data, n_steps=args.steps, ckpt=ckpt, log_every=10)
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"over {args.steps} steps")


if __name__ == "__main__":
    main()

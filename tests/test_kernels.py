"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes/dtypes.  Exact equality for integer kernels."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("na,nb,band", [
    (100, 300, 0), (1000, 5000, 0), (3000, 1200, 3), (1, 1, 0),
    (513, 2049, 7), (4096, 4096, 1), (37, 8192, 0), (2048, 17, 5)])
def test_banded_intersect_matches_ref(na, nb, band):
    rng = np.random.default_rng(na * 31 + nb * 7 + band)
    a = rng.integers(0, 60_000, na).astype(np.int32)
    b = np.sort(rng.integers(0, 60_000, nb)).astype(np.int32)
    got = ops.banded_intersect(jnp.asarray(a), jnp.asarray(b), band)
    want = ops.banded_intersect(jnp.asarray(a), jnp.asarray(b), band,
                                implementation="ref")
    assert bool((got == want).all())


@pytest.mark.parametrize("blocks", [(256, 256), (1024, 512), (512, 2048)])
def test_banded_intersect_block_shapes(blocks):
    ba, bb = blocks
    rng = np.random.default_rng(ba + bb)
    a = rng.integers(0, 100_000, 3000).astype(np.int32)
    b = np.sort(rng.integers(0, 100_000, 5000)).astype(np.int32)
    got = ops.banded_intersect(jnp.asarray(a), jnp.asarray(b), 2,
                               block_a=ba, block_b=bb)
    want = ops.banded_intersect(jnp.asarray(a), jnp.asarray(b), 2,
                                implementation="ref")
    assert bool((got == want).all())


def test_banded_intersect_duplicates_at_boundaries():
    """Duplicate keys straddling tile boundaries (the lo side='left' case)."""
    a = np.array([5000] * 10, np.int32)
    b = np.sort(np.concatenate([np.full(2000, 5000), [1, 2, 3]])).astype(np.int32)
    got = ops.banded_intersect(jnp.asarray(a), jnp.asarray(b), 0,
                               block_a=256, block_b=256)
    assert bool(got.all())


@pytest.mark.parametrize("B,F,V,D", [(8, 5, 100, 16), (32, 39, 1000, 64),
                                     (4, 3, 50, 128), (1, 1, 2, 8)])
@pytest.mark.parametrize("combine", ["sum", "mean"])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_segment_bag_matches_ref(B, F, V, D, combine, dtype):
    rng = np.random.default_rng(B * F + V)
    table = jnp.asarray(rng.normal(size=(V, D)), dtype)
    ids = jnp.asarray(rng.integers(-1, V, (B, F)).astype(np.int32))
    w = jnp.asarray(rng.normal(size=(B, F)), dtype)
    got = ops.segment_bag(table, ids, w, combine)
    want = ops.segment_bag(table, ids, w, combine, implementation="ref")
    tol = 1e-5 if dtype == np.float32 else 5e-2
    assert float(jnp.abs(got.astype(jnp.float32) - want.astype(jnp.float32)).max()) < tol


def test_segment_bag_all_padding():
    table = jnp.ones((10, 8), jnp.float32)
    ids = jnp.full((4, 3), -1, jnp.int32)
    out = ops.segment_bag(table, ids)
    assert float(jnp.abs(out).max()) == 0.0


@pytest.mark.parametrize("B,Hq,Hkv,D,S,bs", [
    (2, 8, 2, 64, 1024, 256), (1, 4, 4, 128, 512, 512),
    (3, 16, 8, 64, 384, 128), (2, 8, 8, 64, 100, 512)])
def test_flash_decode_matches_ref(B, Hq, Hkv, D, S, bs):
    rng = np.random.default_rng(B * S)
    q = jnp.asarray(rng.normal(size=(B, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    kvl = jnp.asarray(rng.integers(1, S + 1, (B,)).astype(np.int32))
    got = ops.flash_decode(q, k, v, kvl, block_s=bs)
    want = ops.flash_decode(q, k, v, kvl, implementation="ref")
    assert float(jnp.abs(got - want).max()) < 2e-5


def test_flash_decode_bf16():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 8, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(2, 512, 2, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(2, 512, 2, 64)), jnp.bfloat16)
    got = ops.flash_decode(q, k, v, 512, block_s=128)
    want = ops.flash_decode(q, k, v, 512, implementation="ref")
    err = float(jnp.abs(got.astype(jnp.float32) - want.astype(jnp.float32)).max())
    assert err < 0.05


@pytest.mark.parametrize("B,S,Hq,Hkv,D,bq,bkv", [
    (2, 256, 8, 2, 64, 64, 64), (1, 512, 4, 4, 128, 128, 256),
    (2, 128, 16, 8, 64, 128, 64), (1, 128, 2, 1, 32, 32, 128)])
def test_flash_prefill_matches_ref(B, S, Hq, Hkv, D, bq, bkv):
    rng = np.random.default_rng(B * S + D)
    q = jnp.asarray(rng.normal(size=(B, S, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    got = ops.flash_prefill(q, k, v, block_q=bq, block_kv=bkv)
    want = ops.flash_prefill(q, k, v, implementation="ref")
    assert float(jnp.abs(got - want).max()) < 3e-5


def test_flash_prefill_matches_model_attention():
    """The kernel agrees with the model's causal_attention layer (the
    chunked online-softmax XLA path) — three-way consistency."""
    from repro.models.layers import causal_attention
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(1, 256, 8, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.float32)
    a = ops.flash_prefill(q, k, v, block_q=64, block_kv=64)
    b = causal_attention(q, k, v, chunk_q=64, chunk_kv=64)
    assert float(jnp.abs(a - b).max()) < 3e-5


def test_flash_decode_vs_full_softmax():
    """Cross-check the oracle itself against plain softmax attention."""
    rng = np.random.default_rng(1)
    B, Hq, Hkv, D, S = 2, 4, 2, 32, 257
    q = jnp.asarray(rng.normal(size=(B, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    out = ref.flash_decode_ref(q, k, v, S)
    G = Hq // Hkv
    kk = jnp.repeat(k, G, axis=2)
    vv = jnp.repeat(v, G, axis=2)
    logits = jnp.einsum("bhd,bshd->bhs", q, kk) / jnp.sqrt(D * 1.0)
    want = jnp.einsum("bhs,bshd->bhd", jax.nn.softmax(logits, -1), vv)
    assert float(jnp.abs(out - want).max()) < 1e-5

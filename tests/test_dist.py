"""Multi-device distribution tests.

These need >1 device, so they run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main test process
keeps the default single device, as required)."""
import os
import subprocess
import sys
import textwrap

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str):
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, %r)
        import numpy as np, jax, jax.numpy as jnp
        import repro
        from jax.sharding import NamedSharding, PartitionSpec as P
        import repro.compat
        from repro.compat import shard_map
    """ % os.path.join(_ROOT, "src")) + textwrap.dedent(body)
    res = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=540)
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    return res.stdout


def test_ring_all_reduce_8dev():
    out = _run("""
        from repro.dist import collectives
        mesh = repro.compat.make_mesh((8,), ("x",),
                             axis_types=repro.compat.auto_axis_types(1))
        X = jnp.asarray(np.random.default_rng(0).normal(size=(8, 64)).astype(np.float32))
        Xs = jax.device_put(X, NamedSharding(mesh, P("x", None)))
        fn = collectives.make_ring_all_reduce(mesh, "x")
        with mesh:
            got = jax.jit(fn)(Xs)
        err = float(jnp.abs(got - X.sum(0)[None]).max())
        assert err < 1e-5, err
        print("OK", err)
    """)
    assert "OK" in out


def test_sharded_train_step_matches_single_device():
    out = _run("""
        from repro.train.train_loop import make_sharded_train_step, make_train_step, init_residual
        from repro.train import OptimizerConfig, init_state
        def loss_fn(params, batch):
            pred = batch["x"] @ params["w"]
            l = jnp.mean((pred - batch["y"]) ** 2)
            return l, {"mse": l}
        cfg = OptimizerConfig(lr=1e-2, weight_decay=0.0)
        params = {"w": jnp.ones((4, 1), jnp.float32)}
        key = jax.random.PRNGKey(0)
        batch = {"x": jax.random.normal(key, (32, 4)),
                 "y": jax.random.normal(jax.random.PRNGKey(1), (32, 1))}
        mesh = repro.compat.make_mesh((8, 1), ("data", "model"),
                             axis_types=repro.compat.auto_axis_types(2))
        sstep = make_sharded_train_step(loss_fn, cfg, mesh)
        with mesh:
            p1, s1, _, m1 = sstep(params, init_state(cfg, params),
                                  init_residual(params), batch)
        step = make_train_step(loss_fn, cfg, donate=False)
        p2, s2, m2 = step(params, init_state(cfg, params), batch)
        err = float(jnp.abs(p1["w"] - p2["w"]).max())
        assert err < 1e-6, err
        print("OK", err)
    """)
    assert "OK" in out


def test_compressed_dp_training_converges():
    out = _run("""
        from repro.train.train_loop import make_sharded_train_step, init_residual
        from repro.train import OptimizerConfig, init_state
        def loss_fn(params, batch):
            pred = batch["x"] @ params["w"]
            l = jnp.mean((pred - batch["y"]) ** 2)
            return l, {}
        cfg = OptimizerConfig(lr=5e-2, weight_decay=0.0, warmup_steps=0)
        key = jax.random.PRNGKey(0)
        w_true = jax.random.normal(key, (4, 1))
        x = jax.random.normal(jax.random.PRNGKey(1), (64, 4))
        batch = {"x": x, "y": x @ w_true}
        params = {"w": jnp.zeros((4, 1), jnp.float32)}
        mesh = repro.compat.make_mesh((8, 1), ("data", "model"),
                             axis_types=repro.compat.auto_axis_types(2))
        sstep = make_sharded_train_step(loss_fn, cfg, mesh, compression="int8")
        state = init_state(cfg, params)
        res = init_residual(params)
        with mesh:
            for i in range(150):
                params, state, res, m = sstep(params, state, res, batch)
        final = float(m["loss"])
        assert final < 1e-2, final
        print("OK", final)
    """)
    assert "OK" in out


def test_elastic_resume_across_mesh_shapes(tmp_path):
    """Save params sharded on an 8x1 mesh; restore onto 2x4 — the
    checkpoint is mesh-agnostic and re-shards on load."""
    out = _run(f"""
        from repro.checkpoint import CheckpointManager
        tree = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
        mesh1 = repro.compat.make_mesh((8, 1), ("data", "model"),
                              axis_types=repro.compat.auto_axis_types(2))
        sh1 = {{"w": NamedSharding(mesh1, P("data", None))}}
        t1 = jax.device_put(tree, sh1)
        mgr = CheckpointManager({str(tmp_path)!r}, keep=2)
        mgr.save(3, t1)
        mesh2 = repro.compat.make_mesh((2, 4), ("data", "model"),
                              axis_types=repro.compat.auto_axis_types(2))
        sh2 = {{"w": NamedSharding(mesh2, P("model", "data"))}}
        step, got = mgr.restore_latest(tree, shardings=sh2)
        assert step == 3
        assert got["w"].sharding == sh2["w"]
        assert float(jnp.abs(got["w"] - tree["w"]).max()) == 0.0
        print("OK")
    """)
    assert "OK" in out


def test_gin_halo_exchange_matches_dense():
    """The §Perf halo-exchange GIN == the dense SPMD reference (8 shards)."""
    out = _run("""
        from repro.models import gnn
        from repro.data import graph_data
        from repro.compat import shard_map
        g = graph_data.generate_graph(400, 3200, d_feat=12, n_classes=4, seed=1)
        cfg = gnn.GINConfig(name="t", n_layers=3, d_hidden=16, d_feat=12, n_classes=4)
        params = gnn.init_params(cfg, jax.random.PRNGKey(0))
        b = {k: jnp.asarray(v) for k, v in
             graph_data.full_graph_batch(g, train_frac=1.0, seed=0).items()}
        l_ref, m_ref = gnn.loss_fn(cfg, params, b)
        part = graph_data.partition_for_halo(g, 8)
        mesh = repro.compat.make_mesh((8,), ("data",),
                             axis_types=repro.compat.auto_axis_types(1))
        keys = ("nodes", "src", "dst", "edge_mask", "labels", "label_mask", "send_idx")
        sb = {k: jnp.asarray(part[k]) for k in keys}
        fn = shard_map(lambda p, s: gnn.halo_loss_fn(cfg, p, s, axis_name="data"),
                       mesh=mesh, in_specs=(P(), {k: P("data") for k in keys}),
                       out_specs=(P(), {"acc": P()}), check_vma=False)
        with mesh:
            l_halo, m_halo = jax.jit(fn)(params, sb)
        err = abs(float(l_ref) - float(l_halo))
        assert err < 1e-4, err
        assert abs(float(m_ref["acc"]) - float(m_halo["acc"])) < 1e-6
        print("OK", err, "cut", part["cut_fraction"])
    """)
    assert "OK" in out


def test_gin_sharded_step_matches_single():
    """Edge-partitioned GIN loss == single-device loss (segment_sum psum)."""
    out = _run("""
        import dataclasses
        from repro.models import gnn
        from repro.data import graph_data
        g = graph_data.generate_graph(256, 2048, 16, 4, seed=0)
        cfg = gnn.GINConfig(name="t", n_layers=2, d_hidden=16, d_feat=16, n_classes=4)
        params = gnn.init_params(cfg, jax.random.PRNGKey(0))
        b = graph_data.full_graph_batch(g)
        # pad edge arrays to a multiple of the mesh (masked edges are no-ops)
        E = len(b["src"])
        pad = (-E) % 8
        for k in ("src", "dst"):
            b[k] = np.concatenate([b[k], np.zeros(pad, b[k].dtype)])
        b["edge_mask"] = np.concatenate([b["edge_mask"], np.zeros(pad, bool)])
        b = {k: jnp.asarray(v) for k, v in b.items()}
        l1, _ = gnn.loss_fn(cfg, params, b)
        mesh = repro.compat.make_mesh((8,), ("data",),
                             axis_types=repro.compat.auto_axis_types(1))
        shard = {
            "nodes": NamedSharding(mesh, P("data", None)),
            "src": NamedSharding(mesh, P("data")),
            "dst": NamedSharding(mesh, P("data")),
            "edge_mask": NamedSharding(mesh, P("data")),
            "labels": NamedSharding(mesh, P("data")),
            "label_mask": NamedSharding(mesh, P("data")),
            "node_mask": NamedSharding(mesh, P("data")),
        }
        bs = {k: jax.device_put(v, shard[k]) for k, v in b.items()}
        with mesh:
            l2, _ = jax.jit(lambda p, bb: gnn.loss_fn(cfg, p, bb))(params, bs)
        err = abs(float(l1) - float(l2))
        assert err < 1e-4, err
        print("OK", err)
    """)
    assert "OK" in out

"""Multi-component key index: construction vs the literal reference,
canonical-key/payload invariants (arXiv:2006.07954), build determinism, the
QTYPE_MULTI planner shape, and the docs_per_shard auto-pick heuristic."""
import numpy as np
import pytest

from repro.core import auto_docs_per_shard
from repro.core.builder import (IndexParams, TokenForms, build_multi_key_index,
                                expand_token_forms,
                                reference_multi_key_postings)
from repro.core.fetch_tables import DOCS_PER_SHARD
from repro.core.lexicon import TIER_STOP
from repro.core.planner import MODE_NEAR, QTYPE_MULTI
from repro.core.postings import (pack_dist_pair, pack_multi_pair_key,
                                 pack_multi_triple_key, unpack_dist_pair,
                                 unpack_multi_pair_key,
                                 unpack_multi_triple_key)


def _pairs_as_tuples(mk):
    out = []
    p = mk.pairs
    for i, k in enumerate(p.keys):
        s, e = int(p.offsets[i]), int(p.offsets[i + 1])
        for d, po, di in zip(p.columns["doc"][s:e], p.columns["pos"][s:e],
                             p.columns["dist"][s:e]):
            out.append((int(k), int(d), int(po), int(di)))
    return out


def _triples_as_tuples(mk):
    out = []
    t = mk.triples
    for i, k in enumerate(t.keys):
        s, e = int(t.offsets[i]), int(t.offsets[i + 1])
        for d, po, di, dp in zip(t.columns["doc"][s:e], t.columns["pos"][s:e],
                                 t.columns["dist"][s:e],
                                 t.columns["dpair"][s:e]):
            d1, d2 = unpack_dist_pair(int(dp))
            out.append((int(k), int(d), int(po), int(di),
                        (int(d1), int(d2))))
    return out


def test_multi_key_matches_literal_reference(small_world):
    """Vectorized builder == the nested-loop reference, as exact multisets —
    this is also the 'exactly one canonical key per stop-adjacent pair'
    property: every (s occurrence, non-stop neighbor) configuration appears
    exactly once, under the stop-first key."""
    idx = small_world["index"]
    tf = expand_token_forms(small_world["corpus"], idx.lexicon, idx.analyzer)
    ref_pairs, ref_triples = reference_multi_key_postings(
        tf, idx.lexicon, idx.params)
    assert sorted(_pairs_as_tuples(idx.multi_key)) == sorted(ref_pairs)
    assert sorted(_triples_as_tuples(idx.multi_key)) == sorted(ref_triples)
    assert len(ref_pairs) > 1000 and len(ref_triples) > 1000


def test_multi_key_tiny_corpus_by_hand():
    """One document, hand-checkable: stop run around two non-stop tokens."""
    #   pos:   0    1    2    3
    #   forms: s0   v10  s1   v11    (D = 2)
    tf = TokenForms(
        doc_of=np.zeros(4, np.int32), pos_of=np.arange(4, dtype=np.int32),
        s1_local=np.array([0, -1, 1, -1], np.int32),
        s2_local=np.full(4, -1, np.int32),
        n1=np.array([-1, 10, -1, 11], np.int32),
        n2=np.full(4, -1, np.int32))

    class _Lex:
        class config:
            n_base = 100
            n_stop = 5
    mk = build_multi_key_index(tf, _Lex, IndexParams(max_distance=2, near_window=2))
    # pairs (pos = pos of s, dist = pos_v - pos_s): s0 sees v10 ahead;
    # s1 sees v10 behind and v11 ahead
    assert sorted(_pairs_as_tuples(mk)) == sorted([
        (int(pack_multi_pair_key(0, 10, 100)), 0, 0, 1),
        (int(pack_multi_pair_key(1, 10, 100)), 0, 2, -1),
        (int(pack_multi_pair_key(1, 11, 100)), 0, 2, 1),
    ])
    # triples: v10 sees s0 at 1 and s1 at 1 -> (0, 1, 10) with max 1;
    # v11 sees only s1 (s0 is 3 away > D) -> no triple
    assert _triples_as_tuples(mk) == [
        (int(pack_multi_triple_key(0, 1, 10, 5)), 0, 1, 1, (1, 1))]
    # same-token (dist 0) pair: token carrying both a stop and non-stop form
    tf2 = TokenForms(
        doc_of=np.zeros(1, np.int32), pos_of=np.zeros(1, np.int32),
        s1_local=np.array([3], np.int32), s2_local=np.full(1, -1, np.int32),
        n1=np.array([42], np.int32), n2=np.full(1, -1, np.int32))
    mk2 = build_multi_key_index(tf2, _Lex, IndexParams(max_distance=2, near_window=2))
    assert _pairs_as_tuples(mk2) == [(int(pack_multi_pair_key(3, 42, 100)),
                                      0, 0, 0)]


def test_multi_key_invariants(small_world):
    """Key-domain invariants: pair keys are (stop, non-stop); triple keys
    have s1 < s2 both stop around a non-stop v; dist == max of the payload
    pair; every distance within NeighborDistance."""
    idx = small_world["index"]
    lex, mk = idx.lexicon, idx.multi_key
    D = mk.neighbor_distance
    s, v = unpack_multi_pair_key(mk.pairs.keys, mk.n_base)
    assert (lex.base_tier[s] == TIER_STOP).all()
    assert (~lex.is_stop(v)).all()
    assert (np.abs(mk.pairs.columns["dist"].astype(np.int32)) <= D).all()
    s1, s2, tv = unpack_multi_triple_key(mk.triples.keys, mk.n_stop)
    assert (s1 < s2).all()                    # canonical sorted, distinct
    assert (lex.base_tier[s1] == TIER_STOP).all()
    assert (lex.base_tier[s2] == TIER_STOP).all()
    assert (~lex.is_stop(tv)).all()
    d1, d2 = unpack_dist_pair(mk.triples.columns["dpair"])
    dist = mk.triples.columns["dist"].astype(np.int32)
    assert np.array_equal(dist, np.maximum(d1, d2))
    assert (dist <= D).all() and (np.minimum(d1, d2) >= 0).all()


def test_multi_key_build_deterministic(small_world):
    """Byte-identical across rebuilds and across chunk sizes (the chunked
    triple construction must not depend on the chunk boundary)."""
    idx = small_world["index"]
    corpus = small_world["corpus"]
    tf = expand_token_forms(corpus, idx.lexicon, idx.analyzer)
    base = idx.multi_key
    import dataclasses
    for chunk in (1 << 20, 1000, 977):
        params = dataclasses.replace(idx.params, chunk=chunk)
        mk = build_multi_key_index(tf, idx.lexicon, params)
        for a, b in ((base.pairs, mk.pairs), (base.triples, mk.triples)):
            assert np.array_equal(a.keys, b.keys)
            assert np.array_equal(a.offsets, b.offsets)
            for c in a.columns:
                assert np.array_equal(a.columns[c], b.columns[c]), (chunk, c)


def test_multi_key_lookup_reaches_every_adjacency(small_world):
    """Query-side canonical reachability: for sampled corpus (stop, word)
    adjacencies, find_pair returns a slice containing that configuration."""
    idx = small_world["index"]
    corpus = small_world["corpus"]
    tf = expand_token_forms(corpus, idx.lexicon, idx.analyzer)
    mk = idx.multi_key
    D = mk.neighbor_distance
    rng = np.random.default_rng(3)
    stops = np.nonzero(tf.s1_local >= 0)[0]
    arena = mk.arena_columns()
    checked = 0
    for g in rng.choice(stops, size=200, replace=False):
        s = int(tf.s1_local[g])
        for sd in range(-D, D + 1):
            u = g + sd
            if not (0 <= u < len(tf.doc_of)) or tf.doc_of[u] != tf.doc_of[g]:
                continue
            if tf.n1[u] < 0:
                continue
            lo, hi = mk.find_pair(s, int(tf.n1[u]))
            assert hi > lo
            sl = slice(lo, hi)
            hit = ((arena["doc"][sl] == tf.doc_of[g])
                   & (arena["pos"][sl] == tf.pos_of[g])
                   & (arena["dist"][sl] == sd))
            assert int(hit.sum()) == 1     # exactly one canonical posting
            checked += 1
    assert checked > 100


def _single_form_surface(world, base):
    """A surface whose ONLY basic form is `base`, or None."""
    ana = world["ana"]
    lo = int(np.searchsorted(ana.primary, base, side="left"))
    hi = int(np.searchsorted(ana.primary, base, side="right"))
    for s in range(lo, hi):
        if ana.forms_of(s) == [base]:
            return s
    return None


def test_planner_type5_shape(small_world):
    """A near query mixing stop + non-stop plans as QTYPE_MULTI with
    multi-stream fetches; two single-form stop slots share one
    three-component group; a lone stop slot uses a two-component lookup.
    Query words are derived from actual index keys, so the lookups hit."""
    mk = small_world["index"].multi_key
    planner = small_world["engine"].planner
    picked = None
    for key in mk.triples.keys:
        s1, s2, v = unpack_multi_triple_key(int(key), mk.n_stop)
        surfs = [_single_form_surface(small_world, int(b))
                 for b in (s1, v, s2)]
        if all(s is not None for s in surfs):
            picked = surfs
            break
    assert picked is not None, "no triple key with single-form surfaces"
    plan = planner.plan(picked, mode=MODE_NEAR)     # [stop, v, stop]
    sp = plan.subplans[0]
    assert sp.qtype == QTYPE_MULTI and sp.mode == MODE_NEAR
    multi_fetches = [f for g in sp.groups for f in g.fetches
                     if f.stream == "multi"]
    # both stop slots pair into ONE triple group: anchored at the pivot
    # (pivot_from_dist False), window via max_abs
    assert multi_fetches
    assert all(not f.pivot_from_dist for f in multi_fetches)
    assert all(f.max_abs_dist is not None for f in multi_fetches)
    n_multi_groups = sum(1 for g in sp.groups
                         if any(f.stream == "multi" for f in g.fetches))
    assert n_multi_groups == 1
    # a lone stop slot uses a two-component (s, pivot) lookup instead
    plan2 = planner.plan([picked[0], picked[1]], mode=MODE_NEAR)
    sp2 = plan2.subplans[0]
    assert sp2.qtype == QTYPE_MULTI
    pair_fetches = [f for g in sp2.groups for f in g.fetches
                    if f.stream == "multi"]
    assert pair_fetches and all(f.pivot_from_dist for f in pair_fetches)


def test_neighbor_distance_dial_parity(small_world):
    """IndexParams.neighbor_distance decoupled from near_window (ND=4 vs the
    default 8): the multi-key index shrinks (raw AND packed bytes); near
    windows <= ND still ride multi-key lookups while wider windows fall back
    to banded full ordinary-index reads (the planner's guard) — recall is
    oracle-parity on stop-heavy near queries at BOTH window settings, per
    query and batched."""
    import dataclasses

    from repro.core import (AdditionalIndexEngine, SearchRequest,
                            brute_force_search)
    from repro.core.builder import build_all
    w = small_world
    index8 = w["index"]
    params = dataclasses.replace(index8.params, neighbor_distance=4)
    assert params.multi_key_neighbor_distance == 4
    index4 = build_all(w["corpus"], w["lex"], w["ana"], params)
    assert index4.multi_key.neighbor_distance == 4
    # the size dial actually dials: fewer postings, fewer raw + packed bytes
    assert index4.multi_key.n_postings < index8.multi_key.n_postings
    assert index4.multi_key.nbytes() < index8.multi_key.nbytes()
    assert index4.multi_key.packed_nbytes() < index8.multi_key.packed_nbytes()
    # every other stream is untouched by the dial
    assert index4.expanded.pairs.n_postings == index8.expanded.pairs.n_postings
    eng = AdditionalIndexEngine(index4)
    rng = np.random.default_rng(99)
    corpus = w["corpus"]
    queries = []
    while len(queries) < 24:
        d = int(rng.integers(corpus.n_docs))
        toks = corpus.doc(d)
        n = int(rng.integers(2, 5))
        if len(toks) <= 2 * n:
            continue
        st = int(rng.integers(0, len(toks) - 2 * n))
        queries.append(toks[st:st + 2 * n:2].tolist())
    streams_seen = set()
    for window in (4, 8):
        reqs = [SearchRequest(q, mode=MODE_NEAR, window=window)
                for q in queries]
        batch = eng.search_batch(reqs)
        for q, req, r in zip(queries, reqs, batch):
            per = eng.search(req)
            assert np.array_equal(per.doc, r.doc), (q, window)
            assert np.array_equal(per.pos, r.pos), (q, window)
            positional, doc_level = brute_force_search(
                corpus, index4, q, mode=MODE_NEAR, window=window)
            if r.doc_only:
                assert set(r.doc.tolist()) == doc_level, (q, window)
            else:
                got = set(zip(r.doc.tolist(), r.pos.tolist()))
                assert got == positional, (q, window)
            for sp in eng.plan(q, mode=MODE_NEAR, window=window).subplans:
                if sp.qtype == QTYPE_MULTI:
                    streams_seen |= {(window, f.stream) for g in sp.groups
                                     for f in g.fetches}
    # window <= ND used multi-key lookups; window > ND fell back to the
    # banded ordinary-index escape
    assert (4, "multi") in streams_seen
    assert (8, "ordinary") in streams_seen
    assert (8, "multi") not in streams_seen


def test_auto_docs_per_shard_heuristic(small_world):
    """The heuristic is pinned at the canonical bench stats (ROADMAP's
    19-shard sweet spot) and behaves at the edges."""
    # canonical scale: 1200 docs, longest list ~9e4 -> 64 docs/shard
    assert auto_docs_per_shard(1200, 90_000) == 64
    assert auto_docs_per_shard(0, 0) == DOCS_PER_SHARD      # degenerate
    assert auto_docs_per_shard(10, 100) <= DOCS_PER_SHARD
    # short lists never over-shard: one shard covers everything
    assert auto_docs_per_shard(1200, 1) >= 1200
    # power of two always
    for nd, ml in ((1200, 90_000), (300, 21_000), (77, 5_000)):
        dps = auto_docs_per_shard(nd, ml)
        assert dps & (dps - 1) == 0
    # the engine default wires it up
    dev = small_world["engine"].batch_executor.dev
    assert dev.docs_per_shard == auto_docs_per_shard(
        small_world["index"].n_docs, small_world["index"].max_posting_run())

"""End-to-end behaviour: the additional-index engine and the ordinary
(Sphinx-style) baseline against the paper-semantics brute-force oracle."""
import numpy as np
import pytest

from repro.core import SearchRequest, brute_force_search
from repro.core.planner import MODE_NEAR, MODE_PHRASE


def _result_sets(r):
    if r.doc_only:
        return None, set(int(d) for d in r.doc)
    return set(zip(r.doc.tolist(), r.pos.tolist())), None


def test_engine_matches_oracle(small_world, paper_queries):
    eng = small_world["engine"]
    idx = small_world["index"]
    corpus = small_world["corpus"]
    n_checked = 0
    for q, mode, _src in paper_queries[:60]:
        truth_pos, truth_doc = brute_force_search(corpus, idx, q, mode=mode)
        r = eng.search(SearchRequest(q, mode=mode))
        got_pos, got_doc = _result_sets(r)
        if got_pos is None:
            # fallback fired: distance-aware truth must be empty, and the
            # doc-level result must equal the stream-1 ground truth
            assert not truth_pos, (q, mode)
            assert got_doc == truth_doc, (q, mode)
        else:
            assert got_pos == truth_pos, (q, mode)
        n_checked += 1
    assert n_checked >= 40


def test_source_document_always_found(small_world, paper_queries):
    """Paper: 'Since phrases are selected from an already-indexed document,
    they should be precisely found.'  Strict for phrase queries; for 2.2
    word-set queries the source occurrence can exceed the distance window
    (words sit up to 2(n-1) apart), in which case the oracle must agree
    that no within-window match exists in the source document."""
    eng = small_world["engine"]
    idx, corpus = small_world["index"], small_world["corpus"]
    for q, mode, src in paper_queries:
        r = eng.search(SearchRequest(q, mode=mode))
        docs = set(r.doc.tolist())
        if mode == "phrase":
            assert src in docs, (q, src)
        elif src not in docs:
            truth_pos, truth_doc = brute_force_search(corpus, idx, q, mode=mode)
            assert src not in {d for d, _ in truth_pos}, (q, src)
            # doc-level reachability holds whenever any interpretation has a
            # non-stop word (all-stop skip queries are sequential-only, so
            # they have no doc-level path — paper semantics)
            if truth_doc:
                assert src in truth_doc, (q, src)


def test_postings_read_improvement(small_world, paper_queries):
    """The paper's headline: additional indexes read orders of magnitude
    fewer postings than the ordinary index, and never more."""
    eng, base = small_world["engine"], small_world["ordinary"]
    ratios = []
    for q, mode, _ in paper_queries:
        pr_add = eng.search(SearchRequest(q, mode=mode)).postings_read
        pr_ord = base.search(SearchRequest(q, mode=mode)).postings_read
        assert pr_add >= 0 and pr_ord > 0
        ratios.append(pr_ord / max(pr_add, 1))
    ratios = np.array(ratios)
    assert np.mean(ratios) > 5.0, np.mean(ratios)
    assert np.max(ratios) > 20.0


def test_ordinary_engine_phrase_exact(small_world, paper_queries):
    """The baseline itself must be correct: strict-order positional truth."""
    corpus, idx = small_world["corpus"], small_world["index"]
    ana = idx.analyzer
    base = small_world["ordinary"]
    for q, mode, _ in paper_queries[:20]:
        if mode != "phrase":
            continue
        r = base.search(SearchRequest(q, mode="phrase"))
        got, _ = _result_sets(r)
        # strict-order scan
        T = corpus.n_tokens
        prim, sec = ana.primary[corpus.tokens], ana.secondary[corpus.tokens]
        doc_of = corpus.doc_ids_per_token()
        pos_of = corpus.positions_per_token()
        n = len(q)
        ms = []
        for s in q:
            forms = set(ana.forms_of(s))
            m = np.isin(prim, list(forms)) | (np.isin(sec, list(forms)) & (sec >= 0))
            ms.append(m)
        ok = ms[0][: T - n + 1].copy()
        for i in range(1, n):
            ok &= ms[i][i: T - n + 1 + i]
        ok &= doc_of[: T - n + 1] == doc_of[n - 1:]
        want = {(int(doc_of[t]), int(pos_of[t])) for t in np.nonzero(ok)[0]}
        assert got == want, q


def test_single_stop_word_unsupported(small_world):
    eng = small_world["engine"]
    # surface 0 maps to the most frequent basic form (a stop word)
    plan = eng.plan([0])
    assert any(not sp.supported for sp in plan.subplans)


def test_long_stop_phrase_split(small_world):
    """Stop phrases longer than MaxLength are split into parts and combined."""
    corpus = small_world["corpus"]
    idx = small_world["index"]
    eng = small_world["engine"]
    tf_stop = None
    # find a run of 7 consecutive stop tokens in the corpus
    from repro.core.builder import expand_token_forms
    tf = expand_token_forms(corpus, idx.lexicon, idx.analyzer)
    run = 0
    start = None
    for t in range(corpus.n_tokens):
        run = run + 1 if tf.stop_mask[t] else 0
        if run >= 7:
            start = t - 6
            break
    if start is None:
        pytest.skip("no 7-stop run in test corpus")
    doc_of = corpus.doc_ids_per_token()
    q = corpus.tokens[start:start + 7].tolist()
    r = eng.search(SearchRequest(q, mode="phrase"))
    assert int(doc_of[start]) in set(r.doc.tolist())

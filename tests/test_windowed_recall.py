"""Windowed recall for near-mode queries containing stop forms, locked to
the brute-force oracle on BOTH execution paths.

The paper's Type-4 rule confined such queries to sequential matching; the
multi-component key index (core/multi_key_index.py, QTYPE_MULTI plans) gives
them TRUE windowed answers.  This suite asserts, on a seeded 200-query
stop-heavy generator that ALWAYS runs (tests/conftest.py::stop_near_queries):

  * engine `search_batch` == brute-force oracle, exactly;
  * `SearchServe` == engine, bit-identical, on the same workload;
  * the promised-recall bookkeeping: a windowed query missing its source
    document must be missing it in the oracle too;

plus the boundary escapes for the new index: multi-key posting lists
overflowing F_SPLIT_CAP union slots, positions overflowing the 17-bit
packed field, and > G_CAP AND-groups mixed with multi-key fetches — each
oracle-verified on the fast path AND the flex fallback.  Hypothesis drivers
run in addition when the package is installed.
"""
import importlib.util

import numpy as np
import pytest

from repro.core import (AdditionalIndexEngine, BatchExecutor,
                        SearchRequest, brute_force_search,
                        near_query_stop_confined)
from repro.core.planner import MODE_NEAR, MODE_PHRASE, QTYPE_MULTI

HAS_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None


def _assert_oracle(corpus, index, q, mode, r, window=None):
    truth_pos, truth_doc = brute_force_search(corpus, index, q, mode=mode,
                                              window=window)
    if r.doc_only:
        assert not truth_pos, (q, mode)
        assert set(r.doc.tolist()) == truth_doc, (q, mode)
    else:
        got = set(zip(r.doc.tolist(), r.pos.tolist()))
        assert got == truth_pos, (q, mode)


def _same_result(r1, r2) -> bool:
    return (np.array_equal(r1.doc, r2.doc) and np.array_equal(r1.pos, r2.pos)
            and r1.postings_read == r2.postings_read
            and r1.used_fallback == r2.used_fallback
            and r1.doc_only == r2.doc_only
            and r1.subplan_types == r2.subplan_types)


# ---------------------------------------------------------------------------
# oracle parity: engine batched path
# ---------------------------------------------------------------------------


def test_engine_batch_matches_windowed_oracle(small_world, stop_near_queries):
    """search_batch on 200 stop-containing near queries == the TRUE windowed
    brute-force answer (no Type-4 confinement), bit for bit."""
    eng = small_world["engine"]
    corpus, index = small_world["corpus"], small_world["index"]
    results = eng.search_batch([SearchRequest(q, mode=MODE_NEAR)
                                for q, _src in stop_near_queries])
    n_multi = 0
    for (q, _src), r in zip(stop_near_queries, results):
        _assert_oracle(corpus, index, q, MODE_NEAR, r)
        plan = eng.plan(q, mode=MODE_NEAR)
        n_multi += int(any(sp.qtype == QTYPE_MULTI for sp in plan.subplans))
    assert n_multi >= 150, n_multi   # the workload does exercise QTYPE_MULTI


def test_engine_batch_matches_per_query_on_stop_near(small_world,
                                                     stop_near_queries):
    """Batched and flexible executors agree on the new plan type."""
    eng = small_world["engine"]
    sample = stop_near_queries[:60]
    results = eng.search_batch([SearchRequest(q, mode=MODE_NEAR)
                                for q, _ in sample])
    for (q, _), r in zip(sample, results):
        assert _same_result(eng.search(SearchRequest(q, mode=MODE_NEAR)), r), q


def test_windowed_recall_promise(small_world, stop_near_queries):
    """Source-document recall for the de-confined population: when a
    stop-containing (but not all-stop) near query misses its source doc,
    the oracle must agree there is no windowed match there AND the result
    must not have silently dropped the doc-level fallback."""
    eng = small_world["engine"]
    corpus, index = small_world["corpus"], small_world["index"]
    lex, ana = small_world["lex"], small_world["ana"]
    checked = 0
    for q, src in stop_near_queries:
        if near_query_stop_confined(lex, ana, q, MODE_NEAR):
            continue          # all-stop-only: sequential semantics, exempt
        r = eng.search(SearchRequest(q, mode=MODE_NEAR))
        if src not in set(r.doc.tolist()):
            truth_pos, truth_doc = brute_force_search(corpus, index, q,
                                                      mode=MODE_NEAR)
            assert src not in {d for d, _ in truth_pos}, (q, src)
            if r.doc_only or not truth_pos:
                assert src not in truth_doc, (q, src)
        checked += 1
    assert checked >= 150


# ---------------------------------------------------------------------------
# oracle parity: serve path
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def windowed_serve(small_world):
    from repro.launch.mesh import make_host_mesh
    from repro.serve.search_serve import SearchServe, SearchServeConfig
    cfg = SearchServeConfig(queries=16, postings_pad=4096, seed_pad=1024,
                            n_basic=1, n_expanded=1, n_stop=1, n_first=1,
                            n_multi=1)
    return SearchServe(small_world["index"], cfg, make_host_mesh(data=1,
                                                                 model=1))


def test_serve_matches_windowed_oracle(small_world, windowed_serve,
                                       stop_near_queries):
    """SearchServe on the same 200-query workload: bit-identical to the
    engine (which the tests above pin to the oracle), source recall
    included."""
    eng = small_world["engine"]
    corpus, index = small_world["corpus"], small_world["index"]
    reqs = [SearchRequest(q, mode=MODE_NEAR)
            for q, _src in stop_near_queries]
    got = windowed_serve.search_batch(reqs)
    want = eng.search_batch(reqs)
    for (q, _src), w, g in zip(stop_near_queries, want, got):
        assert _same_result(w, g), q
    # direct oracle check on a slice, so serve parity can't hide behind a
    # hypothetical engine bug in the batch above
    for (q, _src), g in list(zip(stop_near_queries, got))[:40]:
        _assert_oracle(corpus, index, q, MODE_NEAR, g)


# ---------------------------------------------------------------------------
# boundary escapes: each hatch oracle-verified on fast path AND flex
# ---------------------------------------------------------------------------


def test_boundary_multi_split_overflow_routes_flex(small_world,
                                                   stop_near_queries):
    """Multi-key posting lists long enough to overflow F_SPLIT_CAP union
    slots (caps shrunk) route the plan to the flexible executor with
    identical, oracle-verified results; moderate splits stay batched."""
    import repro.core.batch_executor as bx
    eng = small_world["engine"]
    corpus, index = small_world["corpus"], small_world["index"]
    be = BatchExecutor(index, flex=eng.executor)
    sample = stop_near_queries[:16]
    plans = [eng.plan(q, mode=MODE_NEAR) for q, _ in sample]
    multi_long = [i for i, p in enumerate(plans)
                  if any(f.stream == "multi" and f.length > 16
                         for sp in p.subplans if sp.supported
                         for g in sp.groups for f in g.fetches)]
    assert multi_long, "no long multi-key fetches in the workload"
    old_cap, old_split = bx.P_CAP, bx.F_SPLIT_CAP
    bx.P_CAP, bx.F_SPLIT_CAP = 8, 2
    try:
        for i in multi_long:
            assert not be._build_tasks(i, plans[i], [])
        got = be.execute_batch(plans)
    finally:
        bx.P_CAP, bx.F_SPLIT_CAP = old_cap, old_split
    for (q, _), r in zip(sample, got):
        assert _same_result(eng.search(SearchRequest(q, mode=MODE_NEAR)), r), q
        _assert_oracle(corpus, index, q, MODE_NEAR, r)
    # moderate shrink: splits fit, the multi plans STAY batched
    bx.P_CAP = 8
    try:
        be2 = BatchExecutor(index, flex=eng.executor)
        tasks: list = []
        assert be2._build_tasks(0, plans[multi_long[0]], tasks)
        assert any(len(g.slots) > 1 for t in tasks for row in t.rows
                   for g in row.groups), "long multi fetch was not split"
        got2 = be2.execute_batch(plans)
    finally:
        bx.P_CAP = old_cap
    for (q, _), r in zip(sample, got2):
        assert _same_result(eng.search(SearchRequest(q, mode=MODE_NEAR)), r), q


def test_boundary_position_overflow_with_multi_routes_flex():
    """An index whose positions overflow the 17-bit packed field routes
    stop-containing near plans to flex — results still windowed and
    oracle-exact."""
    from repro.core import (CorpusConfig, LexiconConfig, build_all,
                            generate_corpus, make_lexicon_and_analyzer,
                            near_query_contains_stop)
    from repro.core.fetch_tables import TABLE_POS_BITS
    lc = LexiconConfig(n_surface=2000, n_base=1500, n_stop=50,
                       n_frequent=200, seed=5)
    lex, ana = make_lexicon_and_analyzer(lc)
    corpus = generate_corpus(lc, CorpusConfig(n_docs=2, mean_doc_len=150_000,
                                              seed=5))
    index = build_all(corpus, lex, ana)
    eng = AdditionalIndexEngine(index)
    be = eng.batch_executor
    assert be._pos_budget <= 0
    toks = corpus.doc(0)
    rng = np.random.default_rng(9)
    queries = []
    while len(queries) < 4:
        st = int(rng.integers(0, len(toks) - 8))
        q = toks[st:st + 8:2].tolist()
        if near_query_contains_stop(lex, ana, q):
            queries.append(q)
    plans = [eng.plan(q, mode=MODE_NEAR) for q in queries]
    assert any(sp.qtype == QTYPE_MULTI for p in plans for sp in p.subplans)
    assert all(not be._build_tasks(i, p, []) for i, p in enumerate(plans))
    for q, r in zip(queries, be.execute_batch(plans)):
        assert _same_result(eng.search(SearchRequest(q, mode=MODE_NEAR)), r), q
        _assert_oracle(corpus, index, q, MODE_NEAR, r)


def test_boundary_many_groups_with_multi_routes_flex(small_world):
    """> G_CAP AND-groups in a plan that also carries multi-key fetches
    (a long stop-mixed near query) must route to flex, oracle-verified."""
    import repro.core.batch_executor as bx
    from repro.core import near_query_contains_stop
    corpus = small_world["corpus"]
    index = small_world["index"]
    lex, ana = small_world["lex"], small_world["ana"]
    eng = small_world["engine"]
    be = BatchExecutor(index, flex=eng.executor)
    queries, plans = [], []
    for d in range(corpus.n_docs):
        toks = corpus.doc(d)
        for st in range(0, max(len(toks) - 14, 0), 5):
            q = toks[st:st + 12].tolist()
            if not near_query_contains_stop(lex, ana, q):
                continue
            plan = eng.plan(q, mode=MODE_NEAR)
            # the big subplan must be live (a dead group skips the cap
            # check: the main task is never built, only the fallback)
            big = [sp for sp in plan.subplans if sp.supported
                   and len(sp.groups) > bx.G_CAP
                   and all(g.fetches for g in sp.groups)]
            if big and any(f.stream == "multi" for sp in big
                           for g in sp.groups for f in g.fetches):
                queries.append(q)
                plans.append(plan)
            if len(queries) == 3:
                break
        if len(queries) == 3:
            break
    assert queries, "no >G_CAP stop-mixed near windows found"
    assert all(not be._build_tasks(i, p, []) for i, p in enumerate(plans))
    for q, r in zip(queries, be.execute_batch(plans)):
        assert _same_result(eng.search(SearchRequest(q, mode=MODE_NEAR)), r), q
        _assert_oracle(small_world["corpus"], index, q, MODE_NEAR, r)


def test_wide_window_beyond_reach_matches_oracle(small_world,
                                                 stop_near_queries):
    """A window wider than EVERY index reach (expanded pair reach and
    multi-key NeighborDistance): frequent slots fall back to exact basic
    fetches (with the pivot's own group joining Type-2 plans) and stop
    slots to banded full ordinary-index reads — results must still match
    the windowed oracle exactly.  Guards both reach-guard failure modes:
    silent under-coverage AND killing coverable slots."""
    eng = small_world["engine"]
    corpus, index = small_world["corpus"], small_world["index"]
    lex = small_world["lex"]
    W = index.params.near_window + 4

    # all-frequent pair (Type 2): derived from a stored both-frequent
    # expanded key so the wide-window truth is non-empty
    exp, n_base = index.expanded, index.expanded.n_base
    t2_query = None
    for key in exp.pairs.keys:
        w, v = int(key // n_base), int(key % n_base)
        if w == v or not (lex.is_frequent(np.asarray([w]))[0]
                          and lex.is_frequent(np.asarray([v]))[0]):
            continue
        sw, sv = (_single_form_surface(small_world, b) for b in (w, v))
        if sw is not None and sv is not None:
            t2_query = [sw, sv]
            break
    assert t2_query is not None
    plan = eng.plan(t2_query, mode=MODE_NEAR, window=W)
    sp = next(sp for sp in plan.subplans if sp.supported)
    assert sp.qtype == 2
    # fell back: basic fetches present (reach exceeded), no expanded ones
    streams = {f.stream for g in sp.groups for f in g.fetches}
    assert streams == {"basic"}
    r = eng.search(SearchRequest(t2_query, mode=MODE_NEAR, window=W))
    _assert_oracle(corpus, index, t2_query, MODE_NEAR, r, window=W)
    assert not r.doc_only and len(r.doc) > 0      # non-vacuous

    # stop-containing near queries: stop slots become banded ordinary reads
    sample = stop_near_queries[:10]
    got = eng.search_batch([SearchRequest(q, mode=MODE_NEAR, window=W)
                            for q, _ in sample])
    n_ord = 0
    for (q, _src), r in zip(sample, got):
        plan = eng.plan(q, mode=MODE_NEAR, window=W)
        n_ord += any(f.stream == "ordinary"
                     for sp in plan.subplans if sp.supported
                     for g in sp.groups for f in g.fetches)
        assert _same_result(eng.search(SearchRequest(q, mode=MODE_NEAR, window=W)), r), q
        _assert_oracle(corpus, index, q, MODE_NEAR, r, window=W)
    assert n_ord >= 5     # the escape path is actually exercised


def _single_form_surface(world, base):
    """A surface whose ONLY basic form is `base`, or None."""
    ana = world["ana"]
    lo = int(np.searchsorted(ana.primary, base, side="left"))
    hi = int(np.searchsorted(ana.primary, base, side="right"))
    for s in range(lo, hi):
        if ana.forms_of(s) == [base]:
            return s
    return None


# ---------------------------------------------------------------------------
# hypothesis drivers (when installed: adversarial query search + shrinking)
# ---------------------------------------------------------------------------


if HAS_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @given(st.data())
    @settings(max_examples=25, deadline=None)
    def test_windowed_oracle_hyp(small_world, data):
        corpus, index = small_world["corpus"], small_world["index"]
        eng = small_world["engine"]
        d = data.draw(st.integers(0, corpus.n_docs - 1))
        toks = corpus.doc(d)
        n = data.draw(st.integers(2, 6))
        stride = data.draw(st.integers(1, 3))
        span = stride * (n - 1) + 1
        if len(toks) <= span:
            return
        start = data.draw(st.integers(0, len(toks) - span - 1))
        q = toks[start:start + span:stride].tolist()
        r = eng.search(SearchRequest(q, mode=MODE_NEAR))
        _assert_oracle(corpus, index, q, MODE_NEAR, r)

"""Property-based tests on system invariants.

Every invariant is a plain `check_*` function.  A seeded numpy case
generator drives them ALWAYS (so the suite never silently skips in
containers without `hypothesis`); when `hypothesis` is installed the same
invariants additionally run under `@given` with its shrinking search.
"""
import importlib.util

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.postings import (CSR, PHRASE_BIAS, pack_dist_pair,
                                 pack_multi_pair_key, pack_multi_triple_key,
                                 pack_near_stop_slot, pack_stop_phrase_key,
                                 shifted_key, unpack_dist_pair,
                                 unpack_multi_pair_key,
                                 unpack_multi_triple_key,
                                 unpack_near_stop_slot, unpack_shifted_key)
from repro.core.planner import split_query_parts
from repro.dist.collectives import dequantize_int8, quantize_int8
from repro.kernels import ops

HAS_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None


# ---------------------------------------------------------------------------
# invariants
# ---------------------------------------------------------------------------

def check_shifted_key_roundtrip(pairs, offset):
    doc = np.array([p[0] for p in pairs], np.int64)
    pos = np.array([p[1] for p in pairs], np.int64) + offset
    keys = shifted_key(doc, pos, offset)
    d2, p2 = unpack_shifted_key(keys, offset)
    assert np.array_equal(d2, doc) and np.array_equal(p2, pos)


def check_stop_phrase_key_order_invariant(ids):
    a = np.sort(np.array(ids, np.int64))
    k1 = pack_stop_phrase_key(a[None, :])[0]
    rng = np.random.default_rng(0)
    shuf = a.copy()
    rng.shuffle(shuf)
    k2 = pack_stop_phrase_key(np.sort(shuf)[None, :])[0]
    assert k1 == k2
    # length is part of the key: a prefix never collides
    if len(a) > 2:
        k3 = pack_stop_phrase_key(a[None, :-1])[0]
        assert k3 != k1


def check_near_stop_slot_roundtrip(delta, sid, maxd):
    if abs(delta) > maxd:
        delta = maxd if delta > 0 else -maxd
    slot = pack_near_stop_slot(np.array([delta]), np.array([sid]), maxd)
    d2, s2 = unpack_near_stop_slot(slot, maxd)
    assert d2[0] == delta and s2[0] == sid


def check_multi_key_roundtrip(s1, s2, v, n_base, n_stop):
    """Multi-component key codecs (arXiv:2006.07954 canonical keys): pair
    and triple keys round-trip; triple keys are canonical in (s1, s2) —
    i.e. a sorted component pair produces the same key regardless of the
    order the caller discovered the stops in — and the packed distance-pair
    payload round-trips."""
    ps, pv = unpack_multi_pair_key(pack_multi_pair_key(s1, v, n_base), n_base)
    assert (int(ps), int(pv)) == (s1, v)
    a, b = min(s1, s2), max(s1, s2)
    if a != b:
        k = pack_multi_triple_key(a, b, v, n_stop)
        u1, u2, uv = unpack_multi_triple_key(k, n_stop)
        assert (int(u1), int(u2), int(uv)) == (a, b, v)
        # canonicality: same key from either discovery order via sorting
        assert int(k) == int(pack_multi_triple_key(min(s2, s1), max(s2, s1),
                                                   v, n_stop))
        # injective in each component: bumping any one changes the key
        for da, db, dv in ((1, 0, 0), (0, 1, 0), (0, 0, 1)):
            if a + da < b + db or db:     # keep canonical a < b
                assert int(k) != int(pack_multi_triple_key(
                    a + da, b + db, v + dv, n_stop))


def check_dist_pair_roundtrip(d1, d2):
    u1, u2 = unpack_dist_pair(pack_dist_pair(d1, d2))
    assert (int(u1), int(u2)) == (d1, d2)


def check_csr_from_unsorted_invariants(keys):
    keys = np.array(keys, np.int64)
    vals = np.arange(len(keys), dtype=np.int32)
    csr = CSR.from_unsorted(keys, {"v": vals})
    assert np.all(np.diff(csr.keys) > 0)                 # unique + sorted
    assert csr.offsets[-1] == len(keys)
    # every (key, val) pair is preserved
    rebuilt = []
    for i, k in enumerate(csr.keys):
        for v in csr.columns["v"][csr.offsets[i]:csr.offsets[i + 1]]:
            rebuilt.append((int(k), int(v)))
    assert sorted(rebuilt) == sorted(zip(keys.tolist(), vals.tolist()))


def check_split_query_parts_cover(n, mn, mx):
    if mn > mx or n < mn:
        return
    parts = split_query_parts(n, mn, mx)
    covered = set()
    for s, ln in parts:
        assert mn <= ln <= mx and 0 <= s and s + ln <= n
        covered |= set(range(s, s + ln))
    assert covered == set(range(n))


def check_banded_intersect(a, b, band):
    a = np.array(a, np.int32)
    b = np.sort(np.array(b, np.int32))
    got = np.asarray(ops.banded_intersect(jnp.asarray(a), jnp.asarray(b), band,
                                          block_a=256, block_b=256))
    want = np.array([((b >= x - band) & (b <= x + band)).any() for x in a])
    assert np.array_equal(got, want)


def check_int8_quantization_error_bound(xs):
    x = jnp.asarray(np.array(xs, np.float32))
    q, scale = quantize_int8(x)
    err = float(jnp.abs(dequantize_int8(q, scale) - x).max())
    assert err <= float(scale) * 0.5 + 1e-6


def check_segment_bag(B, F, V, D):
    rng = np.random.default_rng(B * 100 + F)
    table = jnp.asarray(rng.normal(size=(V, D)).astype(np.float32))
    ids = jnp.asarray(rng.integers(-1, V, (B, F)).astype(np.int32))
    got = np.asarray(ops.segment_bag(table, ids))
    want = np.zeros((B, D), np.float32)
    for i in range(B):
        for j in range(F):
            if int(ids[i, j]) >= 0:
                want[i] += np.asarray(table)[int(ids[i, j])]
    assert np.abs(got - want).max() < 1e-4


# ---------------------------------------------------------------------------
# seeded hypothesis-free drivers (always run)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(25))
def test_shifted_key_roundtrip(seed):
    rng = np.random.default_rng(100 + seed)
    n = int(rng.integers(1, 201))
    pairs = list(zip(rng.integers(0, 2**30, n).tolist(),
                     rng.integers(0, 2**20, n).tolist()))
    check_shifted_key_roundtrip(pairs, int(rng.integers(0, 17)))


@pytest.mark.parametrize("seed", range(25))
def test_stop_phrase_key_order_invariant(seed):
    rng = np.random.default_rng(200 + seed)
    ids = rng.integers(0, 1024, int(rng.integers(2, 6))).tolist()
    check_stop_phrase_key_order_invariant(ids)


@pytest.mark.parametrize("seed", range(25))
def test_near_stop_slot_roundtrip(seed):
    rng = np.random.default_rng(300 + seed)
    delta = int(rng.choice([d for d in range(-7, 8) if d != 0]))
    check_near_stop_slot_roundtrip(delta, int(rng.integers(0, 1024)),
                                   int(rng.integers(5, 8)))


@pytest.mark.parametrize("seed", range(25))
def test_multi_key_roundtrip(seed):
    rng = np.random.default_rng(900 + seed)
    n_stop = int(rng.integers(8, 1025))
    n_base = int(rng.integers(n_stop + 8, 50_001))
    s1, s2 = rng.integers(0, n_stop, 2)
    v = int(rng.integers(n_stop, n_base))
    check_multi_key_roundtrip(int(s1), int(s2), v, n_base, n_stop)


@pytest.mark.parametrize("seed", range(15))
def test_dist_pair_roundtrip(seed):
    rng = np.random.default_rng(1000 + seed)
    # full nibbles (NeighborDistance <= 15), incl. the int8 sign bit
    check_dist_pair_roundtrip(int(rng.integers(0, 16)),
                              int(rng.integers(0, 16)))


@pytest.mark.parametrize("seed", range(15))
def test_csr_from_unsorted_invariants(seed):
    rng = np.random.default_rng(400 + seed)
    n = int(rng.integers(0, 301))
    check_csr_from_unsorted_invariants(rng.integers(0, 1001, n).tolist())


@pytest.mark.parametrize("seed", range(40))
def test_split_query_parts_cover(seed):
    rng = np.random.default_rng(500 + seed)
    check_split_query_parts_cover(int(rng.integers(2, 25)),
                                  int(rng.integers(2, 4)),
                                  int(rng.integers(3, 7)))


@pytest.mark.parametrize("seed", range(10))
def test_banded_intersect_property(seed):
    rng = np.random.default_rng(600 + seed)
    a = rng.integers(0, 2**20, int(rng.integers(1, 501))).tolist()
    b = rng.integers(0, 2**20, int(rng.integers(1, 501))).tolist()
    check_banded_intersect(a, b, int(rng.integers(0, 9)))


def test_banded_intersect_edge_cases():
    # duplicates straddling block boundaries, empty band, all-equal keys
    check_banded_intersect([7] * 300, [7] * 300, 0)
    check_banded_intersect([0, 2**20], [2**19], 2**19)
    check_banded_intersect([5], list(range(500)), 0)


@pytest.mark.parametrize("seed", range(20))
def test_int8_quantization_error_bound(seed):
    rng = np.random.default_rng(700 + seed)
    xs = (rng.uniform(-100, 100, int(rng.integers(1, 65)))
          .astype(np.float32).tolist())
    check_int8_quantization_error_bound(xs)


@pytest.mark.parametrize("seed", range(10))
def test_segment_bag_property(seed):
    rng = np.random.default_rng(800 + seed)
    check_segment_bag(int(rng.integers(1, 7)), int(rng.integers(1, 9)),
                      int(rng.integers(2, 51)), int(rng.integers(1, 33)))


# ---------------------------------------------------------------------------
# hypothesis drivers (when installed: adds shrinking + adversarial search)
# ---------------------------------------------------------------------------

if HAS_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @given(st.lists(st.tuples(st.integers(0, 2**30), st.integers(0, 2**20)),
                    min_size=1, max_size=200),
           st.integers(0, 16))
    @settings(max_examples=50, deadline=None)
    def test_shifted_key_roundtrip_hyp(pairs, offset):
        check_shifted_key_roundtrip(pairs, offset)

    @given(st.lists(st.integers(0, 1023), min_size=2, max_size=5))
    @settings(max_examples=100, deadline=None)
    def test_stop_phrase_key_order_invariant_hyp(ids):
        check_stop_phrase_key_order_invariant(ids)

    @given(st.integers(-7, 7).filter(lambda d: d != 0), st.integers(0, 1023),
           st.integers(5, 7))
    @settings(max_examples=50, deadline=None)
    def test_near_stop_slot_roundtrip_hyp(delta, sid, maxd):
        check_near_stop_slot_roundtrip(delta, sid, maxd)

    @given(st.lists(st.integers(0, 1000), min_size=0, max_size=300))
    @settings(max_examples=50, deadline=None)
    def test_csr_from_unsorted_invariants_hyp(keys):
        check_csr_from_unsorted_invariants(keys)

    @given(st.integers(8, 1024), st.data())
    @settings(max_examples=50, deadline=None)
    def test_multi_key_roundtrip_hyp(n_stop, data):
        n_base = data.draw(st.integers(n_stop + 1, 60_000))
        s1 = data.draw(st.integers(0, n_stop - 1))
        s2 = data.draw(st.integers(0, n_stop - 1))
        v = data.draw(st.integers(n_stop, n_base - 1))
        check_multi_key_roundtrip(s1, s2, v, n_base, n_stop)

    @given(st.integers(0, 15), st.integers(0, 15))
    @settings(max_examples=50, deadline=None)
    def test_dist_pair_roundtrip_hyp(d1, d2):
        check_dist_pair_roundtrip(d1, d2)

    @given(st.integers(2, 24), st.integers(2, 3), st.integers(3, 6))
    @settings(max_examples=100, deadline=None)
    def test_split_query_parts_cover_hyp(n, mn, mx):
        check_split_query_parts_cover(n, mn, mx)

    @given(st.lists(st.integers(0, 2**20), min_size=1, max_size=500),
           st.lists(st.integers(0, 2**20), min_size=1, max_size=500),
           st.integers(0, 8))
    @settings(max_examples=30, deadline=None)
    def test_banded_intersect_property_hyp(a, b, band):
        check_banded_intersect(a, b, band)

    @given(st.lists(st.floats(-100, 100, allow_nan=False),
                    min_size=1, max_size=64))
    @settings(max_examples=50, deadline=None)
    def test_int8_quantization_error_bound_hyp(xs):
        check_int8_quantization_error_bound(xs)

    @given(st.integers(1, 6), st.integers(1, 8), st.integers(2, 50),
           st.integers(1, 32))
    @settings(max_examples=30, deadline=None)
    def test_segment_bag_property_hyp(B, F, V, D):
        check_segment_bag(B, F, V, D)

"""Property-based tests (hypothesis) on system invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.postings import (CSR, PHRASE_BIAS, pack_near_stop_slot,
                                 pack_stop_phrase_key, shifted_key,
                                 unpack_near_stop_slot, unpack_shifted_key)
from repro.core.planner import split_query_parts
from repro.dist.collectives import dequantize_int8, quantize_int8
from repro.kernels import ops


@given(st.lists(st.tuples(st.integers(0, 2**30), st.integers(0, 2**20)),
                min_size=1, max_size=200),
       st.integers(0, 16))
@settings(max_examples=50, deadline=None)
def test_shifted_key_roundtrip(pairs, offset):
    doc = np.array([p[0] for p in pairs], np.int64)
    pos = np.array([p[1] for p in pairs], np.int64) + offset
    keys = shifted_key(doc, pos, offset)
    d2, p2 = unpack_shifted_key(keys, offset)
    assert np.array_equal(d2, doc) and np.array_equal(p2, pos)


@given(st.lists(st.integers(0, 1023), min_size=2, max_size=5))
@settings(max_examples=100, deadline=None)
def test_stop_phrase_key_order_invariant(ids):
    a = np.sort(np.array(ids, np.int64))
    k1 = pack_stop_phrase_key(a[None, :])[0]
    rng = np.random.default_rng(0)
    shuf = a.copy()
    rng.shuffle(shuf)
    k2 = pack_stop_phrase_key(np.sort(shuf)[None, :])[0]
    assert k1 == k2
    # length is part of the key: a prefix never collides
    if len(a) > 2:
        k3 = pack_stop_phrase_key(a[None, :-1])[0]
        assert k3 != k1


@given(st.integers(-7, 7).filter(lambda d: d != 0), st.integers(0, 1023),
       st.integers(5, 7))
@settings(max_examples=50, deadline=None)
def test_near_stop_slot_roundtrip(delta, sid, maxd):
    if abs(delta) > maxd:
        delta = maxd if delta > 0 else -maxd
    slot = pack_near_stop_slot(np.array([delta]), np.array([sid]), maxd)
    d2, s2 = unpack_near_stop_slot(slot, maxd)
    assert d2[0] == delta and s2[0] == sid


@given(st.lists(st.integers(0, 1000), min_size=0, max_size=300))
@settings(max_examples=50, deadline=None)
def test_csr_from_unsorted_invariants(keys):
    keys = np.array(keys, np.int64)
    vals = np.arange(len(keys), dtype=np.int32)
    csr = CSR.from_unsorted(keys, {"v": vals})
    assert np.all(np.diff(csr.keys) > 0)                 # unique + sorted
    assert csr.offsets[-1] == len(keys)
    # every (key, val) pair is preserved
    rebuilt = []
    for i, k in enumerate(csr.keys):
        for v in csr.columns["v"][csr.offsets[i]:csr.offsets[i + 1]]:
            rebuilt.append((int(k), int(v)))
    assert sorted(rebuilt) == sorted(zip(keys.tolist(), vals.tolist()))


@given(st.integers(2, 24), st.integers(2, 3), st.integers(3, 6))
@settings(max_examples=100, deadline=None)
def test_split_query_parts_cover(n, mn, mx):
    if mn > mx or n < mn:
        return
    parts = split_query_parts(n, mn, mx)
    covered = set()
    for s, ln in parts:
        assert mn <= ln <= mx and 0 <= s and s + ln <= n
        covered |= set(range(s, s + ln))
    assert covered == set(range(n))


@given(st.lists(st.integers(0, 2**20), min_size=1, max_size=500),
       st.lists(st.integers(0, 2**20), min_size=1, max_size=500),
       st.integers(0, 8))
@settings(max_examples=30, deadline=None)
def test_banded_intersect_property(a, b, band):
    a = np.array(a, np.int32)
    b = np.sort(np.array(b, np.int32))
    got = np.asarray(ops.banded_intersect(jnp.asarray(a), jnp.asarray(b), band,
                                          block_a=256, block_b=256))
    want = np.array([((b >= x - band) & (b <= x + band)).any() for x in a])
    assert np.array_equal(got, want)


@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=1, max_size=64))
@settings(max_examples=50, deadline=None)
def test_int8_quantization_error_bound(xs):
    x = jnp.asarray(np.array(xs, np.float32))
    q, scale = quantize_int8(x)
    err = float(jnp.abs(dequantize_int8(q, scale) - x).max())
    assert err <= float(scale) * 0.5 + 1e-6


@given(st.integers(1, 6), st.integers(1, 8), st.integers(2, 50), st.integers(1, 32))
@settings(max_examples=30, deadline=None)
def test_segment_bag_property(B, F, V, D):
    rng = np.random.default_rng(B * 100 + F)
    table = jnp.asarray(rng.normal(size=(V, D)).astype(np.float32))
    ids = jnp.asarray(rng.integers(-1, V, (B, F)).astype(np.int32))
    got = np.asarray(ops.segment_bag(table, ids))
    want = np.zeros((B, D), np.float32)
    for i in range(B):
        for j in range(F):
            if int(ids[i, j]) >= 0:
                want[i] += np.asarray(table)[int(ids[i, j])]
    assert np.abs(got - want).max() < 1e-4

"""Index-construction correctness: every structure vs first principles."""
import numpy as np

from repro.core.builder import (IndexParams, build_stop_phrase_index,
                                expand_token_forms,
                                reference_stop_phrase_postings)
from repro.core.postings import unpack_near_stop_slot


def test_stop_phrase_matches_paper_literal_reference(small_world):
    """Vectorized builder == the paper's Queue/Process algorithm, exactly."""
    idx = small_world["index"]
    tf = expand_token_forms(small_world["corpus"], idx.lexicon, idx.analyzer)
    ref = sorted(reference_stop_phrase_postings(tf, idx.params))
    got = []
    ph = idx.stop_phrase.phrases
    for i, k in enumerate(ph.keys):
        s, e = int(ph.offsets[i]), int(ph.offsets[i + 1])
        for d, p in zip(ph.columns["doc"][s:e], ph.columns["pos"][s:e]):
            got.append((int(k), int(d), int(p)))
    assert sorted(got) == ref


def test_stop_phrase_run_counts():
    """Paper: 10 consecutive stop words -> nine 2-phrases, eight 3-phrases..."""
    from repro.core.builder import TokenForms
    n = 10
    tf = TokenForms(
        doc_of=np.zeros(n, np.int32), pos_of=np.arange(n, dtype=np.int32),
        s1_local=np.arange(n, dtype=np.int32) % 5,
        s2_local=np.full(n, -1, np.int32),
        n1=np.full(n, -1, np.int32), n2=np.full(n, -1, np.int32))
    params = IndexParams(min_len=2, max_len=5)
    spi = build_stop_phrase_index(tf, params)
    total = spi.phrases.n_postings
    assert total == 9 + 8 + 7 + 6      # lengths 2..5


def test_expanded_index_invariants(small_world):
    """(w,v) postings: w frequent, v non-stop, |dist| <= PD(w), and the
    canonical orientation stores each both-frequent pair once.  dist == 0
    postings are same-token pairs (one token carrying both basic forms) —
    every one must be backed by such a token."""
    idx = small_world["index"]
    lex = idx.lexicon
    pairs = idx.expanded.pairs
    n_base = idx.expanded.n_base
    w = (pairs.keys // n_base).astype(np.int64)
    v = (pairs.keys % n_base).astype(np.int64)
    assert lex.is_frequent(w).all()
    assert (~lex.is_stop(v)).all()
    both = lex.is_frequent(v)
    assert (w[both] <= v[both]).all()          # canonical orientation
    tf = expand_token_forms(small_world["corpus"], lex, idx.analyzer)
    same_token = {(int(d), int(p), *sorted((int(a), int(b))))
                  for d, p, a, b in zip(tf.doc_of[(tf.n1 >= 0) & (tf.n2 >= 0)],
                                        tf.pos_of[(tf.n1 >= 0) & (tf.n2 >= 0)],
                                        tf.n1[(tf.n1 >= 0) & (tf.n2 >= 0)],
                                        tf.n2[(tf.n1 >= 0) & (tf.n2 >= 0)])}
    # dist bounds per key: reach = max(ProcessingDistance, near_window)
    pd = np.maximum(lex.processing_distance(w),
                    small_world["index"].params.near_window)
    n_zero = 0
    for i in range(pairs.n_keys):
        s, e = int(pairs.offsets[i]), int(pairs.offsets[i + 1])
        d = pairs.columns["dist"][s:e]
        assert (np.abs(d.astype(np.int32)) <= pd[i]).all()
        for j in np.nonzero(d == 0)[0]:
            n_zero += 1
            key = (int(pairs.columns["doc"][s + j]),
                   int(pairs.columns["pos"][s + j]),
                   *sorted((int(w[i]), int(v[i]))))
            assert key in same_token, key
    assert n_zero > 0      # the corpus does contain multi-form pairs


def test_expanded_lookup_mirror(small_world):
    """Looking up (v, w) when (w, v) is stored recovers v's positions."""
    idx = small_world["index"]
    lex = idx.lexicon
    pairs = idx.expanded.pairs
    n_base = idx.expanded.n_base
    done = 0
    for key in pairs.keys[:2000]:
        w, v = int(key // n_base), int(key % n_base)
        if w == v or not lex.is_frequent(np.array([v]))[0]:
            continue
        fwd = idx.expanded.lookup(w, v)
        mir = idx.expanded.lookup(v, w)
        assert fwd is not None and mir is not None
        assert np.array_equal(np.sort(fwd["pos"] + fwd["dist"]), np.sort(mir["pos"]))
        done += 1
        if done >= 5:
            break
    assert done > 0


def test_first_occ_stream_counts(small_world):
    """Stream 1 (doc, first pos, count) must tally with the occurrence CSR."""
    idx = small_world["index"]
    b = idx.basic
    rng = np.random.default_rng(0)
    for base in rng.integers(idx.lexicon.config.n_stop,
                             idx.lexicon.config.n_base, 200):
        occ = b.occurrences.slice(int(base))
        fo = b.first_occ.slice(int(base))
        assert fo["count"].sum() == len(occ["doc"])
        docs, first_idx = np.unique(occ["doc"], return_index=True)
        assert np.array_equal(fo["doc"], docs)
        assert np.array_equal(fo["pos"], occ["pos"][first_idx])


def test_near_stop_stream_lossless(small_world):
    """Stream 3 holds EVERY stop form within MaxDistance (near_slots=4D)."""
    idx = small_world["index"]
    corpus = small_world["corpus"]
    tf = expand_token_forms(corpus, idx.lexicon, idx.analyzer)
    b = idx.basic
    D = b.max_distance
    base = int(idx.lexicon.config.n_stop) + 5      # a frequent form
    occ = b.occurrences.slice(base)
    slots = b.near_stop_of(base)
    g_of = {}
    # reconstruct expected near-stops from the corpus for a few occurrences
    doc_of, pos_of = tf.doc_of, tf.pos_of
    starts = corpus.doc_offsets
    for i in range(min(len(occ["doc"]), 50)):
        d, p = int(occ["doc"][i]), int(occ["pos"][i])
        g = int(starts[d]) + p
        want = set()
        for delta in range(-D, D + 1):
            if delta == 0:
                continue
            u = g + delta
            if 0 <= u < corpus.n_tokens and doc_of[u] == d:
                for sl in (tf.s1_local[u], tf.s2_local[u]):
                    if sl >= 0:
                        want.add((delta, int(sl)))
        got = set()
        row = slots[i]
        for slot in row[row >= 0]:
            dd, ss = unpack_near_stop_slot(int(slot), D)
            got.add((int(dd), int(ss)))
        assert got == want, (d, p)

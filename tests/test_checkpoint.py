"""Checkpointing + fault tolerance: atomic publish, keep-k, failure
injection + restart, straggler re-dispatch."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, restore_pytree, save_pytree
from repro.dist.fault_tolerance import (ShardDispatcher, TrainSupervisor,
                                        merge_topk)


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {"a": jax.random.normal(k, (8, 4)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                       "c": [jnp.ones((3,)), jnp.zeros((2, 2))]}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save_pytree(str(tmp_path / "ck"), t, step=5)
    got = restore_pytree(str(tmp_path / "ck"), t)
    for a, b in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(got)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_manager_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.latest_step() == 4
    dirs = sorted(os.listdir(tmp_path))
    assert dirs == ["step_00000003", "step_00000004"]
    step, got = mgr.restore_latest(_tree())
    assert step == 4


def test_supervisor_failure_injection(tmp_path):
    """Training survives injected failures and completes all steps."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    sup = TrainSupervisor(mgr, save_every=5)

    def step_fn(state, i):
        return {"w": state["w"] + 1.0}

    fail_at = {7, 13}
    fired = set()

    def failure_hook(step):
        if step in fail_at and step not in fired:
            fired.add(step)
            return True
        return False

    state, report = sup.run({"w": jnp.zeros(())}, step_fn, n_steps=20,
                            failure_hook=failure_hook)
    assert report.failures == 2
    assert report.final_step == 20
    assert float(state["w"]) == 20.0   # deterministic step => exact replay


def test_dispatcher_straggler_redispatch():
    calls = {"n": 0}

    def flaky(batch):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("shard down")
        return np.array([[1.0, 7.0]])

    def healthy(batch):
        return np.array([[2.0, 3.0]])

    d = ShardDispatcher([flaky, healthy], replica_fns=[healthy, healthy],
                        timeout=10.0)
    res = d.dispatch("q")
    assert d.stats.redispatched == 1
    merged = merge_topk(res, k=2)
    assert merged[0][0] == 2.0


def test_elastic_restore_with_shardings(tmp_path):
    """Restore applies a target sharding tree (single-device NamedSharding
    here; the mesh-shape change path is exercised in test_dist.py)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.compat import auto_axis_types, make_mesh
    mesh = make_mesh((1,), ("data",), axis_types=auto_axis_types(1))
    t = _tree()
    save_pytree(str(tmp_path / "ck"), t)
    sh = jax.tree_util.tree_map(
        lambda l: NamedSharding(mesh, P(*([None] * l.ndim))), t)
    got = restore_pytree(str(tmp_path / "ck"), t, shardings=sh)
    for a, b in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(got)):
        assert np.array_equal(np.asarray(a), np.asarray(b))

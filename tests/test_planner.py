"""Planner: classification, query splitting, pivot choice, parts."""
import numpy as np
import pytest

from repro.core.lexicon import TIER_FREQUENT, TIER_ORDINARY, TIER_STOP
from repro.core.planner import MODE_NEAR, MODE_PHRASE, split_query_parts


def _surface_of_tier(world, tier, k=1):
    """Surfaces whose ONLY basic-form tier is `tier`."""
    ana, lex = world["ana"], world["lex"]
    out = []
    for s in range(world["lex"].config.n_surface):
        forms = ana.forms_of(s)
        tiers = {int(lex.base_tier[f]) for f in forms}
        if tiers == {tier}:
            out.append(s)
        if len(out) >= k:
            break
    assert len(out) >= k
    return out


def test_type_classification(small_world):
    planner = small_world["engine"].planner
    stop = _surface_of_tier(small_world, TIER_STOP, 3)
    freq = _surface_of_tier(small_world, TIER_FREQUENT, 3)
    ordi = _surface_of_tier(small_world, TIER_ORDINARY, 3)

    assert planner.plan(stop).subplans[0].qtype == 1
    assert planner.plan(freq).subplans[0].qtype == 2
    assert planner.plan(freq[:1] + ordi[:2]).subplans[0].qtype == 3
    assert planner.plan(stop[:1] + freq[:1] + ordi[:1]).subplans[0].qtype == 4


def test_query_splitting_multi_tier(small_world):
    """A word with basic forms in two tiers splits the query (paper:
    PROCESSING QUERIES)."""
    ana, lex = small_world["ana"], small_world["lex"]
    planner = small_world["engine"].planner
    mixed = None
    for s in range(lex.config.n_surface):
        tiers = {int(lex.base_tier[f]) for f in ana.forms_of(s)}
        if len(tiers) > 1:
            mixed = s
            break
    assert mixed is not None
    plan = planner.plan([mixed] + _surface_of_tier(small_world, TIER_ORDINARY, 1))
    assert len(plan.subplans) >= 2
    assert len({sp.qtype for sp in plan.subplans}) >= 1


def test_type2_reads_n_minus_1_expanded_lists(small_world):
    """Paper Type 2: n-1 expanded indexes, pivot = rarest word."""
    planner = small_world["engine"].planner
    freq = _surface_of_tier(small_world, TIER_FREQUENT, 3)
    plan = planner.plan(freq, mode=MODE_PHRASE)
    sp = plan.subplans[0]
    assert sp.qtype == 2
    assert len(sp.groups) == len(freq) - 1
    for g in sp.groups:
        for f in g.fetches:
            assert f.stream == "expanded"


def test_type4_pivot_checks_stop_words_via_stream3(small_world):
    planner = small_world["engine"].planner
    stop = _surface_of_tier(small_world, TIER_STOP, 2)
    ordi = _surface_of_tier(small_world, TIER_ORDINARY, 1)
    plan = planner.plan([stop[0], ordi[0], stop[1]])
    sp = plan.subplans[0]
    assert sp.qtype == 4
    pivot_fetches = [f for g in sp.groups for f in g.fetches if f.stop_checks]
    assert pivot_fetches
    deltas = {c[0] for f in pivot_fetches for c in f.stop_checks}
    assert deltas == {-1, 1}
    assert all(f.read_near_stop for f in pivot_fetches)


def test_near_mode_fallback_groups_use_stream1(small_world):
    planner = small_world["engine"].planner
    freq = _surface_of_tier(small_world, TIER_FREQUENT, 2)
    ordi = _surface_of_tier(small_world, TIER_ORDINARY, 1)
    plan = planner.plan(freq + ordi, mode=MODE_NEAR)
    sp = plan.subplans[0]
    assert sp.fallback_groups
    for g in sp.fallback_groups:
        for f in g.fetches:
            assert f.stream == "first"


@pytest.mark.parametrize("n,mn,mx", [(2, 2, 5), (5, 2, 5), (6, 2, 5), (7, 2, 5),
                                     (11, 2, 5), (3, 2, 2), (9, 3, 4)])
def test_split_query_parts_properties(n, mn, mx):
    parts = split_query_parts(n, mn, mx)
    covered = set()
    for start, ln in parts:
        assert mn <= ln <= mx
        assert 0 <= start and start + ln <= n
        covered |= set(range(start, start + ln))
    assert covered == set(range(n))

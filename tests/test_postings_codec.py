"""Packed block store codec (core/postings.PackedPostings): property and
boundary coverage — exact round trips, block-boundary slices, max 17-bit
positions, negative dist payloads, empty and single-posting lists, and
width-class edges — plus the device unpack (kernels/ops.unpack_postings,
ref math AND the Pallas kernel) against the numpy decode."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fetch_tables import TABLE_POS_BITS
from repro.core.postings import (BLOCK, PACK_WIDTHS, PackedPostings,
                                 concat_packed, pack_dist_pair)
from repro.kernels import ops


def _random_cols(rng, n, doc_hi=3000, pos_bits=13):
    return {
        "doc": np.sort(rng.integers(0, doc_hi, n)).astype(np.int32),
        "pos": rng.integers(0, 1 << pos_bits, n).astype(np.int32),
        "dist": rng.integers(-15, 16, n).astype(np.int8),
    }


def _assert_roundtrip(pp, cols):
    for f, col in cols.items():
        assert np.array_equal(pp.decode(f), col.astype(np.int32)), f


def test_roundtrip_exact_seeded():
    """Multiset is too weak a promise: the store must round-trip each column
    EXACTLY, element for element, across sizes spanning every tail shape."""
    rng = np.random.default_rng(0)
    sizes = [1, 2, 127, 128, 129, 255, 256, 257, 1000]
    sizes += [int(rng.integers(1, 5000)) for _ in range(30)]
    for n in sizes:
        cols = _random_cols(rng, n)
        _assert_roundtrip(PackedPostings.from_columns(cols), cols)


def test_block_boundary_slices():
    """decode(start, end) for slices that start/end exactly on, one before,
    and one after block boundaries."""
    rng = np.random.default_rng(1)
    n = 5 * BLOCK + 17
    cols = _random_cols(rng, n)
    pp = PackedPostings.from_columns(cols)
    edges = [0, 1, BLOCK - 1, BLOCK, BLOCK + 1, 2 * BLOCK, 3 * BLOCK - 1, n]
    for s in edges:
        for e in edges:
            if s <= e:
                for f in cols:
                    assert np.array_equal(pp.decode(f, s, e),
                                          cols[f][s:e].astype(np.int32)), (f, s, e)


def test_max_17bit_positions():
    """Positions at the top of the packed-key domain (2**17 - 1) round-trip;
    a block whose pos span crosses 2**16 takes the 32-bit class and still
    decodes exactly."""
    n = 2 * BLOCK
    pos = np.concatenate([np.zeros(BLOCK, np.int32),
                          np.full(BLOCK, (1 << TABLE_POS_BITS) - 1, np.int32)])
    mixed = np.arange(n, dtype=np.int32) * ((1 << TABLE_POS_BITS) // n)
    for col in (pos, mixed):
        pp = PackedPostings.from_columns({"pos": col})
        assert np.array_equal(pp.decode("pos"), col)


def test_negative_dist_payloads():
    """Signed int8 dist incl. the extremes, and anchors below zero."""
    dist = np.array([-128, 127, 0, -1, 1, -15, 15, -128] * BLOCK, np.int8)
    pp = PackedPostings.from_columns({"dist": dist})
    assert np.array_equal(pp.decode("dist").astype(np.int8), dist)
    assert np.array_equal(pp.decode("dist"), dist.astype(np.int32))
    # all-negative block: anchor is negative, deltas stay unsigned
    neg = np.full(BLOCK, -7, np.int8)
    pp = PackedPostings.from_columns({"dist": neg})
    assert int(pp.anchors["dist"][0]) == -7
    assert int(pp.field_width("dist")[0]) == 0
    assert np.array_equal(pp.decode("dist"), np.full(BLOCK, -7, np.int32))


def test_dpair_payload_roundtrip():
    """The triples' packed nibble payload (int8 holding two 4-bit distances)
    survives bit-exactly — decode returns the container's signed value."""
    rng = np.random.default_rng(2)
    d1 = rng.integers(0, 16, 500)
    d2 = rng.integers(0, 16, 500)
    dpair = pack_dist_pair(d1, d2)
    pp = PackedPostings.from_columns({"dpair": dpair})
    assert np.array_equal(pp.decode("dpair").astype(np.int8), dpair)


def test_empty_and_single_posting_lists():
    for n in (0, 1):
        cols = _random_cols(np.random.default_rng(3), n)
        pp = PackedPostings.from_columns(cols)
        assert pp.n == n
        assert pp.n_padded == BLOCK          # one (padded) block
        _assert_roundtrip(pp, cols)
    # pads decode to the edge-replicated tail value
    cols = _random_cols(np.random.default_rng(4), 3)
    pp = PackedPostings.from_columns(cols)
    tail = pp.decode("doc", 3, BLOCK)
    assert (tail == cols["doc"][-1]).all()


@pytest.mark.parametrize("w", PACK_WIDTHS)
def test_width_class_edges(w):
    """A block whose span is exactly 2**w - 1 packs at width w; span 2**w
    forces the next class up.  Both round-trip."""
    span = (1 << w) - 1 if w else 0
    base = 1000
    col = np.full(BLOCK, base, np.int64)
    col[1] = base + span
    pp = PackedPostings.from_columns({"x": col.astype(np.int64)})
    assert int(pp.field_width("x")[0]) == w
    assert np.array_equal(pp.decode("x"), col.astype(np.int32))
    if w < 32:
        col[1] = base + span + 1
        pp = PackedPostings.from_columns({"x": col})
        nxt = PACK_WIDTHS[PACK_WIDTHS.index(w) + 1]
        assert int(pp.field_width("x")[0]) == nxt
        assert np.array_equal(pp.decode("x"), col.astype(np.int32))


def test_full_int32_range():
    """Width-32 blocks recover values exactly modulo 2**32 — i.e. bit-exact
    int32 incl. both extremes in one block."""
    x = np.array([-2**31, 2**31 - 1, 0, 12345] * (BLOCK // 4), np.int32)
    pp = PackedPostings.from_columns({"x": x})
    assert int(pp.field_width("x")[0]) == 32
    assert np.array_equal(pp.decode("x"), x)


def test_constant_blocks_cost_no_lanes():
    """An all-constant column is width 0 everywhere: metadata only."""
    c = np.full(10 * BLOCK, 42, np.int32)
    pp = PackedPostings.from_columns({"c": c})
    assert (pp.field_width("c") == 0).all()
    assert len(pp.lanes) == 1                  # the single safety word
    assert np.array_equal(pp.decode("c"), c)


def test_concat_packed_block_aligned_ordinals():
    """concat_packed shifts ordinals by each predecessor's PADDED count —
    the contract stream bases in the executor arena rely on."""
    rng = np.random.default_rng(5)
    parts = [_random_cols(rng, n) for n in (200, 77, 128)]
    stores = [PackedPostings.from_columns(c) for c in parts]
    cat = concat_packed(stores)
    base = 0
    for c, s in zip(parts, stores):
        for f in c:
            assert np.array_equal(cat.decode(f, base, base + s.n),
                                  c[f].astype(np.int32))
        base += s.n_padded
    assert cat.n_padded == base


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_unpack_ops_matches_numpy_decode(impl):
    """Device unpack (gather + bit extract; ref math and the Pallas kernel)
    == host numpy decode on random gathers, incl. repeated and boundary
    ordinals."""
    rng = np.random.default_rng(6)
    n = 2000
    cols = _random_cols(rng, n, doc_hi=100_000, pos_bits=TABLE_POS_BITS)
    pp = PackedPostings.from_columns(cols, fields=("doc", "pos", "dist"))
    arena = {"lanes": jnp.asarray(pp.lanes),
             "blk_meta": jnp.asarray(pp.meta_matrix())}
    idx_np = np.concatenate([rng.integers(0, n, 1000),
                             [0, 1, BLOCK - 1, BLOCK, n - 1], [n - 1] * 19])
    doc, pos, dist = ops.unpack_postings(
        arena, jnp.asarray(idx_np.astype(np.int32)), implementation=impl,
        interpret=True)
    assert np.array_equal(np.asarray(doc), cols["doc"][idx_np])
    assert np.array_equal(np.asarray(pos), cols["pos"][idx_np])
    assert np.array_equal(np.asarray(dist), cols["dist"][idx_np].astype(np.int32))


def test_unpack_fields_pallas_matches_ref_on_tiles():
    """The raw bit-extract kernel on exact [R, 128] tiles, every width."""
    rng = np.random.default_rng(7)
    shape = (16, 128)
    words = rng.integers(-2**31, 2**31, shape).astype(np.int32)
    widths = rng.choice(PACK_WIDTHS, shape).astype(np.int32)
    # shifts valid for the width: multiples of w below 32
    slots = np.where(widths > 0, 32 // np.maximum(widths, 1), 1)
    shifts = (rng.integers(0, 1 << 16, shape) % slots) * widths
    anchors = rng.integers(-2**20, 2**20, shape).astype(np.int32)
    args = [jnp.asarray(a.astype(np.int32))
            for a in (words, shifts, widths, anchors)]
    ref = ops.unpack_fields(*args, implementation="ref")
    pal = ops.unpack_fields(*args, implementation="pallas", interpret=True)
    assert np.array_equal(np.asarray(ref), np.asarray(pal))

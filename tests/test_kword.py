"""K-word proximity search (arXiv:2009.02684 on arXiv:1812.07640 keys),
locked to the literal nested-loop oracle on every execution path.

ISSUE 9 acceptance contract, on the seeded stop-heavy K in {3,4,5} suite
(tests/conftest.py::kword_queries, 200 queries):

  * flexible executor (`engine.search`) == `brute_force_kword`, exactly —
    positional anchors, doc-only fallback docs, span semantics;
  * `search_batch` == flex, bit for bit (postings accounting included) —
    the device delta-mask join against the numpy int64 one;
  * ranked kword: batched == flex bit-identical AND anchor/doc scores match
    `brute_force_kword_ranked` (arXiv:2108.00410 accumulation) to tolerance;
  * `SearchServe` == engine on the same workload, ranked included;
  * the multi-key cover actually covers (most plans read pair/triple
    streams) and reads fewer postings than the ordinary-index plan;
  * API validation, the all-stop unsupported combo, wide windows (> the
    device int32 mask reach) riding flex, and the serve tier-ladder
    persistence round-trip (satellite: warm restarts).
"""
import numpy as np
import pytest

from repro.core import (SearchRequest, brute_force_kword,
                        brute_force_kword_ranked)
from repro.core.kword import KW_DEVICE_MAX_WINDOW, MODE_KWORD
from repro.core.planner import QTYPE_KWORD


def _assert_kword_oracle(corpus, index, q, window, r):
    truth_pos, truth_doc = brute_force_kword(corpus, index, q, window)
    if r.doc_only:
        assert not truth_pos, (q, window)
        assert set(r.doc.tolist()) == truth_doc, (q, window)
    else:
        got = set(zip(r.doc.tolist(), r.pos.tolist()))
        assert got == truth_pos, (q, window)


def _same_result(r1, r2) -> bool:
    return (np.array_equal(r1.doc, r2.doc) and np.array_equal(r1.pos, r2.pos)
            and r1.postings_read == r2.postings_read
            and r1.used_fallback == r2.used_fallback
            and r1.doc_only == r2.doc_only
            and r1.subplan_types == r2.subplan_types)


def _ranked_same(r1, r2) -> bool:
    same = _same_result(r1, r2)
    same = same and np.array_equal(r1.doc_ids, r2.doc_ids)
    same = same and np.array_equal(r1.doc_scores, r2.doc_scores)
    if r1.anchor_scores is not None or r2.anchor_scores is not None:
        same = same and np.array_equal(r1.anchor_scores, r2.anchor_scores)
    return same


def _reqs(queries, **kw):
    return [SearchRequest(q, mode=MODE_KWORD, window=w, **kw)
            for q, w, _src in queries]


# ---------------------------------------------------------------------------
# oracle parity: flexible executor, then batched pinned to flex
# ---------------------------------------------------------------------------


def test_flex_matches_kword_oracle(small_world, kword_queries):
    """engine.search on all 200 queries == the nested-loop span oracle."""
    eng = small_world["engine"]
    corpus, index = small_world["corpus"], small_world["index"]
    for q, w, _src in kword_queries:
        r = eng.search(SearchRequest(q, mode=MODE_KWORD, window=w))
        _assert_kword_oracle(corpus, index, q, w, r)


def test_batch_matches_flex_bit_identical(small_world, kword_queries):
    """search_batch (device delta-mask join) == flex (numpy int64 masks),
    bit for bit including postings_read / used_fallback / doc_only."""
    eng = small_world["engine"]
    results = eng.search_batch(_reqs(kword_queries))
    for (q, w, _src), r in zip(kword_queries, results):
        assert _same_result(
            eng.search(SearchRequest(q, mode=MODE_KWORD, window=w)), r), (q, w)


def test_kword_plans_use_multi_key_cover(small_world, kword_queries):
    """The planner's cover must actually reach the additional indexes: a
    large share of the stop-heavy workload's supported kword subplans carry
    pair/triple multi-key fetches (the rest have no stop slot adjacent to a
    stored key and ride expanded/basic fetches)."""
    eng = small_world["engine"]
    n_kword = n_multi = 0
    for q, w, _src in kword_queries:
        plan = eng.plan_request(SearchRequest(q, mode=MODE_KWORD, window=w))
        sps = [sp for sp in plan.subplans if sp.supported]
        if not sps:
            continue
        assert all(sp.qtype == QTYPE_KWORD for sp in sps), q
        n_kword += 1
        n_multi += int(any(f.stream == "multi" for sp in sps
                           for g in sp.groups for f in g.fetches))
    assert n_kword >= 150, n_kword
    assert n_multi >= 60, n_multi      # the cover is exercised, not vestigial


def test_kword_cover_reads_fewer_postings(small_world, kword_queries):
    """Acceptance: the multi-key cover plan reads measurably fewer postings
    than the ordinary-index plan over the suite (mirrors the
    kword_postings_ratio counter in BENCH_search.json)."""
    eng, ordi = small_world["engine"], small_world["ordinary"]
    add = ord_ = 0
    for q, w, _src in kword_queries[:60]:
        req = SearchRequest(q, mode=MODE_KWORD, window=w)
        add += eng.search(req).postings_read
        ord_ += ordi.search(req).postings_read
    assert ord_ >= 1.5 * add, (add, ord_)


# ---------------------------------------------------------------------------
# ranked kword (arXiv:2108.00410 accumulation over the span join)
# ---------------------------------------------------------------------------


def test_ranked_kword_matches_oracle_and_flex(small_world, kword_queries):
    """Ranked kword on a 60-query slice: batched == flex bit-identical, and
    anchor scores / doc scores / rank order match the nested-loop
    reference."""
    eng = small_world["engine"]
    corpus, index = small_world["corpus"], small_world["index"]
    sample = kword_queries[:60]
    reqs = _reqs(sample, rank=True)
    results = eng.search_batch(reqs)
    rtol = 1e-4
    for req, r in zip(reqs, results):
        assert _ranked_same(eng.search(req), r), req
        a_sc, d_sc, d_lvl = brute_force_kword_ranked(
            corpus, index, req.surface_ids, req.window, ranking=req.ranking)
        if r.doc_only:
            assert set(r.doc.tolist()) == d_lvl, req
            continue
        got = dict(zip(zip(r.doc.tolist(), r.pos.tolist()),
                       r.anchor_scores.tolist()))
        assert set(got) == set(a_sc), (req, sorted(set(got) ^ set(a_sc))[:5])
        for k, v in got.items():
            assert abs(v - a_sc[k]) <= rtol * max(1.0, abs(a_sc[k])), (req, k)
        assert set(r.doc_ids.tolist()) == set(d_sc), req
        for d, s in zip(r.doc_ids.tolist(), r.doc_scores.tolist()):
            assert abs(s - d_sc[d]) <= rtol * max(1.0, abs(d_sc[d])), (req, d)
        for i in range(len(r.doc_ids) - 1):
            s0, s1 = float(r.doc_scores[i]), float(r.doc_scores[i + 1])
            assert s0 > s1 or (s0 == s1
                               and r.doc_ids[i] < r.doc_ids[i + 1]), req


# ---------------------------------------------------------------------------
# serve path: bit-identical to the engine, ranked included
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def kword_serve(small_world):
    from repro.launch.mesh import make_host_mesh
    from repro.serve.search_serve import SearchServe, SearchServeConfig
    cfg = SearchServeConfig(queries=16, postings_pad=4096, seed_pad=1024,
                            n_basic=1, n_expanded=1, n_stop=1, n_first=1,
                            n_multi=1)
    return SearchServe(small_world["index"], cfg, make_host_mesh(data=1,
                                                                 model=1))


def test_serve_matches_engine_kword(small_world, kword_serve, kword_queries):
    """SearchServe on the full suite: bit-identical to the engine (which the
    tests above pin to the oracle), plus a direct oracle slice so serve
    parity can't hide behind a hypothetical engine bug."""
    eng = small_world["engine"]
    corpus, index = small_world["corpus"], small_world["index"]
    reqs = _reqs(kword_queries)
    got = kword_serve.search_batch(reqs)
    want = eng.search_batch(reqs)
    for (q, w, _src), wr, gr in zip(kword_queries, want, got):
        assert _same_result(wr, gr), (q, w)
    for (q, w, _src), gr in list(zip(kword_queries, got))[:40]:
        _assert_kword_oracle(corpus, index, q, w, gr)


def test_serve_matches_engine_kword_ranked(small_world, kword_serve,
                                           kword_queries):
    eng = small_world["engine"]
    sample = kword_queries[:40]
    reqs = _reqs(sample, rank=True)
    for req, wr, gr in zip(reqs, eng.search_batch(reqs),
                           kword_serve.search_batch(reqs)):
        assert _ranked_same(wr, gr), req


# ---------------------------------------------------------------------------
# semantics edges: wide windows, all-stop combos, source-doc recall
# ---------------------------------------------------------------------------


def test_wide_window_rides_flex_and_matches_oracle(small_world,
                                                   kword_queries):
    """Windows beyond the device int32 delta-mask reach (W > 15) must route
    to the flexible executor and still match the oracle bit for bit."""
    eng = small_world["engine"]
    corpus, index = small_world["corpus"], small_world["index"]
    be = eng.batch_executor
    wide = [(q, w) for q, w, _src in kword_queries
            if w > KW_DEVICE_MAX_WINDOW]
    assert len(wide) >= 10, len(wide)     # the fixture promises ~10%
    plans = [eng.plan_request(SearchRequest(q, mode=MODE_KWORD, window=w))
             for q, w in wide]
    n_flex = 0
    for i, p in enumerate(plans):
        if not any(sp.supported for sp in p.subplans):
            continue        # all-stop combo: empty plan, nothing to route
        assert not be._build_tasks(i, p, []), wide[i]
        n_flex += 1
    assert n_flex >= 8, n_flex
    for (q, w), r in zip(wide, be.execute_batch(plans)):
        assert _same_result(
            eng.search(SearchRequest(q, mode=MODE_KWORD, window=w)), r), q
        _assert_kword_oracle(corpus, index, q, w, r)


def test_all_stop_kword_unsupported_matches_oracle(small_world):
    """A query whose every slot is stop-only has no anchor: the planner
    marks the combo unsupported and the oracle skips it — both sides must
    agree (empty positional result, no phantom fallback docs)."""
    lex, ana = small_world["lex"], small_world["ana"]
    eng = small_world["engine"]
    corpus, index = small_world["corpus"], small_world["index"]
    all_stop = [s for s in range(400)
                if bool(lex.is_stop(np.asarray(ana.forms_of(s))).all())][:3]
    if len(all_stop) < 3:
        pytest.skip("lexicon seed yields < 3 stop-only surfaces")
    r = eng.search(SearchRequest(all_stop, mode=MODE_KWORD, window=4))
    _assert_kword_oracle(corpus, index, all_stop, 4, r)
    truth_pos, _ = brute_force_kword(corpus, index, all_stop, 4)
    assert not truth_pos and len(r.pos) == 0


def test_kword_source_doc_recall(small_world, kword_queries):
    """Every query was sampled from a real document span of width <= W, so
    a non-doc-only result missing its source doc must be missing it in the
    oracle too (i.e. only when the sampled span's tier combo was all-stop,
    which the additional engine does not serve)."""
    eng = small_world["engine"]
    corpus, index = small_world["corpus"], small_world["index"]
    checked = 0
    for (q, w, src), r in zip(kword_queries,
                              eng.search_batch(_reqs(kword_queries))):
        if src not in set(r.doc.tolist()):
            truth_pos, truth_doc = brute_force_kword(corpus, index, q, w)
            assert src not in {d for d, _p in truth_pos}, (q, w, src)
            if r.doc_only:
                assert src not in truth_doc, (q, w, src)
        checked += 1
    assert checked == 200


# ---------------------------------------------------------------------------
# API validation
# ---------------------------------------------------------------------------


def test_kword_request_validation():
    with pytest.raises(ValueError):
        SearchRequest([1], mode=MODE_KWORD, window=4)       # K < 2
    with pytest.raises(ValueError):
        SearchRequest([1, 2, 3], mode=MODE_KWORD)           # window required
    with pytest.raises(ValueError):
        SearchRequest([1, 2, 3], mode=MODE_KWORD, window=0)
    with pytest.raises(ValueError):
        SearchRequest([1, 2, 3], mode=MODE_KWORD, window=32)  # > flex reach
    SearchRequest([1, 2, 3], mode=MODE_KWORD, window=31)    # max OK


# ---------------------------------------------------------------------------
# satellite: serve tier-ladder persistence (warm restarts)
# ---------------------------------------------------------------------------


def test_serve_tier_ladder_round_trip(small_world, kword_serve,
                                      kword_queries, tmp_path):
    """dump_tiers/load_tiers: a fresh _ServeBatchExecutor warmed from file
    carries the learned (G, F, P0, P) ladder verbatim and answers the same
    workload bit-identically; stale entries beyond the config caps are
    clipped, junk entries dropped."""
    import json
    from repro.launch.mesh import make_host_mesh
    from repro.serve.search_serve import SearchServe, SearchServeConfig
    sample = kword_queries[:24]
    reqs = _reqs(sample)
    want = kword_serve.search_batch(reqs)     # learns the ladder
    be = kword_serve.executor
    assert be._tiers, "serve executor never derived a tier ladder"
    path = tmp_path / "tiers.json"
    assert be.dump_tiers(path)
    cfg = SearchServeConfig(queries=16, postings_pad=4096, seed_pad=1024,
                            n_basic=1, n_expanded=1, n_stop=1, n_first=1,
                            n_multi=1)
    fresh = SearchServe(small_world["index"], cfg,
                        make_host_mesh(data=1, model=1))
    assert fresh.executor._tiers is None
    assert fresh.executor.load_tiers(path)
    assert fresh.executor._tiers == be._tiers
    for (q, w, _src), wr, gr in zip(sample, want, fresh.search_batch(reqs)):
        assert _same_result(wr, gr), (q, w)
    # corrupt/stale files degrade warmth, never correctness
    assert not fresh.executor.load_tiers(tmp_path / "missing.json")
    oversized = {"tiers": [[9999, 9999, 99999, 99999], [0, 1, 1, 1], [2, 1]]}
    (tmp_path / "stale.json").write_text(json.dumps(oversized))
    assert fresh.executor.load_tiers(tmp_path / "stale.json")
    cap = (cfg.groups, cfg.fetch_slots, cfg.p_seed, cfg.postings_pad)
    assert fresh.executor._tiers == [cap]          # clipped to caps, junk dropped

"""Serve ↔ engine oracle parity: the unified serve tier (batch-executor
tables + shard_map'd bucket step) must return EXACTLY what `engine.search`
and `engine.search_batch` return — including the multi-subplan (tier-split)
and multi-form queries the old single-subplan serve path silently dropped."""
import jax
import numpy as np
import pytest

from repro.core import SearchRequest
from repro.core.planner import MODE_NEAR, MODE_PHRASE
from repro.launch.mesh import make_host_mesh
from repro.serve.search_serve import (SearchServe, SearchServeConfig,
                                      make_search_serve_step,
                                      query_table_specs)


def _serve_cfg(queries=16):
    # tiny arena segment sizes: the real arenas are built from the index;
    # the n_* fields only size the dry-run ShapeDtypeStructs
    return SearchServeConfig(queries=queries, postings_pad=4096, seed_pad=1024,
                             n_basic=1, n_expanded=1, n_stop=1, n_first=1)


@pytest.fixture(scope="module")
def serve_setup(small_world):
    mesh = make_host_mesh(data=1, model=1)
    return SearchServe(small_world["index"], _serve_cfg(), mesh)


def _assert_same(w, g, ctx):
    assert np.array_equal(w.doc, g.doc), ctx
    assert np.array_equal(w.pos, g.pos), ctx
    assert w.postings_read == g.postings_read, ctx
    assert w.used_fallback == g.used_fallback, ctx
    assert w.doc_only == g.doc_only, ctx
    assert w.subplan_types == g.subplan_types, ctx


def test_serve_matches_engine_on_paper_queries(small_world, serve_setup,
                                               paper_queries):
    """Every paper-procedure query (phrase AND near — the old serve path only
    handled conjunctive single-form plans): serve == search == search_batch,
    and the source document is always found (missed_source_docs == 0) on
    every query whose semantics promise recall.  Since the multi-component
    key index, that promise covers near queries CONTAINING stop forms too
    (QTYPE_MULTI windowed plans); the only exempt class is near queries
    whose EVERY word form is a stop form — those have just the Type-1
    contiguous interpretation and no doc-level fallback, so their source
    doc legitimately may not match (near_query_stop_confined now means
    exactly that class, as in the benchmark's near_stop_seq_only bucket)."""
    from repro.core import near_query_stop_confined
    eng = small_world["engine"]
    lex, ana = small_world["lex"], small_world["ana"]

    def stop_confined(q, m):
        return near_query_stop_confined(lex, ana, q, m)

    reqs = [SearchRequest(q, mode=m) for q, m, _s in paper_queries]
    got = serve_setup.search_batch(reqs)
    want_batch = eng.search_batch(reqs)
    missed = 0
    for (q, m, src), w, g in zip(paper_queries, want_batch, got):
        _assert_same(w, g, (q, m))
        _assert_same(eng.search(SearchRequest(q, mode=m)), g, (q, m))
        if not stop_confined(q, m):
            missed += int(src not in set(g.doc.tolist()))
    assert missed == 0


def test_serve_covers_multi_subplan_and_multi_form(small_world, serve_setup,
                                                   paper_queries):
    """The parity workload must actually contain the shapes the old serve
    executor dropped: tier-split plans (>1 subplan) and groups with >1 fetch
    (multiple lemma forms / expanded orientations)."""
    eng = small_world["engine"]
    multi_sub = multi_form = 0
    picked = []
    for q, m, _ in paper_queries:
        plan = eng.plan(q, mode=m)
        sub = [sp for sp in plan.subplans if sp.supported]
        if len(sub) > 1:
            multi_sub += 1
        if any(len(g.fetches) > 1 for sp in sub for g in sp.groups):
            multi_form += 1
        if len(sub) > 1 or any(len(g.fetches) > 1 for sp in sub
                               for g in sp.groups):
            picked.append((q, m))
    assert multi_sub >= 3, "workload has no tier-split queries"
    assert multi_form >= 3, "workload has no multi-form groups"
    reqs = [SearchRequest(q, mode=m) for q, m in picked]
    for (q, m), w, g in zip(picked, eng.search_batch(reqs),
                            serve_setup.search_batch(reqs)):
        _assert_same(w, g, (q, m))


def test_serve_fallback_queries(small_world, serve_setup):
    """Doc-only fallback (cross-document word scrambles) through the serve
    tier: stream-1 tasks execute per shard and merge like the engine."""
    corpus = small_world["corpus"]
    eng = small_world["engine"]
    rng = np.random.default_rng(23)
    queries = []
    for _ in range(8):
        d1, d2 = rng.integers(corpus.n_docs, size=2)
        t1, t2 = corpus.doc(int(d1)), corpus.doc(int(d2))
        if len(t1) < 8 or len(t2) < 8:
            continue
        queries.append([int(t1[3]), int(t2[5]), int(t1[7])])
    assert queries
    got = serve_setup.search_batch([SearchRequest(q) for q in queries])
    n_fallback = 0
    for q, g in zip(queries, got):
        _assert_same(eng.search(SearchRequest(q, mode=MODE_PHRASE)), g, q)
        n_fallback += int(g.used_fallback)
    assert n_fallback > 0


def test_serve_multi_shard_parity(small_world, paper_queries):
    """Doc-shard segmentation: with the corpus split into many small doc
    shards (rows per query multiply), results stay bit-identical."""
    eng = small_world["engine"]
    mesh = make_host_mesh(data=1, model=1)
    serve = SearchServe(small_world["index"], _serve_cfg(), mesh,
                        docs_per_shard=16)
    assert serve.executor.dev.n_shards >= 8
    sample = paper_queries[:24]
    reqs = [SearchRequest(q, mode=m) for q, m, _s in sample]
    for (q, m, _), w, g in zip(sample, eng.search_batch(reqs),
                               serve.search_batch(reqs)):
        _assert_same(w, g, (q, m))


def test_serve_smoke_dryrun_shapes():
    """The smoke-scale serve cell lowers and runs on 1 device with random
    tables in the unified schema (random postings packed into the block
    store, padded out to the cfg's spec shapes)."""
    from repro.configs.registry import get_arch
    from repro.core.postings import PackedPostings
    from repro.serve.search_serve import arena_specs
    spec = get_arch("veretennikov")
    cfg = spec.make_smoke_config()
    mesh = make_host_mesh(data=1, model=1)
    step = make_search_serve_step(cfg, mesh)
    rng = np.random.default_rng(0)
    pp = PackedPostings.from_columns(
        {"doc": np.sort(rng.integers(0, 50, cfg.n_arena)).astype(np.int32),
         "pos": rng.integers(0, 400, cfg.n_arena).astype(np.int32),
         "dist": rng.integers(-5, 6, cfg.n_arena).astype(np.int8)},
        fields=("doc", "pos", "dist"))
    specs = arena_specs(cfg, 1)
    parts = {"lanes": pp.lanes, "blk_meta": pp.meta_matrix()}
    arenas = {}
    for k, v in parts.items():
        buf = np.zeros(specs[k].shape, np.int32)
        assert len(v) <= buf.shape[1], (k, len(v))   # spec budgets hold
        buf[0, :len(v)] = v
        arenas[k] = jax.numpy.asarray(buf)
    arenas["basic_ns"] = jax.numpy.asarray(
        np.full((1, cfg.n_basic, cfg.ns_k), -1, np.int16))
    t = {}
    for k, s in query_table_specs(cfg).items():
        if k == "length":
            t[k] = np.full(s.shape, 16, s.dtype)
        elif k in ("active",):
            t[k] = np.ones(s.shape, s.dtype)
        elif k == "req_dist":
            t[k] = np.full(s.shape, -128, s.dtype)
        elif k == "max_abs":
            t[k] = np.full(s.shape, 2**20, s.dtype)
        elif k == "ns_packed":
            t[k] = np.full(s.shape, -1, s.dtype)
        else:
            t[k] = np.zeros(s.shape, s.dtype)
    t = {k: jax.numpy.asarray(v) for k, v in t.items()}
    with mesh:
        keys, found = jax.jit(step)(arenas, t)
    R = cfg.task_rows
    assert keys.shape == (R, cfg.fetch_slots * cfg.p_seed)
    assert found.shape == (R, cfg.fetch_slots * cfg.p_seed)
    assert keys.dtype == jax.numpy.int64 and found.dtype == jax.numpy.bool_
    # the ranked variant (serve_ranked dry-run shape) lowers with a third
    # float32 score output on the same row layout
    import dataclasses
    rstep = make_search_serve_step(dataclasses.replace(cfg, ranked=True), mesh)
    with mesh:
        rkeys, rfound, rscores = jax.jit(rstep)(arenas, t)
    assert rscores.shape == rkeys.shape == keys.shape
    assert rscores.dtype == jax.numpy.float32

"""Batched search serving: the tensorized serve_step must agree with the
flexible executor on conjunctive plans, on a real (small) index."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.planner import MODE_PHRASE
from repro.core.postings import PHRASE_BIAS, POS_BITS
from repro.launch.mesh import make_host_mesh
from repro.serve.search_serve import (SERVE_BIAS, SERVE_POS_BITS, SENT32,
                                      SearchServeConfig, build_arenas,
                                      make_search_serve_step, tensorize_plans)


@pytest.fixture(scope="module")
def serve_setup(small_world):
    idx = small_world["index"]
    cfg = SearchServeConfig(
        queries=8, groups=4, postings_pad=4096, top_m=64, check_slots=4,
        n_basic=idx.basic.occurrences.n_postings,
        n_expanded=idx.expanded.pairs.n_postings,
        n_stop=idx.stop_phrase.phrases.n_postings)
    arenas, bases = build_arenas(idx, cfg)
    mesh = make_host_mesh(data=1, model=1)
    step = make_search_serve_step(cfg, mesh)
    return cfg, arenas, bases, mesh, step


def _serve_compatible(plan):
    """Conjunctive single-fetch-per-group plans only (the serve fast path)."""
    sp = plan.subplans
    if len(sp) != 1 or not sp[0].supported:
        return False
    groups = [g for g in sp[0].groups if g.fetches]
    if not groups or len(groups) > 4:
        return False
    for g in groups:
        if len(g.fetches) != 1:
            return False
        f = g.fetches[0]
        if f.stream not in ("basic", "expanded", "stop"):
            return False
        if f.stop_checks and any(len(ids) > 1 for _, ids in f.stop_checks):
            return False
    return True


def test_serve_step_matches_executor(small_world, serve_setup, paper_queries):
    cfg, arenas, bases, mesh, step = serve_setup
    eng = small_world["engine"]
    picked, plans = [], []
    for q, mode, _ in paper_queries:
        if mode != "phrase":
            continue
        plan = eng.plan(q, mode=MODE_PHRASE)
        if _serve_compatible(plan):
            picked.append(q)
            plans.append(plan)
        if len(picked) == cfg.queries:
            break
    assert len(picked) >= 4, "not enough serve-compatible queries"
    while len(plans) < cfg.queries:
        plans.append(plans[-1])
        picked.append(picked[-1])

    tables = tensorize_plans(cfg, plans, stream_bases=bases,
                             max_distance=small_world["index"].params.max_distance)
    tables = {k: jax.numpy.asarray(v) for k, v in tables.items()}
    with mesh:
        hits, counts = jax.jit(step)(arenas, tables)
    hits, counts = np.asarray(hits), np.asarray(counts)

    for qi, (q, plan) in enumerate(zip(picked, plans)):
        r = eng.executor.execute(plan)
        want = {(int(d), int(p)) for d, p in zip(r.doc, r.pos)} if not r.doc_only else set()
        got = set()
        for h in hits[qi]:
            if h >= SENT32:
                continue
            doc = int(h) >> SERVE_POS_BITS
            pos = (int(h) & ((1 << SERVE_POS_BITS) - 1)) - SERVE_BIAS
            got.add((doc, pos))
        if len(want) <= cfg.top_m:
            assert got == want, (qi, q)
        else:
            assert got <= want
        assert int(counts[qi]) == len(want), (qi, q)


def test_serve_smoke_dryrun_shapes():
    """The smoke-scale serve cell lowers and runs on 1 device."""
    from repro.configs.registry import get_arch
    spec = get_arch("veretennikov")
    cfg = spec.make_smoke_config()
    mesh = make_host_mesh(data=1, model=1)
    step = make_search_serve_step(cfg, mesh)
    rng = np.random.default_rng(0)
    arenas = {
        "arena_doc": jax.numpy.asarray(
            rng.integers(0, 50, (1, cfg.n_arena)).astype(np.int32)),
        "arena_pos": jax.numpy.asarray(
            rng.integers(0, 400, (1, cfg.n_arena)).astype(np.int32)),
        "arena_dist": jax.numpy.asarray(
            rng.integers(-5, 6, (1, cfg.n_arena)).astype(np.int8)),
        "basic_ns": jax.numpy.asarray(
            np.full((1, cfg.n_basic, cfg.ns_k), -1, np.int32)),
    }
    q = {
        "start": np.zeros((cfg.queries, cfg.groups), np.int32),
        "length": np.full((cfg.queries, cfg.groups), 16, np.int32),
        "offset": np.zeros((cfg.queries, cfg.groups), np.int32),
        "req_dist": np.full((cfg.queries, cfg.groups), -128, np.int32),
        "band": np.zeros((cfg.queries, cfg.groups), np.int32),
        "active": np.ones((cfg.queries, cfg.groups), bool),
        "ns_packed": np.full((cfg.queries, cfg.check_slots), -1, np.int32),
    }
    q = {k: jax.numpy.asarray(v) for k, v in q.items()}
    with mesh:
        hits, counts = jax.jit(step)(arenas, q)
    assert hits.shape == (cfg.queries, cfg.top_m)
    assert counts.shape == (cfg.queries,)

"""Ranked search (SearchRequest.rank=True): proximity relevance per
arXiv:2108.00410, locked to the brute-force reference on BOTH execution
paths.

  * engine `search_batch` ranked == flexible per-query ranked, bit for bit
    (scores included), on the seeded 200-query stop-heavy suite;
  * `SearchServe` ranked == engine ranked, bit for bit, same workload;
  * anchor and document scores match `brute_force_ranked` (float64 literal
    nested loops) to tolerance, and the ranked ORDER is the score order;
  * score monotonicity on a hand-built corpus: tighter word sets and
    repeated matches rank strictly higher;
  * escape-hatch (flex-path) queries rank identically to the batched path;
  * triple-gated indexes (IndexParams.triple_pair_min_count) return
    identical results with triples answered by two pair lookups;
  * the typed API itself: deprecation shims warn, responses carry hits /
    provenance, top_k truncates by score.
"""
import dataclasses
import warnings

import numpy as np
import pytest

from repro.core import (AdditionalIndexEngine, BatchExecutor, DocHit,
                        IndexParams, OrdinaryEngine, RankingParams,
                        SearchRequest, brute_force_ranked, build_all,
                        near_query_stop_confined)
from repro.core.builder import build_multi_key_index, expand_token_forms
from repro.core.corpus import Corpus
from repro.core.planner import MODE_NEAR, MODE_PHRASE, QTYPE_MULTI


def _ranked_same(r1, r2) -> bool:
    """Bit-identity of two ranked responses (the engine/serve contract)."""
    same = (np.array_equal(r1.doc, r2.doc) and np.array_equal(r1.pos, r2.pos)
            and r1.postings_read == r2.postings_read
            and r1.doc_only == r2.doc_only
            and r1.subplan_types == r2.subplan_types
            and np.array_equal(r1.doc_ids, r2.doc_ids)
            and np.array_equal(r1.doc_scores, r2.doc_scores))
    if r1.anchor_scores is not None or r2.anchor_scores is not None:
        same = same and np.array_equal(r1.anchor_scores, r2.anchor_scores)
    return same


def _assert_oracle_ranked(corpus, index, req, r, rtol=1e-4):
    """Engine scores (float32 device accumulation) vs the float64 literal
    oracle, anchors and docs; and the response order IS the score order."""
    a_sc, d_sc, d_lvl = brute_force_ranked(corpus, index, req.surface_ids,
                                           mode=req.mode, window=req.window,
                                           ranking=req.ranking)
    if r.doc_only:
        assert set(r.doc.tolist()) == d_lvl, req
        return
    got = dict(zip(zip(r.doc.tolist(), r.pos.tolist()),
                   r.anchor_scores.tolist()))
    assert set(got) == set(a_sc), (req, sorted(set(got) ^ set(a_sc))[:5])
    for k, v in got.items():
        assert abs(v - a_sc[k]) <= rtol * max(1.0, abs(a_sc[k])), (req, k)
    assert len(r.doc_ids) == len(set(r.doc_ids.tolist()))
    if req.top_k is None:
        assert set(r.doc_ids.tolist()) == set(d_sc), req
    for d, s in zip(r.doc_ids.tolist(), r.doc_scores.tolist()):
        assert abs(s - d_sc[d]) <= rtol * max(1.0, abs(d_sc[d])), (req, d)
    # order: score desc, doc asc on ties
    for i in range(len(r.doc_ids) - 1):
        s0, s1 = float(r.doc_scores[i]), float(r.doc_scores[i + 1])
        assert s0 > s1 or (s0 == s1 and r.doc_ids[i] < r.doc_ids[i + 1]), req


# ---------------------------------------------------------------------------
# oracle parity: the seeded 200-query suite, engine AND serve
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def ranked_requests(stop_near_queries):
    return [SearchRequest(q, mode=MODE_NEAR, rank=True)
            for q, _src in stop_near_queries]


@pytest.fixture(scope="module")
def ranked_batch(small_world, ranked_requests):
    return small_world["engine"].search_batch(ranked_requests)


def test_ranked_batch_matches_flex(small_world, ranked_requests, ranked_batch):
    """Batched ranked == per-query ranked, scores bit-identical (same
    canonical float32 accumulation order)."""
    eng = small_world["engine"]
    for req, r in zip(ranked_requests[:60], ranked_batch):
        assert _ranked_same(eng.search(req), r), req


def test_ranked_matches_oracle(small_world, ranked_requests, ranked_batch):
    """200 stop-heavy near queries: anchor scores, doc scores, and rank
    order against the literal nested-loop reference."""
    corpus, index = small_world["corpus"], small_world["index"]
    n_multi = 0
    for req, r in zip(ranked_requests, ranked_batch):
        _assert_oracle_ranked(corpus, index, req, r)
        plan = small_world["engine"].plan_request(req)
        n_multi += int(any(sp.qtype == QTYPE_MULTI for sp in plan.subplans))
    assert n_multi >= 150, n_multi


def test_ranked_serve_matches_engine(small_world, ranked_requests,
                                     ranked_batch):
    """SearchServe ranked == engine ranked, bit for bit (the acceptance
    contract), plus a direct oracle slice so serve parity can't hide behind
    an engine bug."""
    from repro.launch.mesh import make_host_mesh
    from repro.serve.search_serve import SearchServe, SearchServeConfig
    cfg = SearchServeConfig(queries=16, postings_pad=4096, seed_pad=1024,
                            n_basic=1, n_expanded=1, n_stop=1, n_first=1,
                            n_multi=1)
    serve = SearchServe(small_world["index"], cfg,
                        make_host_mesh(data=1, model=1))
    got = serve.search_batch(ranked_requests)
    for req, w, g in zip(ranked_requests, ranked_batch, got):
        assert _ranked_same(w, g), req
    for req, g in list(zip(ranked_requests, got))[:25]:
        _assert_oracle_ranked(small_world["corpus"], small_world["index"],
                              req, g)


def test_ranked_mixed_with_unranked_batch(small_world, stop_near_queries):
    """Ranked and unranked requests mix in ONE batch; each behaves exactly
    as in a uniform batch."""
    eng = small_world["engine"]
    sample = stop_near_queries[:20]
    reqs = [SearchRequest(q, mode=MODE_NEAR, rank=bool(i % 2))
            for i, (q, _src) in enumerate(sample)]
    mixed = eng.search_batch(reqs)
    for req, r in zip(reqs, mixed):
        assert r.ranked == req.rank
        if req.rank:
            assert _ranked_same(eng.search(req), r), req
        else:
            want = eng.search(req)
            assert np.array_equal(want.doc, r.doc), req
            assert np.array_equal(want.pos, r.pos), req
            assert r.anchor_scores is None and r.doc_ids is None


def test_ranked_paper_modes(small_world, paper_queries):
    """Phrase + near paper-procedure queries (Types 1-4 incl. tier splits):
    ranked responses match the oracle on both modes."""
    eng = small_world["engine"]
    corpus, index = small_world["corpus"], small_world["index"]
    reqs = [SearchRequest(q, mode=m, rank=True) for q, m, _s in
            paper_queries[:40]]
    for req, r in zip(reqs, eng.search_batch(reqs)):
        assert _ranked_same(eng.search(req), r), req
        _assert_oracle_ranked(corpus, index, req, r)


def test_ranked_ordinary_engine(small_world, paper_queries):
    """The Sphinx-style baseline ranks through the same executor: batched ==
    flexible bit for bit, order follows scores, and phrase-mode scores have
    the closed form n_slots * n_anchors (every slot at exact offset).  (The
    baseline picks its pivot over ALL slots including stops, so the
    additional-index oracle's anchor sets don't apply to its near mode.)"""
    base = small_world["ordinary"]
    reqs = [SearchRequest(q, mode=m, rank=True) for q, m, _s in
            paper_queries[:16]]
    n_phrase = 0
    for req, r in zip(reqs, base.search_batch(reqs)):
        assert _ranked_same(base.search(req), r), req
        if r.doc_only or not len(r.doc):
            continue
        for i in range(len(r.doc_ids) - 1):
            s0, s1 = float(r.doc_scores[i]), float(r.doc_scores[i + 1])
            assert s0 > s1 or (s0 == s1
                               and r.doc_ids[i] < r.doc_ids[i + 1]), req
        if req.mode == MODE_PHRASE:
            n = len(req.surface_ids)
            for d, s in zip(r.doc_ids.tolist(), r.doc_scores.tolist()):
                n_anchors = int((r.doc == d).sum())
                assert abs(s - n * n_anchors) < 1e-4, (req, d, s)
            n_phrase += 1
    assert n_phrase >= 4


# ---------------------------------------------------------------------------
# score monotonicity: closer phrase => higher score
# ---------------------------------------------------------------------------


def _single_form_ordinary_surfaces(world, n):
    """Surfaces whose only basic form is ordinary-tier (and distinct)."""
    from repro.core import TIER_ORDINARY
    lex, ana = world["lex"], world["ana"]
    out, used = [], set()
    for s in range(len(ana.primary)):
        forms = ana.forms_of(s)
        if len(forms) != 1 or forms[0] in used:
            continue
        if int(lex.base_tier[forms[0]]) != TIER_ORDINARY:
            continue
        used.add(forms[0])
        out.append(s)
        if len(out) == n:
            return out
    pytest.skip("not enough single-form ordinary surfaces")


def test_score_monotonicity_distance(small_world):
    """Hand-built docs with the same two words at growing gaps: w(d) is
    strictly decreasing, so the ranked order is exactly the gap order —
    and a doc holding TWO tight matches outranks every single-match doc."""
    a, b, filler = _single_form_ordinary_surfaces(small_world, 3)
    gaps = [1, 2, 4, 6]
    docs = [[a] + [filler] * g + [b] + [filler] * 3 for g in gaps]
    # doc 4: two adjacent (gap-1) occurrences of the pair
    docs.append([a, filler, b] + [filler] * 2 + [a, filler, b])
    tokens = np.concatenate([np.array(d, np.int32) for d in docs])
    offsets = np.zeros(len(docs) + 1, np.int64)
    np.cumsum([len(d) for d in docs], out=offsets[1:])
    corpus = Corpus(doc_offsets=offsets, tokens=tokens)
    index = build_all(corpus, small_world["lex"], small_world["ana"])
    eng = AdditionalIndexEngine(index)

    req = SearchRequest([a, b], mode=MODE_NEAR, rank=True)
    r = eng.search(req)
    assert not r.doc_only
    _assert_oracle_ranked(corpus, index, req, r)
    # two tight matches beat one; then by gap ascending
    assert r.doc_ids.tolist()[0] == 4, r.doc_ids
    assert r.doc_ids.tolist()[1:] == [0, 1, 2, 3], r.doc_ids
    scores = r.doc_scores.tolist()
    assert all(s0 > s1 for s0, s1 in zip(scores, scores[1:])), scores
    # the closed form: g fillers => |pos_b - pos_a| = g + 1, so doc score
    # = 1 (pivot) + w(g + 1) = 1 + 1/(2+g) per anchor
    for d, g in enumerate(gaps):
        want = 1.0 + 1.0 / (2.0 + g)
        got = float(r.doc_scores[r.doc_ids.tolist().index(d)])
        assert abs(got - want) < 1e-5, (d, got, want)


def test_score_monotonicity_proximity_scale(small_world):
    """RankingParams.proximity_scale multiplies every positional score;
    order is invariant."""
    eng = small_world["engine"]
    corpus = small_world["corpus"]
    for d in range(corpus.n_docs):
        toks = corpus.doc(d)
        if len(toks) < 8:
            continue
        base = SearchRequest(toks[0:8:2].tolist(), mode=MODE_NEAR, rank=True)
        r1 = eng.search(base)
        if r1.doc_only or not len(r1.doc):
            continue
        scaled = dataclasses.replace(
            base, ranking=RankingParams(proximity_scale=2.5))
        r2 = eng.search(scaled)
        assert np.array_equal(r1.doc_ids, r2.doc_ids)
        assert np.allclose(r2.doc_scores, 2.5 * r1.doc_scores, rtol=1e-6)
        return
    pytest.fail("no positional near query found in the corpus")


# ---------------------------------------------------------------------------
# boundary: flex-path (escape-hatch) queries rank identically
# ---------------------------------------------------------------------------


def test_flex_escape_ranks_identically(small_world, stop_near_queries):
    """Caps shrunk so every plan routes to the flexible executor: ranked
    output (scores included) must be IDENTICAL to the batched path."""
    import repro.core.batch_executor as bx
    eng = small_world["engine"]
    sample = stop_near_queries[:16]
    reqs = [SearchRequest(q, mode=MODE_NEAR, rank=True) for q, _ in sample]
    plans = [eng.plan_request(r) for r in reqs]
    want = eng.search_batch(reqs)
    be = BatchExecutor(small_world["index"], flex=eng.executor)
    old_cap, old_split = bx.P_CAP, bx.F_SPLIT_CAP
    bx.P_CAP, bx.F_SPLIT_CAP = 8, 2
    try:
        routed = [not be._build_tasks(i, p, [], ranked=True)
                  for i, p in enumerate(plans)]
        assert any(routed), "nothing routed to flex"
        got = be.execute_batch(plans, requests=reqs)
    finally:
        bx.P_CAP, bx.F_SPLIT_CAP = old_cap, old_split
    for req, w, g in zip(reqs, want, got):
        assert _ranked_same(w, g), req


def test_position_overflow_ranks_identically():
    """17-bit position overflow: the whole index is flex-only; ranked
    results still match the oracle."""
    from repro.core import (CorpusConfig, LexiconConfig, generate_corpus,
                            make_lexicon_and_analyzer,
                            near_query_contains_stop)
    lc = LexiconConfig(n_surface=2000, n_base=1500, n_stop=50,
                       n_frequent=200, seed=5)
    lex, ana = make_lexicon_and_analyzer(lc)
    corpus = generate_corpus(lc, CorpusConfig(n_docs=2, mean_doc_len=150_000,
                                              seed=5))
    index = build_all(corpus, lex, ana)
    eng = AdditionalIndexEngine(index)
    assert eng.batch_executor._pos_budget <= 0
    toks = corpus.doc(0)
    rng = np.random.default_rng(9)
    reqs = []
    while len(reqs) < 3:
        st = int(rng.integers(0, len(toks) - 8))
        q = toks[st:st + 8:2].tolist()
        if near_query_contains_stop(lex, ana, q):
            reqs.append(SearchRequest(q, mode=MODE_NEAR, rank=True))
    for req, r in zip(reqs, eng.search_batch(reqs)):
        assert _ranked_same(eng.search(req), r), req
        _assert_oracle_ranked(corpus, index, req, r)


# ---------------------------------------------------------------------------
# triple gating (multi-key size dial): two pair lookups, same answers
# ---------------------------------------------------------------------------


def test_triple_gating_parity(small_world, stop_near_queries):
    """An index whose triples are gated to common (s1, s2) pairs answers
    every query identically (the planner falls back to two pair lookups);
    postings_read may differ — that's the dial's price."""
    index = small_world["index"]
    tf = expand_token_forms(small_world["corpus"], index.lexicon,
                            index.analyzer)
    params = dataclasses.replace(index.params, triple_pair_min_count=20)
    gated_mk = build_multi_key_index(tf, index.lexicon, params)
    assert gated_mk.triple_stop_pairs is not None
    assert gated_mk.n_triple_postings < index.multi_key.n_triple_postings
    assert gated_mk.n_pair_postings == index.multi_key.n_pair_postings
    gated_index = dataclasses.replace(index, multi_key=gated_mk,
                                      params=params)
    eng = small_world["engine"]
    eng_gated = AdditionalIndexEngine(gated_index)
    sample = stop_near_queries[:40]
    reqs = [SearchRequest(q, mode=MODE_NEAR) for q, _ in sample]
    for req, w, g in zip(reqs, eng.search_batch(reqs),
                         eng_gated.search_batch(reqs)):
        assert np.array_equal(w.doc, g.doc), req
        assert np.array_equal(w.pos, g.pos), req
        assert w.doc_only == g.doc_only, req
    # gated pairs really do take the two-pair fallback somewhere
    n_pairs_only = 0
    for req in reqs:
        plan = eng_gated.plan_request(req)
        for sp in plan.subplans:
            if sp.qtype != QTYPE_MULTI:
                continue
            n_pairs_only += sum(
                1 for g in sp.groups for f in g.fetches
                if f.stream == "multi" and f.pivot_from_dist)
    assert n_pairs_only > 0
    # ranked requests agree bit-for-bit too (ranked plans never use triples)
    rreqs = [dataclasses.replace(r, rank=True) for r in reqs[:12]]
    for req, w, g in zip(rreqs, eng.search_batch(rreqs),
                         eng_gated.search_batch(rreqs)):
        assert _ranked_same(w, g), req


def test_triple_gate_all_common_is_identity(small_world):
    """min_count=1 keeps every triple: the gate must be a no-op."""
    index = small_world["index"]
    tf = expand_token_forms(small_world["corpus"], index.lexicon,
                            index.analyzer)
    params = dataclasses.replace(index.params, triple_pair_min_count=1)
    mk = build_multi_key_index(tf, index.lexicon, params)
    assert mk.n_triple_postings == index.multi_key.n_triple_postings
    assert mk.has_triple_pair(0, 1) or mk.triple_stop_pairs is not None


# ---------------------------------------------------------------------------
# the typed API itself
# ---------------------------------------------------------------------------


def test_score_delta_bits_constants_agree():
    """The kernel layer keeps its own literal of the composite delta width
    (it must not import core); pin the two constants together so widening
    SCORE_DELTA_BITS can't silently desync the ref/pallas unpacking."""
    from repro.core.fetch_tables import SCORE_DELTA_BITS
    from repro.kernels.ops import _SDB
    assert _SDB == SCORE_DELTA_BITS


def test_legacy_signatures_warn(small_world):
    eng = small_world["engine"]
    q = small_world["corpus"].doc(0)[:3].tolist()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        r_old = eng.search(q, mode=MODE_PHRASE)
        eng.search_batch([q], modes=MODE_PHRASE)
    assert sum(issubclass(x.category, DeprecationWarning) for x in rec) >= 2
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        r_new = eng.search(SearchRequest(q, mode=MODE_PHRASE))
    assert np.array_equal(r_old.doc, r_new.doc)


def test_response_hits_and_provenance(small_world, stop_near_queries):
    """Ranked DocHits: score desc, positions per doc, and subplan indices
    that actually contributed the doc."""
    eng = small_world["engine"]
    for q, _src in stop_near_queries:
        req = SearchRequest(q, mode=MODE_NEAR, rank=True)
        r = eng.search(req)
        if r.doc_only or not len(r.doc):
            continue
        hits = r.hits
        assert [h.doc for h in hits] == r.doc_ids.tolist()
        for h in hits:
            assert isinstance(h, DocHit)
            assert np.array_equal(np.sort(r.pos[r.doc == h.doc]), h.positions)
            assert h.subplans, h                      # some subplan made it
            assert all(0 <= i < len(r.subplan_types) for i in h.subplans)
        break
    else:
        pytest.skip("no positional ranked result in the suite")


def test_top_k_truncates_by_score(small_world, paper_queries):
    eng = small_world["engine"]
    for q, m, _src in paper_queries:
        full = eng.search(SearchRequest(q, mode=m, rank=True))
        if full.doc_only or len(full.doc_ids) < 3:
            continue
        k = 2
        cut = eng.search(SearchRequest(q, mode=m, rank=True, top_k=k))
        assert len(cut.doc_ids) == k
        assert np.array_equal(cut.doc_ids, full.doc_ids[:k])
        assert np.array_equal(cut.doc_scores, full.doc_scores[:k])
        # unranked top_k keeps the legacy max_results truncation
        un = eng.search(SearchRequest(q, mode=m, top_k=k))
        assert len(un.doc) <= k
        return
    pytest.skip("no query with 3+ ranked docs")


def test_doc_only_fallback_ranked(small_world):
    """Cross-document scrambles: ranked responses fall back to doc-only
    hits at RankingParams.doc_only_score."""
    corpus = small_world["corpus"]
    eng = small_world["engine"]
    rng = np.random.default_rng(23)
    for _ in range(12):
        d1, d2 = rng.integers(corpus.n_docs, size=2)
        t1, t2 = corpus.doc(int(d1)), corpus.doc(int(d2))
        if len(t1) < 8 or len(t2) < 8:
            continue
        req = SearchRequest([int(t1[3]), int(t2[5]), int(t1[7])], rank=True)
        r = eng.search(req)
        if not r.used_fallback or not r.doc_only:
            continue
        assert np.array_equal(r.doc_ids, r.doc)
        assert (r.doc_scores == np.float32(req.ranking.doc_only_score)).all()
        assert _ranked_same(eng.search_batch([req])[0], r)
        return
    pytest.skip("no fallback query found")

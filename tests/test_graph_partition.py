"""Host-side properties of the halo-exchange graph partitioner."""
import numpy as np
import pytest

from repro.data import graph_data


@pytest.mark.parametrize("n_shards", [2, 4, 8])
def test_partition_preserves_all_kept_edges(n_shards):
    g = graph_data.generate_graph(300, 2400, d_feat=8, n_classes=4, seed=3)
    part = graph_data.partition_for_halo(g, n_shards)
    Nl = part["n_local"]
    B = part["boundary"]

    # node relabeling: features/labels are a permutation of the originals
    feats = part["nodes"].reshape(-1, 8)[part["label_mask"].reshape(-1)]
    assert feats.shape[0] == g.n_nodes
    assert np.isclose(np.sort(feats.sum(1)), np.sort(g.features.sum(1))).all()

    # every kept edge's endpoints resolve to valid rows
    kept = 0
    for s in range(n_shards):
        em = part["edge_mask"][s]
        src, dst = part["src"][s][em], part["dst"][s][em]
        assert (dst >= 0).all() and (dst < Nl).all()
        assert (src >= 0).all() and (src < Nl + n_shards * B).all()
        kept += em.sum()
    assert kept <= g.n_edges
    assert kept >= 0.95 * g.n_edges       # few edges dropped to budget

    # send_idx rows are valid local rows
    si = part["send_idx"]
    assert ((si == -1) | ((si >= 0) & (si < Nl))).all()
    assert 0.0 <= part["cut_fraction"] <= 1.0


def test_partition_roundtrip_degree_sum():
    """Sum of kept in-degrees == number of kept edges (scatter correctness)."""
    g = graph_data.generate_graph(200, 1600, d_feat=4, n_classes=3, seed=5)
    part = graph_data.partition_for_halo(g, 4)
    total = 0
    for s in range(4):
        em = part["edge_mask"][s]
        deg = np.bincount(part["dst"][s][em], minlength=part["n_local"])
        total += deg.sum()
    assert total == sum(part["edge_mask"][s].sum() for s in range(4))

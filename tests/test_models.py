"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU asserting output shapes + finiteness — the assigned-architecture
deliverable.  Full configs are exercised only via the dry-run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ALL_ARCHS, get_arch
from repro.data import graph_data, recsys_data
from repro.models import gnn, recsys, transformer as tfm
from repro.train import OptimizerConfig, apply_updates, init_state

LM_ARCHS = ["granite-3-8b", "qwen2.5-32b", "llama3-8b",
            "granite-moe-1b-a400m", "moonshot-v1-16b-a3b"]
REC_ARCHS = ["fm", "autoint", "bst", "mind"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    cfg = get_arch(arch).make_smoke_config()
    key = jax.random.PRNGKey(0)
    params = tfm.init_params(cfg, key)
    B, S = 2, 32
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab, dtype=jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    logits, aux = tfm.forward(cfg, params, toks)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits).all())
    ocfg = OptimizerConfig(lr=1e-3)
    state = init_state(ocfg, params)
    (loss, m), grads = jax.value_and_grad(
        lambda p: tfm.loss_fn(cfg, p, batch), has_aux=True)(params)
    assert bool(jnp.isfinite(loss))
    new_params, state, _ = apply_updates(ocfg, params, grads, state)
    (loss2, _), _ = jax.value_and_grad(
        lambda p: tfm.loss_fn(cfg, p, batch), has_aux=True)(new_params)
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("arch", LM_ARCHS[:2] + LM_ARCHS[3:4])
def test_lm_smoke_decode_matches_forward(arch):
    cfg = get_arch(arch).make_smoke_config()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab,
                              dtype=jnp.int32)
    cache = tfm.init_cache(cfg, 2, 16)
    outs = []
    for t in range(8):
        lg, cache = tfm.decode_step(cfg, params, cache, toks[:, t], jnp.int32(t))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    full, _ = tfm.forward(cfg, params, toks)
    assert float(jnp.abs(dec - full).max()) < 5e-3


def test_gin_smoke_all_shapes():
    spec = get_arch("gin-tu")
    base = spec.make_smoke_config()
    rng = np.random.default_rng(0)
    # full graph
    g = graph_data.generate_graph(300, 1500, base.d_feat, base.n_classes, seed=0)
    cfg = dataclasses.replace(base, d_feat=g.features.shape[1])
    p = gnn.init_params(cfg, jax.random.PRNGKey(0))
    b = {k: jnp.asarray(v) for k, v in graph_data.full_graph_batch(g).items()}
    loss, m = gnn.loss_fn(cfg, p, b)
    assert bool(jnp.isfinite(loss))
    # sampled minibatch
    sub = graph_data.sample_subgraph(g, np.arange(16), (4, 3), rng)
    loss2, _ = gnn.loss_fn(cfg, p, {k: jnp.asarray(v) for k, v in sub.items()})
    assert bool(jnp.isfinite(loss2))
    # molecule readout
    mcfg = dataclasses.replace(base, graph_readout=True)
    mp = gnn.init_params(mcfg, jax.random.PRNGKey(0))
    mb = graph_data.molecule_batch(8, 10, 20, base.d_feat, base.n_classes)
    mb = {k: (jnp.asarray(v) if isinstance(v, np.ndarray) else v) for k, v in mb.items()}
    loss3, _ = gnn.loss_fn(mcfg, mp, mb)
    assert bool(jnp.isfinite(loss3))


@pytest.mark.parametrize("arch", REC_ARCHS)
def test_recsys_smoke_train_and_retrieval(arch):
    cfg = get_arch(arch).make_smoke_config()
    params = recsys.init_params(cfg, jax.random.PRNGKey(0))
    log = recsys_data.ClickLog(cfg.field_vocabs, item_vocab=cfg.item_vocab,
                               seq_len=cfg.seq_len, seed=0)
    batch = log.seq_batch(16) if cfg.model in ("bst", "mind") else log.ctr_batch(16)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    loss, m = recsys.loss_fn(cfg, params, batch)
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: recsys.loss_fn(cfg, p, batch)[0])(params)
    gn = sum(float(jnp.abs(g).sum()) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0
    rb = {k: jnp.asarray(v) for k, v in log.retrieval_batch(2, 100).items()}
    scores = recsys.retrieval_scores(cfg, params, rb)
    assert scores.shape == (2, 100)
    assert bool(jnp.isfinite(scores).all())


def test_registry_covers_all_archs():
    assert len(ALL_ARCHS) == 11          # 10 assigned + the paper's engine
    for a in ALL_ARCHS:
        spec = get_arch(a)
        assert spec.shapes, a
        assert spec.make_config() is not None
        assert spec.make_smoke_config() is not None


def test_moe_capacity_drops_overflow():
    """Tokens beyond capacity are dropped, not mis-routed."""
    from repro.models.moe import MoEConfig, moe_ffn
    cfg = MoEConfig(n_experts=2, top_k=1, d_expert=8, capacity_factor=0.1)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (32, 16), jnp.float32)
    rw = jnp.zeros((16, 2), jnp.float32)      # uniform router -> argmax=expert0
    wg = jax.random.normal(key, (2, 16, 8), jnp.float32) * 0.1
    wu = jax.random.normal(key, (2, 16, 8), jnp.float32) * 0.1
    wd = jax.random.normal(key, (2, 8, 16), jnp.float32) * 0.1
    y, aux = moe_ffn(x, rw, wg, wu, wd, cfg, jnp.float32)
    # capacity = int(32*1/2*0.1)+1 = 2 slots per expert; everything routes to
    # expert 0 -> at most 2 tokens produce nonzero output
    nonzero = int((jnp.abs(y).sum(axis=1) > 1e-9).sum())
    assert nonzero <= 2 * cfg.n_experts


def test_transformer_vocab_padding_masked():
    cfg = tfm.TransformerConfig(name="t", n_layers=1, d_model=32, n_heads=2,
                                n_kv_heads=1, d_ff=64, vocab=100,
                                dtype=jnp.float32)
    assert cfg.vocab_padded == 256
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.zeros((1, 4), jnp.int32)
    loss, _ = tfm.loss_fn(cfg, params, {"tokens": toks, "labels": toks})
    # the loss can never prefer a padding token: nll <= log(vocab_padded)
    # would fail if padding leaked; just require finiteness + sane range
    assert 0 < float(loss) < 20

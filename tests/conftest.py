"""Shared fixtures: a small built index + engines (session-scoped).

NOTE: no XLA_FLAGS here — smoke tests run on the single real CPU device;
multi-device tests spawn subprocesses (test_dist.py).
"""
import numpy as np
import pytest

from repro.core import (AdditionalIndexEngine, CorpusConfig, IndexParams,
                        LexiconConfig, OrdinaryEngine, build_all,
                        generate_corpus, make_lexicon_and_analyzer)


@pytest.fixture(scope="session")
def small_world():
    lc = LexiconConfig(n_surface=8000, n_base=6000, n_stop=150,
                       n_frequent=500, seed=2)
    lex, ana = make_lexicon_and_analyzer(lc)
    corpus = generate_corpus(lc, CorpusConfig(n_docs=120, mean_doc_len=400, seed=2))
    index = build_all(corpus, lex, ana)
    return {"lex": lex, "ana": ana, "corpus": corpus, "index": index,
            "engine": AdditionalIndexEngine(index),
            "ordinary": OrdinaryEngine(index)}


@pytest.fixture(scope="session")
def stop_near_queries(small_world):
    """Seeded 200-query near-mode generator, biased so nearly every query
    contains a stop basic form — the population the paper's Type-4 rule used
    to confine to sequential matching and the multi-component key index now
    serves with TRUE windowed semantics.  Always runs (no hypothesis
    dependency); hypothesis drivers add shrinking on top when installed.

    Yields (surface_ids, source_doc) tuples: word-set samples from indexed
    documents at strides 1..3 (the paper's 2.2 procedure is stride 2), plus
    explicit stop-injected variants.
    """
    from repro.core import near_query_contains_stop
    corpus = small_world["corpus"]
    lex, ana = small_world["lex"], small_world["ana"]
    rng = np.random.default_rng(2024)
    # a few guaranteed-stop surfaces to inject (surface 0 maps to base 0)
    stop_surfaces = [s for s in range(200)
                     if bool(lex.is_stop(np.asarray(ana.forms_of(s))).any())][:8]
    queries = []
    while len(queries) < 200:
        d = int(rng.integers(corpus.n_docs))
        toks = corpus.doc(d)
        n = int(rng.integers(2, 7))
        stride = int(rng.integers(1, 4))
        span = stride * (n - 1) + 1
        if len(toks) <= span:
            continue
        st = int(rng.integers(0, len(toks) - span))
        q = toks[st:st + span:stride].tolist()
        if not near_query_contains_stop(lex, ana, q):
            if len(q) < 2:
                continue
            q[int(rng.integers(len(q)))] = int(rng.choice(stop_surfaces))
        queries.append((q, d))
    return queries


@pytest.fixture(scope="session")
def kword_queries(small_world):
    """Seeded 200-query stop-heavy K-word proximity suite (arXiv:2009.02684,
    ISSUE 9 acceptance workload): K in {3, 4, 5} word sets sampled from
    indexed documents at strides 1..3, ~70% with an explicit stop-surface
    injection, window covering the sampled span plus jitter.  ~10% of the
    windows exceed the device executors' int32 delta masks (W > 15) so the
    flexible escape path stays under test.  Yields
    (surface_ids, window, source_doc) triples."""
    corpus = small_world["corpus"]
    lex, ana = small_world["lex"], small_world["ana"]
    rng = np.random.default_rng(2026)
    stop_surfaces = [s for s in range(200)
                     if bool(lex.is_stop(np.asarray(ana.forms_of(s))).any())][:8]
    queries = []
    while len(queries) < 200:
        d = int(rng.integers(corpus.n_docs))
        toks = corpus.doc(d)
        k = int(rng.integers(3, 6))
        stride = int(rng.integers(1, 4))
        span = stride * (k - 1) + 1
        if len(toks) <= span:
            continue
        st = int(rng.integers(0, len(toks) - span))
        q = toks[st:st + span:stride].tolist()
        if rng.random() < 0.7:
            q[int(rng.integers(k))] = int(rng.choice(stop_surfaces))
        if rng.random() < 0.1:
            window = 16 + int(rng.integers(0, 16))      # flex-only range
        else:
            window = max(2, min(span - 1 + int(rng.integers(0, 4)), 15))
        queries.append((q, window, d))
    return queries


@pytest.fixture(scope="session")
def paper_queries(small_world):
    """The paper's experiment procedure: random doc, consecutive words (2.1)
    and every-other-word (2.2) queries of 3..5 words."""
    corpus = small_world["corpus"]
    rng = np.random.default_rng(7)
    queries = []
    for _ in range(60):
        d = int(rng.integers(corpus.n_docs))
        toks = corpus.doc(d)
        n = int(rng.integers(3, 6))
        if len(toks) < 2 * n + 2:
            continue
        st = int(rng.integers(0, len(toks) - 2 * n))
        queries.append((toks[st:st + n].tolist(), "phrase", d))
        queries.append((toks[st:st + 2 * n:2].tolist(), "near", d))
    return queries

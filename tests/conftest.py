"""Shared fixtures: a small built index + engines (session-scoped).

NOTE: no XLA_FLAGS here — smoke tests run on the single real CPU device;
multi-device tests spawn subprocesses (test_dist.py).
"""
import numpy as np
import pytest

from repro.core import (AdditionalIndexEngine, CorpusConfig, IndexParams,
                        LexiconConfig, OrdinaryEngine, build_all,
                        generate_corpus, make_lexicon_and_analyzer)


@pytest.fixture(scope="session")
def small_world():
    lc = LexiconConfig(n_surface=8000, n_base=6000, n_stop=150,
                       n_frequent=500, seed=2)
    lex, ana = make_lexicon_and_analyzer(lc)
    corpus = generate_corpus(lc, CorpusConfig(n_docs=120, mean_doc_len=400, seed=2))
    index = build_all(corpus, lex, ana)
    return {"lex": lex, "ana": ana, "corpus": corpus, "index": index,
            "engine": AdditionalIndexEngine(index),
            "ordinary": OrdinaryEngine(index)}


@pytest.fixture(scope="session")
def paper_queries(small_world):
    """The paper's experiment procedure: random doc, consecutive words (2.1)
    and every-other-word (2.2) queries of 3..5 words."""
    corpus = small_world["corpus"]
    rng = np.random.default_rng(7)
    queries = []
    for _ in range(60):
        d = int(rng.integers(corpus.n_docs))
        toks = corpus.doc(d)
        n = int(rng.integers(3, 6))
        if len(toks) < 2 * n + 2:
            continue
        st = int(rng.integers(0, len(toks) - 2 * n))
        queries.append((toks[st:st + n].tolist(), "phrase", d))
        queries.append((toks[st:st + 2 * n:2].tolist(), "near", d))
    return queries

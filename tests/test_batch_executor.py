"""Batched executor: search_batch must agree with per-query search (the
flexible executor) and with the brute-force oracle on mixed Type 1-4 query
batches, including doc-only fallback queries inside a batch; and the Pallas
banded-intersect path must agree with the ref path on re-based int32 keys."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdditionalIndexEngine, BatchExecutor,
                        SearchRequest, brute_force_search)
from repro.core.planner import MODE_NEAR, MODE_PHRASE
from repro.kernels import ops


def _mixed_batch(small_world, n=50, seed=11):
    """Phrase + near queries sampled from indexed docs (the paper's 2.1/2.2
    procedure) plus hand-picked stop-heavy queries for Type 1/4 coverage."""
    corpus = small_world["corpus"]
    lex = small_world["lex"]
    ana = small_world["ana"]
    rng = np.random.default_rng(seed)
    queries, modes = [], []
    while len(queries) < n:
        d = int(rng.integers(corpus.n_docs))
        toks = corpus.doc(d)
        k = int(rng.integers(3, 6))
        if len(toks) < 2 * k + 2:
            continue
        st = int(rng.integers(0, len(toks) - 2 * k))
        queries.append(toks[st:st + k].tolist())
        modes.append(MODE_PHRASE)
        if len(queries) < n:
            queries.append(toks[st:st + 2 * k:2].tolist())
            modes.append(MODE_NEAR)
    # short queries: single-word (one-group task) and two-word
    t0 = corpus.doc(0)
    queries.append([int(t0[0])])
    modes.append(MODE_PHRASE)
    queries.append([int(t0[0]), int(t0[1])])
    modes.append(MODE_PHRASE)
    # stop-run (Type 1) and stop-mixed (Type 4) windows, if the corpus has any
    stops = 0
    for d in range(corpus.n_docs):
        toks = corpus.doc(d)
        forms = ana.primary[toks]
        is_stop = np.asarray(lex.is_stop(forms))
        for st in range(len(toks) - 3):
            if is_stop[st:st + 3].all() and stops < 4:
                queries.append(toks[st:st + 3].tolist())
                modes.append(MODE_PHRASE)
                stops += 1
        if stops >= 4:
            break
    return queries, modes


def _same_result(r1, r2) -> bool:
    return (np.array_equal(r1.doc, r2.doc) and np.array_equal(r1.pos, r2.pos)
            and r1.postings_read == r2.postings_read
            and r1.used_fallback == r2.used_fallback
            and r1.doc_only == r2.doc_only
            and r1.subplan_types == r2.subplan_types)


def test_search_batch_matches_per_query(small_world):
    eng = small_world["engine"]
    queries, modes = _mixed_batch(small_world)
    batch = eng.search_batch([SearchRequest(q, mode=m)
                              for q, m in zip(queries, modes)])
    assert len(batch) == len(queries)
    for q, m, got in zip(queries, modes, batch):
        want = eng.search(SearchRequest(q, mode=m))
        assert _same_result(want, got), (q, m)


def test_search_batch_matches_per_query_ordinary(small_world):
    base = small_world["ordinary"]
    queries, modes = _mixed_batch(small_world, n=24, seed=3)
    batch = base.search_batch([SearchRequest(q, mode=m)
                               for q, m in zip(queries, modes)])
    for q, m, got in zip(queries, modes, batch):
        want = base.search(SearchRequest(q, mode=m))
        assert _same_result(want, got), (q, m)


def test_search_batch_matches_brute_force(small_world):
    """Positional results (or the doc-only fallback set) against the
    O(corpus) oracle, per query of a mixed batch."""
    eng = small_world["engine"]
    corpus, index = small_world["corpus"], small_world["index"]
    queries, modes = _mixed_batch(small_world, n=20, seed=5)
    batch = eng.search_batch([SearchRequest(q, mode=m)
                              for q, m in zip(queries, modes)])
    for q, m, r in zip(queries, modes, batch):
        positional, doc_level = brute_force_search(corpus, index, q, mode=m)
        if r.doc_only:
            assert set(r.doc.tolist()) == doc_level, (q, m)
        else:
            got = set(zip(r.doc.tolist(), r.pos.tolist()))
            assert got == positional, (q, m)


def test_search_batch_fallback_queries_in_batch(small_world):
    """Queries that positionally miss (scrambled word order across docs) must
    fall back to doc-only results inside a batch, exactly like per-query."""
    corpus = small_world["corpus"]
    eng = small_world["engine"]
    rng = np.random.default_rng(23)
    queries = []
    for _ in range(8):
        d1, d2 = rng.integers(corpus.n_docs, size=2)
        t1, t2 = corpus.doc(int(d1)), corpus.doc(int(d2))
        if len(t1) < 8 or len(t2) < 8:
            continue
        queries.append([int(t1[3]), int(t2[5]), int(t1[7])])
    assert queries
    batch = eng.search_batch([SearchRequest(q) for q in queries])
    n_fallback = 0
    for q, r in zip(queries, batch):
        want = eng.search(SearchRequest(q, mode=MODE_PHRASE))
        assert _same_result(want, r)
        n_fallback += int(r.used_fallback)
    assert n_fallback > 0    # the batch did exercise the fallback path


def test_search_batch_pallas_matches_ref(small_world):
    eng_p = AdditionalIndexEngine(small_world["index"], batch_impl="pallas")
    eng_r = small_world["engine"]
    queries, modes = _mixed_batch(small_world, n=16, seed=7)
    reqs = [SearchRequest(q, mode=m) for q, m in zip(queries, modes)]
    bp = eng_p.search_batch(reqs)
    br = eng_r.search_batch(reqs)
    for a, b in zip(bp, br):
        assert np.array_equal(a.doc, b.doc) and np.array_equal(a.pos, b.pos)


def test_search_batch_max_results(small_world):
    eng = small_world["engine"]
    queries, modes = _mixed_batch(small_world, n=6, seed=13)
    batch = eng.search_batch([SearchRequest(q, mode=m, top_k=2)
                              for q, m in zip(queries, modes)])
    for q, m, r in zip(queries, modes, batch):
        want = eng.search(SearchRequest(q, mode=m, top_k=2))
        assert np.array_equal(want.doc, r.doc)
        assert len(r.doc) <= 2


def test_batch_executor_flex_escape_hatch(small_world):
    """Plans exceeding the table caps route through the flexible executor
    with identical results."""
    import repro.core.batch_executor as bx
    eng = small_world["engine"]
    queries, modes = _mixed_batch(small_world, n=8, seed=17)
    be = BatchExecutor(small_world["index"], flex=eng.executor)
    old_cap, old_split = bx.P_CAP, bx.F_SPLIT_CAP
    bx.P_CAP = 1          # every fetch must split per posting...
    bx.F_SPLIT_CAP = 2    # ...and immediately overflows the slots => flex
    try:
        plans = [eng.plan(q, mode=m) for q, m in zip(queries, modes)]
        # every real posting list (length > 2) overflows the split slots
        assert sum(not be._build_tasks(i, p, [])
                   for i, p in enumerate(plans)) >= len(plans) // 2
        got = be.execute_batch(plans)
    finally:
        bx.P_CAP, bx.F_SPLIT_CAP = old_cap, old_split
    for q, m, r in zip(queries, modes, got):
        want = eng.search(SearchRequest(q, mode=m))
        assert _same_result(want, r)


# ---------------------------------------------------------------------------
# fallback boundaries: each escape hatch routes to flex AND matches the
# brute-force oracle; the lifted postings cap stays on the batched path
# ---------------------------------------------------------------------------


def _assert_oracle(small_world, q, m, r):
    positional, doc_level = brute_force_search(
        small_world["corpus"], small_world["index"], q, mode=m)
    if r.doc_only:
        assert set(r.doc.tolist()) == doc_level, (q, m)
    else:
        assert set(zip(r.doc.tolist(), r.pos.tolist())) == positional, (q, m)


def test_boundary_many_and_groups_routes_flex(small_world):
    """> G_CAP AND-groups (an 11-word phrase) must route to flex and still
    match per-query search and the oracle."""
    import repro.core.batch_executor as bx
    corpus = small_world["corpus"]
    eng = small_world["engine"]
    be = BatchExecutor(small_world["index"], flex=eng.executor)
    queries, plans = [], []
    for d in range(corpus.n_docs):
        toks = corpus.doc(d)
        for st in range(0, max(len(toks) - bx.G_CAP - 3, 0), 4):
            q = toks[st:st + bx.G_CAP + 3].tolist()
            plan = eng.plan(q, mode=MODE_PHRASE)
            # stop words become checks, not groups: keep only windows whose
            # plan really carries > G_CAP AND-groups in one subplan
            if any(sp.supported and len(sp.groups) > bx.G_CAP
                   for sp in plan.subplans):
                queries.append(q)
                plans.append(plan)
            if len(queries) == 3:
                break
        if len(queries) == 3:
            break
    assert queries, "no >G_CAP-group windows found"
    assert all(not be._build_tasks(i, p, []) for i, p in enumerate(plans))
    for q, r in zip(queries, be.execute_batch(plans)):
        assert _same_result(eng.search(SearchRequest(q, mode=MODE_PHRASE)), r), q
        _assert_oracle(small_world, q, MODE_PHRASE, r)


def test_boundary_many_fetches_per_group_routes_flex(small_world):
    """> F_CAP unioned form fetches in one group must route to flex (shrunk
    cap: real multi-form groups have 2-4 fetches) and match the oracle."""
    import repro.core.batch_executor as bx
    eng = small_world["engine"]
    be = BatchExecutor(small_world["index"], flex=eng.executor)
    queries, modes = _mixed_batch(small_world, n=12, seed=29)
    plans = [eng.plan(q, mode=m) for q, m in zip(queries, modes)]
    multi = [i for i, p in enumerate(plans)
             if any(len(g.fetches) > 1 for sp in p.subplans if sp.supported
                    for g in sp.groups + sp.fallback_groups)]
    assert multi, "no multi-fetch groups in the workload"
    old = bx.F_CAP
    bx.F_CAP = 1
    try:
        for i in multi:
            assert not be._build_tasks(i, plans[i], [])
        got = be.execute_batch(plans)
    finally:
        bx.F_CAP = old
    for q, m, r in zip(queries, modes, got):
        assert _same_result(eng.search(SearchRequest(q, mode=m)), r), (q, m)
        _assert_oracle(small_world, q, m, r)


def test_boundary_long_fetches_stay_batched(small_world):
    """Fetches longer than P_CAP no longer escape: task-row splitting keeps
    them on the batched path (slots > 1) with oracle-identical results."""
    import repro.core.batch_executor as bx
    eng = small_world["engine"]
    be = BatchExecutor(small_world["index"], flex=eng.executor)
    queries, modes = _mixed_batch(small_world, n=12, seed=31)
    plans = [eng.plan(q, mode=m) for q, m in zip(queries, modes)]
    long_q = [i for i, p in enumerate(plans)
              if any(f.length > 256 for sp in p.subplans if sp.supported
                     for g in sp.groups for f in g.fetches)]
    assert long_q, "no long posting lists in the workload"
    old = bx.P_CAP
    bx.P_CAP = 256
    try:
        tasks: list = []
        assert be._build_tasks(0, plans[long_q[0]], tasks)   # batched, not flex
        assert any(len(g.slots) > 1 for t in tasks for r in t.rows
                   for g in r.groups), "long fetch was not split"
        got = be.execute_batch(plans)
    finally:
        bx.P_CAP = old
    for q, m, r in zip(queries, modes, got):
        assert _same_result(eng.search(SearchRequest(q, mode=m)), r), (q, m)
        _assert_oracle(small_world, q, m, r)


def test_boundary_position_overflow_routes_flex():
    """An index whose positions overflow the 17-bit packed-key field must
    route every plan to flex and still match the brute-force oracle."""
    from repro.core import (CorpusConfig, LexiconConfig, build_all,
                            generate_corpus, make_lexicon_and_analyzer)
    from repro.core.fetch_tables import TABLE_POS_BITS
    lc = LexiconConfig(n_surface=2000, n_base=1500, n_stop=50,
                       n_frequent=200, seed=5)
    lex, ana = make_lexicon_and_analyzer(lc)
    corpus = generate_corpus(lc, CorpusConfig(n_docs=2, mean_doc_len=150_000,
                                              seed=5))
    index = build_all(corpus, lex, ana)
    eng = AdditionalIndexEngine(index)
    be = eng.batch_executor
    assert be.dev.max_pos + 64 > (1 << TABLE_POS_BITS) - 64, \
        "corpus too short to overflow the packed-key field"
    assert be._pos_budget <= 0
    toks = corpus.doc(0)
    queries = [toks[10:13].tolist(), toks[100:104].tolist(),
               toks[140_000:140_003].tolist()]
    plans = [eng.plan(q, mode=MODE_PHRASE) for q in queries]
    assert all(not be._build_tasks(i, p, []) for i, p in enumerate(plans))
    for q, r in zip(queries, be.execute_batch(plans)):
        assert _same_result(eng.search(SearchRequest(q, mode=MODE_PHRASE)), r), q
        _assert_oracle({"corpus": corpus, "index": index}, q, MODE_PHRASE, r)


def _kword_boundary_queries(small_world, k_lo=6, k_hi=9, n=6, seed=41):
    """Contiguous K in [k_lo, k_hi) word windows from indexed docs with a
    device-reach span window — the ISSUE's K=6-8 overflow population."""
    corpus = small_world["corpus"]
    rng = np.random.default_rng(seed)
    out = []
    while len(out) < n:
        d = int(rng.integers(corpus.n_docs))
        toks = corpus.doc(d)
        k = int(rng.integers(k_lo, k_hi))
        if len(toks) <= k + 2:
            continue
        st = int(rng.integers(0, len(toks) - k))
        out.append((toks[st:st + k].tolist(), min(k + 1, 15)))
    return out


def test_boundary_kword_many_groups_routes_flex(small_world):
    """K=6-8 kword plans whose cover still carries > G_CAP AND-groups
    (shrunk cap: the multi-key cover compresses real K=8 plans under the
    production cap) must route to flex and stay oracle-identical —
    positional anchors AND postings accounting."""
    import repro.core.batch_executor as bx
    from repro.core import brute_force_kword
    from repro.core.kword import MODE_KWORD
    eng = small_world["engine"]
    corpus, index = small_world["corpus"], small_world["index"]
    be = BatchExecutor(index, flex=eng.executor)
    cases = _kword_boundary_queries(small_world, n=6)
    reqs = [SearchRequest(q, mode=MODE_KWORD, window=w) for q, w in cases]
    plans = [eng.plan_request(r) for r in reqs]
    old = bx.G_CAP
    bx.G_CAP = 3
    try:
        over = [i for i, p in enumerate(plans)
                if any(sp.supported and len(sp.groups) > bx.G_CAP
                       and all(g.fetches for g in sp.groups)
                       for sp in p.subplans)]
        assert len(over) >= 3, "K=6-8 covers never exceeded the shrunk cap"
        for i in over:
            assert not be._build_tasks(i, plans[i], []), cases[i]
        got = be.execute_batch(plans)
    finally:
        bx.G_CAP = old
    for (q, w), req, r in zip(cases, reqs, got):
        assert _same_result(eng.search(req), r), (q, w)
        truth_pos, truth_doc = brute_force_kword(corpus, index, q, w)
        if r.doc_only:
            assert set(r.doc.tolist()) == truth_doc, (q, w)
        else:
            assert set(zip(r.doc.tolist(), r.pos.tolist())) == truth_pos, (q, w)


def test_boundary_kword_default_caps_stay_batched(small_world):
    """The same K=6-8 population at PRODUCTION caps: the multi-key cover
    must compress every plan under G_CAP so it stays on the device path
    (guards cover-bloat regressions), still bit-identical to flex."""
    from repro.core.kword import MODE_KWORD
    eng = small_world["engine"]
    be = BatchExecutor(small_world["index"], flex=eng.executor)
    cases = _kword_boundary_queries(small_world, n=6, seed=43)
    reqs = [SearchRequest(q, mode=MODE_KWORD, window=w) for q, w in cases]
    plans = [eng.plan_request(r) for r in reqs]
    n_batched = sum(bool(be._build_tasks(i, p, []))
                    for i, p in enumerate(plans))
    assert n_batched >= 4, n_batched
    for req, r in zip(reqs, be.execute_batch(plans)):
        assert _same_result(eng.search(req), r), req


@pytest.mark.parametrize("dps", [16, 64])
def test_search_batch_segmented_shards_match(small_world, dps):
    """Shard-segmented gather: cutting the corpus into many small doc shards
    (one row per task x shard) must not change any result bit."""
    eng = AdditionalIndexEngine(small_world["index"], docs_per_shard=dps)
    assert eng.batch_executor.dev.n_shards > 1
    ref = small_world["engine"]
    queries, modes = _mixed_batch(small_world, n=24, seed=19)
    for q, m, got in zip(queries, modes, eng.search_batch(
            [SearchRequest(q, mode=m) for q, m in zip(queries, modes)])):
        assert _same_result(ref.search(SearchRequest(q, mode=m)), got), (q, m, dps)


# ---------------------------------------------------------------------------
# rows-kernel agreement on re-based int32 keys
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("N,Pa,Pb,seed", [(4, 256, 256, 0), (9, 512, 1024, 1),
                                          (16, 256, 2048, 2), (1, 128, 128, 3)])
def test_banded_intersect_rows_matches_ref(N, Pa, Pb, seed):
    """Pallas vs ref on keys shaped like the executor's re-based int32 domain
    (doc_local << 17 | pos), with mixed per-row bands and sentinel padding."""
    from repro.core.fetch_tables import TABLE_BIAS, TABLE_POS_BITS
    rng = np.random.default_rng(seed)
    doc_a = rng.integers(0, 50, (N, Pa))
    doc_b = rng.integers(0, 50, (N, Pb))
    pos_a = rng.integers(0, 400, (N, Pa))
    pos_b = rng.integers(0, 400, (N, Pb))
    a = ((doc_a << TABLE_POS_BITS) | (pos_a + TABLE_BIAS)).astype(np.int32)
    b = np.sort((doc_b << TABLE_POS_BITS) | (pos_b + TABLE_BIAS), axis=1).astype(np.int32)
    a[:, -7:] = np.iinfo(np.int32).max            # sentinel pads
    b[-1, :] = np.iinfo(np.int32).max             # one empty (dead) group
    bands = rng.integers(0, 6, N).astype(np.int32)
    got = ops.banded_intersect_rows(jnp.asarray(a), jnp.asarray(b),
                                    jnp.asarray(bands))
    want = ops.banded_intersect_rows(jnp.asarray(a), jnp.asarray(b),
                                     jnp.asarray(bands), implementation="ref")
    assert bool((got == want).all())
    # sentinel entries never match
    assert not np.asarray(got)[:, -7:].any()


@pytest.mark.parametrize("N,Pa,Pb,seed", [(4, 256, 256, 0), (9, 512, 1024, 1),
                                          (1, 128, 128, 3)])
def test_banded_min_delta_rows_matches_ref(N, Pa, Pb, seed):
    """Pallas vs ref for the proximity-scoring kernel, on the valid domain:
    band-0 rows carry mixed stored deltas (dist-fetch groups), band>0 rows
    all-zero deltas (full-list groups) — rows sorted by (key, delta)."""
    from repro.core.fetch_tables import TABLE_BIAS, TABLE_POS_BITS
    rng = np.random.default_rng(seed)
    doc_a = rng.integers(0, 50, (N, Pa))
    doc_b = rng.integers(0, 50, (N, Pb))
    pos_a = rng.integers(0, 400, (N, Pa))
    pos_b = rng.integers(0, 400, (N, Pb))
    a = ((doc_a << TABLE_POS_BITS) | (pos_a + TABLE_BIAS)).astype(np.int32)
    bk = ((doc_b << TABLE_POS_BITS) | (pos_b + TABLE_BIAS)).astype(np.int32)
    bands = rng.integers(0, 6, N).astype(np.int32)
    bd = np.where(bands[:, None] == 0,
                  rng.integers(0, 16, (N, Pb)), 0).astype(np.int32)
    order = np.lexsort((bd, bk), axis=-1)
    bk = np.take_along_axis(bk, order, axis=-1)
    bd = np.take_along_axis(bd, order, axis=-1)
    a[:, -5:] = np.iinfo(np.int32).max           # sentinel pads
    bk[-1, :] = np.iinfo(np.int32).max           # one dead group
    got = ops.banded_min_delta_rows(jnp.asarray(a), jnp.asarray(bk),
                                    jnp.asarray(bd), jnp.asarray(bands))
    want = ops.banded_min_delta_rows(jnp.asarray(a), jnp.asarray(bk),
                                     jnp.asarray(bd), jnp.asarray(bands),
                                     implementation="ref")
    assert bool((got == want).all())
    # the membership bit agrees with the boolean kernel
    member = ops.banded_intersect_rows(jnp.asarray(a), jnp.asarray(bk),
                                       jnp.asarray(bands),
                                       implementation="ref")
    assert bool(((np.asarray(got) < np.iinfo(np.int32).max)
                 == np.asarray(member)).all())
    assert (np.asarray(got)[:, -5:] == np.iinfo(np.int32).max).all()


def test_banded_intersect_rows_band_isolation():
    """Rows with band 0 must not leak band-W semantics from neighbours."""
    a = np.tile(np.arange(0, 1280, 10, np.int32), (2, 1))[:, :128]
    b = np.tile((np.arange(0, 1280, 10, np.int32) + 3), (2, 1))[:, :128]
    bands = np.array([0, 5], np.int32)
    got = np.asarray(ops.banded_intersect_rows(jnp.asarray(a), jnp.asarray(b),
                                               jnp.asarray(bands)))
    assert not got[0].any()       # off by 3, band 0 -> no hits
    assert got[1].all()           # band 5 covers the offset

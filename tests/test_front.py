"""Front-door chaos + parity suite (drives serve/front.py via dist/chaos.py).

Two invariants, proven under injected shard failures, shard stalls longer
than the dispatcher timeout, 4x-capacity queue floods, and clock skew:

  * no request is ever silently dropped — every submitted ticket resolves
    with exactly one explicit status, and the stats ledger balances
    (submitted == served_exact + served_degraded + shed);
  * non-degraded responses are bit-identical to `engine.search_batch` —
    docs, positions, fallback flags, ranked float32 scores, and the
    postings_read accounting, for single- AND multi-shard backends.
"""
import time

import numpy as np
import pytest

from repro.core.api import (MODE_NEAR, MODE_PHRASE, STATUS_SERVED_DEGRADED,
                            STATUS_SERVED_EXACT, STATUS_SHED, SearchRequest)
from repro.dist.chaos import ChaosShard, SkewedClock, flood
from repro.dist.fault_tolerance import ShardDispatcher, merge_topk
from repro.serve.front import (FrontDoor, FrontDoorConfig, ShardBackend,
                               build_doc_shards, merge_shard_responses)

# generous enough that first-call jit compiles never masquerade as stalls
SLOW = 300.0
FAST_CFG = dict(default_deadline_ms=600_000.0, shard_timeout_s=SLOW)


def _requests(corpus, n=48, ranked_every=3, seed=11):
    """Phrase/near/ranked mix with known source docs (so hits are nonempty)."""
    rng = np.random.default_rng(seed)
    reqs = []
    d = 0
    while len(reqs) < n:
        d = (d + 7) % corpus.n_docs
        toks = np.asarray(corpus.doc(d))
        if len(toks) < 12:
            continue
        st = int(rng.integers(0, len(toks) - 8))
        k = int(rng.integers(2, 4))
        i = len(reqs)
        if ranked_every and i % ranked_every == 2:
            reqs.append(SearchRequest(tuple(int(x) for x in toks[st:st + k]),
                                      mode=MODE_PHRASE, rank=True, top_k=10))
        elif i % 2:
            reqs.append(SearchRequest(
                tuple(int(x) for x in toks[st:st + 2 * k:2]),
                mode=MODE_NEAR, window=6))
        else:
            reqs.append(SearchRequest(tuple(int(x) for x in toks[st:st + k]),
                                      mode=MODE_PHRASE))
    return reqs


def _assert_identical(ref, got):
    assert np.array_equal(ref.doc, got.doc)
    assert np.array_equal(ref.pos, got.pos)
    assert ref.postings_read == got.postings_read
    assert ref.used_fallback == got.used_fallback
    assert ref.doc_only == got.doc_only
    assert ref.subplan_types == got.subplan_types
    assert ref.ranked == got.ranked
    if ref.ranked:
        assert np.array_equal(ref.doc_ids, got.doc_ids)
        assert np.array_equal(ref.doc_scores, got.doc_scores)
        assert np.array_equal(ref.anchor_scores, got.anchor_scores)


def _ledger_balances(front):
    st = front.stats
    assert st.responded == st.submitted, \
        f"silent drop: {st.submitted} submitted, {st.responded} responded"


@pytest.fixture(scope="module")
def shard_world(small_world):
    corpus, index = small_world["corpus"], small_world["index"]
    backends, replicas = build_doc_shards(corpus, index, 4, replicate=True)
    return {"corpus": corpus, "index": index, "engine": small_world["engine"],
            "backends": backends, "replicas": replicas,
            "requests": _requests(corpus),
            }


@pytest.fixture(scope="module")
def reference(shard_world):
    return shard_world["engine"].search_batch(shard_world["requests"])


# ---------------------------------------------------------------------------
# parity: SERVED_EXACT == engine.search_batch, bit for bit
# ---------------------------------------------------------------------------


def test_front_single_shard_bit_identical(shard_world, reference):
    front = FrontDoor(shard_world["index"], cfg=FrontDoorConfig(**FAST_CFG))
    try:
        got = front.search_batch(shard_world["requests"])
        for ref, g in zip(reference, got):
            assert g.status == STATUS_SERVED_EXACT
            assert g.shards == (0,)
            _assert_identical(ref, g)
        _ledger_balances(front)
        assert front.stats.shed == 0
    finally:
        front.close()


def test_front_multi_shard_bit_identical(shard_world, reference):
    front = FrontDoor(shard_world["index"], backends=shard_world["backends"],
                      cfg=FrontDoorConfig(cache_capacity=0, **FAST_CFG))
    try:
        got = front.search_batch(shard_world["requests"])
        for ref, g in zip(reference, got):
            assert g.status == STATUS_SERVED_EXACT
            assert g.shards == (0, 1, 2, 3)
            _assert_identical(ref, g)
        _ledger_balances(front)
    finally:
        front.close()


def test_front_flex_overflow_exact(shard_world, small_world):
    """A plan wider than the batched executor's caps routes through the flex
    bucket and still comes back SERVED_EXACT + bit-identical."""
    from repro.core.batch_executor import G_CAP
    corpus, eng = shard_world["corpus"], shard_world["engine"]
    req = None
    for d in range(corpus.n_docs):
        toks = corpus.doc(d)
        for st in range(0, max(len(toks) - G_CAP - 3, 0), 4):
            q = toks[st:st + G_CAP + 3].tolist()
            plan = eng.plan(q, mode=MODE_PHRASE)
            # stop words become checks, not groups: need a window whose plan
            # really carries > G_CAP AND-groups in one subplan
            if any(sp.supported and len(sp.groups) > G_CAP
                   for sp in plan.subplans):
                req = SearchRequest(q, mode=MODE_PHRASE)
                break
        if req is not None:
            break
    assert req is not None, "no >G_CAP-group windows found"
    ref = eng.search_batch([req])[0]
    front = FrontDoor(shard_world["index"], cfg=FrontDoorConfig(**FAST_CFG))
    try:
        got = front.search(req)
        assert got.status == STATUS_SERVED_EXACT
        _assert_identical(ref, got)
        assert front.stats.flex_routed >= 1
    finally:
        front.close()


def test_front_cache_hit(shard_world, reference):
    front = FrontDoor(shard_world["index"],
                      cfg=FrontDoorConfig(cache_capacity=16, **FAST_CFG))
    try:
        req = shard_world["requests"][0]
        first = front.search(req)
        assert not first.cached
        again = front.search(req)
        assert again.cached and again.status == STATUS_SERVED_EXACT
        assert front.stats.cache_hits == 1
        _assert_identical(first, again)
        _assert_identical(reference[0], again)
    finally:
        front.close()


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------


def test_front_rate_limit_sheds_explicitly(shard_world):
    front = FrontDoor(shard_world["index"],
                      cfg=FrontDoorConfig(rate_per_s=0.001, rate_burst=3,
                                          cache_capacity=0, **FAST_CFG))
    try:
        reqs = shard_world["requests"][:12]
        tickets = flood(front, reqs, client="greedy")
        resps = [t.result() for t in tickets]
        shed = [r for r in resps if r.status == STATUS_SHED]
        ok = [r for r in resps if r.status != STATUS_SHED]
        assert len(ok) == 3 and len(shed) == 9
        assert all(r.shed_reason == "rate_limited" for r in shed)
        # a second client has its own bucket
        other = front.search(reqs[0], client="polite")
        assert other.status == STATUS_SERVED_EXACT
        _ledger_balances(front)
    finally:
        front.close()


def test_front_queue_flood_no_silent_drops(shard_world, reference):
    """4x-capacity flood while a chaos shard pins the dispatcher: every
    ticket resolves; overflow is shed with reason queue_full; everything
    that was admitted is served bit-exactly once the stall clears."""
    chaos = ChaosShard(ShardBackend(shard_world["index"]), stall_s=1.0)
    front = FrontDoor(shard_world["index"], backends=[chaos],
                      cfg=FrontDoorConfig(max_queue=8, max_batch=4,
                                          cache_capacity=0, **FAST_CFG))
    try:
        reqs = (shard_world["requests"] * 2)[:64]    # 8x queue capacity
        tickets = flood(front, reqs, wait=False)
        resps = [t.result(timeout=SLOW) for t in tickets]
        statuses = {}
        for r in resps:
            statuses[(r.status, r.shed_reason)] = \
                statuses.get((r.status, r.shed_reason), 0) + 1
        assert statuses.get((STATUS_SHED, "queue_full"), 0) > 0
        served = [i for i, r in enumerate(resps)
                  if r.status == STATUS_SERVED_EXACT]
        assert served, statuses
        ref_all = {i: r for i, r in enumerate(reference)}
        for i in served:
            _assert_identical(ref_all[i % len(reference)], resps[i])
        # the ledger balances: nothing hung, nothing vanished
        _ledger_balances(front)
        assert front.stats.shed == statuses.get((STATUS_SHED, "queue_full"), 0)
    finally:
        chaos.set()
        front.close()


def test_front_clock_skew_deadline_shed(shard_world):
    """Queued requests admitted under one clock become unmeetable when the
    clock steps forward (NTP jump / long pause): they shed with reason
    deadline instead of burning the whole batch's budget."""
    clock = SkewedClock()
    stall = ChaosShard(ShardBackend(shard_world["index"]), stall_s=1.5)
    front = FrontDoor(shard_world["index"], backends=[stall],
                      cfg=FrontDoorConfig(default_deadline_ms=5000.0,
                                          shard_timeout_s=SLOW, max_batch=2,
                                          cache_capacity=0),
                      clock=clock)
    try:
        reqs = shard_world["requests"][:8]
        tickets = [front.submit(r) for r in reqs]
        clock.skew_s = 30.0          # every queued deadline is now in the past
        resps = [t.result(timeout=SLOW) for t in tickets]
        assert any(r.status == STATUS_SHED and r.shed_reason == "deadline"
                   for r in resps)
        assert all(r.status in (STATUS_SHED, STATUS_SERVED_EXACT,
                                STATUS_SERVED_DEGRADED) for r in resps)
        _ledger_balances(front)
    finally:
        stall.set()
        front.close()


# ---------------------------------------------------------------------------
# degradation: shard failure, stall, replica rescue
# ---------------------------------------------------------------------------


def test_front_replica_rescues_failed_primary(shard_world, reference):
    """Primary shard 1 fails hard; its replica absorbs the re-dispatch and
    the responses stay SERVED_EXACT and bit-identical."""
    backends = [ChaosShard(b) for b in shard_world["backends"]]
    backends[1].set(fail=True)
    front = FrontDoor(shard_world["index"], backends=backends,
                      replicas=shard_world["replicas"],
                      cfg=FrontDoorConfig(cache_capacity=0, **FAST_CFG))
    try:
        reqs = shard_world["requests"][:16]
        got = front.search_batch(reqs)
        for ref, g in zip(reference[:16], got):
            assert g.status == STATUS_SERVED_EXACT
            assert g.shards == (0, 1, 2, 3)
            _assert_identical(ref, g)
        assert front.dispatcher.stats.redispatched > 0
        assert backends[1].calls > 0
        _ledger_balances(front)
    finally:
        front.close()


def test_front_dead_shard_degrades_explicitly(shard_world, reference):
    """Shard 2 stalls past the dispatcher timeout with NO replica: responses
    degrade explicitly — status SERVED_DEGRADED, contributing shards listed,
    and no doc from the dead shard's range is fabricated."""
    backends = [ChaosShard(b) for b in shard_world["backends"]]
    backends[2].set(stall_s=8.0)
    lo = shard_world["backends"][2].doc_base
    hi = lo + shard_world["backends"][2].n_docs
    front = FrontDoor(shard_world["index"], backends=backends,
                      cfg=FrontDoorConfig(default_deadline_ms=600_000.0,
                                          shard_timeout_s=1.0, max_retries=1,
                                          retry_backoff_ms=5.0,
                                          cache_capacity=0))
    try:
        reqs = shard_world["requests"][:8]
        got = front.search_batch(reqs)
        for ref, g in zip(reference[:8], got):
            assert g.status == STATUS_SERVED_DEGRADED
            assert g.shed_reason == "shards"
            assert g.shards == (0, 1, 3)
            docs = g.doc[g.doc >= 0]
            assert not np.any((docs >= lo) & (docs < hi))
            # the live shards' contribution is exactly the reference minus
            # the dead range
            keep = (ref.doc < lo) | (ref.doc >= hi)
            if not ref.doc_only and not g.doc_only:
                assert np.array_equal(ref.doc[keep], g.doc)
                assert np.array_equal(ref.pos[keep], g.pos)
        # bounded retry actually ran, and never un-degraded the result
        assert front.stats.retries > 0
        _ledger_balances(front)
        assert front.stats.served_degraded == len(reqs)
    finally:
        backends[2].set()
        front.close()


def test_front_all_shards_down_still_responds(shard_world):
    chaos = ChaosShard(ShardBackend(shard_world["index"]), fail=True)
    front = FrontDoor(shard_world["index"], backends=[chaos],
                      cfg=FrontDoorConfig(default_deadline_ms=600_000.0,
                                          shard_timeout_s=2.0, max_retries=1,
                                          retry_backoff_ms=5.0,
                                          cache_capacity=0))
    try:
        got = front.search_batch(shard_world["requests"][:4])
        for g in got:
            assert g.status == STATUS_SERVED_DEGRADED
            assert g.shed_reason == "no_shards"
            assert g.shards == () and len(g.doc) == 0
        _ledger_balances(front)
    finally:
        chaos.set()
        front.close()


# ---------------------------------------------------------------------------
# satellite: ShardDispatcher merge path under concurrent replica failure +
# timeout, against real serve arenas (the doc-sharded backends)
# ---------------------------------------------------------------------------


def test_dispatcher_concurrent_stall_and_fail(shard_world):
    """Three concurrent fault modes in ONE dispatch: shard 0 healthy,
    shard 1 stalls past timeout but its replica is healthy (rescued),
    shard 2 fails hard AND its replica fails (lost)."""
    b = shard_world["backends"]
    primaries = [ChaosShard(b[0]), ChaosShard(b[1], stall_s=6.0),
                 ChaosShard(b[2], fail=True)]
    replicas = [ChaosShard(shard_world["replicas"][0]),
                ChaosShard(shard_world["replicas"][1]),
                ChaosShard(shard_world["replicas"][2], fail=True)]
    d = ShardDispatcher(primaries, replica_fns=replicas, timeout=1.5)
    reqs = shard_world["requests"][:6]
    try:
        out = d.dispatch(reqs)
        assert out[0] is not None
        assert out[1] is not None          # replica rescued the straggler
        assert out[2] is None              # primary AND replica down
        assert replicas[1].calls == 1 and replicas[2].calls == 1
        assert d.stats.redispatched == 2 and d.stats.failed == 1
        # the rescued shard's answers match a direct call to the replica
        direct = shard_world["replicas"][1](reqs)
        for x, y in zip(out[1], direct):
            _assert_identical(x, y)
        # subset re-dispatch heals the lost shard once chaos clears
        primaries[2].set()
        again = d.dispatch(reqs, shards=[2])
        assert again[2] is not None and again[0] is None and again[1] is None
    finally:
        primaries[1].set()
        d.close()


def test_dispatcher_merge_topk_real_ranked_outputs(shard_world):
    """merge_topk over real per-shard ranked outputs equals the global
    ranked doc list (scores are per-doc sums, disjoint across doc shards)."""
    req = next(r for r in shard_world["requests"] if r.rank)
    per_shard = [b([req])[0] for b in shard_world["backends"]]
    # positional hits win over shard-local doc-only fallbacks (the same
    # have_pos gating merge_shard_responses applies)
    rows = [np.stack([r.doc_scores.astype(np.float64),
                      r.doc_ids.astype(np.float64)], axis=1)
            for r in per_shard
            if not r.doc_only and r.doc_ids is not None and len(r.doc_ids)]
    merged = merge_topk(rows, k=req.top_k)
    ref = shard_world["engine"].search_batch([req])[0]
    assert len(merged) == len(ref.doc_ids)
    np.testing.assert_allclose(merged[:, 0],
                               np.sort(ref.doc_scores)[::-1], rtol=0)
    assert set(merged[:, 1].astype(int)) == set(int(x) for x in ref.doc_ids)


# ---------------------------------------------------------------------------
# satellite: serve-tier slab sizing derived from the plan population
# ---------------------------------------------------------------------------


def test_serve_tier_ladder_kills_dead_slab_rows(small_world):
    """The packed unpack no longer runs over dead slab rows: with the
    G=8/F=8/T=2*queries caps, a smoke workload's steps use pow2-tight row
    counts and population-derived (G, F, P0, P) tiers."""
    from repro.launch.mesh import make_host_mesh
    from repro.serve.search_serve import SearchServe, SearchServeConfig

    corpus, index = small_world["corpus"], small_world["index"]
    cfg = SearchServeConfig(queries=16, postings_pad=4096, seed_pad=1024,
                            n_basic=1, n_expanded=1, n_stop=1, n_first=1,
                            n_multi=1)
    serve = SearchServe(index, cfg, make_host_mesh(data=1, model=1))
    reqs = _requests(corpus, n=16)
    got = serve.search_batch(reqs)
    ref = small_world["engine"].search_batch(reqs)
    for x, y in zip(ref, got):
        _assert_identical(x, y)
    st = serve.executor.slab_stats
    assert st["steps"] > 0
    # tight T: pow2 padding bounds dead rows per step
    assert st["slab_rows"] <= 2 * st["live_rows"] + 4 * st["steps"]
    # population-derived tiers: the slab is far below the cap slab the old
    # fixed shapes would have billed (T=32 rows x G8/F8/P0=1024/P=4096)
    cap_elems = st["steps"] * cfg.task_rows * (
        cfg.fetch_slots * cfg.p_seed
        + (cfg.groups - 1) * cfg.fetch_slots * cfg.postings_pad)
    assert st["slab_elems"] < cap_elems / 4
    assert len(serve.executor._tiers) <= 3


# ---------------------------------------------------------------------------
# mutable index: segment ingest vs the result cache, late-shard backfill
# ---------------------------------------------------------------------------


def test_front_segment_ingest_never_serves_stale_cache(small_world):
    """THE stale-cache regression: a cached response must never survive a
    segment ingest.  Query before ingest (cached), ingest a batch containing
    a new matching doc, re-query — the response must be fresh (non-cached),
    contain the new doc, and the stale tripwire must stay at zero."""
    from repro.core.segments import SegmentManager, corpus_batches

    corpus, index = small_world["corpus"], small_world["index"]
    batches = corpus_batches(corpus, 4)
    pre_docs = sum(b.n_docs for b in batches[:3])
    mgr = SegmentManager(small_world["lex"], small_world["ana"],
                         params=index.params, auto_merge=False)
    for b in batches[:3]:
        mgr.ingest(b)
    # query sourced from a batch-4 doc (not yet ingested)
    d_new = pre_docs + batches[3].n_docs // 2
    toks = corpus.doc(d_new)
    req = SearchRequest(tuple(int(x) for x in toks[4:7]), mode=MODE_PHRASE)
    front = FrontDoor(segments=mgr,
                      cfg=FrontDoorConfig(cache_capacity=16, **FAST_CFG))
    try:
        first = front.search(req)
        assert first.status == STATUS_SERVED_EXACT and not first.cached
        assert all(int(x) < pre_docs for x in first.doc)
        again = front.search(req)
        assert again.cached and front.stats.cache_hits == 1

        mgr.ingest(batches[3])              # the index just changed

        fresh = front.search(req)
        assert not fresh.cached, "served a pre-ingest cached response"
        assert fresh.status == STATUS_SERVED_EXACT
        assert d_new in set(int(x) for x in fresh.doc)
        # bit-identical to the one-shot engine over the full corpus
        ref = small_world["engine"].search_batch([req])[0]
        assert np.array_equal(ref.doc, fresh.doc)
        assert np.array_equal(ref.pos, fresh.pos)
        assert ref.used_fallback == fresh.used_fallback
        assert ref.doc_only == fresh.doc_only
        # the new generation caches normally
        again2 = front.search(req)
        assert again2.cached and np.array_equal(fresh.doc, again2.doc)
        assert front.stats.generation_bumps >= 1
        assert front.stats.stale_cache_hits == 0
        _ledger_balances(front)
    finally:
        front.close()
        mgr.close()


def test_front_late_shard_backfills_cache(shard_world, reference):
    """A shard that answers AFTER the dispatch timeout degrades the delivered
    response — but its work is not thrown away: the straggler's result
    re-merges into the cache, and the next identical query is SERVED_EXACT
    and bit-identical to the unsharded engine."""
    backends = [ChaosShard(b) for b in shard_world["backends"]]
    backends[1].set(stall_s=3.0)
    front = FrontDoor(shard_world["index"], backends=backends,
                      cfg=FrontDoorConfig(default_deadline_ms=600_000.0,
                                          shard_timeout_s=1.0, max_retries=0,
                                          cache_capacity=16))
    try:
        req = shard_world["requests"][0]
        got = front.search(req)
        assert got.status == STATUS_SERVED_DEGRADED
        assert got.shed_reason == "shards"
        assert got.shards == (0, 2, 3)
        # the straggler finishes ~2s later and backfills the cache
        deadline = time.monotonic() + SLOW
        while front.stats.backfilled < 1:
            assert time.monotonic() < deadline, "backfill never landed"
            time.sleep(0.02)
        again = front.search(req)
        assert again.cached and again.status == STATUS_SERVED_EXACT
        assert again.shards == (0, 1, 2, 3)
        _assert_identical(reference[0], again)
        assert front.stats.stale_cache_hits == 0
        _ledger_balances(front)
    finally:
        backends[1].set()
        front.close()


# ---------------------------------------------------------------------------
# open-loop smoke: offered load through the front door, shed_rate == 0
# ---------------------------------------------------------------------------


def test_front_open_loop_smoke_no_shedding(shard_world):
    """Paced offered load at smoke scale: everything served exactly, nothing
    shed, p99 under a generous deadline (the CI gate in stricter form runs
    in the bench smoke)."""
    front = FrontDoor(shard_world["index"],
                      cfg=FrontDoorConfig(default_deadline_ms=30_000.0,
                                          shard_timeout_s=SLOW,
                                          cache_capacity=0))
    try:
        reqs = shard_world["requests"][:24]
        front.search_batch(reqs)     # warm the jit caches
        front.stats = type(front.stats)()   # don't bill compiles to p99
        for r in reqs:
            front.submit(r)
            time.sleep(0.005)
        deadline = time.monotonic() + SLOW
        while front.stats.responded < front.stats.submitted:
            assert time.monotonic() < deadline, "front door hung"
            time.sleep(0.01)
        assert front.stats.shed == 0
        assert front.stats.served_degraded == 0
        assert front.stats.percentile(99) <= 30_000.0
        _ledger_balances(front)
    finally:
        front.close()


def test_front_kword_ingest_never_serves_stale_cache(small_world):
    """K-word twin of the stale-cache regression: a cached kword response
    must never survive a segment ingest — re-query post-ingest is fresh,
    EXACT, contains the newly ingested source doc, and is bit-identical to
    the one-shot engine over the full corpus."""
    from repro.core.segments import SegmentManager, corpus_batches

    corpus, index = small_world["corpus"], small_world["index"]
    batches = corpus_batches(corpus, 4)
    pre_docs = sum(b.n_docs for b in batches[:3])
    mgr = SegmentManager(small_world["lex"], small_world["ana"],
                         params=index.params, auto_merge=False)
    for b in batches[:3]:
        mgr.ingest(b)
    # kword query sourced from a batch-4 doc (not yet ingested)
    d_new = pre_docs + batches[3].n_docs // 2
    toks = corpus.doc(d_new)
    req = SearchRequest(tuple(int(x) for x in toks[4:8]), mode="kword",
                        window=5)
    front = FrontDoor(segments=mgr,
                      cfg=FrontDoorConfig(cache_capacity=16, **FAST_CFG))
    try:
        first = front.search(req)
        assert first.status == STATUS_SERVED_EXACT and not first.cached
        assert all(int(x) < pre_docs for x in first.doc)
        again = front.search(req)
        assert again.cached and front.stats.cache_hits == 1

        mgr.ingest(batches[3])              # the index just changed

        fresh = front.search(req)
        assert not fresh.cached, "served a pre-ingest cached kword response"
        assert fresh.status == STATUS_SERVED_EXACT
        assert d_new in set(int(x) for x in fresh.doc)
        ref = small_world["engine"].search_batch([req])[0]
        assert np.array_equal(ref.doc, fresh.doc)
        assert np.array_equal(ref.pos, fresh.pos)
        assert ref.used_fallback == fresh.used_fallback
        assert ref.doc_only == fresh.doc_only
        # postings_read deliberately unasserted: the segment union plans
        # with the manager's own occ stats (same bits, different accounting)
        again2 = front.search(req)
        assert again2.cached and np.array_equal(fresh.doc, again2.doc)
        assert front.stats.generation_bumps >= 1
        assert front.stats.stale_cache_hits == 0
        _ledger_balances(front)
    finally:
        front.close()
        mgr.close()

"""Segment lifecycle suite: incremental ingest + background merge
(core/segments.py) against the one-shot build and the brute-force oracle.

The acceptance contract: a corpus built via K-batch incremental ingest (with
at least one merge) returns bit-identical results — doc/pos/score/accounting
— to the same corpus built one-shot, on the engine, serve, and front-door
paths, at every generation; and a merger crash leaves serving on the old
generation with no silent drops.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import (SearchRequest, SegmentManager, brute_force_search,
                        concat_corpora, corpus_batches)
from repro.core.planner import Planner, pick_pivot
from repro.core.segments import SEG_FRESH, SEG_RETIRED


def _requests(corpus, n=32, seed=11):
    """Phrase/near mix sampled from indexed docs, every third ranked."""
    rng = np.random.default_rng(seed)
    out = []
    while len(out) < n:
        d = int(rng.integers(corpus.n_docs))
        toks = corpus.doc(d)
        k = int(rng.integers(2, 5))
        if len(toks) < 2 * k + 2:
            continue
        st = int(rng.integers(0, len(toks) - 2 * k))
        i = len(out)
        if i % 2:
            q, mode = toks[st:st + k], "phrase"
        else:
            q, mode = toks[st:st + 2 * k:2], "near"
        out.append(SearchRequest(tuple(int(x) for x in q), mode=mode,
                                 rank=(i % 3 == 0)))
    return out


def _assert_identical(ref, got, accounting=True, ctx=""):
    assert np.array_equal(ref.doc, got.doc), ctx
    assert np.array_equal(ref.pos, got.pos), ctx
    assert ref.used_fallback == got.used_fallback, ctx
    assert ref.doc_only == got.doc_only, ctx
    assert ref.subplan_types == got.subplan_types, ctx
    if accounting:
        assert ref.postings_read == got.postings_read, ctx
    assert ref.ranked == got.ranked, ctx
    if ref.ranked:
        assert np.array_equal(ref.anchor_scores, got.anchor_scores), ctx
        assert np.array_equal(ref.doc_ids, got.doc_ids), ctx
        assert np.array_equal(ref.doc_scores, got.doc_scores), ctx


@pytest.fixture()
def manager(small_world):
    mgr = SegmentManager(small_world["lex"], small_world["ana"],
                         small_world["index"].params, auto_merge=False)
    yield mgr
    mgr.close()


def test_corpus_batches_round_trip(small_world):
    corpus = small_world["corpus"]
    parts = corpus_batches(corpus, 5)
    assert sum(p.n_docs for p in parts) == corpus.n_docs
    back = concat_corpora(parts)
    assert np.array_equal(back.doc_offsets, corpus.doc_offsets)
    assert np.array_equal(back.tokens, corpus.tokens)


def test_generation_listeners_and_global_occ(small_world, manager):
    """Ingest bumps are monotonic and observed; occurrence counts are
    additive across segments — the union's occ equals the one-shot index's
    at every step's corresponding prefix."""
    corpus, index = small_world["corpus"], small_world["index"]
    seen = []
    manager.subscribe(seen.append)
    gens = [manager.ingest(b) for b in corpus_batches(corpus, 4)]
    assert gens == sorted(gens) and len(set(gens)) == 4
    assert seen == gens
    assert manager.generation == gens[-1]
    assert manager.n_docs == corpus.n_docs
    assert [s.doc_base for s in manager.segments] == \
        [round(i * corpus.n_docs / 4) for i in range(4)]
    assert np.array_equal(manager.occ_counts(), index.base_occ_counts())


def test_multi_segment_union_parity(small_world, manager):
    """4 live segments, no merge: union results are bit-identical to the
    one-shot engine — accounting included when the union replays the
    one-shot plan (`plan_index`), and doc/pos/score identical under the
    manager's own planner."""
    corpus, index = small_world["corpus"], small_world["index"]
    for b in corpus_batches(corpus, 4):
        manager.ingest(b)
    reqs = _requests(corpus, n=32)
    ref = small_world["engine"].search_batch(reqs)
    got = manager.search_batch(reqs, plan_index=index)
    for q, (r, g) in zip(reqs, zip(ref, got)):
        _assert_identical(r, g, accounting=True, ctx=q)
    own = manager.search_batch(reqs)
    for q, (r, g) in zip(reqs, zip(ref, own)):
        _assert_identical(r, g, accounting=False, ctx=q)


def test_merge_bit_identical_to_one_shot(small_world, manager):
    """K ingest batches + merge == one-shot build: the merged segment's
    streams are rebuilt over the concatenated corpus, so results (accounting
    included, via the manager's OWN planner) match the one-shot engine, and
    positional results match the brute-force oracle."""
    corpus, index = small_world["corpus"], small_world["index"]
    for b in corpus_batches(corpus, 3):
        manager.ingest(b)
    assert manager.merge_now()
    segs = manager.segments
    assert len(segs) == 1 and segs[0].doc_base == 0
    assert manager.merges_completed == 1
    assert all(s.state == SEG_RETIRED for s in manager.retired_segments)
    merged = segs[0].index
    assert np.array_equal(merged.base_occ_counts(), index.base_occ_counts())
    reqs = _requests(corpus, n=32)
    ref = small_world["engine"].search_batch(reqs)
    got = manager.search_batch(reqs)
    for q, (r, g) in zip(reqs, zip(ref, got)):
        _assert_identical(r, g, accounting=True, ctx=q)
    # oracle cross-check (paper: indexed phrases are precisely found)
    for q, g in list(zip(reqs, got))[:8]:
        positional, doc_level = brute_force_search(
            corpus, index, list(q.surface_ids), mode=q.mode)
        if g.doc_only:
            assert set(g.doc.tolist()) == doc_level, q
        else:
            assert set(zip(g.doc.tolist(), g.pos.tolist())) == positional, q


def test_planner_occ_refresh(small_world, manager):
    """The frozen-stats bugfix, both halves: (a) refresh_occ_counts moves
    pick_pivot when the statistics change; (b) after ingest, every segment
    planner plans the same structure as the one-shot planner."""
    corpus, index = small_world["corpus"], small_world["index"]
    # (a) direct: doctor the counts so the old pivot becomes the most
    # frequent slot — a planner that never refreshes keeps the stale pivot
    from repro.core.lexicon import TIER_ORDINARY
    lex = small_world["lex"]
    planner = Planner(index)
    reqs = _requests(corpus, n=24, seed=5)
    for near in reqs:
        if near.mode != "near":
            continue
        form_lists = [index.analyzer.forms_of(s) for s in near.surface_ids]
        tiered = [(int(lex.base_tier[int(f[0])]), [int(x) for x in f])
                  for f in form_lists]
        if sum(t == TIER_ORDINARY for t, _ in tiered) >= 2:
            break
    else:
        pytest.fail("no near query with two ordinary slots in the sample")
    occ = index.base_occ_counts().astype(np.int64)
    old_pivot = pick_pivot(tiered, occ)
    doctored = occ.copy()
    for f in form_lists[old_pivot]:
        doctored[f] = int(occ.max()) + 1
    planner.refresh_occ_counts(doctored)
    assert planner._occ_counts[int(form_lists[old_pivot][0])] == \
        int(occ.max()) + 1
    assert pick_pivot(tiered, doctored) != old_pivot
    planner.refresh_occ_counts()                  # back to the index's own
    assert np.array_equal(planner._occ_counts, occ)

    # (b) plan parity after ingest: segment backends + union planner agree
    # with the one-shot planner on plan structure (pivot bands included)
    for b in corpus_batches(corpus, 3):
        manager.ingest(b)

    def sig(plan):
        return tuple(
            (sp.qtype, tuple((g.slot, g.band) for g in sp.groups),
             tuple((g.slot, g.band) for g in sp.fallback_groups))
            for sp in plan.subplans if sp.supported)

    one_shot = small_world["engine"].planner
    union = manager.current_planner()
    backends = manager.engine_backends()
    for r in reqs:
        want = sig(one_shot.plan(list(r.surface_ids), mode=r.mode,
                                 ranked=r.rank))
        assert sig(union.plan(list(r.surface_ids), mode=r.mode,
                              ranked=r.rank)) == want, r
        for b in backends:
            assert sig(b.engine.planner.plan(
                list(r.surface_ids), mode=r.mode, ranked=r.rank)) == want, r


def test_search_during_merge(small_world, manager):
    """Concurrent search-during-merge safety: queries issued while the
    merger is re-packing return bit-identical results throughout, and the
    post-merge generation still matches."""
    corpus = small_world["corpus"]
    for b in corpus_batches(corpus, 4):
        manager.ingest(b)
    reqs = _requests(corpus, n=12, seed=3)
    ref = small_world["engine"].search_batch(reqs)
    manager.merge_fault = lambda: time.sleep(0.4)   # widen the merge window
    done = threading.Event()
    ok = []

    def merge():
        ok.append(manager.merge_now())
        done.set()

    th = threading.Thread(target=merge)
    th.start()
    rounds = 0
    while not done.is_set():
        got = manager.search_batch(reqs)
        for q, (r, g) in zip(reqs, zip(ref, got)):
            _assert_identical(r, g, accounting=False, ctx=(rounds, q))
        rounds += 1
    th.join()
    assert ok == [True] and rounds >= 1
    assert len(manager.segments) == 1
    got = manager.search_batch(reqs)
    for q, (r, g) in zip(reqs, zip(ref, got)):
        _assert_identical(r, g, accounting=True, ctx=("post", q))


def test_merger_crash_leaves_old_generation(small_world, manager):
    """Chaos tier: a merger crash mid-merge reverts the sources to FRESH,
    leaves the generation (and every query result) untouched, and a later
    healthy merge succeeds — no silent drops at any point."""
    corpus = small_world["corpus"]
    for b in corpus_batches(corpus, 3):
        manager.ingest(b)
    gen = manager.generation
    reqs = _requests(corpus, n=12, seed=9)
    ref = small_world["engine"].search_batch(reqs)

    def boom():
        raise RuntimeError("injected merger crash")

    manager.merge_fault = boom
    assert manager.merge_now() is False
    assert manager.merge_failures == 1
    assert manager.generation == gen               # old generation serves on
    assert len(manager.segments) == 3
    assert all(s.state == SEG_FRESH for s in manager.segments)
    got = manager.search_batch(reqs)
    for q, (r, g) in zip(reqs, zip(ref, got)):
        _assert_identical(r, g, accounting=False, ctx=q)
    manager.merge_fault = None                     # heal
    assert manager.merge_now()
    assert manager.generation == gen + 1
    assert len(manager.segments) == 1
    got = manager.search_batch(reqs)
    for q, (r, g) in zip(reqs, zip(ref, got)):
        _assert_identical(r, g, accounting=True, ctx=q)


def test_background_merger_thread(small_world):
    """auto_merge: the background thread compacts once the fresh-segment
    count reaches the threshold; results stay identical before and after."""
    corpus = small_world["corpus"]
    mgr = SegmentManager(small_world["lex"], small_world["ana"],
                         small_world["index"].params,
                         merge_threshold=2, auto_merge=True)
    try:
        for b in corpus_batches(corpus, 4):
            mgr.ingest(b)
        deadline = time.monotonic() + 60.0
        while time.monotonic() < deadline:
            if len(mgr.segments) == 1 and mgr.merges_completed >= 1:
                break
            time.sleep(0.05)
        assert len(mgr.segments) == 1, [s.state for s in mgr.segments]
        reqs = _requests(corpus, n=16, seed=21)
        ref = small_world["engine"].search_batch(reqs)
        for q, (r, g) in zip(reqs, zip(ref, mgr.search_batch(reqs))):
            _assert_identical(r, g, accounting=True, ctx=q)
    finally:
        mgr.close()


def test_serve_union_parity(small_world, manager):
    """The distributed serve tier unions across segments too: per-segment
    SearchServe backends under the shard merge are bit-identical to the
    one-shot engine (accounting via the one-shot plan replay)."""
    from repro.launch.mesh import make_host_mesh
    from repro.serve.search_serve import SearchServeConfig

    corpus, index = small_world["corpus"], small_world["index"]
    for b in corpus_batches(corpus, 2):
        manager.ingest(b)
    cfg = SearchServeConfig(queries=16, postings_pad=4096, seed_pad=1024,
                            n_basic=1, n_expanded=1, n_stop=1, n_first=1,
                            n_multi=1)
    backends = manager.serve_backends(cfg, make_host_mesh(data=1, model=1))
    reqs = _requests(corpus, n=16, seed=17)
    ref = small_world["engine"].search_batch(reqs)
    got = manager.search_batch(reqs, backends=backends, plan_index=index)
    for q, (r, g) in zip(reqs, zip(ref, got)):
        _assert_identical(r, g, accounting=True, ctx=q)


# ---------------------------------------------------------------------------
# K-word proximity across the segment lifecycle (arXiv:2009.02684)
# ---------------------------------------------------------------------------


def _kword_requests(corpus, n=24, seed=27):
    """K in {3,4,5} contiguous windows from indexed docs, span-wide window,
    every third ranked — the segment-union kword population."""
    rng = np.random.default_rng(seed)
    out = []
    while len(out) < n:
        d = int(rng.integers(corpus.n_docs))
        toks = corpus.doc(d)
        k = int(rng.integers(3, 6))
        if len(toks) <= k + 4:
            continue
        st = int(rng.integers(0, len(toks) - k - 1))
        i = len(out)
        out.append(SearchRequest(tuple(int(x) for x in toks[st:st + k]),
                                 mode="kword", window=min(k + 1, 15),
                                 rank=(i % 3 == 0)))
    return out


def test_kword_union_and_merge_parity(small_world, manager):
    """K-word spans across 4 live segments (global doc grid, cluster-global
    occ pivots) are bit-identical to the one-shot engine; after the merge
    the manager's OWN planner matches with accounting, and positional
    anchors match the nested-loop oracle."""
    from repro.core import brute_force_kword

    corpus, index = small_world["corpus"], small_world["index"]
    for b in corpus_batches(corpus, 4):
        manager.ingest(b)
    reqs = _kword_requests(corpus, n=24)
    ref = small_world["engine"].search_batch(reqs)
    got = manager.search_batch(reqs, plan_index=index)
    for q, (r, g) in zip(reqs, zip(ref, got)):
        _assert_identical(r, g, accounting=True, ctx=q)
    own = manager.search_batch(reqs)
    for q, (r, g) in zip(reqs, zip(ref, own)):
        _assert_identical(r, g, accounting=False, ctx=q)

    assert manager.merge_now()
    merged = manager.search_batch(reqs)
    for q, (r, g) in zip(reqs, zip(ref, merged)):
        _assert_identical(r, g, accounting=True, ctx=q)
    for q, g in list(zip(reqs, merged))[:8]:
        positional, doc_level = brute_force_kword(
            corpus, index, list(q.surface_ids), q.window)
        if g.doc_only:
            assert set(g.doc.tolist()) == doc_level, q
        else:
            assert set(zip(g.doc.tolist(), g.pos.tolist())) == positional, q


def test_kword_search_during_background_merge(small_world):
    """kword queries racing a live background merge return EXACT
    post-ingest answers at every poll — never a pre-merge/pre-ingest
    partial — and the post-merge steady state matches the one-shot
    engine with accounting."""
    corpus = small_world["corpus"]
    mgr = SegmentManager(small_world["lex"], small_world["ana"],
                         small_world["index"].params,
                         merge_threshold=2, auto_merge=True)
    try:
        for b in corpus_batches(corpus, 4):
            mgr.ingest(b)
        reqs = _kword_requests(corpus, n=8, seed=29)
        ref = small_world["engine"].search_batch(reqs)
        deadline = time.monotonic() + 60.0
        polls = 0
        while time.monotonic() < deadline:
            for q, (r, g) in zip(reqs, zip(ref, mgr.search_batch(reqs))):
                _assert_identical(r, g, accounting=False, ctx=q)
            polls += 1
            if len(mgr.segments) == 1 and mgr.merges_completed >= 1:
                break
            time.sleep(0.05)
        assert len(mgr.segments) == 1, [s.state for s in mgr.segments]
        assert polls >= 1
        for q, (r, g) in zip(reqs, zip(ref, mgr.search_batch(reqs))):
            _assert_identical(r, g, accounting=True, ctx=q)
    finally:
        mgr.close()

"""Launcher CLIs run end-to-end (subprocess smoke, one per family)."""
import os
import subprocess
import sys

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_ENV = dict(os.environ, PYTHONPATH=os.path.join(_ROOT, "src"))


def _run(args, timeout=480):
    res = subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                         text=True, timeout=timeout, env=_ENV, cwd=_ROOT)
    assert res.returncode == 0, res.stderr[-2000:]
    return res.stdout


@pytest.mark.parametrize("args", [
    ["repro.launch.train", "--arch", "llama3-8b", "--steps", "12",
     "--batch", "4", "--seq", "32"],
    ["repro.launch.train", "--arch", "fm", "--steps", "10"],
    ["repro.launch.train", "--arch", "gin-tu", "--shape", "molecule",
     "--steps", "10"],
])
def test_train_launcher(args):
    out = _run(args)
    assert "[train] loss" in out


def test_serve_launcher_search():
    out = _run(["repro.launch.serve", "--mode", "search", "--queries", "4"])
    assert "us/query" in out


def test_serve_launcher_lm():
    out = _run(["repro.launch.serve", "--mode", "lm", "--arch",
                "granite-moe-1b-a400m", "--tokens", "4"])
    assert "ms/token" in out


def test_dryrun_smoke_cell():
    """The dry-run CLI itself compiles a small cell (512 fake devices in the
    subprocess only)."""
    out = _run(["repro.launch.dryrun", "--arch", "gin-tu", "--shape",
                "molecule", "--mesh", "single", "--out", "/tmp/dr_test"],
               timeout=540)
    assert "done; 0 failures" in out

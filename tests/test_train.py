"""Optimizers + train loop: convergence on toy problems, accumulation
equivalence, LR schedule shape."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import OptimizerConfig, apply_updates, init_state, lr_schedule
from repro.train.train_loop import make_train_step


def _toy_loss(params, batch):
    pred = batch["x"] @ params["w"] + params["b"]
    loss = jnp.mean((pred - batch["y"]) ** 2)
    return loss, {"mse": loss}


def _toy_data(key, n=256, d=8):
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (n, d), jnp.float32)
    w_true = jax.random.normal(kw, (d,), jnp.float32)
    y = x @ w_true + 0.5
    return {"x": x, "y": y}


@pytest.mark.parametrize("name", ["adamw", "sgdm", "adafactor"])
def test_optimizer_converges(name):
    cfg = OptimizerConfig(name=name, lr=0.05 if name != "sgdm" else 0.01,
                          weight_decay=0.0, warmup_steps=5, decay_steps=400)
    params = {"w": jnp.zeros((8,), jnp.float32), "b": jnp.zeros((), jnp.float32)}
    state = init_state(cfg, params)
    batch = _toy_data(jax.random.PRNGKey(0))
    loss0 = float(_toy_loss(params, batch)[0])
    step = make_train_step(_toy_loss, cfg, donate=False)
    opt_state = state
    for _ in range(200):
        params, opt_state, metrics = step(params, opt_state, batch)
    assert float(metrics["loss"]) < loss0 * 0.05, name


def test_grad_accumulation_equivalence():
    """accum over k microbatches == one big batch (same grads => same step)."""
    cfg = OptimizerConfig(name="adamw", lr=1e-2, weight_decay=0.0)
    params = {"w": jnp.ones((8,), jnp.float32), "b": jnp.zeros((), jnp.float32)}
    batch = _toy_data(jax.random.PRNGKey(1), n=64)
    big = make_train_step(_toy_loss, cfg, accum_steps=1, donate=False)
    acc = make_train_step(_toy_loss, cfg, accum_steps=4, donate=False)
    micro = {k: v.reshape((4, 16) + v.shape[1:]) for k, v in batch.items()}
    p1, s1, m1 = big(params, init_state(cfg, params), batch)
    p2, s2, m2 = acc(params, init_state(cfg, params), micro)
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        assert float(jnp.abs(a - b).max()) < 1e-5


def test_lr_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, decay_steps=110,
                          min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in range(0, 130, 5)]
    assert lrs[0] == 0.0
    assert abs(max(lrs) - 1.0) < 1e-6
    assert abs(lrs[-1] - 0.1) < 1e-2
    assert np.argmax(lrs) <= 3


def test_fit_trains_tiny_lm(tmp_path):
    from repro.configs.registry import get_arch
    from repro.data.lm_data import lm_batches
    from repro.models import transformer as tfm
    from repro.train.train_loop import fit
    from repro.checkpoint import CheckpointManager

    cfg = get_arch("llama3-8b").make_smoke_config()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    data = lm_batches(cfg.vocab, batch=8, seq_len=32, seed=0)
    ckpt = CheckpointManager(str(tmp_path / "ck"), keep=2)
    params, _, hist = fit(params,
                          lambda p, b: tfm.loss_fn(cfg, p, b),
                          OptimizerConfig(lr=3e-3, warmup_steps=10,
                                          decay_steps=100),
                          data, n_steps=60, ckpt=ckpt, log_every=10,
                          log_fn=lambda *_: None)
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.9
    assert ckpt.latest_step() == 60
